"""Tests for persistent-TLB replay sessions (repro.core.session / ref_des).

Covers the session API contracts (warm-vs-cold across invocations, idle-gap
aging, engine-session == simulate(iterations=k) equivalence, per-call
counter deltas), the session-mode oracle (RefSession mirrors SimSession),
and the oracle-equivalence of the optimization paths (pre-translation and
prefetch probes are now replayed identically by the reference DES).
"""
import pytest

from repro.core import (RefSession, SimSession, paper_config, simulate,
                        simulate_ref, ratsim, KB, MB)
from repro.core.config import (FabricConfig, PreTranslationConfig,
                               PrefetchConfig)
from repro.core.tlb import Counters


# ------------------------------------------------------------ warm vs cold
class TestSessionWarmth:
    def test_second_identical_collective_warmer(self):
        s = SimSession(paper_config(16))
        cold = s.run(1 * MB)
        warm = s.run(1 * MB)
        assert warm.completion_ns < cold.completion_ns
        assert cold.counters.walks > 0
        assert warm.counters.walks == 0

    @pytest.mark.parametrize("coll", ["ring_allreduce", "broadcast",
                                      "hier_all_to_all"])
    def test_warmth_holds_across_patterns(self, coll):
        s = SimSession(paper_config(16).replace(collective=coll))
        cold = s.run(1 * MB)
        warm = s.run(1 * MB)
        assert warm.completion_ns <= cold.completion_ns + 1e-9

    def test_distinct_buffers_walk_again(self):
        # base_offset moves the collective to fresh pages: the Link-TLB
        # warmth does not carry (cold walks fire again), though the
        # page-walk caches legitimately stay warm (shorter walks).
        s = SimSession(paper_config(16))
        a = s.run(1 * MB)
        same = s.run(1 * MB)
        moved = s.run(1 * MB, base_offset=64 * MB)
        assert same.counters.walks == 0
        assert moved.counters.walks == a.counters.walks > 0

    def test_subgroup_collective_inside_pod(self):
        # An 8-GPU TP collective inside a 16-GPU pod is legal and warms the
        # same per-target state a later pod-wide collective reuses.
        s = SimSession(paper_config(16))
        sub = s.run(1 * MB, collective="all_gather", n_gpus=8)
        assert sub.n_gpus == 8
        assert sub.counters.requests > 0
        with pytest.raises(ValueError, match="exceeds pod size"):
            s.run(1 * MB, n_gpus=32)


# ----------------------------------------------------------- idle-gap aging
class TestIdleGaps:
    def test_gap_without_retention_keeps_warmth(self):
        s = SimSession(paper_config(16))
        s.run(1 * MB)
        warm = s.run(1 * MB, gap_ns=1e9)   # a full second of idle
        assert warm.counters.walks == 0

    def test_gap_beyond_retention_flushes(self):
        cfg = paper_config(16).replace(tlb_retention_ns=1e6)
        s = SimSession(cfg)
        cold = s.run(1 * MB)
        aged = s.run(1 * MB, gap_ns=2e6)   # gap >= retention: flushed
        assert aged.counters.walks == cold.counters.walks
        assert aged.completion_ns == pytest.approx(cold.completion_ns)
        warm = s.run(1 * MB, gap_ns=0.5e6)  # short gap: stays warm
        assert warm.counters.walks == 0


# ------------------------------------------- session == simulate(iterations)
class TestSessionSimulateEquivalence:
    @pytest.mark.parametrize("k", [1, 2, 3])
    def test_k_runs_equal_iterations_k(self, k):
        sess = SimSession(paper_config(16))
        for _ in range(k):
            sess.run(1 * MB)
        one = simulate(1 * MB, paper_config(16).replace(iterations=k))
        got = sess.result()
        assert ([i.completion_ns for i in got.iterations]
                == [i.completion_ns for i in one.iterations])
        assert got.counters.requests == one.counters.requests
        assert got.counters.by_class == one.counters.by_class
        assert got.mean_stall_ns == one.mean_stall_ns

    def test_trace_first_run_only(self):
        cfg = paper_config(16).replace(collect_trace=True)
        sess = SimSession(cfg)
        sess.run(1 * MB)
        sess.run(1 * MB)
        ref = simulate(1 * MB, cfg.replace(iterations=2))
        got = sess.result()
        assert got.trace is not None
        assert (got.trace == ref.trace).all()

    def test_per_call_counter_deltas_sum_to_total(self):
        sess = SimSession(paper_config(16))
        r1 = sess.run(1 * MB)
        r2 = sess.run(4 * MB)
        total = sess.result().counters
        assert r1.counters.requests + r2.counters.requests == total.requests
        assert r1.counters.walks + r2.counters.walks == total.walks
        for k in total.by_class:
            assert (r1.counters.by_class[k] + r2.counters.by_class[k]
                    == total.by_class[k])


# --------------------------------------------------- trace target selection
class TestTraceDstSelection:
    """Regression for the trace-target pick: ``SimSession.run`` used to
    trace ``dsts[0]`` unconditionally; it must be the first destination
    that actually *produces flows* (``None`` when no destination does)."""

    @pytest.mark.parametrize("engine", ["event", "vectorized"])
    def test_asymmetric_group_traces_first_flowing_dst(self, engine):
        # broadcast is an asymmetric pattern: the root (GPU 0) never
        # receives, so the simulated target set excludes it and the trace
        # must land on the first *receiving* target.
        cfg = paper_config(8).replace(collect_trace=True, engine=engine)
        s = SimSession(cfg)
        s.run(1 * MB, collective="broadcast")
        assert s._trace_dst == 1
        r = s.result()
        assert r.trace is not None and (r.trace > 0).any()

    @pytest.mark.parametrize("engine", ["event", "vectorized"])
    def test_all_zero_byte_collective_traces_none(self, engine):
        # A collective smaller than the group size chunks to zero bytes on
        # every destination — no destination produces flows.  The trace
        # target must fall back to None (the old dsts[0] pick pointed the
        # trace bookkeeping at a flowless engine) and the result trace
        # stays a well-formed all-zeros vector.
        cfg = paper_config(8).replace(collect_trace=True, engine=engine)
        s = SimSession(cfg)
        s.run(4)                    # 4 B / 8 GPUs -> zero-byte chunks
        assert s._trace_dst is None
        r = s.result()
        assert r.trace is not None and (r.trace == 0).all()

    def test_trace_identical_across_engines_for_asymmetric_group(self):
        traces = []
        for engine in ("event", "vectorized"):
            cfg = paper_config(8).replace(collect_trace=True, engine=engine)
            s = SimSession(cfg)
            s.run(1 * MB, collective="broadcast")
            traces.append(s.result().trace)
        assert (traces[0] == traces[1]).all()


# ------------------------------------------------------ session-mode oracle
class TestRefSessionOracle:
    def test_session_sequence_matches_oracle(self):
        cfg = paper_config(8)
        eng, ref = SimSession(cfg), RefSession(cfg)
        seq = [(256 * KB, {}), (256 * KB, {}),
               (512 * KB, {"collective": "ring_allreduce"}),
               (256 * KB, {"gap_ns": 5e3})]
        for nbytes, kw in seq:
            eng.run(nbytes, **kw)
            ref.run(nbytes, **kw)
        a, b = eng.result(), ref.result()
        for ia, ib in zip(a.iterations, b.iterations):
            assert ia.completion_ns == pytest.approx(ib.completion_ns,
                                                     rel=0.05)
        assert a.counters.walks == b.counters.walks
        assert a.counters.requests == b.counters.requests

    def test_oracle_session_warms_too(self):
        s = RefSession(paper_config(8))
        cold = s.run(512 * KB)
        warm = s.run(512 * KB)
        assert warm.counters.walks == 0
        assert warm.completion_ns < cold.completion_ns

    def test_oracle_retention_flush(self):
        cfg = paper_config(8).replace(tlb_retention_ns=1e6)
        s = RefSession(cfg)
        cold = s.run(512 * KB)
        aged = s.run(512 * KB, gap_ns=2e6)
        assert aged.counters.walks == cold.counters.walks

    def test_oracle_rejects_oversized_group_like_engine(self):
        # Mirrored validation: identical call sequences must behave
        # identically on both sides, including the error path.
        for sess in (SimSession(paper_config(8)), RefSession(paper_config(8))):
            with pytest.raises(ValueError, match="exceeds pod size"):
                sess.run(256 * KB, n_gpus=32)


# ------------------------------------- oracle equivalence: optimization paths
class TestOptimizationOracleEquivalence:
    """Engine vs reference DES with the paper's §6 optimizations enabled:
    the DES now replays the identical probe schedule, so completion, walk
    and probe counts must agree (TestOptimizations in test_core_sim.py only
    checks directional behavior)."""

    @pytest.mark.parametrize("n,size", [(8, 1 * MB), (8, 4 * MB),
                                        (16, 1 * MB)])
    def test_pretranslation_equivalence(self, n, size):
        cfg = paper_config(n).replace(
            pretranslation=PreTranslationConfig(
                enabled=True, lead_time_ns=3000.0, pages_per_flow=0))
        a, b = simulate(size, cfg), simulate_ref(size, cfg)
        assert a.completion_ns == pytest.approx(b.completion_ns, rel=0.05)
        assert a.counters.walks == b.counters.walks
        assert a.counters.probes == b.counters.probes
        assert a.counters.probes > 0

    @pytest.mark.parametrize("n,size", [(8, 32 * MB)])
    def test_prefetch_equivalence(self, n, size):
        # Multi-page flows so next-page probes actually fire; paper-default
        # ingress buffering (the regime where the engine/DES contract binds,
        # DESIGN.md §7).
        cfg = paper_config(n).replace(
            prefetch=PrefetchConfig(enabled=True, depth=2))
        a, b = simulate(size, cfg), simulate_ref(size, cfg)
        assert a.completion_ns == pytest.approx(b.completion_ns, rel=0.05)
        assert a.counters.walks == b.counters.walks
        assert a.counters.probes == b.counters.probes
        assert a.counters.probes > 0


# -------------------------------------------------- probe striping (fixed)
class TestProbeStriping:
    def test_prefetched_page_first_request_is_l1_hit(self):
        """Regression for the probe-striping fix: probes must land on the
        station where the page's first data request lands, so that request
        classifies ``l1_hit`` (it previously warmed the wrong L1 and the
        first touch fell through to the L2)."""
        cfg = paper_config(8).replace(
            prefetch=PrefetchConfig(enabled=True, depth=2),
            collect_trace=True)
        r = simulate(32 * MB, cfg)
        l1_lat = cfg.translation.l1.hit_latency_ns
        # 32 MB / 8 GPUs = 4 MB per flow = two 2 MB pages; page 1's first
        # request is request 8192 (= 2 MB / 256 B) of each flow.
        b = r.trace_flow_bounds
        page1_first = 4 * MB // 2 // cfg.fabric.request_bytes
        for fi in range(7):
            assert r.trace[b[fi] + page1_first] == l1_lat
        assert r.counters.probes == 7       # one next-page probe per flow

    def test_pretranslation_probe_alignment(self):
        # Multi-page flows: the old striping sent the page-1 probe to
        # station (stripe + 1) while page 1's first request lands back on
        # station stripe (8192 requests per 2 MB page = a whole number of
        # 16-station rounds).  Aligned probes make that request an L1 hit.
        cfg = paper_config(8).replace(
            pretranslation=PreTranslationConfig(
                enabled=True, lead_time_ns=3000.0, pages_per_flow=0),
            collect_trace=True)
        r = simulate(32 * MB, cfg)
        l1_lat = cfg.translation.l1.hit_latency_ns
        b = r.trace_flow_bounds
        page1_first = 4 * MB // 2 // cfg.fabric.request_bytes
        for fi in range(7):
            assert r.trace[b[fi] + page1_first] == l1_lat
        assert r.counters.probes == 14       # two pages per flow, warmed all


# ------------------------------------------------------------ ratsim helper
def test_ratsim_session_helper():
    s = ratsim.session(16, collective="ring_allreduce")
    assert isinstance(s, SimSession)
    assert s.cfg.collective == "ring_allreduce"
    rec = s.run(1 * MB)
    assert rec.collective == "ring_allreduce"


# ------------------------------------------------------------- counter math
class TestCounterMath:
    def test_merge_accumulates_every_field(self):
        a, b = Counters(), Counters()
        a.add_request("l1_hit", 100.0, n=2)
        a.note_max(60.0)
        a.walks, a.walk_mem_reads, a.pwc_hits, a.probes = 3, 5, 7, 2
        b.add_request("walk", 1700.0)
        b.note_max(1700.0)
        b.walks, b.pwc_misses, b.mshr_stall_ns = 1, 4, 12.5
        a.merge(b)
        assert a.requests == 3
        assert a.by_class["l1_hit"] == 2 and a.by_class["walk"] == 1
        assert a.rat_ns_sum == 1800.0
        assert a.rat_ns_max == 1700.0
        assert (a.walks, a.walk_mem_reads, a.pwc_hits, a.pwc_misses,
                a.probes, a.mshr_stall_ns) == (4, 5, 7, 4, 2, 12.5)

    def test_copy_and_delta(self):
        a = Counters()
        a.add_request("l1_hit", 50.0)
        snap = a.copy()
        a.add_request("walk", 1700.0)
        a.walks += 1
        d = a.delta(snap)
        assert d.requests == 1
        assert d.by_class == {"l1_hit": 0, "l1_mshr_hum": 0, "l2_hit": 0,
                              "l2_hum": 0, "walk": 1}
        assert d.rat_ns_sum == 1700.0
        assert d.walks == 1
        snap.add_request("l2_hit", 1.0)      # copy is independent
        assert a.by_class["l2_hit"] == 0

    def test_mean_stall_denominator_is_merged_requests(self):
        # PR 1 fixed mean_stall_ns to divide by the merged request count;
        # golden value at the scarce-ingress stall config.
        cfg = paper_config(16).replace(
            fabric=FabricConfig(n_gpus=16, ingress_entries=64))
        r = simulate(64 * MB, cfg)
        assert r.counters.requests == 245760
        assert r.mean_stall_ns == pytest.approx(0.9237597656249985,
                                                rel=1e-9)
