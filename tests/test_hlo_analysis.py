"""Validate the scan-aware HLO analyzer against XLA's own cost analysis.

Strategy: compile the same program twice — scanned (while loop) and fully
unrolled — and require the analyzer's scanned-module numbers to match (a) the
analyzer's unrolled numbers and (b) XLA cost_analysis() on the unrolled
module (which has no loops, so XLA counts everything).
"""
import jax
import jax.numpy as jnp
import pytest
from jax import lax

from repro.launch.hlo_analysis import analyze_hlo_text


def _compiled(f, *args):
    return jax.jit(f).lower(*args).compile()


def _xla_flops(compiled):
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    return float(ca.get("flops", 0.0))


S = jax.ShapeDtypeStruct


class TestScanVsUnroll:
    def _pair(self, n_steps=10, dim=128):
        def body_fn(c, w):
            return jnp.tanh(c @ w)

        def f_scan(x, ws):
            y, _ = lax.scan(lambda c, w: (body_fn(c, w), None), x, ws)
            return y

        def f_unroll(x, ws):
            for i in range(n_steps):
                x = body_fn(x, ws[i])
            return x

        x = S((dim, dim), jnp.float32)
        ws = S((n_steps, dim, dim), jnp.float32)
        return _compiled(f_scan, x, ws), _compiled(f_unroll, x, ws)

    def test_flops_match_unrolled_xla(self):
        scanned, unrolled = self._pair()
        got = analyze_hlo_text(scanned.as_text()).flops
        want = _xla_flops(unrolled)
        assert got == pytest.approx(want, rel=0.01)

    def test_scanned_equals_unrolled_analyzer(self):
        scanned, unrolled = self._pair()
        a = analyze_hlo_text(scanned.as_text())
        b = analyze_hlo_text(unrolled.as_text())
        assert a.flops == pytest.approx(b.flops, rel=0.01)
        assert a.mem_bytes == pytest.approx(b.mem_bytes, rel=0.35)
        # (mem differs slightly: the scanned form adds dynamic-slice reads)

    def test_xla_undercounts_scan_confirming_need(self):
        scanned, _ = self._pair()
        xla = _xla_flops(scanned)
        ours = analyze_hlo_text(scanned.as_text()).flops
        assert ours > 5 * xla   # XLA counted the body once (trip=10)


class TestDotFlops:
    def test_plain_matmul(self):
        m, k, n = 64, 128, 32
        c = _compiled(lambda a, b: a @ b, S((m, k), jnp.float32),
                      S((k, n), jnp.float32))
        got = analyze_hlo_text(c.as_text()).flops
        assert got == pytest.approx(2 * m * k * n, rel=0.01)

    def test_batched_einsum(self):
        c = _compiled(lambda a, b: jnp.einsum("bij,bjk->bik", a, b),
                      S((4, 32, 64), jnp.float32), S((4, 64, 16), jnp.float32))
        got = analyze_hlo_text(c.as_text()).flops
        assert got == pytest.approx(2 * 4 * 32 * 64 * 16, rel=0.01)

    def test_matches_xla_on_mlp(self):
        def mlp(x, w1, w2):
            return jax.nn.relu(x @ w1) @ w2
        c = _compiled(mlp, S((32, 64), jnp.float32), S((64, 256), jnp.float32),
                      S((256, 8), jnp.float32))
        got = analyze_hlo_text(c.as_text()).flops
        assert got == pytest.approx(_xla_flops(c), rel=0.05)


class TestNestedScan:
    def test_scan_in_scan(self):
        def f(x, ws):
            def outer(c, w):
                def inner(c2, _):
                    return jnp.tanh(c2 @ w), None
                c2, _ = lax.scan(inner, c, None, length=3)
                return c2, None
            y, _ = lax.scan(outer, x, ws)
            return y
        n, d = 4, 64
        c = _compiled(f, S((d, d), jnp.float32), S((n, d, d), jnp.float32))
        got = analyze_hlo_text(c.as_text()).flops
        want = 2 * d * d * d * n * 3  # dot flops x nested trip counts
        assert got == pytest.approx(want, rel=0.02)


class TestCollectives:
    def test_collective_bytes_in_scan_multiplied(self):
        import os
        # uses however many devices exist; on 1 device XLA removes the
        # collective, so guard
        if len(jax.devices()) < 2:
            pytest.skip("needs >1 device (dry-run covers this at 512)")

    def test_grad_includes_backward_flops(self):
        def loss(w, x):
            return jnp.sum(jnp.tanh(x @ w))
        d = 64
        c_f = _compiled(loss, S((d, d), jnp.float32), S((d, d), jnp.float32))
        c_g = _compiled(jax.grad(loss), S((d, d), jnp.float32),
                        S((d, d), jnp.float32))
        f = analyze_hlo_text(c_f.as_text()).flops
        g = analyze_hlo_text(c_g.as_text()).flops
        assert g > 1.6 * f
