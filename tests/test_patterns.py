"""Tests for the collective traffic-pattern layer (repro.core.patterns).

Three contracts per pattern:
  1. Oracle equivalence — the page-epoch engine agrees with the request-level
     reference DES on completion time, walk count and request count at small
     collective sizes (same bound the seed all-to-all tests use).
  2. Conservation — the emitted flow sets move exactly the collective's
     analytic fabric volume.
  3. The all-to-all default reproduces the seed engine bit-for-bit.
"""
import math

import pytest

from repro.core import (ratsim, paper_config, simulate, simulate_ref,
                        get_pattern, analytic_volume, PATTERNS, KB, MB)
from repro.core.config import FabricConfig

ALL_PATTERNS = sorted(PATTERNS)
NEW_PATTERNS = [p for p in ALL_PATTERNS if p != "all_to_all"]


def _expected_requests(name, nbytes, cfg):
    """Requests the simulator should count: flows into the simulated dsts."""
    pattern = get_pattern(name)
    steps = pattern.steps(nbytes, cfg.fabric)
    if cfg.symmetric and pattern.symmetric:
        dsts = {pattern.representative_dst(cfg.fabric)}
    else:
        dsts = {s.dst for step in steps for s in step}
    rb = cfg.fabric.request_bytes
    return sum(max(1, math.ceil(s.nbytes / rb))
               for step in steps for s in step
               if s.dst in dsts and s.nbytes > 0)


# --------------------------------------------------- engine vs reference DES
@pytest.mark.parametrize("name", ALL_PATTERNS)
@pytest.mark.parametrize("n,size", [(8, 256 * KB), (8, 1 * MB), (16, 1 * MB)])
def test_pattern_engine_matches_reference_des(name, n, size):
    cfg = paper_config(n).replace(collective=name)
    a = simulate(size, cfg)
    b = simulate_ref(size, cfg)
    assert a.completion_ns == pytest.approx(b.completion_ns, rel=0.05)
    assert a.counters.walks == b.counters.walks
    assert a.counters.requests == b.counters.requests


@pytest.mark.parametrize("name", ["ring_allreduce", "rd_allreduce",
                                  "hier_all_to_all"])
def test_pattern_multipage_matches_reference_des(name):
    # 4 MB spans multiple 2 MB pages -> mid-stream cold walks per step.
    cfg = paper_config(8).replace(collective=name)
    a = simulate(4 * MB, cfg)
    b = simulate_ref(4 * MB, cfg)
    assert a.completion_ns == pytest.approx(b.completion_ns, rel=0.05)
    assert a.counters.walks == b.counters.walks


@pytest.mark.parametrize("name", ALL_PATTERNS)
def test_pattern_ideal_matches_reference_des(name):
    cfg = paper_config(8).replace(collective=name).ideal()
    a = simulate(1 * MB, cfg)
    b = simulate_ref(1 * MB, cfg)
    assert a.completion_ns == pytest.approx(b.completion_ns, rel=0.005)


# -------------------------------------------------------------- conservation
@pytest.mark.parametrize("name", ALL_PATTERNS)
@pytest.mark.parametrize("n", [8, 16, 32])
def test_flow_sets_move_analytic_volume(name, n):
    fab = FabricConfig(n_gpus=n)
    nbytes = 8 * MB
    pattern = get_pattern(name)
    emitted = sum(s.nbytes for step in pattern.steps(nbytes, fab)
                  for s in step)
    assert emitted == analytic_volume(name, nbytes, fab)
    assert emitted == pattern.total_bytes(nbytes, fab)


@pytest.mark.parametrize("name", ALL_PATTERNS)
@pytest.mark.parametrize("n", [8, 16, 32])
def test_steps_arrays_conserve_analytic_volume(name, n):
    # The columnar schedule (what the vectorized engine consumes) must move
    # exactly the same bytes per step as the object form — the conservation
    # contract holds in both representations.
    fab = FabricConfig(n_gpus=n)
    nbytes = 8 * MB
    pattern = get_pattern(name)
    arrays = pattern.steps_arrays(nbytes, fab)
    obj = pattern.steps(nbytes, fab)
    assert [int(st.nbytes.sum()) for st in arrays] \
        == [sum(s.nbytes for s in step) for step in obj]
    assert sum(int(st.nbytes.sum()) for st in arrays) \
        == analytic_volume(name, nbytes, fab)


@pytest.mark.parametrize("name", ALL_PATTERNS)
def test_request_conservation_through_engine(name):
    cfg = paper_config(16).replace(collective=name)
    r = simulate(2 * MB, cfg)
    ctr = r.counters
    assert sum(ctr.by_class.values()) == ctr.requests
    assert ctr.requests == _expected_requests(name, 2 * MB, cfg)


@pytest.mark.parametrize("name", ALL_PATTERNS)
def test_flow_specs_well_formed(name):
    fab = FabricConfig(n_gpus=16)
    for step in get_pattern(name).steps(4 * MB, fab):
        for s in step:
            assert 0 <= s.src < fab.n_gpus
            assert 0 <= s.dst < fab.n_gpus
            assert s.src != s.dst
            assert s.nbytes > 0
            assert s.offset >= 0


# --------------------------------------------------- seed behavior unchanged
# Golden values captured from the seed (pre-pattern) engine; the default
# all-to-all must reproduce them bit-for-bit.
SEED_GOLDEN = [
    # (size, n_gpus, baseline_ns, ideal_ns, requests, walks)
    (1 * MB, 16, 3890.0, 2802.0, 3840, 1),
    (4 * MB, 8, 5805.2, 4482.64, 14336, 2),
    (16 * MB, 32, 13642.64, 12343.119999999999, 63488, 8),
]


@pytest.mark.parametrize("size,n,base,ideal,reqs,walks", SEED_GOLDEN)
def test_all_to_all_default_bit_for_bit(size, n, base, ideal, reqs, walks):
    r = simulate(size, paper_config(n))
    i = simulate(size, paper_config(n).ideal())
    assert r.completion_ns == base
    assert i.completion_ns == ideal
    assert r.counters.requests == reqs
    assert r.counters.walks == walks


def test_explicit_all_to_all_equals_default():
    a = simulate(1 * MB, paper_config(16))
    b = ratsim.run(1 * MB, 16, collective="all_to_all")
    assert a.completion_ns == b.completion_ns
    assert a.counters.requests == b.counters.requests


# ------------------------------------------------------------------ the API
@pytest.mark.parametrize("name", NEW_PATTERNS)
def test_ratsim_compare_collective_axis(name):
    c = ratsim.compare(1 * MB, 16, collective=name)
    assert c.baseline.completion_ns > 0
    assert c.degradation >= 1.0 - 1e-12


def test_sweep_grows_collective_axis():
    out = ratsim.sweep([1 * MB], [8, 16],
                       collectives=["all_to_all", "ring_allreduce"])
    assert set(out) == {("all_to_all", 8, 1 * MB), ("all_to_all", 16, 1 * MB),
                       ("ring_allreduce", 8, 1 * MB),
                       ("ring_allreduce", 16, 1 * MB)}
    # legacy keys without the axis
    legacy = ratsim.sweep([1 * MB], [8])
    assert set(legacy) == {(8, 1 * MB)}


def test_unknown_collective_raises():
    with pytest.raises(ValueError, match="unknown collective"):
        ratsim.run(1 * MB, 16, collective="nope")


def test_rd_allreduce_requires_power_of_two():
    with pytest.raises(ValueError, match="power-of-two"):
        ratsim.run(1 * MB, 12, collective="rd_allreduce")


def test_broadcast_forces_every_target():
    # Asymmetric pattern: even under symmetric config every receiver is
    # simulated, so n-1 GPUs each count one full-buffer flow.
    cfg = paper_config(8).replace(collective="broadcast")
    assert cfg.symmetric
    r = simulate(1 * MB, cfg)
    rb = cfg.fabric.request_bytes
    assert r.counters.requests == 7 * math.ceil(1 * MB / rb)


def test_small_collectives_more_rat_sensitive_than_large():
    # The paper's Fig-4 shape holds for every pattern: degradation shrinks
    # as the collective grows and TLBs warm.
    for name in ALL_PATTERNS:
        small = ratsim.compare(1 * MB, 16, collective=name).degradation
        large = ratsim.compare(64 * MB, 16, collective=name).degradation
        assert large < small or large == pytest.approx(small, abs=1e-3), name


def test_ring_amortizes_cold_walks_vs_all_to_all():
    # Headline of the fig12 sweep: one flow per step amortizes the single
    # cold walk, all-pairs pays it on every flow concurrently.
    a2a = ratsim.compare(1 * MB, 16).degradation
    ring = ratsim.compare(1 * MB, 16, collective="ring_allreduce").degradation
    assert ring < a2a
