"""Substrate tests: data determinism, checkpoint/restart, failure injection,
gradient compression convergence parity, elastic control plane, optimizers."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.data import SyntheticLMDataset, DataIterator
from repro.checkpoint import (CheckpointManager, save_checkpoint,
                              load_checkpoint, latest_step)
from repro.runtime import (Trainer, TrainerConfig, ElasticController,
                           compress_gradients, make_compressor)
from repro.optim import adamw, adafactor, with_master, cosine_with_warmup


def tiny_cfg():
    return configs.get_smoke_config("qwen2-1.5b").replace(
        n_layers=2, remat=False)


# ------------------------------------------------------------------- data
class TestData:
    def test_deterministic(self):
        ds = SyntheticLMDataset(vocab_size=100, seq_len=16, seed=3)
        a = ds.batch(5, 8)
        b = ds.batch(5, 8)
        np.testing.assert_array_equal(a["inputs"], b["inputs"])

    def test_sharding_partitions_batch(self):
        ds = SyntheticLMDataset(vocab_size=100, seq_len=16, seed=3)
        full = ds.batch(2, 8)["inputs"]
        parts = [ds.batch(2, 8, shard=i, num_shards=4)["inputs"]
                 for i in range(4)]
        np.testing.assert_array_equal(np.concatenate(parts), full)

    def test_iterator_checkpoint_resume(self):
        ds = SyntheticLMDataset(vocab_size=100, seq_len=16)
        it = DataIterator(ds, 4)
        for _ in range(3):
            next(it)
        state = it.state_dict()
        want = next(it)["inputs"]
        it2 = DataIterator(ds, 4)
        it2.load_state_dict(state)
        got = next(it2)["inputs"]
        np.testing.assert_array_equal(got, want)

    def test_elastic_reshard_preserves_stream(self):
        ds = SyntheticLMDataset(vocab_size=100, seq_len=16)
        it = DataIterator(ds, 8, shard=0, num_shards=2)
        it.step = 7
        re = it.reshard(shard=1, num_shards=4)
        assert re.step == 7
        got = next(re)["inputs"]
        want = ds.batch(7, 8, shard=1, num_shards=4)["inputs"]
        np.testing.assert_array_equal(got, want)

    def test_targets_shift_inputs(self):
        ds = SyntheticLMDataset(vocab_size=100, seq_len=16)
        b = ds.batch(0, 2)
        ex = ds.example(0)
        np.testing.assert_array_equal(b["inputs"][0], ex[:-1])
        np.testing.assert_array_equal(b["targets"][0], ex[1:])


# -------------------------------------------------------------- checkpoint
class TestCheckpoint:
    def tree(self):
        return {"a": jnp.arange(12.0).reshape(3, 4),
                "b": {"c": jnp.ones((5,), jnp.int32)}}

    def test_roundtrip(self, tmp_path):
        t = self.tree()
        save_checkpoint(tmp_path, 7, t)
        assert latest_step(tmp_path) == 7
        out = load_checkpoint(tmp_path, 7, t)
        np.testing.assert_array_equal(out["a"], t["a"])
        np.testing.assert_array_equal(out["b"]["c"], t["b"]["c"])

    def test_gc_keeps_latest(self, tmp_path):
        t = self.tree()
        for s in (1, 2, 3, 4, 5):
            save_checkpoint(tmp_path, s, t, keep=2)
        assert latest_step(tmp_path) == 5
        steps = sorted(int(p.name.split("_")[1])
                       for p in tmp_path.glob("step_*"))
        assert steps == [4, 5]

    def test_corruption_detected(self, tmp_path):
        t = self.tree()
        d = save_checkpoint(tmp_path, 1, t)
        # flip bytes in one leaf
        f = next(d.glob("leaf_*.npy"))
        data = bytearray(f.read_bytes())
        data[-1] ^= 0xFF
        f.write_bytes(bytes(data))
        with pytest.raises(IOError, match="crc"):
            load_checkpoint(tmp_path, 1, t)

    def test_uncommitted_ignored(self, tmp_path):
        t = self.tree()
        d = save_checkpoint(tmp_path, 3, t)
        (d / "_COMMITTED").unlink()
        assert latest_step(tmp_path) is None

    def test_async_manager(self, tmp_path):
        m = CheckpointManager(tmp_path)
        t = self.tree()
        m.async_save(1, t)
        m.wait()
        step, out = m.restore_latest(t)
        assert step == 1
        np.testing.assert_array_equal(out["a"], t["a"])


# ------------------------------------------------ failure injection / restart
class TestFailureRecovery:
    def test_restart_continues_identically(self, tmp_path):
        cfg = tiny_cfg()
        tcfg = TrainerConfig(steps=12, batch_size=4, seq_len=32,
                             checkpoint_dir=str(tmp_path / "ckpt"),
                             checkpoint_every=5, async_checkpoint=False,
                             log_every=1)
        # uninterrupted run
        ref = Trainer(cfg, tcfg).run(resume=False)
        # crashed run + restart
        t2 = Trainer(cfg, TrainerConfig(**{**tcfg.__dict__,
                                           "checkpoint_dir": str(tmp_path / "ckpt2")}))
        with pytest.raises(RuntimeError, match="injected failure"):
            t2.run(resume=False, fail_at_step=10)
        t3 = Trainer(cfg, TrainerConfig(**{**tcfg.__dict__,
                                           "checkpoint_dir": str(tmp_path / "ckpt2")}))
        out = t3.run(resume=True)
        assert out["data_step"] == ref["data_step"]
        assert out["final_loss"] == pytest.approx(ref["final_loss"],
                                                  rel=1e-4)

    def test_resume_skips_completed_steps(self, tmp_path):
        cfg = tiny_cfg()
        tcfg = TrainerConfig(steps=6, batch_size=4, seq_len=32,
                             checkpoint_dir=str(tmp_path / "c"),
                             checkpoint_every=3, async_checkpoint=False,
                             log_every=1)
        Trainer(cfg, tcfg).run(resume=False)
        out = Trainer(cfg, tcfg).run(resume=True)
        # resumed at step 6 == steps -> no extra work, history empty
        assert out["history"] == [] or out["history"][0]["step"] >= 5


# ------------------------------------------------------------- compression
class TestCompression:
    def grads(self):
        k = jax.random.PRNGKey(0)
        return {"w": jax.random.normal(k, (64, 64)) * 0.01,
                "b": jax.random.normal(jax.random.fold_in(k, 1), (64,))}

    def test_bf16_close(self):
        g = self.grads()
        out, _ = compress_gradients(g, "bf16")
        np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(g["w"]),
                                   rtol=1e-2, atol=1e-4)

    def test_int8_error_feedback_unbiased(self):
        """With error feedback the accumulated compressed sum tracks the
        accumulated true sum (residual never grows)."""
        init, apply = make_compressor("int8")
        k = jax.random.PRNGKey(1)
        g0 = {"w": jax.random.normal(k, (32, 32)) * 0.01}
        state = init(g0)
        total_true = jnp.zeros((32, 32))
        total_comp = jnp.zeros((32, 32))
        for i in range(50):
            g = {"w": jax.random.normal(jax.random.fold_in(k, i), (32, 32)) * 0.01}
            out, state = apply(g, state)
            total_true += g["w"]
            total_comp += out["w"]
        err = jnp.abs(total_true - total_comp).max()
        scale = jnp.abs(total_true).max()
        assert float(err) < 0.02 * float(scale) + 1e-3

    def test_int8_training_convergence_parity(self, tmp_path):
        cfg = tiny_cfg()
        base = TrainerConfig(steps=30, batch_size=4, seq_len=32, log_every=1)
        ref = Trainer(cfg, base).run(resume=False)
        comp = Trainer(cfg, TrainerConfig(
            **{**base.__dict__, "grad_compression": "int8"})).run(resume=False)
        # same order of magnitude of progress
        assert comp["final_loss"] < ref["history"][0]["loss"]
        assert comp["final_loss"] < ref["final_loss"] * 1.25


# ------------------------------------------------------------------ elastic
class TestElastic:
    def test_detects_dead_host_and_remeshes(self):
        t = [0.0]
        ctl = ElasticController(8, heartbeat_timeout_s=12,
                                clock=lambda: t[0])
        for i in range(8):
            ctl.heartbeat(i)
        t[0] = 5.0
        for i in range(7):
            ctl.heartbeat(i)      # host 7 silent
        t[0] = 16.0
        d = ctl.poll()
        assert d.kind == "remesh"
        assert d.dead_hosts == (7,)
        assert d.new_num_shards == 4   # 7 alive -> largest pow2 = 4

    def test_detects_straggler(self):
        t = [0.0]
        ctl = ElasticController(4, clock=lambda: t[0])
        for i in range(4):
            for _ in range(8):
                ctl.heartbeat(i, step_seconds=1.0 if i != 2 else 5.0)
        d = ctl.poll()
        assert d.kind == "replace_straggler"
        assert d.stragglers == (2,)

    def test_all_healthy_ok(self):
        ctl = ElasticController(4)
        for i in range(4):
            ctl.heartbeat(i, step_seconds=1.0)
        assert ctl.poll().kind == "ok"


# ---------------------------------------------------------------- optimizers
class TestOptimizers:
    def quad(self, opt, steps=120):
        target = jnp.asarray([1.0, -2.0, 3.0])
        params = {"w": jnp.zeros((128, 130)), "b": jnp.zeros(3)}
        state = opt.init(params)

        def loss(p):
            return (jnp.sum((p["b"] - target) ** 2)
                    + jnp.mean(p["w"] ** 2))

        @jax.jit
        def step(p, s):
            g = jax.grad(loss)(p)
            return opt.update(g, s, p)

        for _ in range(steps):
            params, state = step(params, state)
        return float(loss(params))

    def test_adamw_converges(self):
        sched = cosine_with_warmup(0.1, 5, 200)
        assert self.quad(adamw(sched, weight_decay=0.0)) < 1e-2

    def test_adafactor_converges(self):
        sched = cosine_with_warmup(0.5, 5, 200)
        assert self.quad(adafactor(sched)) < 1e-2

    def test_with_master_bf16_params(self):
        sched = cosine_with_warmup(0.1, 5, 200)
        opt = with_master(adamw(sched, weight_decay=0.0))
        target = jnp.asarray([1.0, -2.0, 3.0])
        params = {"b": jnp.zeros(3, jnp.bfloat16)}
        state = opt.init(params)
        assert state["master"]["b"].dtype == jnp.float32

        def loss(p):
            return jnp.sum((p["b"].astype(jnp.float32) - target) ** 2)

        for _ in range(150):
            g = jax.grad(loss)(params)
            params, state = opt.update(g, state, params)
        assert params["b"].dtype == jnp.bfloat16
        assert float(loss(params)) < 0.05

    # Plain parametrization (was hypothesis sampled_from — same four shapes)
    # so this module collects without hypothesis installed.
    @pytest.mark.parametrize("shape", [(4,), (16, 130), (128, 129), (3, 4, 5)])
    def test_adafactor_state_shapes(self, shape):
        sched = cosine_with_warmup(0.1, 5, 100)
        opt = adafactor(sched)
        p = {"x": jnp.zeros(shape)}
        s = opt.init(p)
        g = jax.tree.map(jnp.ones_like, p)
        newp, news = opt.update(g, s, p)
        assert newp["x"].shape == shape
        assert np.isfinite(np.asarray(newp["x"])).all()
