"""Tests for the fleet-scale serving layer (repro.serving.fleet).

Covers: router policies (round-robin distribution, least-loaded balancing,
deterministic rid-hash affinity), the bounded admission queue (rejections
recorded and excluded from percentiles), the queue-depth autoscaler
(spin-ups under pressure, the live-replica cap across churn, idle
retirement) and its central accounting contract — a freshly spun replica
starts with stone-cold TLBs and re-pays the full cold-walk warmup even
when the rest of the fleet is warm — plus request conservation, the total
steps cap, and serial-vs-pooled sweep determinism on both engines.
"""
import os
import pathlib
import subprocess
import sys

import pytest

from repro.core.config import SimConfig
from repro.serving import (FleetPoint, Request, TrafficPoint,
                           simulate_fleet, sweep_fleet)
from repro.serving.fleet import _rid_hash
from repro.workloads import PodSpec, pod_fabric, resolve_pod


class TinyFleetMoE:
    """Duck-typed stand-in for ModelConfig (only the fields derive reads)."""
    name = "tiny-fleet-moe"
    n_layers = 4
    d_model = 512
    n_heads = 8
    n_kv_heads = 4
    d_head = 64
    d_ff = 0
    n_experts = 16
    top_k = 2
    d_ff_expert = 256
    moe_every = 1
    capacity_factor = 1.25


TINY = TinyFleetMoE()


def tiny_requests(arrivals, prompt=16, output=2):
    return [Request(i, float(t), prompt, output)
            for i, t in enumerate(arrivals)]


def burst_times(n_bursts, per_burst, gap_ns, intra_ns=1000.0):
    """n_bursts tight clumps separated by gap_ns."""
    out = []
    for b in range(n_bursts):
        t0 = b * gap_ns
        out.extend(t0 + i * intra_ns for i in range(per_burst))
    return out


# ----------------------------------------------------------------- routing
class TestRouting:
    def _run(self, router, n=8, replicas=2, **kw):
        reqs = tiny_requests([i * 1000.0 for i in range(n)])
        return simulate_fleet(TINY, reqs, n_gpus=16, replicas=replicas,
                              router=router, **kw)

    def test_round_robin_distributes_cyclically(self):
        res = self._run("round_robin", n=8, replicas=2)
        assert [rep.routed for rep in res.replicas] == [4, 4]
        # Strict alternation: even rids on replica 0, odd on replica 1.
        rids = [sorted(r.rid for r in rep.stats)
                for rep in res.replicas]
        assert rids == [[0, 2, 4, 6], [1, 3, 5, 7]]

    def test_least_loaded_balances(self):
        res = self._run("least_loaded", n=12, replicas=3)
        routed = [rep.routed for rep in res.replicas]
        assert sum(routed) == 12
        assert max(routed) - min(routed) <= 2

    def test_affinity_is_deterministic_rid_hash(self):
        res = self._run("affinity", n=8, replicas=2)
        for rep in res.replicas:
            for r in rep.stats:
                assert _rid_hash(r.rid) % 2 == rep.idx
        # And reproducible run to run.
        res2 = self._run("affinity", n=8, replicas=2)
        assert ([rep.routed for rep in res.replicas]
                == [rep.routed for rep in res2.replicas])

    def test_unknown_router_rejected(self):
        with pytest.raises(ValueError):
            self._run("random")

    def test_all_requests_finish_and_are_rid_sorted(self):
        res = self._run("round_robin", n=8, replicas=3)
        assert len(res.finished) == 8
        assert [r.rid for r in res.requests] == list(range(8))


# ------------------------------------------------------------- admission
class TestAdmissionQueue:
    def test_overflow_rejected_and_excluded(self):
        # One slow replica, a clump of simultaneous arrivals, queue of 3:
        # the clump exceeds capacity while nothing has started prefill.
        reqs = tiny_requests([0.0] * 8, prompt=64, output=4)
        res = simulate_fleet(TINY, reqs, n_gpus=16, replicas=1,
                             max_queue=3, max_decode_slots=2)
        assert len(res.rejected) > 0
        assert len(res.requests) + len(res.rejected) == 8
        # Rejected requests never appear in latency accounting.
        served_rids = {r.rid for r in res.requests}
        assert all(q.rid not in served_rids for q in res.rejected)
        assert len(res.finished) == len(res.requests)

    def test_unbounded_queue_rejects_nothing(self):
        reqs = tiny_requests([0.0] * 8, prompt=64, output=4)
        res = simulate_fleet(TINY, reqs, n_gpus=16, replicas=1,
                             max_decode_slots=2)
        assert res.rejected == [] and len(res.finished) == 8


# ------------------------------------------------------------- autoscaler
class TestAutoscaler:
    GAP = 5e7                            # 50 ms between bursts

    def _bursty(self, n_bursts=3, per_burst=6):
        return tiny_requests(burst_times(n_bursts, per_burst, self.GAP),
                             prompt=16, output=2)

    def test_scales_up_under_queue_pressure(self):
        res = simulate_fleet(TINY, self._bursty(1), n_gpus=16, replicas=4,
                             autoscale=True, min_replicas=1,
                             scale_up_queued=2)
        assert res.spin_ups >= 1
        assert len(res.finished) == 6

    def test_live_cap_respected_across_churn(self):
        res = simulate_fleet(TINY, self._bursty(4), n_gpus=16, replicas=2,
                             autoscale=True, min_replicas=1,
                             scale_up_queued=1,
                             scale_down_idle_ns=self.GAP / 4)
        assert res.retired >= 1                  # churn actually happened
        assert res.spin_ups >= 2                 # ...and re-spun later
        # At no arrival instant did live replicas exceed the cap of 2:
        # verify via lifecycle intervals.
        events = []
        for rep in res.replicas:
            events.append((rep.spun_up_ns, 1))
            if rep.retired_ns is not None:
                events.append((rep.retired_ns, -1))
        live = peak = 0
        for _t, d in sorted(events):
            live += d
            peak = max(peak, live)
        assert peak <= 2
        assert res.peak_replicas == peak
        assert len(res.finished) == len(res.requests)

    def test_min_replicas_never_retired(self):
        res = simulate_fleet(TINY, self._bursty(3), n_gpus=16, replicas=3,
                             autoscale=True, min_replicas=2,
                             scale_up_queued=1,
                             scale_down_idle_ns=self.GAP / 4)
        live_at_end = sum(1 for rep in res.replicas if rep.live)
        assert live_at_end >= 2

    def test_cold_spinup_repays_walks_while_fleet_is_warm(self):
        """The fleet-scale RAT event: a replica spun mid-run starts with
        stone-cold TLBs and performs page walks on its first step, even
        though the incumbent replica is fully warm by then (no retention —
        warmth only ever disappears by being born without it)."""
        res = simulate_fleet(TINY, self._bursty(2, 8), n_gpus=16,
                             replicas=2, autoscale=True, min_replicas=1,
                             scale_up_queued=1)
        assert res.spin_ups >= 1
        spun = [rep for rep in res.replicas if rep.spun_up_ns > 0.0
                and rep.steps]
        assert spun, "a spun replica must have served traffic"
        for rep in spun:
            assert rep.steps[0].walks > 0
        # The incumbent replica is warm on every post-warmup step of the
        # second burst (retention is None, so its warmth persists).
        first = res.replicas[0].steps
        second_burst = [s for s in first if s.t_start >= self.GAP]
        assert second_burst and all(s.walks == 0 for s in second_burst)

    def test_spinup_latency_delays_availability(self):
        lat = 1e6
        res = simulate_fleet(TINY, self._bursty(1, 8), n_gpus=16,
                             replicas=2, autoscale=True, min_replicas=1,
                             scale_up_queued=1, spinup_latency_ns=lat)
        spun = [rep for rep in res.replicas if rep.spun_up_ns > 0.0]
        assert spun
        for rep in spun:
            assert rep.spun_up_ns >= lat
            for s in rep.steps:
                assert s.t_start >= rep.spun_up_ns


# ------------------------------------------------------------------ bounds
class TestStepsCap:
    def test_total_fleet_steps_bounded(self):
        reqs = tiny_requests([0.0] * 12, prompt=16, output=40)
        res = simulate_fleet(TINY, reqs, n_gpus=16, replicas=3,
                             steps_cap=9)
        assert res.steps_capped
        assert len(res.steps) == 9               # fleet-wide, not per pod
        assert len(res.finished) < 12


# ------------------------------------------------------------------ sweeps
class TestFleetSweepDeterminism:
    def _points(self, engine):
        base = TrafficPoint(arch=TINY, rps=300.0, arrival="bursty", seed=9,
                            n_requests=10, burst_size=4, steps_cap=60,
                            prompt_mean=16, output_mean=2,
                            retention_ns=100_000.0, max_decode_slots=4,
                            prefill_chunk_tokens=32, engine=engine)
        return [
            FleetPoint(traffic=base, replicas=2, router="round_robin"),
            FleetPoint(traffic=base, replicas=2, router="least_loaded",
                       autoscale=True, min_replicas=1, scale_up_queued=1,
                       scale_down_idle_ns=1e6, spinup_latency_ns=1e5),
        ]

    @pytest.mark.parametrize("engine", ["event", "vectorized"])
    def test_serial_and_pool_bit_for_bit(self, engine):
        pts = self._points(engine)
        serial = sweep_fleet(pts, workers=0)
        pooled = sweep_fleet(pts, workers=2)
        for pt in pts:
            a, b = serial[pt], pooled[pt]
            assert ([(s.t_start, s.t_end, s.comm_ns, s.ideal_comm_ns,
                      s.walks) for s in a.steps]
                    == [(s.t_start, s.t_end, s.comm_ns, s.ideal_comm_ns,
                         s.walks) for s in b.steps])
            assert ([(rep.spun_up_ns, rep.retired_ns, rep.routed)
                     for rep in a.replicas]
                    == [(rep.spun_up_ns, rep.retired_ns, rep.routed)
                        for rep in b.replicas])
            assert a.ttft_percentiles() == b.ttft_percentiles()
            assert ([r.rid for r in a.rejected]
                    == [r.rid for r in b.rejected])

    def test_engines_agree_bit_for_bit(self):
        ev = sweep_fleet(self._points("event"), workers=0)
        vec = sweep_fleet(self._points("vectorized"), workers=0)
        for a, b in zip(ev.values(), vec.values()):
            assert ([(s.t_start, s.t_end, s.comm_ns, s.walks)
                     for s in a.steps]
                    == [(s.t_start, s.t_end, s.comm_ns, s.walks)
                        for s in b.steps])
            assert a.ttft_percentiles() == b.ttft_percentiles()

    def test_duplicate_points_priced_once(self, monkeypatch):
        import repro.serving.fleet as fleet_mod
        pts = self._points("event")
        calls = []
        orig = fleet_mod._fleet_point

        def counting(task):
            calls.append(task)
            return orig(task)

        monkeypatch.setattr(fleet_mod, "_fleet_point", counting)
        out = fleet_mod.sweep_fleet([pts[0], pts[0], pts[1]], workers=0)
        assert len(calls) == 2
        assert set(out) == set(pts)


# -------------------------------------------------------------------- CLI
class TestFleetCLI:
    def test_fleet_cli_runs_offline_without_jax(self):
        code = (
            "import sys\n"
            "from repro.serving.__main__ import main\n"
            "rc = main(['--arch', 'granite-moe-1b-a400m', '--rps', '20',\n"
            "           '--arrival', 'bursty', '--requests', '8',\n"
            "           '--steps-cap', '40', '--prompt-mean', '16',\n"
            "           '--output-mean', '2', '--fleet', '2',\n"
            "           '--router', 'least_loaded', '--autoscale',\n"
            "           '--min-replicas', '1', '--scale-up-queued', '1'])\n"
            "assert rc == 0, rc\n"
            "assert 'jax' not in sys.modules, 'CLI must stay jax-free'\n"
        )
        root = pathlib.Path(__file__).resolve().parent.parent
        out = subprocess.run(
            [sys.executable, "-c", code], capture_output=True, text=True,
            timeout=300,
            env={**os.environ, "PYTHONPATH": str(root / "src")},
            cwd=str(root))
        assert out.returncode == 0, out.stderr
        assert "# fleet: autoscale 1..2 replicas" in out.stdout
        assert "replica,spun_up_us,retired_us,routed,steps,walks," in out.stdout
        assert "metric,p50_us,p95_us,p99_us" in out.stdout


# ------------------------------------------------------------------ fig16
@pytest.mark.slow
def test_fig16_autoscale_cold_spinups_tax_the_tail():
    from benchmarks.paper_figs import fig16_fleet_scaling
    rows = {name: derived for name, _us, derived in fig16_fleet_scaling()}
    tax = rows["fig16/check_cold_spinup_tax"]
    assert "taxed=True" in tax
    assert "equal_capacity=True" in tax
    assert "any_fit=True" in rows["fig16/check_static_provisioning"]


# --------------------------------------------------------------- retention
class TestFleetRetention:
    def test_idle_fleet_repays_cold_walks_per_replica(self):
        """Each replica's TLB ages independently: after a fleet-wide quiet
        period beyond retention, every replica re-pays its own cold walks."""
        pod = resolve_pod(PodSpec(n_gpus=16), TINY, "decode")
        cfg = SimConfig(fabric=pod_fabric(pod), tlb_retention_ns=100_000.0)
        reqs = tiny_requests([0.0, 1000.0, 1e9, 1e9 + 1000.0],
                             prompt=16, output=2)
        res = simulate_fleet(TINY, reqs, n_gpus=16, cfg=cfg, replicas=2,
                             router="round_robin")
        for rep in res.replicas:
            steps = rep.steps
            assert steps[0].walks > 0
            late = [s for s in steps if s.t_start >= 1e9]
            assert late and late[0].walks == steps[0].walks
