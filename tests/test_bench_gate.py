"""Tests for the engine micro-benchmark regression gate (benchmarks/run.py).

The CI job measures the fixed grid on both engines, uploads it as an
artifact, then gates it against the committed
``benchmarks/BENCH_baseline.json``; these tests pin the gate's semantics —
most importantly that a synthetic 2x-slower point demonstrably fails and
that a vectorized engine slower than the event engine fails — without ever
timing anything.
"""
import copy
import json
import pathlib

from benchmarks.run import BASELINE_PATH, _bench_points, check_against

ROOT = pathlib.Path(__file__).resolve().parent.parent


def payload(walls, vec_walls=None):
    """A BENCH_engine.json-shaped dict over the real grid.

    ``vec_walls`` adds the dual-engine columns (every point, the fleet
    serving point included); without it the payload has the
    pre-vectorization single-engine schema, which the gate must still
    accept (an old baseline after a schema change should not crash it).
    """
    points = []
    for i, ((t, n, b), w) in enumerate(zip(_bench_points(), walls)):
        p = {"topology": t, "n_gpus": n, "nbytes": b, "wall_s": w}
        if vec_walls is not None:
            p["wall_vec_s"] = vec_walls[i]
            p["speedup"] = round(w / vec_walls[i], 2) if vec_walls[i] else 0.0
        points.append(p)
    return {"grid": "engine-v2", "points": points}


WALLS = [0.5, 1.0, 0.8, 0.9, 1.2, 0.3, 0.6, 2.0]
VEC_WALLS = [0.05, 0.2, 0.06, 0.07, 0.05, 0.04, 0.03, 0.4]


class TestCheckAgainst:
    def test_identical_passes(self):
        base = payload(WALLS)
        assert check_against(copy.deepcopy(base), base, 0.35) == []

    def test_2x_slower_point_fails(self):
        base = payload(WALLS)
        cur = copy.deepcopy(base)
        cur["points"][1]["wall_s"] = 2.0          # 2x the 1.0s baseline
        failures = check_against(cur, base, 0.35)
        assert len(failures) == 1
        assert "gpus64" in failures[0] and "+100.0%" in failures[0]

    def test_within_tolerance_passes(self):
        base = payload(WALLS)
        cur = copy.deepcopy(base)
        cur["points"][1]["wall_s"] = 1.3          # +30% < 35%
        assert check_against(cur, base, 0.35) == []

    def test_small_absolute_jitter_ignored(self):
        # A 5ms point doubling is timer noise, not an engine regression:
        # the absolute floor keeps the relative gate from flaking.
        base = payload([0.005] + WALLS[1:])
        cur = copy.deepcopy(base)
        cur["points"][0]["wall_s"] = 0.010
        assert check_against(cur, base, 0.35) == []
        cur["points"][0]["wall_s"] = 0.500        # a real 100x blowup fails
        assert len(check_against(cur, base, 0.35)) == 1

    def test_faster_never_fails(self):
        base = payload(WALLS)
        cur = payload([w / 5 for w in WALLS])
        assert check_against(cur, base, 0.35) == []

    def test_grid_mismatch_fails_both_ways(self):
        base = payload(WALLS)
        cur = copy.deepcopy(base)
        dropped = cur["points"].pop()             # missing point
        failures = check_against(cur, base, 0.35)
        assert any("not measured" in f for f in failures)
        extra = copy.deepcopy(base)
        extra["points"].append(dict(dropped, topology="ring"))
        failures = check_against(extra, base, 0.35)
        assert any("not in baseline" in f for f in failures)


class TestVectorizedGate:
    def test_identical_dual_engine_passes(self):
        base = payload(WALLS, VEC_WALLS)
        assert check_against(copy.deepcopy(base), base, 0.35) == []

    def test_vectorized_slower_than_event_fails(self):
        # The whole point of the vectorized engine: on any grid point it
        # must not lose to the event engine, regardless of the baseline.
        base = payload(WALLS, VEC_WALLS)
        cur = copy.deepcopy(base)
        cur["points"][1]["wall_vec_s"] = 1.5      # event wall is 1.0s
        failures = check_against(cur, base, 0.35)
        assert any("slower than event" in f for f in failures)

    def test_vectorized_wall_regression_fails(self):
        base = payload(WALLS, VEC_WALLS)
        cur = copy.deepcopy(base)
        cur["points"][1]["wall_vec_s"] = 0.5      # 2.5x the 0.2s baseline
        failures = check_against(cur, base, 0.35)
        assert len(failures) == 1
        assert "[vec]" in failures[0] and "gpus64" in failures[0]

    def test_vec_vs_event_jitter_floor(self):
        # Sub-floor inversions on millisecond points are timer noise.
        base = payload([0.010] + WALLS[1:], [0.008] + VEC_WALLS[1:])
        cur = copy.deepcopy(base)
        cur["points"][0]["wall_vec_s"] = 0.012    # > event 0.010, by 2ms
        assert check_against(cur, base, 0.35) == []

    def test_fleet_point_gates_both_engines(self):
        # Since the serving hot path the fleet point is dual-engine: both
        # walls gate against the baseline and the vec-vs-event rule
        # applies to it like any grid point.
        base = payload(WALLS, VEC_WALLS)
        cur = copy.deepcopy(base)
        assert cur["points"][-1]["topology"] == "fleet"
        assert cur["points"][-1]["wall_vec_s"] == 0.4
        assert check_against(copy.deepcopy(cur), base, 0.35) == []
        cur["points"][-1]["wall_s"] = 4.0         # 2x the 2.0s baseline
        failures = check_against(cur, base, 0.35)
        assert len(failures) == 1
        assert "fleet/gpus16/serving" in failures[0]
        cur = copy.deepcopy(base)
        cur["points"][-1]["wall_vec_s"] = 3.0     # slower than event 2.0s
        failures = check_against(cur, base, 0.35)
        assert any("slower than event" in f for f in failures)

    def test_old_single_engine_baseline_still_gates(self):
        # A baseline predating the dual-engine schema gates the event wall
        # only; the vec-vs-event rule still applies to the current run.
        base = payload(WALLS)                     # no wall_vec_s
        cur = payload(WALLS, VEC_WALLS)
        assert check_against(copy.deepcopy(cur), base, 0.35) == []
        cur["points"][2]["wall_vec_s"] = 2.0      # event wall is 0.8s
        failures = check_against(cur, base, 0.35)
        assert any("slower than event" in f for f in failures)


class TestCommittedBaseline:
    def test_baseline_matches_bench_grid(self):
        """The committed baseline covers exactly the current grid, so the
        CI gate can never silently skip a point."""
        with open(ROOT / BASELINE_PATH) as f:
            base = json.load(f)
        keys = {(p["topology"], p["n_gpus"], p["nbytes"])
                for p in base["points"]}
        assert keys == set(_bench_points())
        assert all(p["wall_s"] > 0 for p in base["points"])

    def test_baseline_has_vectorized_walls(self):
        """Every point — the fleet serving point included — carries the
        dual-engine schema, and the committed aggregate speedup stays at
        or above the serving-inclusive headline.  (The aggregate dropped
        from the pre-serving 20x when the fleet point was folded in: it
        now averages over scheduler-driven small-collective replay, the
        regime the paper says matters most, not just pod-scale
        collectives.)"""
        with open(ROOT / BASELINE_PATH) as f:
            base = json.load(f)
        assert all(p["wall_vec_s"] > 0 for p in base["points"])
        assert all(p["speedup"] > 0 for p in base["points"])
        assert base["speedup"] >= 7.0

    def test_fleet_serving_speedup_committed(self):
        """The serving hot path (geometry memoization + warm fast path +
        batched stepping, DESIGN.md §15) must keep the fleet serving
        point fast on the vectorized engine.  Target was >= 5x over the
        pre-optimization committed event wall (2.2026 s); the honest
        paired best-of measurement floor on the CI-class single-vCPU box
        is ~4.6x (wall noise is ±20-30%, so both engines are timed
        interleaved and best-of), which is what the committed baseline
        records and this gate holds."""
        with open(ROOT / BASELINE_PATH) as f:
            base = json.load(f)
        fleet = [p for p in base["points"] if p["topology"] == "fleet"]
        assert len(fleet) == 1
        assert fleet[0]["wall_s"] > 0 and fleet[0]["wall_vec_s"] > 0
        assert fleet[0]["wall_s"] / fleet[0]["wall_vec_s"] >= 4.5
