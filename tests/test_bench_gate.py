"""Tests for the engine micro-benchmark regression gate (benchmarks/run.py).

The CI job measures the fixed grid, uploads it as an artifact, then gates
it against the committed ``benchmarks/BENCH_baseline.json``; these tests
pin the gate's semantics — most importantly that a synthetic 2x-slower
point demonstrably fails — without ever timing anything.
"""
import copy
import json
import pathlib

from benchmarks.run import BASELINE_PATH, _bench_points, check_against

ROOT = pathlib.Path(__file__).resolve().parent.parent


def payload(walls):
    return {"grid": "engine-v1",
            "points": [{"topology": t, "n_gpus": n, "nbytes": b,
                        "wall_s": w}
                       for (t, n, b), w in zip(_bench_points(), walls)]}


class TestCheckAgainst:
    def test_identical_passes(self):
        base = payload([0.5, 1.0, 0.8, 0.9, 0.3])
        assert check_against(copy.deepcopy(base), base, 0.35) == []

    def test_2x_slower_point_fails(self):
        base = payload([0.5, 1.0, 0.8, 0.9, 0.3])
        cur = copy.deepcopy(base)
        cur["points"][1]["wall_s"] = 2.0          # 2x the 1.0s baseline
        failures = check_against(cur, base, 0.35)
        assert len(failures) == 1
        assert "gpus64" in failures[0] and "+100.0%" in failures[0]

    def test_within_tolerance_passes(self):
        base = payload([0.5, 1.0, 0.8, 0.9, 0.3])
        cur = copy.deepcopy(base)
        cur["points"][1]["wall_s"] = 1.3          # +30% < 35%
        assert check_against(cur, base, 0.35) == []

    def test_small_absolute_jitter_ignored(self):
        # A 5ms point doubling is timer noise, not an engine regression:
        # the absolute floor keeps the relative gate from flaking.
        base = payload([0.005, 1.0, 0.8, 0.9, 0.3])
        cur = copy.deepcopy(base)
        cur["points"][0]["wall_s"] = 0.010
        assert check_against(cur, base, 0.35) == []
        cur["points"][0]["wall_s"] = 0.500        # a real 100x blowup fails
        assert len(check_against(cur, base, 0.35)) == 1

    def test_faster_never_fails(self):
        base = payload([0.5, 1.0, 0.8, 0.9, 0.3])
        cur = payload([0.1, 0.2, 0.1, 0.1, 0.1])
        assert check_against(cur, base, 0.35) == []

    def test_grid_mismatch_fails_both_ways(self):
        base = payload([0.5, 1.0, 0.8, 0.9, 0.3])
        cur = copy.deepcopy(base)
        dropped = cur["points"].pop()             # missing point
        failures = check_against(cur, base, 0.35)
        assert any("not measured" in f for f in failures)
        extra = copy.deepcopy(base)
        extra["points"].append(dict(dropped, topology="ring"))
        failures = check_against(extra, base, 0.35)
        assert any("not in baseline" in f for f in failures)


class TestCommittedBaseline:
    def test_baseline_matches_bench_grid(self):
        """The committed baseline covers exactly the current grid, so the
        CI gate can never silently skip a point."""
        with open(ROOT / BASELINE_PATH) as f:
            base = json.load(f)
        keys = {(p["topology"], p["n_gpus"], p["nbytes"])
                for p in base["points"]}
        assert keys == set(_bench_points())
        assert all(p["wall_s"] > 0 for p in base["points"])
