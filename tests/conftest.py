"""Shared pytest policy: the `slow` tier.

Tier-1 (`pytest` with the default ``-m "not slow"`` from pyproject.toml)
must stay well under two minutes; the heavyweight cases below — the largest
smoke-model (jamba's 8-layer hybrid block) and the long-convergence runtime
tests — run in CI's separate, non-blocking ``-m slow`` job.  Tests can also
opt in explicitly with ``@pytest.mark.slow``.
"""
import pytest

SLOW_NODEID_PARTS = (
    "jamba-1.5-large-398b",                      # slowest smoke arch (~95 s)
    "test_restart_continues_identically",        # trainer restart (~14 s)
    "test_int8_training_convergence_parity",     # convergence run (~8 s)
)


def pytest_collection_modifyitems(config, items):
    for item in items:
        if any(part in item.nodeid for part in SLOW_NODEID_PARTS):
            item.add_marker(pytest.mark.slow)
