"""Property-based half of the engine differential harness.

Hypothesis draws random ``SimConfig``s — pattern, topology, group
placement/stride, L1/L2 geometry, PTW width, retention-free optimization
probes, message sizes from sub-page to multi-GB — and asserts the
vectorized engine reproduces the event engine *bit-for-bit* (and both
match the reference DES where the exact-count contract is established).
The deterministic regression corpus lives in ``tests/test_engine_diff.py``
so tier-1 replays past counterexamples even without hypothesis installed;
this module is skipped entirely in that case.

``ENGINE_DIFF_EXAMPLES`` scales the per-test example budget (default 25);
the CI slow tier (``-m slow``) additionally runs the >=200-example deep
variant.  Found a disagreement?  Pin the shrunken config into
``test_engine_diff.CORPUS`` before fixing the engine.
"""
import os

import pytest

from repro.core import SimSession, paper_config, simulate_ref, KB, MB, GB
from repro.core.config import (FabricConfig, PreTranslationConfig,
                               PrefetchConfig, SimConfig, TLBConfig,
                               TranslationConfig)

from test_engine_diff import (PATTERN_NAMES, REF_MAX_BYTES,
                              assert_bit_for_bit, assert_deltas_equal,
                              assert_matches_ref, run_both)

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

FUZZ_EXAMPLES = int(os.environ.get("ENGINE_DIFF_EXAMPLES", "25"))
DEEP_EXAMPLES = max(200, FUZZ_EXAMPLES)


@st.composite
def fabrics(draw):
    topo = draw(st.sampled_from(["single_clos", "two_tier", "multi_pod"]))
    n = draw(st.sampled_from([4, 8, 16]))
    kw = dict(n_gpus=n, topology=topo,
              ingress_entries=draw(st.sampled_from([64, 256])))
    if topo == "two_tier":
        kw["leaf_size"] = draw(st.sampled_from([0, 4]))
        kw["oversubscription"] = draw(st.sampled_from([1.0, 2.0, 4.0]))
    elif topo == "multi_pod":
        kw["pod_size"] = draw(st.sampled_from([0, 4]))
        kw["interpod_oversubscription"] = draw(st.sampled_from([1.0, 4.0]))
    return FabricConfig(**kw)


@st.composite
def translations(draw):
    if draw(st.booleans()):
        return TranslationConfig()      # paper Table-1 defaults
    return TranslationConfig(
        l1=TLBConfig(entries=draw(st.sampled_from([2, 8, 32])),
                     assoc=draw(st.sampled_from([0, 2])),
                     hit_latency_ns=50.0, mshr_entries=256),
        l2=TLBConfig(entries=draw(st.sampled_from([16, 128, 512])),
                     assoc=draw(st.sampled_from([0, 2, 4])),
                     hit_latency_ns=100.0, mshr_entries=512),
        n_ptw=draw(st.sampled_from([1, 4, 100])))


@st.composite
def sim_configs(draw):
    cfg = SimConfig(
        fabric=draw(fabrics()),
        translation=draw(translations()),
        collective=draw(st.sampled_from(PATTERN_NAMES)),
        iterations=draw(st.sampled_from([1, 2])),
        symmetric=draw(st.booleans()))
    opt = draw(st.sampled_from(["none", "none", "pretranslate", "prefetch"]))
    if opt == "pretranslate":
        cfg = cfg.replace(pretranslation=PreTranslationConfig(
            enabled=True,
            lead_time_ns=draw(st.sampled_from([1000.0, 3000.0])),
            pages_per_flow=draw(st.sampled_from([0, 1]))))
    elif opt == "prefetch":
        cfg = cfg.replace(prefetch=PrefetchConfig(
            enabled=True, depth=draw(st.sampled_from([1, 2]))))
    nbytes = draw(st.one_of(
        st.integers(min_value=1 * KB, max_value=4 * MB),
        st.sampled_from([4 * KB, 1 * MB, 16 * MB, 2 * GB])))
    if nbytes <= REF_MAX_BYTES:
        # Trace arrays are per-request: keep them off multi-GB draws.
        cfg = cfg.replace(collect_trace=draw(st.booleans()))
    return nbytes, cfg


def _check_example(nbytes, cfg):
    a, b = run_both(nbytes, cfg)
    assert_bit_for_bit(a, b)
    # Three-way only where the engine/DES exact-count contract is
    # established: paper-default translation and ingress (DESIGN.md §7);
    # elsewhere the two engines' mutual exactness is the property under
    # fuzz (the event engine's own oracle equivalence has its own tests).
    if (nbytes <= REF_MAX_BYTES and cfg.iterations == 1
            and cfg.translation == TranslationConfig()
            and cfg.fabric.ingress_entries == 256):
        assert_matches_ref(a, simulate_ref(nbytes, cfg))


@settings(max_examples=FUZZ_EXAMPLES, deadline=None)
@given(sim_configs())
def test_fuzz_engines_agree(case):
    _check_example(*case)


@pytest.mark.slow
@settings(max_examples=DEEP_EXAMPLES, deadline=None)
@given(sim_configs())
def test_fuzz_engines_agree_deep(case):
    """The CI slow tier's >=200-example budget over the same strategy."""
    _check_example(*case)


@st.composite
def group_placements(draw):
    group = draw(st.sampled_from([4, 8, 16]))
    max_stride = (16 - 1) // max(group - 1, 1)
    stride = draw(st.integers(min_value=1, max_value=max(1, max_stride)))
    name = draw(st.sampled_from(PATTERN_NAMES))
    nbytes = draw(st.sampled_from([64 * KB, 1 * MB]))
    return group, stride, name, nbytes


@settings(max_examples=FUZZ_EXAMPLES, deadline=None)
@given(group_placements())
def test_fuzz_group_placement(case):
    """Subgroups on strided pod ranks inside a 16-GPU pod: cold + warm
    calls through both engines, per-call deltas exactly equal."""
    group, stride, name, nbytes = case
    cfg = paper_config(16)
    sessions = []
    for engine in ("event", "vectorized"):
        s = SimSession(cfg.replace(engine=engine))
        for _ in range(2):
            s.run(nbytes, collective=name, n_gpus=group,
                  rank_stride=stride)
        sessions.append(s)
    ev, vec = sessions
    assert_deltas_equal(ev.records, vec.records)
    assert vec.result().counters.__dict__ == ev.result().counters.__dict__
