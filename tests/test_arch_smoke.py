"""Per-architecture smoke tests: reduced config, one train step + decode on CPU.

Asserts output shapes and absence of NaNs for every assigned architecture,
covering forward/loss/grad and prefill+decode paths.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import api

ARCHS = configs.list_archs()
B, S = 2, 32


def make_batch(cfg, key):
    ks = jax.random.split(key, 4)
    s_text = S - (cfg.n_img_tokens if cfg.n_img_tokens else 0)
    batch = {
        "inputs": jax.random.randint(ks[0], (B, s_text), 0, cfg.vocab_size),
        "targets": jax.random.randint(ks[1], (B, s_text), 0, cfg.vocab_size),
    }
    if cfg.n_img_tokens > 0:
        batch["img_embeds"] = jax.random.normal(
            ks[2], (B, cfg.n_img_tokens, cfg.d_model), jnp.float32)
    if cfg.is_encoder_decoder:
        batch["enc_embeds"] = jax.random.normal(
            ks[3], (B, cfg.enc_frames, cfg.d_model), jnp.float32)
    return batch


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_shapes_and_finite(arch, rng):
    cfg = configs.get_smoke_config(arch)
    params, specs = api.init(cfg, rng)
    # specs pytree mirrors params
    assert (jax.tree.structure(jax.tree.map(lambda x: 0, params))
            == jax.tree.structure(
                jax.tree.map(lambda x: 0, specs,
                             is_leaf=lambda x: isinstance(x, tuple))))
    batch = make_batch(cfg, rng)

    @jax.jit
    def step(p, b):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: api.loss_fn(cfg, p, b), has_aux=True)(p)
        return loss, metrics, grads

    loss, metrics, grads = step(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)), f"{arch}: non-finite grads"
    assert float(gnorm) > 0.0


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_logits_shape(arch, rng):
    cfg = configs.get_smoke_config(arch)
    params, _ = api.init(cfg, rng)
    batch = make_batch(cfg, rng)
    logits, aux = jax.jit(lambda p, b: api.forward(cfg, p, b))(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_then_decode(arch, rng):
    cfg = configs.get_smoke_config(arch)
    params, _ = api.init(cfg, rng)
    batch = make_batch(cfg, rng)
    s_max = S + 8
    logits, caches = jax.jit(
        lambda p, b: api.prefill(cfg, p, b, s_max))(params, batch)
    assert logits.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()
    tok = jnp.argmax(logits, axis=-1)
    step = jax.jit(lambda p, t, c: api.decode_step(cfg, p, t, c))
    for _ in range(3):
        logits, caches = step(params, tok, caches)
        assert logits.shape == (B, cfg.vocab_size)
        assert np.isfinite(np.asarray(logits)).all()
        tok = jnp.argmax(logits, axis=-1)


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "mamba2-780m",
                                  "jamba-1.5-large-398b", "whisper-medium"])
def test_decode_consistent_with_forward(arch, rng):
    """Greedy decode logits == teacher-forced forward logits (same prefix)."""
    cfg = configs.get_smoke_config(arch)
    params, _ = api.init(cfg, rng)
    batch = make_batch(cfg, rng)
    # forward logits at position S-1 predict token S; compare with prefill
    logits_full, _ = api.forward(cfg, params, batch)
    last_fwd = logits_full[:, -1]
    last_pre, _ = api.prefill(cfg, params, batch, S + 4)
    np.testing.assert_allclose(np.asarray(last_pre, np.float32),
                               np.asarray(last_fwd, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_full_configs_match_assignment():
    """Spot-check the paper-exact dimensions of the full configs."""
    c = configs.get_config("mistral-large-123b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (88, 12288, 96, 8, 28672, 32768)
    c = configs.get_config("qwen3-moe-235b-a22b")
    assert (c.n_layers, c.n_experts, c.top_k, c.vocab_size) == (94, 128, 8, 151936)
    assert c.qk_norm
    c = configs.get_config("jamba-1.5-large-398b")
    assert c.n_layers == 72 and c.block_size == 8
    assert c.pattern.count("attn") == 1 and c.pattern.count("mamba") == 7
    c = configs.get_config("mamba2-780m")
    assert c.ssm_state == 128 and c.d_ff == 0
    c = configs.get_config("qwen2-1.5b")
    assert c.qkv_bias and c.n_kv_heads == 2
    c = configs.get_config("whisper-medium")
    assert c.is_encoder_decoder and c.n_enc_layers == 24
    c = configs.get_config("phi-3-vision-4.2b")
    assert c.n_img_tokens > 0 and c.d_model == 3072


def test_param_counts_in_expected_range():
    """Sanity: analytic parameter counts are near the advertised sizes."""
    expect = {
        "qwen2-1.5b": (1.2e9, 2.2e9),
        "qwen3-14b": (12e9, 17e9),
        "qwen3-1.7b": (1.4e9, 2.4e9),
        "mistral-large-123b": (110e9, 135e9),
        "qwen3-moe-235b-a22b": (200e9, 260e9),
        "mamba2-780m": (0.6e9, 1.0e9),
        "jamba-1.5-large-398b": (330e9, 430e9),
    }
    for arch, (lo, hi) in expect.items():
        n = configs.param_count(configs.get_config(arch))
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B params not in [{lo/1e9},{hi/1e9}]B"
