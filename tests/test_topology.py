"""Tests for the fabric topology layer (repro.core.topology).

Four contracts:
  1. Geometry — tier classification, per-path latencies, tier capacities and
     validation of the registered topologies.
  2. Bandwidth shaping — flows crossing an oversubscribed tier split the
     tier capacity, not the flat station pool; the flat default keeps the
     exact pre-topology spacing arithmetic.
  3. Oracle equivalence — the page-epoch engine matches the request-level
     reference DES request-for-request on ``two_tier`` and ``multi_pod``
     at small sizes, for every pattern and for the pre-translation /
     prefetch probe schedules (the same contract the per-pattern suite
     pins on the flat default).
  4. The API — ``topology=`` axes on ratsim.run/compare/session/sweep, the
     session warm-vs-cold story per topology, and the workload-derivation
     tier mapping (TP intra-leaf, EP cross-tier).
"""
import math

import pytest

from repro.core import (ratsim, paper_config, simulate, simulate_ref,
                        get_pattern, get_topology, analytic_volume,
                        SimSession, RefSession, TOPOLOGIES, KB, MB)
from repro.core.config import (FabricConfig, SimConfig, PreTranslationConfig,
                               PrefetchConfig)
from repro.core.engine import flows_for_dst
from repro.core.patterns import FlowSpec


def two_tier(n=8, leaf=4, ov=2.0, **kw) -> SimConfig:
    return SimConfig(fabric=FabricConfig(
        n_gpus=n, topology="two_tier", leaf_size=leaf, oversubscription=ov),
        **kw)


def multi_pod(n=8, pod=4, ov=4.0, **kw) -> SimConfig:
    return SimConfig(fabric=FabricConfig(
        n_gpus=n, topology="multi_pod", pod_size=pod,
        interpod_oversubscription=ov), **kw)


# ---------------------------------------------------------------- geometry
class TestGeometry:
    def test_single_clos_is_flat(self):
        fab = FabricConfig(n_gpus=16)
        t = get_topology(fab)
        assert t.flat and t.name == "single_clos"
        assert t.tier(0, 15) == 0
        assert t.path_latency_ns(0, 15) == fab.oneway_ns
        assert t.return_latency_ns(15, 0) == fab.return_ns
        assert t.tier_capacity(0) is None
        assert t.tier0_group() == 16
        assert t.local_group() == fab.gpus_per_node

    def test_two_tier_tiers_and_latency(self):
        fab = two_tier(n=8, leaf=4).fabric
        t = get_topology(fab)
        assert not t.flat
        assert t.tier(0, 3) == 0 and t.tier(0, 4) == 1
        assert t.path_latency_ns(0, 3) == fab.oneway_ns
        # spine crossing + the second leaf switch
        assert t.path_latency_ns(0, 4) == (fab.oneway_ns
                                           + fab.spine_latency_ns
                                           + fab.switch_latency_ns)
        assert t.return_latency_ns(4, 0) == t.path_latency_ns(0, 4)
        assert t.tier_capacity(0) is None
        assert t.tier_capacity(1) == fab.gpu_bw / fab.oversubscription
        assert t.tier0_group() == t.local_group() == 4

    def test_multi_pod_tiers_and_latency(self):
        fab = multi_pod(n=8, pod=4).fabric
        t = get_topology(fab)
        assert t.tier(1, 2) == 0 and t.tier(1, 6) == 1
        assert t.path_latency_ns(1, 6) == (fab.oneway_ns
                                           + fab.interpod_latency_ns)
        assert t.tier_capacity(1) == (fab.gpu_bw
                                      / fab.interpod_oversubscription)
        assert t.tier0_group() == t.pod_group() == 4

    def test_leaf_defaults_to_gpus_per_node(self):
        fab = FabricConfig(n_gpus=8, topology="two_tier")  # leaf_size=0
        assert get_topology(fab).local_group() == fab.gpus_per_node

    def test_small_group_fits_one_leaf(self):
        # Session subgroups smaller than a leaf degenerate to a single leaf.
        fab = FabricConfig(n_gpus=4, topology="two_tier", leaf_size=16)
        t = get_topology(fab)
        assert t.tier(0, 3) == 0 and t.tier0_group() == 4

    def test_indivisible_leaf_raises(self):
        with pytest.raises(ValueError, match="divisible"):
            get_topology(FabricConfig(n_gpus=12, topology="two_tier",
                                      leaf_size=8))
        with pytest.raises(ValueError, match="divisible"):
            get_topology(FabricConfig(n_gpus=12, topology="multi_pod",
                                      pod_size=8))

    def test_unknown_topology_raises(self):
        with pytest.raises(ValueError, match="unknown topology"):
            get_topology(FabricConfig(topology="hypercube"))

    def test_registry(self):
        assert set(TOPOLOGIES) == {"single_clos", "two_tier", "multi_pod"}


# ------------------------------------------------------- bandwidth shaping
class TestBandwidthShaping:
    def _a2a_specs(self, n, nbytes):
        chunk = nbytes // n
        return [FlowSpec(src=s, dst=d, nbytes=chunk, offset=s * chunk)
                for d in range(n) for s in range(n) if s != d]

    def test_flat_spacing_is_pre_topology_arithmetic(self):
        cfg = paper_config(8)
        fab = cfg.fabric
        specs = self._a2a_specs(8, 1 * MB)
        for f in flows_for_dst(specs, cfg, 0, 0.0):
            assert f.delta_ns == fab.request_bytes * 7 / fab.gpu_bw
            assert f.oneway_ns == fab.oneway_ns
            assert f.return_ns == fab.return_ns

    def test_oversubscribed_tier_splits_uplink(self):
        cfg = two_tier(n=8, leaf=4, ov=4.0)
        fab = cfg.fabric
        specs = self._a2a_specs(8, 1 * MB)
        flows = flows_for_dst(specs, cfg, 0, 0.0)
        base = fab.request_bytes * 7 / fab.gpu_bw
        uplink = fab.gpu_bw / 4.0
        # src 1..3 are intra-leaf to dst 0; src 4..7 cross the spine and
        # each has 4 cross-tier flows (to GPUs 0..3) sharing its uplink.
        for f in flows:
            if f.src < 4:
                assert f.delta_ns == base
            else:
                assert f.delta_ns == max(base,
                                         fab.request_bytes * 4 / uplink)
                assert f.delta_ns > base

    def test_unity_oversubscription_only_changes_latency(self):
        cfg = two_tier(n=8, leaf=4, ov=1.0)
        fab = cfg.fabric
        specs = self._a2a_specs(8, 1 * MB)
        for f in flows_for_dst(specs, cfg, 0, 0.0):
            assert f.delta_ns == fab.request_bytes * 7 / fab.gpu_bw
            if f.src < 4:
                assert f.oneway_ns == fab.oneway_ns
            else:
                assert f.oneway_ns > fab.oneway_ns

    def test_degenerate_two_tier_bit_for_bit(self):
        # leaf == pod: every pair is intra-leaf, so the numbers are exactly
        # the single-Clos ones.
        a = simulate(1 * MB, two_tier(n=8, leaf=8, ov=2.0))
        b = simulate(1 * MB, paper_config(8))
        assert a.completion_ns == b.completion_ns
        assert a.counters.requests == b.counters.requests
        assert a.counters.walks == b.counters.walks


# ------------------------------------------------------- oracle equivalence
TOPO_CFGS = [("two_tier", two_tier), ("multi_pod", multi_pod)]
PATTERN_NAMES = ["all_to_all", "ring_allreduce", "rd_allreduce",
                 "all_gather", "broadcast", "hier_all_to_all",
                 "multipod_all_to_all"]


class TestOracleEquivalence:
    @pytest.mark.parametrize("topo,mk", TOPO_CFGS)
    @pytest.mark.parametrize("name", PATTERN_NAMES)
    def test_engine_matches_reference_des(self, topo, mk, name):
        cfg = mk(n=8).replace(collective=name)
        a = simulate(1 * MB, cfg)
        b = simulate_ref(1 * MB, cfg)
        assert a.completion_ns == pytest.approx(b.completion_ns, rel=0.05)
        assert a.counters.walks == b.counters.walks
        assert a.counters.requests == b.counters.requests

    @pytest.mark.parametrize("topo,mk", TOPO_CFGS)
    def test_multipage_matches_reference_des(self, topo, mk):
        cfg = mk(n=8).replace(collective="hier_all_to_all")
        a = simulate(4 * MB, cfg)
        b = simulate_ref(4 * MB, cfg)
        assert a.completion_ns == pytest.approx(b.completion_ns, rel=0.05)
        assert a.counters.walks == b.counters.walks

    @pytest.mark.parametrize("topo,mk", TOPO_CFGS)
    def test_ideal_matches_reference_des(self, topo, mk):
        cfg = mk(n=8).ideal()
        a = simulate(1 * MB, cfg)
        b = simulate_ref(1 * MB, cfg)
        assert a.completion_ns == pytest.approx(b.completion_ns, rel=0.005)

    @pytest.mark.parametrize("topo,mk", TOPO_CFGS)
    def test_pretranslation_probe_schedule_equivalent(self, topo, mk):
        cfg = mk(n=8).replace(pretranslation=PreTranslationConfig(
            enabled=True, lead_time_ns=3000.0, pages_per_flow=0))
        a = simulate(1 * MB, cfg)
        b = simulate_ref(1 * MB, cfg)
        assert a.counters.probes == b.counters.probes > 0
        assert a.counters.walks == b.counters.walks
        assert a.completion_ns == pytest.approx(b.completion_ns, rel=0.05)

    @pytest.mark.parametrize("topo,mk", TOPO_CFGS)
    def test_prefetch_probe_schedule_equivalent(self, topo, mk):
        # 32 MB / 8 GPUs = 4 MB per flow = 2 pages: next-page prefetches
        # fire mid-stream on every flow.  Unity oversubscription: latency
        # tiers only, the regime where the engine/DES completion contract
        # binds tightly (paper-default ingress buffering, DESIGN.md §7).
        cfg = mk(n=8, ov=1.0).replace(
            prefetch=PrefetchConfig(enabled=True, depth=2))
        a = simulate(32 * MB, cfg)
        b = simulate_ref(32 * MB, cfg)
        assert a.counters.probes == b.counters.probes > 0
        assert a.counters.walks == b.counters.walks
        # Long heterogeneous-latency streams: the epoch tail diverges by at
        # most one end-of-stream walk window (absolute), tight relative
        # otherwise (DESIGN.md §10.3).
        assert a.completion_ns == pytest.approx(b.completion_ns, rel=0.05,
                                                abs=2e3)

    def test_prefetch_under_shaping_schedule_stays_exact(self):
        # With an oversubscribed uplink the same flows run at two rates;
        # the epoch engine's closed-form tail expansion then diverges from
        # the slot-accurate DES by a bounded end-of-stream window
        # (DESIGN.md §10.3) — but the probe schedule, walk count and
        # request count remain request-for-request identical.
        cfg = two_tier(n=8, leaf=4, ov=2.0).replace(
            prefetch=PrefetchConfig(enabled=True, depth=2))
        a = simulate(32 * MB, cfg)
        b = simulate_ref(32 * MB, cfg)
        assert a.counters.probes == b.counters.probes > 0
        assert a.counters.walks == b.counters.walks
        assert a.counters.requests == b.counters.requests
        assert a.completion_ns == pytest.approx(b.completion_ns, rel=0.08)

    @pytest.mark.parametrize("topo,mk", TOPO_CFGS)
    def test_session_sequence_equivalent(self, topo, mk):
        # Heterogeneous session replay: the RefSession mirror stays
        # request-for-request equivalent on hierarchical topologies.
        cfg = mk(n=8)
        s, r = SimSession(cfg), RefSession(cfg)
        for sess in (s, r):
            sess.run(512 * KB)
            sess.run(512 * KB)                      # warm rerun
            sess.run(256 * KB, collective="all_gather", n_gpus=4)
            sess.run(512 * KB, base_offset=32 * MB)  # fresh buffer
        for a, b in zip(s.records, r.records):
            assert a.completion_ns == pytest.approx(b.completion_ns,
                                                    rel=0.05)
            assert a.counters.walks == b.counters.walks
            assert a.counters.requests == b.counters.requests


# ------------------------------------------------------------------ physics
class TestTopologyPhysics:
    def test_two_tier_slower_than_flat(self):
        flat = simulate(1 * MB, paper_config(8))
        tiered = simulate(1 * MB, two_tier(n=8, leaf=4, ov=2.0))
        assert tiered.completion_ns > flat.completion_ns

    def test_oversubscription_monotone(self):
        prev = None
        for ov in (1.0, 2.0, 4.0):
            t = simulate(4 * MB, two_tier(n=8, leaf=4, ov=ov))
            if prev is not None:
                assert t.completion_ns >= prev
            prev = t.completion_ns

    def test_hier_stages_on_leaf_group(self):
        fab = two_tier(n=8, leaf=4).fabric
        steps = get_pattern("hier_all_to_all").steps(1 * MB, fab)
        assert len(steps) == 2
        # Phase 1 flows never leave the leaf; phase 2 always crosses it.
        t = get_topology(fab)
        assert all(t.tier(s.src, s.dst) == 0 for s in steps[0])
        assert all(t.tier(s.src, s.dst) == 1 for s in steps[1])

    def test_multipod_pattern_stages_on_pod_group(self):
        fab = multi_pod(n=8, pod=4).fabric
        steps = get_pattern("multipod_all_to_all").steps(1 * MB, fab)
        t = get_topology(fab)
        assert all(t.tier(s.src, s.dst) == 0 for s in steps[0])
        assert all(t.tier(s.src, s.dst) == 1 for s in steps[1])
        emitted = sum(s.nbytes for step in steps for s in step)
        assert emitted == analytic_volume("multipod_all_to_all", 1 * MB, fab)

    def test_hier_beats_direct_a2a_crossings(self):
        # The point of staging: per GPU, hier crosses the spine (m-1) times
        # with aggregated chunks vs (n - g) direct crossings.
        fab = two_tier(n=16, leaf=4, ov=4.0).fabric
        t = get_topology(fab)
        direct = get_pattern("all_to_all").steps(1 * MB, fab)
        hier = get_pattern("hier_all_to_all").steps(1 * MB, fab)
        cross = lambda steps: sum(1 for step in steps for s in step
                                  if t.tier(s.src, s.dst) == 1 and s.src == 0)
        assert cross(hier) == 3 < cross(direct) == 12


# ---------------------------------------------------------------- the API
class TestTopologyAPI:
    def test_run_compare_session_topology_kwarg(self):
        r = ratsim.run(1 * MB, 8, topology="two_tier")
        assert r.config.fabric.topology == "two_tier"
        c = ratsim.compare(1 * MB, 8, topology="two_tier")
        assert c.degradation >= 1.0 - 1e-12
        s = ratsim.session(8, topology="two_tier")
        cold = s.run(1 * MB)
        warm = s.run(1 * MB)
        assert warm.completion_ns < cold.completion_ns
        assert warm.counters.walks == 0

    def test_default_topology_kwarg_is_noop(self):
        a = ratsim.run(1 * MB, 16)
        b = ratsim.run(1 * MB, 16, topology="single_clos")
        assert a.completion_ns == b.completion_ns

    def test_sweep_topology_axis_keys(self):
        out = ratsim.sweep([1 * MB], [8],
                           topologies=["single_clos", "two_tier"], workers=0)
        assert set(out) == {("single_clos", 8, 1 * MB),
                            ("two_tier", 8, 1 * MB)}
        both = ratsim.sweep([1 * MB], [8], topologies=["two_tier"],
                            collectives=["all_to_all", "ring_allreduce"],
                            workers=0)
        assert set(both) == {("two_tier", "all_to_all", 8, 1 * MB),
                             ("two_tier", "ring_allreduce", 8, 1 * MB)}

    def test_sweep_topology_matches_compare(self):
        out = ratsim.sweep([1 * MB], [8], topologies=["two_tier"], workers=0)
        c = ratsim.compare(1 * MB, 8, topology="two_tier")
        g = out[("two_tier", 8, 1 * MB)]
        assert g.baseline.completion_ns == c.baseline.completion_ns
        assert g.ideal.completion_ns == c.ideal.completion_ns

    def test_sweep_base_cfg_keeps_tier_params(self):
        base = two_tier(n=8, leaf=4, ov=4.0)
        out = ratsim.sweep([1 * MB], [8, 16], base_cfg=base, workers=0)
        direct = ratsim.compare(
            1 * MB, 16,
            cfg=two_tier(n=16, leaf=4, ov=4.0))
        assert (out[(16, 1 * MB)].baseline.completion_ns
                == direct.baseline.completion_ns)


# ------------------------------------------------------ workload placement
class TinyMoE:
    name = "tiny-moe"
    n_layers = 4
    d_model = 512
    n_heads = 8
    n_kv_heads = 4
    d_head = 64
    d_ff = 0
    n_experts = 16
    top_k = 2
    d_ff_expert = 256
    moe_every = 1
    capacity_factor = 1.25


class TestWorkloadTierMapping:
    def test_two_tier_tp_intra_leaf_ep_cross_tier(self):
        from repro.workloads import PodSpec, derive_workload, pod_fabric

        pod = PodSpec(topology="two_tier", leaf_size=4, oversubscription=2.0)
        tr = derive_workload(TinyMoE(), "decode_32k", pod=pod, n_gpus=8,
                             n_steps=1)
        assert tr.pod.tp == 4          # one leaf
        assert tr.pod.ep == 8          # spans both leaves (cross-tier a2a)
        assert tr.pod.dp == 2
        groups = {(c.collective, c.group) for c in tr.calls}
        assert ("all_gather", 4) in groups and ("all_to_all", 8) in groups
        assert pod_fabric(tr.pod).topology == "two_tier"

    def test_single_clos_defaults_unchanged(self):
        from repro.workloads import PodSpec, derive_workload

        tr = derive_workload(TinyMoE(), "decode_32k", pod=PodSpec(),
                             n_gpus=8, n_steps=1)
        assert tr.pod.tp == 8 and tr.pod.dp == 1   # whole pod, as before

    def test_replay_simulates_pod_topology(self):
        from repro.workloads import PodSpec, derive_workload, replay

        pod = PodSpec(topology="two_tier", leaf_size=4, oversubscription=2.0)
        tr = derive_workload(TinyMoE(), "decode_32k", pod=pod, n_gpus=8,
                             n_steps=2)
        rep = replay(tr)
        assert rep.cfg.fabric.topology == "two_tier"
        assert rep.cold_degradation > rep.steady_degradation
        assert rep.steps[1].walks == 0             # warmth carries per-tier


# ------------------------------------------------------------ strided groups
class TestStridedGroups:
    def test_strided_ring_crosses_tiers(self):
        # DP ring over ranks {0, 4} in a leaf-4 pod: every hop is
        # inter-leaf, so cold completion exceeds the contiguous placement's.
        cfg = two_tier(n=8, leaf=4, ov=2.0)
        contiguous = SimSession(cfg).run(
            1 * MB, collective="ring_allreduce", n_gpus=2)
        strided = SimSession(cfg).run(
            1 * MB, collective="ring_allreduce", n_gpus=2, rank_stride=4)
        assert strided.completion_ns > contiguous.completion_ns

    def test_strided_oracle_equivalence(self):
        cfg = two_tier(n=8, leaf=4, ov=2.0)
        s, r = SimSession(cfg), RefSession(cfg)
        for sess in (s, r):
            sess.run(1 * MB, collective="ring_allreduce", n_gpus=2,
                     rank_stride=4)
            sess.run(512 * KB, collective="all_to_all", n_gpus=2,
                     rank_stride=4, base_offset=16 * MB)
        for a, b in zip(s.records, r.records):
            assert a.completion_ns == pytest.approx(b.completion_ns,
                                                    rel=0.05)
            assert a.counters.walks == b.counters.walks
            assert a.counters.requests == b.counters.requests

    def test_stride_noop_on_flat_topology(self):
        # Flat Clos: rank labeling is isomorphic up to station striping of
        # a symmetric fabric — same walk/request counts, same completion.
        s1 = SimSession(paper_config(8)).run(
            1 * MB, collective="ring_allreduce", n_gpus=2)
        s2 = SimSession(paper_config(8)).run(
            1 * MB, collective="ring_allreduce", n_gpus=2, rank_stride=4)
        assert s2.completion_ns == s1.completion_ns
        assert s2.counters.walks == s1.counters.walks

    def test_misaligned_stride_simulates_every_target(self):
        # Stride 2 on a leaf-4 block mixes intra/inter pairs per target:
        # the symmetric single-target shortcut must switch off.
        cfg = two_tier(n=8, leaf=4, ov=2.0)
        rec = SimSession(cfg).run(1 * MB, collective="ring_allreduce",
                                  n_gpus=4, rank_stride=2)
        rb = cfg.fabric.request_bytes
        n_req_flow = math.ceil((1 * MB // 4) / rb)
        n_steps = 2 * (4 - 1)
        assert rec.counters.requests == n_steps * 4 * n_req_flow  # all dsts

    def test_block_straddling_subgroup_simulates_every_target(self):
        # A contiguous group of 5 on leaf-4 blocks straddles a partial
        # leaf: target 0 (leaf 0, 3 intra-peers) and target 4 (leaf 1,
        # alone) see different tier mixes, so the shortcut must switch off
        # and completion must equal the explicit every-target run.
        cfg = two_tier(n=8, leaf=4, ov=2.0)
        rec = SimSession(cfg).run(1 * MB, n_gpus=5)
        full = SimSession(cfg.replace(symmetric=False)).run(1 * MB, n_gpus=5)
        assert rec.completion_ns == full.completion_ns
        assert rec.counters.requests == full.counters.requests

    def test_whole_block_multiples_keep_single_target_shortcut(self):
        # g a multiple of the block (or inside one block): every target is
        # loaded identically, the shortcut stays exact.
        cfg = two_tier(n=8, leaf=4, ov=2.0)
        for g in (2, 4, 8):
            short = SimSession(cfg).run(1 * MB, n_gpus=g)
            full = SimSession(cfg.replace(symmetric=False)).run(
                1 * MB, n_gpus=g)
            assert short.completion_ns == full.completion_ns, g

    def test_stride_overflow_raises(self):
        with pytest.raises(ValueError, match="strided group"):
            SimSession(paper_config(8)).run(
                1 * MB, collective="ring_allreduce", n_gpus=4, rank_stride=4)

    def test_train_grad_ring_strided_on_two_tier(self):
        from repro.workloads import PodSpec, derive_workload, replay

        pod = PodSpec(topology="two_tier", leaf_size=4, oversubscription=2.0)
        tr = derive_workload(TinyMoE(), "train_4k", pod=pod, n_gpus=8,
                             n_steps=1)
        assert tr.pod.tp == 4 and tr.pod.dp == 2
        grads = [c for c in tr.calls if c.collective == "ring_allreduce"]
        assert grads and all(c.stride == tr.pod.tp for c in grads)
        # Flat default keeps contiguous ranks (bit-for-bit pre-topology).
        flat = derive_workload(TinyMoE(), "train_4k", pod=PodSpec(),
                               n_gpus=8, n_steps=1)
        assert all(c.stride == 1 for c in flat.calls)
        rep = replay(tr)                     # strided replay runs end-to-end
        assert rep.steps[0].walks > 0

    def test_train_tp_cap_not_power_of_two(self):
        # leaf 6 in a 24-GPU pod: tp must stop at 4, not overshoot to 8
        # across two leaves.
        from repro.workloads import PodSpec, resolve_pod

        pod = PodSpec(n_gpus=24, topology="two_tier", leaf_size=6)
        r = resolve_pod(pod, TinyMoE(), "train")
        assert r.tp == 4 and r.tp <= 6 and r.tp * r.dp == 24


# ---------------------------------------------------------------- figures
@pytest.mark.slow
def test_fig14_topology_scaling_runs_to_1024():
    from benchmarks.paper_figs import fig14_topology_scaling

    rows = fig14_topology_scaling()
    names = {r[0] for r in rows}
    assert "fig14/two_tier/gpus1024/size1MB" in names
    checks = {r[0]: r[2] for r in rows if "check" in r[0]}
    assert checks["fig14/check_16gpu_topologies_degenerate"] == "agree=True"
    assert checks["fig14/check_warm_never_worse_than_cold"] == "ok=True"
