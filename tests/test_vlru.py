"""Direct unit tests for ``repro.core.engine_vec._VLRU`` internals.

The differential/fuzz tiers prove ``_VLRU`` end-to-end against the event
engine's ``LRUCache``, but two of its invariants deserve targeted coverage
because their failure modes are silent recency corruption rather than a
timing mismatch a diff run is guaranteed to trip over:

* **stale-heap-generation skip** — a re-staged (earlier) fill leaves the
  superseded heap entry in place; when it finally pops, the
  ``staged.get(k) != (ft, seq)`` check must drop it without touching the
  set (a spurious ``move_to_end`` would silently reorder evictions);
* **cross-set isolation** — keys hash to ``hash(k) % n_sets`` independent
  sets; pressure in one set must never evict or reorder another.

Plus the two contracts the warm fast path builds on: ``resident`` mirrors
the union of the set dicts exactly, and the shared mutation-epoch cell
bumps on staging/commit but never on recency-only hits.
"""
from repro.core.engine_vec import _VLRU


def _set_keys(c):
    return [list(s) for s in c._sets]


class TestRestagedFills:
    def test_later_refill_ignored(self):
        c = _VLRU(entries=4, assoc=4)
        c.fill("k", 5.0)
        c.fill("k", 9.0)            # later fill of a staged page: no-op
        assert c._staged["k"] == (5.0, 0)
        assert len(c._heap) == 1    # no superseded entry pushed
        assert c.lookup("k", 6.0)   # committed at its original time
        assert not c.lookup("q", 4.0)

    def test_stale_entry_skipped_without_recency_touch(self):
        # Re-staging "a" earlier supersedes its t=10 heap entry.  When the
        # stale entry pops later it must be dropped; a buggy commit would
        # move_to_end("a") at t=10, flipping the LRU order.
        c = _VLRU(entries=2, assoc=2)      # one set
        c.fill("a", 10.0)
        c.fill("a", 5.0)                   # earlier re-fill supersedes
        c.fill("b", 6.0)
        assert c.lookup("b", 7.0)          # commits a@5 then b@6
        assert c.resident == {"a", "b"}
        # Recency now [a, b] (b touched last).  Popping the stale a@10
        # entry must not promote "a".
        assert not c.lookup("zz", 11.0)    # drains the stale entry
        c.fill("d", 12.0)
        c._commit(13.0)                    # set full: evicts LRU
        assert c.resident == {"b", "d"}    # "a" was LRU and went
        assert c.lookup("b", 14.0) and not c.lookup("a", 14.0)

    def test_earlier_refill_keeps_staging_index(self):
        # An earlier re-fill keeps the original staging index, exactly as
        # a dict value update keeps the key's position: on a fill-time
        # tie, first-staged commits (and therefore evicts) first.
        c = _VLRU(entries=2, assoc=2)
        c.fill("a", 10.0)                  # staged first (seq 0)
        c.fill("b", 8.0)                   # seq 1
        c.fill("a", 8.0)                   # ties b's time, keeps seq 0
        c._commit(9.0)                     # inserts a (seq 0) then b
        c.fill("d", 20.0)
        c._commit(21.0)                    # evicts the LRU: "a"
        assert c.resident == {"b", "d"}


class TestCrossSetBehavior:
    # Small-int hash is identity, so with n_sets=2 even keys share set 0
    # and odd keys set 1 — a deterministic collision layout.
    def test_pressure_is_per_set(self):
        c = _VLRU(entries=4, assoc=2)      # 2 sets x 2 ways
        for k, t in ((0, 1.0), (2, 2.0), (1, 3.0)):
            c.fill(k, t)
        c._commit(4.0)
        assert _set_keys(c) == [[0, 2], [1]]
        c.fill(4, 5.0)                     # set 0 overflows
        c._commit(6.0)
        # Set 0 evicted its own LRU (0); set 1 untouched.
        assert _set_keys(c) == [[2, 4], [1]]
        assert c.resident == {2, 4, 1}
        assert not c.lookup(0, 7.0) and c.lookup(1, 7.0)

    def test_recency_is_per_set(self):
        c = _VLRU(entries=4, assoc=2)
        for k, t in ((0, 1.0), (2, 2.0), (1, 3.0), (3, 4.0)):
            c.fill(k, t)
        c._commit(5.0)
        assert c.lookup(0, 6.0)            # promote 0 within set 0 only
        c.fill(4, 7.0)                     # set 0 overflow evicts 2
        c.fill(5, 8.0)                     # set 1 overflow evicts 1
        c._commit(9.0)
        assert c.resident == {0, 4, 3, 5}

    def test_resident_mirrors_sets_exactly(self):
        c = _VLRU(entries=4, assoc=2)
        for k in range(10):
            c.fill(k, float(k))
            c._commit(k + 0.5)
            assert c.resident == {k for s in c._sets for k in s}
        assert len(c.resident) == 4        # both sets at capacity


class TestMutationEpoch:
    # The warm fast path proves "nothing changed since last observed" via
    # the shared epoch cell: staging and commit batches bump it, recency
    # moves must not (they never change a fast-path verdict).
    def test_fill_and_commit_bump(self):
        mut = [0]
        c = _VLRU(entries=2, assoc=2, mut=mut)
        c.fill("a", 1.0)
        assert mut[0] == 1
        c._commit(2.0)
        assert mut[0] == 2

    def test_recency_only_lookup_does_not_bump(self):
        mut = [0]
        c = _VLRU(entries=2, assoc=2, mut=mut)
        c.fill("a", 1.0)
        c._commit(2.0)
        before = mut[0]
        assert c.lookup("a", 3.0)          # hit: recency move only
        assert not c.lookup("x", 3.0)      # miss, nothing staged
        c._commit(4.0)                     # empty heap: early return
        assert mut[0] == before
