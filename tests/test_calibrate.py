"""Compute-window calibration (repro.workloads.calibrate) + the kernel tier
smoke layer that tier-1 keeps even though the full Pallas suites run in the
dedicated jax CI job.

Covers: ComputeProfile JSON round-trips (offline, no jax), the
roofline-anchored calibration invariants (total step compute preserved,
windows positive), profile threading through derive/replay/SimSession
(mismatch rejected, default path untouched), and the acceptance criterion —
the calibrated fig13 path runs end-to-end for granite and qwen3-moe.
"""
import math

import pytest

from repro.core import paper_config
from repro.core.session import SimSession
from repro.workloads import PodSpec, derive_workload, replay
from repro.workloads.calibrate import (ComputeProfile, PhaseWindow,
                                       calibrate, default_cache_path,
                                       ffn_phase, mixer_phase)


class TinyMoE:
    """Duck-typed ModelConfig stand-in (fields derive/calibrate read)."""
    name = "tiny-moe"
    n_layers = 4
    d_model = 512
    n_heads = 8
    n_kv_heads = 4
    d_head = 64
    d_ff = 0
    n_experts = 16
    top_k = 2
    d_ff_expert = 256
    moe_every = 1
    capacity_factor = 1.25


class TinyHybrid(TinyMoE):
    name = "tiny-hybrid"
    layer_pattern = ("ssm", "attn")
    n_experts = 0
    top_k = 0
    d_ff_expert = 0
    d_ff = 2048
    ssm_state = 16
    ssm_expand = 2
    ssm_head_dim = 16


def _profile(**over):
    base = dict(arch="tiny-moe", shape="decode_32k", n_gpus=8, ep=8, tp=8,
                dp=1)
    base.update(over)
    prof = ComputeProfile(**base)
    prof.phases["attn_mixer"] = PhaseWindow(
        phase="attn_mixer", kernels=("rmsnorm", "flash_attention"),
        roofline_ns=100.0, measured_wall_ns=1e6, measured_flops=1e6,
        calibrated_ns=150.0)
    prof.phases["moe_ffn"] = PhaseWindow(
        phase="moe_ffn", kernels=("grouped_matmul",),
        roofline_ns=300.0, measured_wall_ns=2e6, measured_flops=4e6,
        calibrated_ns=250.0)
    return prof


# ------------------------------------------------------------ offline layer
class TestProfileIO:
    def test_json_round_trip(self):
        prof = _profile()
        back = ComputeProfile.from_json(prof.to_json())
        assert back == prof
        assert back.window_ns("attn_mixer") == 150.0
        assert back.window_ns("nope") is None

    def test_save_load(self, tmp_path):
        p = _profile().save(tmp_path / "cal" / "p.json")
        assert ComputeProfile.load(p) == _profile()

    def test_version_mismatch_rejected(self):
        from repro.workloads.calibrate import PROFILE_VERSION
        bad = _profile().to_json().replace(
            f'"version": {PROFILE_VERSION}', '"version": 99')
        with pytest.raises(ValueError, match="version"):
            ComputeProfile.from_json(bad)

    def test_default_cache_path_is_keyed(self):
        a = default_cache_path("a", "decode_32k", 16)
        b = default_cache_path("a", "decode_32k", 64)
        assert a != b and a.suffix == ".json"

    def test_phase_naming(self):
        assert mixer_phase(TinyMoE(), 0) == "attn_mixer"
        assert ffn_phase(TinyMoE(), 0) == "moe_ffn"
        assert mixer_phase(TinyHybrid(), 0) == "ssm_mixer"
        assert mixer_phase(TinyHybrid(), 1) == "attn_mixer"
        assert ffn_phase(TinyHybrid(), 0) == "dense_ffn"


# ------------------------------------------------------- profile threading
class TestProfileThreading:
    def test_derive_uses_profile_windows(self):
        prof = _profile()
        base = derive_workload(TinyMoE(), "decode_32k", n_gpus=8)
        cal = derive_workload(TinyMoE(), "decode_32k", n_gpus=8,
                              compute_profile=prof)
        assert [c.label for c in cal.calls] == [c.label for c in base.calls]
        rss = [c for c in cal.calls if c.label.endswith("mixer_rs")]
        assert all(c.compute_ns == 150.0 for c in rss)
        assert all(c.phase == "attn_mixer" for c in rss)
        combines = [c for c in cal.calls if c.label.endswith("moe_combine")]
        assert all(c.compute_ns == 250.0 for c in combines)
        # traffic sizing is untouched — only the gaps move
        assert [c.nbytes for c in cal.calls] == [c.nbytes for c in base.calls]

    def test_derive_rejects_mismatched_profile(self):
        with pytest.raises(ValueError, match="does not match"):
            derive_workload(TinyMoE(), "decode_32k", n_gpus=16,
                            compute_profile=_profile())  # profile is g8

    def test_derive_rejects_mismatched_parallelism_split(self):
        # Same pod size, different tp/dp split: rooflines scale with the
        # split, so the profile must be refused, not silently applied.
        with pytest.raises(ValueError, match="does not match"):
            derive_workload(TinyMoE(), "decode_32k", n_gpus=8,
                            pod=PodSpec(tp=4, dp=2),
                            compute_profile=_profile())  # profile is tp=8

    def test_replay_time_application_matches_derive_time(self):
        prof = _profile()
        at_derive = replay(derive_workload(TinyMoE(), "decode_32k", n_gpus=8,
                                           n_steps=2, compute_profile=prof))
        at_replay = replay(derive_workload(TinyMoE(), "decode_32k", n_gpus=8,
                                           n_steps=2),
                           compute_profile=prof)
        for a, b in zip(at_derive.steps, at_replay.steps):
            assert a.comm_ns == b.comm_ns
            assert a.compute_ns == b.compute_ns
            assert a.walks == b.walks

    def test_replay_time_matches_derive_time_with_carried_windows(self):
        # tp == 1 folds the mixer window into the next call's gap
        # (pending_ns); window_parts must let replay-time application
        # calibrate the carried component exactly like derive-time did.
        prof = _profile(tp=1, dp=8)
        pod = PodSpec(tp=1, dp=8)
        at_derive = replay(derive_workload(TinyMoE(), "decode_32k", n_gpus=8,
                                           n_steps=2, pod=pod,
                                           compute_profile=prof))
        at_replay = replay(derive_workload(TinyMoE(), "decode_32k", n_gpus=8,
                                           n_steps=2, pod=pod),
                           compute_profile=prof)
        assert at_derive.steps[0].compute_ns > 0
        for a, b in zip(at_derive.steps, at_replay.steps):
            assert a.comm_ns == b.comm_ns
            assert a.compute_ns == b.compute_ns
            assert a.walks == b.walks

    def test_window_parts_decompose_gaps_exactly(self):
        tr = derive_workload(TinyMoE(), "decode_32k", n_gpus=8,
                             pod=PodSpec(tp=1, dp=8))
        for c in tr.calls:
            if c.window_parts:
                assert sum(ns for _, ns in c.window_parts) \
                    == pytest.approx(c.compute_ns, rel=1e-12)
            else:
                assert c.compute_ns == 0.0

    def test_session_resolve_gap(self):
        sess = SimSession(paper_config(8), compute_profile=_profile())
        assert sess.resolve_gap(7.0, "attn_mixer") == 150.0
        assert sess.resolve_gap(7.0, "unknown_phase") == 7.0
        assert sess.resolve_gap(7.0) == 7.0
        bare = SimSession(paper_config(8))
        assert bare.compute_profile is None
        assert bare.resolve_gap(7.0, "attn_mixer") == 7.0


# ------------------------------------------------------- measurement layer
class TestCalibrate:
    @pytest.fixture(scope="class")
    def tiny_profile(self):
        pytest.importorskip("jax")
        return calibrate(TinyMoE(), "decode_32k", n_gpus=8, reps=1)

    def test_phases_and_anchor(self, tiny_profile):
        prof = tiny_profile
        assert set(prof.phases) == {"attn_mixer", "moe_ffn"}
        # roofline anchor: redistribution preserves the layer-weighted step
        # total (every TinyMoE layer has both phases, so layers == 4 each)
        assert all(w.layers == 4 for w in prof.phases.values())
        total_roof = sum(w.layers * w.roofline_ns
                         for w in prof.phases.values())
        total_cal = sum(w.layers * w.calibrated_ns
                        for w in prof.phases.values())
        assert math.isclose(total_cal, total_roof, rel_tol=1e-9)
        assert all(w.calibrated_ns > 0 for w in prof.phases.values())
        assert all(w.measured_wall_ns > 0 for w in prof.phases.values())

    def test_cache_round_trip(self, tiny_profile, tmp_path):
        path = tmp_path / "tiny.json"
        tiny_profile.save(path)
        again = calibrate(TinyMoE(), "decode_32k", n_gpus=8, reps=1,
                          cache_path=path)
        # measurement skipped: the cached windows come back identically
        assert again == tiny_profile

    def test_stale_version_cache_remeasured_not_fatal(self, tiny_profile,
                                                      tmp_path):
        path = tmp_path / "tiny.json"
        path.write_text(tiny_profile.to_json().replace(
            f'"version": {tiny_profile.version}', '"version": 1'))
        prof = calibrate(TinyMoE(), "decode_32k", n_gpus=8, reps=1,
                         cache_path=path)
        # the stale cache was re-measured and overwritten in place
        assert prof.version == tiny_profile.version
        assert ComputeProfile.load(path).version == tiny_profile.version

    def test_hybrid_phases_and_weighted_anchor(self):
        # Unequal phase multiplicity (2 ssm + 2 attn mixers vs 4 dense
        # ffns): the anchor must hold on the layer-weighted step total,
        # not the per-phase sum.
        pytest.importorskip("jax")
        prof = calibrate(TinyHybrid(), "decode_32k", n_gpus=8, reps=1)
        assert set(prof.phases) == {"ssm_mixer", "attn_mixer", "dense_ffn"}
        assert prof.phases["ssm_mixer"].layers == 2
        assert prof.phases["attn_mixer"].layers == 2
        assert prof.phases["dense_ffn"].layers == 4
        total_roof = sum(w.layers * w.roofline_ns
                         for w in prof.phases.values())
        total_cal = sum(w.layers * w.calibrated_ns
                        for w in prof.phases.values())
        assert math.isclose(total_cal, total_roof, rel_tol=1e-9)

    def test_calibrated_replay_end_to_end(self, tiny_profile):
        trace = derive_workload(TinyMoE(), "decode_32k", n_gpus=8,
                                n_steps=2, compute_profile=tiny_profile)
        rep = replay(trace, compute_profile=tiny_profile)
        assert rep.cold_degradation > rep.steady_degradation
        assert rep.steps[0].compute_ns > 0


# --------------------------------------------- acceptance: real arch configs
@pytest.mark.parametrize("arch", ["granite-moe-1b-a400m",
                                  "qwen3-moe-235b-a22b"])
def test_fig13_calibrated_end_to_end(arch):
    pytest.importorskip("jax")
    prof = calibrate(arch, "decode_32k", n_gpus=16, reps=1)
    trace = derive_workload(arch, "decode_32k", n_gpus=16, n_steps=2,
                            compute_profile=prof)
    rep = replay(trace, compute_profile=prof)
    assert len(rep.steps) == 2
    assert rep.steps[0].walks > 0
    assert all(s.compute_ns > 0 for s in rep.steps)
    assert all(s.degradation > 1.0 for s in rep.steps)


# --------------------------------------------------- kernel tier smoke layer
# Minimal interpret-mode runs of all four kernels vs their oracles: tier-1
# proof the tier imports and computes on any jax this repo supports; the
# full sweeps live in tests/test_kernels.py (the blocking jax CI job).
class TestKernelSmoke:
    def test_rmsnorm(self):
        pytest.importorskip("jax")
        import jax, jax.numpy as jnp, numpy as np
        from repro.kernels import ref
        from repro.kernels.rmsnorm import rmsnorm_kernel
        x = jax.random.normal(jax.random.PRNGKey(0), (32, 64), jnp.float32)
        w = jnp.ones((64,))
        np.testing.assert_allclose(
            np.asarray(rmsnorm_kernel(x, w, interpret=True)),
            np.asarray(ref.rmsnorm_ref(x, w)), rtol=1e-5, atol=1e-5)

    def test_flash_attention(self):
        pytest.importorskip("jax")
        import jax, jax.numpy as jnp, numpy as np
        from repro.kernels import ref
        from repro.kernels.flash_attention import flash_attention_kernel
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        q = jax.random.normal(ks[0], (1, 128, 2, 64), jnp.float32)
        k = jax.random.normal(ks[1], (1, 128, 2, 64), jnp.float32)
        v = jax.random.normal(ks[2], (1, 128, 2, 64), jnp.float32)
        out = flash_attention_kernel(q, k, v, causal=True, interpret=True)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref.attention_ref(q, k, v,
                                                          causal=True)),
            rtol=2e-5, atol=2e-5)

    def test_grouped_matmul(self):
        pytest.importorskip("jax")
        import jax, jax.numpy as jnp, numpy as np
        from repro.kernels import ref
        from repro.kernels.grouped_matmul import grouped_matmul_kernel
        ks = jax.random.split(jax.random.PRNGKey(2), 2)
        lhs = jax.random.normal(ks[0], (128, 64), jnp.float32)
        rhs = jax.random.normal(ks[1], (2, 64, 128), jnp.float32)
        offs = jnp.asarray([0, 48, 128], jnp.int32)
        np.testing.assert_allclose(
            np.asarray(grouped_matmul_kernel(lhs, rhs, offs, interpret=True)),
            np.asarray(ref.grouped_matmul_ref(lhs, rhs, offs)),
            rtol=2e-5, atol=2e-5)

    def test_ssd_chunk(self):
        pytest.importorskip("jax")
        import jax, jax.numpy as jnp, numpy as np
        from repro.kernels import ref
        from repro.kernels.ssd_scan import ssd_chunk_kernel
        ks = jax.random.split(jax.random.PRNGKey(3), 5)
        G, Q, P, N = 2, 16, 8, 8
        x = jax.random.normal(ks[0], (G, Q, P), jnp.float32)
        dt = jax.nn.softplus(jax.random.normal(ks[1], (G, Q), jnp.float32))
        a = -jnp.abs(jax.random.normal(ks[2], (G, Q), jnp.float32))
        B = jax.random.normal(ks[3], (G, Q, N), jnp.float32)
        C = jax.random.normal(ks[4], (G, Q, N), jnp.float32)
        y_k, s_k = ssd_chunk_kernel(x, dt, a, B, C, interpret=True)
        y_r, s_r = ref.ssd_chunk_ref(x, dt, a, B, C)
        np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r),
                                   rtol=1e-5, atol=1e-5)
