"""Unit tests for the logical-sharding machinery (no heavy compiles)."""
import jax
import jax.numpy as jnp

from jax.sharding import PartitionSpec as P

from repro.models.base import logical_to_pspec
from repro.parallel.sharding import (WorkloadKind, rules_for, fit_pspec,
                                     cache_pspecs, batch_pspec, shard_map)
from repro.models.layers import KVCache
from repro.models.ssd import SSMCache


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape


MESH = FakeMesh({"data": 16, "model": 16})


class TestLogicalMapping:
    def test_basic(self):
        rules = rules_for(WorkloadKind.TRAIN)
        assert logical_to_pspec(("embed", "heads", "head_dim"), rules) \
            == P(("data",), "model", None)

    def test_duplicate_axis_dropped(self):
        rules = rules_for(WorkloadKind.TRAIN, seq_shard=True)
        # seq takes `model` first; heads must fall back to replication
        assert logical_to_pspec(("batch", "seq", "heads"), rules) \
            == P(("data",), "model", None)

    def test_multipod_batch(self):
        rules = rules_for(WorkloadKind.TRAIN, multi_pod=True)
        assert batch_pspec(rules, 2) == P(("pod", "data"), None)

    def test_decode_rules_shard_head_dim(self):
        rules = rules_for(WorkloadKind.DECODE)
        assert rules["head_dim"] == "model"
        assert rules["kv_heads"] is None

    def test_long_decode_shards_cache_seq(self):
        rules = rules_for(WorkloadKind.LONG_DECODE)
        assert rules["batch"] is None
        assert rules["cache_seq"] == ("data",)


class TestFitPspec:
    def test_drops_indivisible(self):
        # kv=2 cannot shard over model=16
        got = fit_pspec(P(None, "model", None), (28, 2, 128), MESH)
        assert got == P(None, None, None)

    def test_keeps_divisible(self):
        got = fit_pspec(P(("data",), "model"), (4096, 32), MESH)
        assert got == P(("data",), "model")

    def test_tuple_axis_size(self):
        mesh = FakeMesh({"pod": 2, "data": 16, "model": 16})
        got = fit_pspec(P(("pod", "data"), None), (64, 8), mesh)
        assert got == P(("pod", "data"), None)
        got = fit_pspec(P(("pod", "data"), None), (48, 8), mesh)
        assert got == P(None, None)   # 48 % 32 != 0

    def test_pads_short_spec(self):
        got = fit_pspec(P("model"), (32, 4, 4), MESH)
        assert got == P("model", None, None)


class TestCachePspecs:
    def test_kv_cache_decode(self):
        rules = rules_for(WorkloadKind.DECODE)
        kv = KVCache(
            k=jax.ShapeDtypeStruct((8, 128, 32896, 8, 128), jnp.bfloat16),
            v=jax.ShapeDtypeStruct((8, 128, 32896, 8, 128), jnp.bfloat16),
            length=jax.ShapeDtypeStruct((8,), jnp.int32))
        spec = cache_pspecs(None, {"l0": kv}, rules)["l0"]
        assert spec.k == P(None, ("data",), None, None, "model")
        assert spec.length == P(None)

    def test_ssm_cache(self):
        rules = rules_for(WorkloadKind.DECODE)
        c = SSMCache(
            conv=jax.ShapeDtypeStruct((48, 128, 3, 3328), jnp.bfloat16),
            state=jax.ShapeDtypeStruct((48, 128, 48, 64, 128), jnp.float32))
        spec = cache_pspecs(None, {"l0": c}, rules)["l0"]
        assert spec.conv == P(None, ("data",), None, None)
        assert spec.state == P(None, ("data",), None, None, None)

    def test_long_decode_seq_sharded(self):
        rules = rules_for(WorkloadKind.LONG_DECODE)
        kv = KVCache(
            k=jax.ShapeDtypeStruct((9, 1, 524416, 8, 128), jnp.bfloat16),
            v=jax.ShapeDtypeStruct((9, 1, 524416, 8, 128), jnp.bfloat16),
            length=jax.ShapeDtypeStruct((9,), jnp.int32))
        spec = cache_pspecs(None, {"l0": kv}, rules)["l0"]
        assert spec.k == P(None, None, ("data",), None, "model")


class TestOverlapPrimitives:
    """core.overlap on a single device (axis size 1: a2a == identity)."""

    def _mesh1(self):
        return jax.make_mesh((1,), ("model",))

    def test_pipelined_a2a_identity(self):
        from repro.core.overlap import pipelined_all_to_all
        mesh = self._mesh1()
        x = jnp.arange(32.0).reshape(8, 4)

        def f(x):
            return pipelined_all_to_all(x, "model", n_chunks=4)

        out = jax.jit(shard_map(
            f, mesh=mesh, in_specs=jax.sharding.PartitionSpec(),
            out_specs=jax.sharding.PartitionSpec(), check_vma=False))(x)
        assert jnp.allclose(out, x)

    def test_warmup_a2a_identity_and_compute(self):
        from repro.core.overlap import warmup_all_to_all
        mesh = self._mesh1()
        x = jnp.arange(32.0).reshape(8, 4)
        w = jnp.eye(4)

        def f(x, w):
            out, y = warmup_all_to_all(x, "model", warmup_rows=2,
                                       compute_fn=lambda a: a @ w,
                                       compute_arg=x)
            return out, y

        out, y = jax.jit(shard_map(
            f, mesh=mesh,
            in_specs=(jax.sharding.PartitionSpec(),) * 2,
            out_specs=(jax.sharding.PartitionSpec(),) * 2,
            check_vma=False))(x, w)
        assert jnp.allclose(out, x)
        assert jnp.allclose(y, x)

    def test_moe_block_ep_single_shard(self):
        from repro.models.moe import moe_block_ep, init_moe
        from repro.models.base import ParamBuilder
        from repro import configs
        cfg = configs.get_smoke_config("granite-moe-1b-a400m")
        b = ParamBuilder(jax.random.PRNGKey(0))
        init_moe(b, cfg, "moe")
        p = b.params["moe"]
        x = jax.random.normal(jax.random.PRNGKey(1), (32, cfg.d_model))
        mesh = self._mesh1()

        def f(x, wg, wu, wo, r):
            pp = {"wi_gate": wg, "wi_up": wu, "wo": wo, "router": r}
            y, aux = moe_block_ep(pp, cfg, x, "model")
            return y

        y = jax.jit(shard_map(
            f, mesh=mesh,
            in_specs=(jax.sharding.PartitionSpec(),) * 5,
            out_specs=jax.sharding.PartitionSpec(), check_vma=False))(
                x, p["wi_gate"], p["wi_up"], p["wo"], p["router"])
        assert y.shape == x.shape
        assert jnp.isfinite(y).all()
