"""Tests for the request-level serving layer (repro.serving).

Covers: seeded arrival generation (bit-for-bit determinism, also across the
serial vs pooled sweep executors — the arrival stream is data derived from
the seed, not a side effect of execution), continuous-batching invariants
(slot bounds, chunked prefill interleaving, token/latency-sample
conservation), live-batch collective sizing, the TLB-retention contract
(an idle gap longer than ``tlb_retention_ns`` between bursts re-pays the
cold walks), per-request accounting (causality, cold-vs-warm split), and
the offline (jax-free) CLI.
"""
import os
import pathlib
import subprocess
import sys

import pytest

from repro.core.config import SimConfig
from repro.serving import (DisaggPoint, Request, TrafficPoint,
                           bursty_requests, poisson_requests,
                           simulate_disagg, simulate_traffic, sweep_disagg,
                           sweep_traffic, trace_requests)
from repro.workloads import PodSpec, pod_fabric, resolve_pod
from repro.workloads.derive import StepEmitter


class TinyServeMoE:
    """Duck-typed stand-in for ModelConfig (only the fields derive reads)."""
    name = "tiny-serve-moe"
    n_layers = 4
    d_model = 512
    n_heads = 8
    n_kv_heads = 4
    d_head = 64
    d_ff = 0
    n_experts = 16
    top_k = 2
    d_ff_expert = 256
    moe_every = 1
    capacity_factor = 1.25


TINY = TinyServeMoE()


def tiny_requests(arrivals, prompt=24, output=3):
    return [Request(i, float(t), prompt, output)
            for i, t in enumerate(arrivals)]


# ---------------------------------------------------------------- arrivals
class TestArrivals:
    def test_poisson_deterministic_for_seed(self):
        a = poisson_requests(32, 100.0, seed=11)
        b = poisson_requests(32, 100.0, seed=11)
        assert a == b
        assert a != poisson_requests(32, 100.0, seed=12)

    def test_bursty_deterministic_and_bursty(self):
        a = bursty_requests(32, 100.0, seed=3, burst_size=4)
        assert a == bursty_requests(32, 100.0, seed=3, burst_size=4)
        gaps = sorted(y.arrival_ns - x.arrival_ns
                      for x, y in zip(a, a[1:]))
        # 8 bursts of 4 -> the 7 largest gaps are the off periods; their
        # mean dwarfs the mean intra-burst gap (draws are exponential, so
        # compare means, not extremes).
        inter, intra = gaps[-7:], gaps[:-7]
        assert (sum(inter) / len(inter)) > 5 * (sum(intra) / len(intra))

    def test_streams_sorted_with_positive_lengths(self):
        for reqs in (poisson_requests(64, 50.0, seed=0),
                     bursty_requests(64, 50.0, seed=0)):
            assert all(x.arrival_ns <= y.arrival_ns
                       for x, y in zip(reqs, reqs[1:]))
            assert all(r.prompt_tokens >= 1 and r.output_tokens >= 1
                       for r in reqs)
            assert [r.rid for r in reqs] == list(range(64))

    def test_trace_roundtrip(self, tmp_path):
        p = tmp_path / "trace.csv"
        p.write_text("# t,prompt,output\n1000,8,2\n\n2000,16,4\n")
        reqs = trace_requests(str(p))
        assert reqs == [Request(0, 1000.0, 8, 2), Request(1, 2000.0, 16, 4)]

    def test_trace_rids_assigned_after_sort_with_ties(self, tmp_path):
        # Regression: rids used to be assigned in *file* order before the
        # arrival sort, so an out-of-order trace produced rid sequences
        # like [2, 0, 1] — leaking file order into every rid-based
        # tie-break downstream (admission order, router affinity).
        p = tmp_path / "trace.csv"
        p.write_text("3000,32,8\n1000,8,2\n1000,16,4\n2000,24,6\n")
        reqs = trace_requests(str(p))
        assert [r.rid for r in reqs] == [0, 1, 2, 3]
        assert ([r.arrival_ns for r in reqs]
                == [1000.0, 1000.0, 2000.0, 3000.0])
        # The t=1000 tie keeps file order (stable sort).
        assert (reqs[0].prompt_tokens, reqs[1].prompt_tokens) == (8, 16)
        # limit truncates in file order first, then sorts what was kept.
        head = trace_requests(str(p), limit=2)
        assert ([(r.rid, r.arrival_ns) for r in head]
                == [(0, 1000.0), (1, 3000.0)])

    def test_invalid_parameters_raise(self):
        with pytest.raises(ValueError):
            poisson_requests(4, 0.0)
        with pytest.raises(ValueError):
            bursty_requests(4, 10.0, burstiness=1.0)
        with pytest.raises(ValueError):
            poisson_requests(4, 10.0, prompt_mean=0)


# ------------------------------------------------- batch-derived collectives
class TestLiveBatchSizing:
    def test_tp_activation_bytes_track_live_tokens(self):
        pod = resolve_pod(PodSpec(n_gpus=16), TINY, "decode")
        em = StepEmitter(TINY, pod)
        em.step(0, 1)
        em.step(1, 64)
        ag0 = next(c for c in em.calls if c.step == 0
                   and c.collective == "all_gather")
        ag1 = next(c for c in em.calls if c.step == 1
                   and c.collective == "all_gather")
        assert ag1.nbytes == 64 * ag0.nbytes
        assert ag0.nbytes == TINY.d_model * pod.dtype_bytes

    def test_ep_dispatch_bytes_track_live_tokens(self):
        pod = resolve_pod(PodSpec(n_gpus=16), TINY, "decode")
        em = StepEmitter(TINY, pod)
        em.step(0, 128)     # t_loc 8 -> capacity floor
        em.step(1, 4096)    # t_loc 256 -> capacity 40
        a2a = [c for c in em.calls if c.collective == "all_to_all"]
        small = next(c.nbytes for c in a2a if c.step == 0)
        large = next(c.nbytes for c in a2a if c.step == 1)
        assert large > small

    def test_buffers_stable_across_steps(self):
        pod = resolve_pod(PodSpec(n_gpus=16), TINY, "decode")
        em = StepEmitter(TINY, pod)
        em.step(0, 3)
        em.step(1, 17)
        bufs0 = {c.buffer for c in em.calls if c.step == 0}
        bufs1 = {c.buffer for c in em.calls if c.step == 1}
        assert bufs0 == bufs1    # same pages -> steady steps stay warm


# ------------------------------------------------------------- scheduling
class TestContinuousBatching:
    def test_slots_bound_and_conservation(self):
        reqs = tiny_requests([0.0] * 7, prompt=16, output=4)
        res = simulate_traffic(TINY, reqs, n_gpus=16, max_decode_slots=2,
                               prefill_chunk_tokens=16)
        assert all(s.decode_tokens <= 2 for s in res.steps)
        assert len(res.finished) == 7
        for r in res.requests:
            # One TTFT sample plus output_tokens-1 inter-token samples.
            assert r.tokens_out == r.req.output_tokens
            assert len(r.itl_ns) == r.req.output_tokens - 1
            assert r.ttft_ns is not None and r.ttft_ns > 0

    def test_prefill_interleaves_with_decode(self):
        reqs = tiny_requests([0.0, 1.0], prompt=64, output=8)
        res = simulate_traffic(TINY, reqs, n_gpus=16,
                               prefill_chunk_tokens=16)
        assert any(s.decode_tokens and s.prefill_tokens for s in res.steps)

    def test_chunked_prefill_spans_steps(self):
        reqs = tiny_requests([0.0], prompt=100, output=1)
        res = simulate_traffic(TINY, reqs, n_gpus=16,
                               prefill_chunk_tokens=32)
        pre = [s.prefill_tokens for s in res.steps if s.prefill_tokens]
        assert pre == [32, 32, 32, 4]
        assert len(res.finished) == 1

    def test_steps_cap_leaves_requests_unfinished(self):
        reqs = tiny_requests([0.0] * 4, prompt=16, output=50)
        res = simulate_traffic(TINY, reqs, n_gpus=16, steps_cap=5)
        assert res.steps_capped and len(res.steps) == 5
        assert len(res.finished) < 4

    def test_ideal_timeline_causal(self):
        reqs = tiny_requests([0.0, 5e8, 1e9], prompt=16, output=2)
        res = simulate_traffic(TINY, reqs, n_gpus=16)
        for r in res.requests:
            assert r.ideal_first_token_ns > r.req.arrival_ns
            assert r.ttft_degradation >= 1.0 - 1e-9

    def test_single_output_token_finishes_at_prefill_commit(self):
        # output_tokens == 1: the prefill commit *is* the finish.  The
        # request never enters the decode set, so it contributes a TTFT
        # sample but zero inter-token samples, and finish == first token.
        import math
        reqs = tiny_requests([0.0, 1000.0], prompt=16, output=1)
        res = simulate_traffic(TINY, reqs, n_gpus=16)
        assert len(res.finished) == 2
        for r in res.requests:
            assert r.tokens_out == 1
            assert r.itl_ns == [] and r.mean_itl_ns is None
            assert r.first_token_ns == r.finish_ns
            assert r.ideal_first_token_ns == r.ideal_finish_ns
        assert all(s.decode_tokens == 0 for s in res.steps)
        assert math.isnan(res.itl_percentiles()[99.0])

    def test_steps_cap_mid_prefill_excluded_from_percentiles(self):
        # A steps_cap hit mid-prefill leaves a partial RequestStats: no
        # first token, no finish — it must be excluded from finished /
        # first_token_served and every percentile, not counted as a
        # zero-latency sample.
        import math
        reqs = tiny_requests([0.0], prompt=100, output=4)
        res = simulate_traffic(TINY, reqs, n_gpus=16,
                               prefill_chunk_tokens=32, steps_cap=2)
        assert res.steps_capped and len(res.steps) == 2
        (r,) = res.requests
        assert 0 < r.prefill_done < r.req.prompt_tokens
        assert r.first_token_ns is None and not r.finished
        assert r.ttft_ns is None and r.ttft_degradation is None
        assert res.finished == [] and res.first_token_served == []
        assert res.ttft_degradations() == []
        assert math.isnan(res.ttft_percentiles()[99.0])
        assert math.isnan(res.p99_ttft_degradation)


# ------------------------------------------------------ degradation ratios
class TestDegradationAccounting:
    def _commit_one(self, arrival, t_end, ideal_t_end):
        from repro.serving import ContinuousBatcher
        b = ContinuousBatcher([Request(0, arrival, 4, 1)],
                              prefill_chunk_tokens=8)
        plan = b.plan(arrival)
        b.commit(plan, t_end, ideal_t_end, 500.0, 100.0, 1)
        return b.stats[0]

    def test_zero_ideal_ttft_is_infinite_degradation(self):
        # Regression: the ideal step can end exactly at the arrival (the
        # counterfactual serves the first token the instant the request
        # exists).  `not ideal_ttft` treated that legitimate 0.0 as a
        # missing sample, silently dropping the *worst*-degraded requests
        # from the percentiles.
        r = self._commit_one(arrival=1000.0, t_end=2000.0,
                             ideal_t_end=1000.0)
        assert r.ideal_ttft_ns == 0.0 and r.ttft_ns == 1000.0
        assert r.ttft_degradation == float("inf")
        assert r.e2e_degradation == float("inf")
        # ...and it flows into the aggregates instead of vanishing.
        from repro.serving.simulate import TrafficResult
        res = TrafficResult(arch="t", pod=None, cfg=None,
                            requests=[r], steps=[])
        assert res.ttft_degradations() == [float("inf")]
        assert res.p99_ttft_degradation == float("inf")

    def test_zero_over_zero_ttft_is_unit_degradation(self):
        r = self._commit_one(arrival=1000.0, t_end=1000.0,
                             ideal_t_end=1000.0)
        assert r.ttft_ns == 0.0 and r.ideal_ttft_ns == 0.0
        assert r.ttft_degradation == 1.0 and r.e2e_degradation == 1.0

    def test_unserved_request_still_reports_none(self):
        from repro.serving import RequestStats
        r = RequestStats(req=Request(0, 0.0, 4, 1))
        assert r.ttft_degradation is None and r.e2e_degradation is None


# --------------------------------------------------------- TLB interaction
class TestRetentionContract:
    def _run(self, retention):
        cfg = SimConfig(fabric=pod_fabric(resolve_pod(
            PodSpec(n_gpus=16), TINY, "decode")),
            tlb_retention_ns=retention)
        # Two widely separated single-request bursts; the 1s gap between
        # them dwarfs any retention window under test.
        reqs = tiny_requests([0.0, 1e9], prompt=16, output=3)
        return simulate_traffic(TINY, reqs, n_gpus=16, cfg=cfg)

    def test_idle_gap_beyond_retention_repays_cold_misses(self):
        res = self._run(retention=100_000.0)
        # First step of each burst pays the walks; the steps in between
        # ride warm entries.
        walks = [s.walks for s in res.steps]
        burst2_first = next(i for i, s in enumerate(res.steps)
                            if s.t_start >= 1e9)
        assert walks[0] > 0
        assert walks[burst2_first] == walks[0]   # full cold re-pay
        assert all(w == 0 for w in walks[1:burst2_first])
        # The split shows up per request: both requests saw cold comm.
        assert all(r.cold_comm_ns > 0 for r in res.requests)

    def test_no_retention_keeps_entries_across_gap(self):
        res = self._run(retention=None)
        burst2_first = next(i for i, s in enumerate(res.steps)
                            if s.t_start >= 1e9)
        assert res.steps[0].walks > 0
        assert res.steps[burst2_first].walks == 0
        second = res.requests[1]
        assert second.cold_comm_ns == 0 and second.warm_comm_ns > 0

    def test_cold_warm_split_partitions_comm(self):
        res = self._run(retention=100_000.0)
        # Only one request is ever active at a time here, so per-request
        # attributions partition the total comm exactly.
        total = sum(s.comm_ns for s in res.steps)
        attributed = sum(r.cold_comm_ns + r.warm_comm_ns
                         for r in res.requests)
        assert attributed == pytest.approx(total)

    def test_degradation_concentrates_after_flush(self):
        res = self._run(retention=100_000.0)
        cold = [s.degradation for s in res.steps if s.walks > 0]
        warm = [s.degradation for s in res.steps if s.walks == 0]
        assert min(cold) > max(warm)


# ------------------------------------------------- warm-fast-path engagement
class TestWarmFastPath:
    """DESIGN.md §15.2: steady-state decode runs all-warm on the vec engine.

    Acceptance criterion for the serving hot path: once prefill is done and
    the Link-TLBs hold every decode page, the vectorized warm fast path
    should serve essentially every step — surfaced per step through
    ``ServingStep.fastpath_calls``.
    """

    def _run(self, engine):
        cfg = SimConfig(fabric=pod_fabric(resolve_pod(
            PodSpec(n_gpus=16), TINY, "decode")), engine=engine)
        # One long-decode request: a single prefill chunk, then ~63 pure
        # decode steps re-touching the same warmed pages.
        reqs = tiny_requests([0.0], prompt=16, output=64)
        return simulate_traffic(TINY, reqs, n_gpus=16, cfg=cfg)

    def test_steady_state_decode_engages_fastpath(self):
        res = self._run("vectorized")
        assert len(res.steps) > 20
        assert res.fastpath_step_fraction > 0.9
        assert res.fastpath_calls > 0

    def test_event_engine_reports_zero(self):
        res = self._run("event")
        assert res.fastpath_calls == 0
        assert res.fastpath_step_fraction == 0.0


# ----------------------------------------------------------------- sweeps
class TestSweepDeterminism:
    def _points(self):
        base = dict(arch=TINY, n_requests=6, steps_cap=24,
                    prompt_mean=16, output_mean=3, retention_ns=100_000.0,
                    max_decode_slots=4, prefill_chunk_tokens=32)
        return [TrafficPoint(rps=200.0, arrival="poisson", seed=5, **base),
                TrafficPoint(rps=200.0, arrival="bursty", seed=5,
                             burst_size=3, **base)]

    def test_serial_and_pool_bit_for_bit(self):
        pts = self._points()
        serial = sweep_traffic(pts, workers=0)
        pooled = sweep_traffic(pts, workers=2)
        for pt in pts:
            a, b = serial[pt], pooled[pt]
            # Arrival generation is bit-for-bit identical...
            assert [r.req for r in a.requests] == [r.req for r in b.requests]
            # ...and so is everything priced from it.
            assert ([(s.t_start, s.t_end, s.comm_ns, s.ideal_comm_ns,
                      s.walks) for s in a.steps]
                    == [(s.t_start, s.t_end, s.comm_ns, s.ideal_comm_ns,
                         s.walks) for s in b.steps])
            assert a.ttft_percentiles() == b.ttft_percentiles()
            assert a.itl_percentiles() == b.itl_percentiles()

    def test_point_regenerates_identical_arrivals(self):
        pt = self._points()[1]
        assert pt.requests() == pt.requests()

    def test_duplicate_points_priced_once(self, monkeypatch):
        # Regression: the serial path priced duplicate points once each —
        # a sweep grid with repeated points paid for every repetition even
        # though equal points are, by construction, identical work.
        import repro.serving.simulate as sim_mod
        pts = self._points()
        calls = []
        orig = sim_mod._traffic_point

        def counting(task):
            calls.append(task)
            return orig(task)

        monkeypatch.setattr(sim_mod, "_traffic_point", counting)
        out = sim_mod.sweep_traffic([pts[0], pts[0], pts[1], pts[0]],
                                    workers=0)
        assert len(calls) == 2
        # The mapping still covers every input point (equal points are
        # equal keys) and matches a duplicate-free sweep bit-for-bit.
        assert set(out) == set(pts)
        clean = sweep_traffic(pts, workers=0)
        for pt in pts:
            assert ([(s.t_start, s.t_end) for s in out[pt].steps]
                    == [(s.t_start, s.t_end) for s in clean[pt].steps])


# ------------------------------------------------------ compute profiles
class TestProfileThreading:
    def _profile(self, calibrated_ns):
        from repro.workloads.calibrate import ComputeProfile, PhaseWindow
        phases = {ph: PhaseWindow(phase=ph, kernels=(), roofline_ns=1000.0,
                                  measured_wall_ns=1000.0,
                                  measured_flops=1.0,
                                  calibrated_ns=calibrated_ns)
                  for ph in ("attn_mixer", "moe_ffn")}
        return ComputeProfile(arch=TINY.name, shape="serving", n_gpus=16,
                              ep=16, tp=1, dp=1, phases=phases)

    def test_profile_path_threads_through_pool(self, tmp_path):
        # Regression: TrafficPoint silently dropped the compute profile —
        # the pooled worker rebuilt the point without it, so calibrated
        # sweeps diverged between the serial and pooled executors.
        path = self._profile(50_000.0).save(tmp_path / "prof.json")
        base = dict(arch=TINY, rps=200.0, arrival="poisson", seed=5,
                    n_requests=4, steps_cap=16, prompt_mean=16,
                    output_mean=2, max_decode_slots=4,
                    prefill_chunk_tokens=32)
        pt = TrafficPoint(profile_path=str(path), **base)
        bare = TrafficPoint(**base)
        serial = sweep_traffic([pt, bare], workers=0)
        pooled = sweep_traffic([pt, bare], workers=2)
        for p in (pt, bare):
            assert ([(s.t_start, s.t_end, s.compute_ns, s.comm_ns)
                     for s in serial[p].steps]
                    == [(s.t_start, s.t_end, s.compute_ns, s.comm_ns)
                        for s in pooled[p].steps])
        # The profile actually reached the session: calibrated compute
        # windows change the step timing vs the bare (roofline) point.
        assert ([s.compute_ns for s in serial[pt].steps]
                != [s.compute_ns for s in serial[bare].steps])

    def test_profile_affects_ideal_timeline_consistently(self, tmp_path):
        # Both the baseline and the ideal counterfactual see the same
        # calibrated windows, so degradation stays a pure-RAT ratio.
        path = self._profile(200_000.0).save(tmp_path / "p.json")
        reqs = tiny_requests([0.0], prompt=16, output=2)
        from repro.workloads.calibrate import ComputeProfile
        res = simulate_traffic(TINY, reqs, n_gpus=16,
                               compute_profile=ComputeProfile.load(path))
        (r,) = res.requests
        assert r.ttft_degradation is not None
        # Huge calibrated windows dominate both timelines equally, so
        # degradation is pinned near 1 even on the cold first step.
        assert 1.0 - 1e-9 <= r.ttft_degradation < 1.5


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                    # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**32 - 1),
           rps=st.floats(0.5, 1000.0),
           n=st.integers(1, 64),
           arrival=st.sampled_from(["poisson", "bursty"]))
    def test_property_arrival_generation_deterministic(seed, rps, n,
                                                       arrival):
        gen = (poisson_requests if arrival == "poisson"
               else bursty_requests)
        a, b = gen(n, rps, seed=seed), gen(n, rps, seed=seed)
        assert a == b
        assert all(x.arrival_ns <= y.arrival_ns for x, y in zip(a, a[1:]))
        assert all(r.prompt_tokens >= 1 and r.output_tokens >= 1
                   for r in a)


# -------------------------------------------------------------------- CLI
class TestCLI:
    def test_cli_runs_offline_without_jax(self):
        # The acceptance command, scaled down: must resolve the registry
        # arch, simulate, and print the percentile summary with the
        # cold-vs-warm split — all without jax ever being imported.
        code = (
            "import sys\n"
            "from repro.serving.__main__ import main\n"
            "rc = main(['--arch', 'granite-moe-1b-a400m', '--rps', '8',\n"
            "           '--steps-cap', '8', '--requests', '2',\n"
            "           '--prompt-mean', '8', '--output-mean', '2'])\n"
            "assert rc == 0, rc\n"
            "assert 'jax' not in sys.modules, 'CLI must stay jax-free'\n"
        )
        root = pathlib.Path(__file__).resolve().parent.parent
        out = subprocess.run(
            [sys.executable, "-c", code], capture_output=True, text=True,
            timeout=300,
            env={**os.environ, "PYTHONPATH": str(root / "src")},
            cwd=str(root))
        assert out.returncode == 0, out.stderr
        assert "metric,p50_us,p95_us,p99_us" in out.stdout
        assert "ttft," in out.stdout and "inter_token," in out.stdout
        assert "cold-vs-warm comm split" in out.stdout


# ------------------------------------------------------------------ fig15
@pytest.mark.slow
def test_fig15_bursty_tail_exceeds_mean():
    from benchmarks.paper_figs import fig15_serving_tail_latency
    rows = {name: derived for name, _us, derived
            in fig15_serving_tail_latency()}
    assert "p99_exceeds_mean=True" in rows[
        "fig15/check_bursty_tail_concentration"]
    assert "claws_back=True" in rows[
        "fig15/check_pretranslation_claws_back_tail"]


# ---------------------------------------------------------- disaggregation
class TinyDisaggMoE(TinyServeMoE):
    """TinyServeMoE plus the KV-sizing hook the disagg handoff reads."""
    name = "tiny-disagg-moe"

    def kv_bytes_per_token(self, dtype_bytes: int = 2) -> int:
        return (self.n_kv_heads * self.d_head * 2 * dtype_bytes
                * self.n_layers)


TINY_KV = TinyDisaggMoE()


def _disagg_cfg(retention=None, engine="event"):
    cfg = SimConfig(fabric=pod_fabric(resolve_pod(
        PodSpec(n_gpus=16), TINY_KV, "decode")), engine=engine)
    if retention is not None:
        cfg = cfg.replace(tlb_retention_ns=retention)
    return cfg


class TestDisaggHandoff:
    def test_every_multi_token_request_hands_off(self):
        reqs = tiny_requests([0.0, 1000.0, 2000.0], prompt=16, output=3)
        reqs.append(Request(3, 3000.0, 16, 1))       # single-token request
        res = simulate_disagg(TINY_KV, reqs, n_gpus=16, cfg=_disagg_cfg())
        assert sorted(h.rid for h in res.handoffs) == [0, 1, 2]
        # output_tokens <= 1 finishes at prefill, never crosses the hop
        one = res.requests[3]
        assert one.req.rid == 3 and one.kv_start_ns is None
        assert one.finished and one.first_token_ns is not None

    def test_ttft_decomposition_sums(self):
        reqs = tiny_requests([0.0, 1000.0, 2000.0], prompt=16, output=3)
        res = simulate_disagg(TINY_KV, reqs, n_gpus=16, cfg=_disagg_cfg())
        bd = res.ttft_breakdown()
        assert bd["n"] == 3
        assert bd["ttft_ns"] == pytest.approx(
            bd["prefill_ns"] + bd["kv_wait_ns"] + bd["kv_transfer_ns"]
            + bd["decode_wait_ns"])
        # per request: the transfer lands on TTFT (DESIGN.md §16.1)
        for r in res.requests[:3]:
            assert r.kv_transfer_ns > 0
            assert (r.req.arrival_ns + r.first_token_ns
                    >= r.kv_start_ns + r.kv_transfer_ns)

    def test_transfer_serialization_keeps_decode_arrivals_sorted(self):
        # A burst of simultaneous prompts: every handoff routes to the one
        # decode pod, whose link serializes them — admission must still be
        # nondecreasing (ContinuousBatcher.add asserts this itself).
        reqs = tiny_requests([0.0] * 6, prompt=16, output=3)
        res = simulate_disagg(TINY_KV, reqs, n_gpus=16, cfg=_disagg_cfg())
        starts = [h.start_ns for h in res.handoffs]
        assert starts == sorted(starts)
        assert all(r.finished for r in res.requests)

    def test_bad_split_and_router_raise(self):
        reqs = tiny_requests([0.0], prompt=16, output=2)
        with pytest.raises(ValueError):
            simulate_disagg(TINY_KV, reqs, n_gpus=16, prefill_pods=0)
        with pytest.raises(ValueError):
            simulate_disagg(TINY_KV, reqs, n_gpus=16, router="nope")


def test_disagg_retention():
    """An idle decode pod re-pays the KV-transfer walks (DESIGN.md §16.3).

    One-slot arena (kv_arena_bytes == one page-aligned shard), so both
    transfers hit the same arena offset: without retention the second
    rides the first's warmed translations; with the 5 s gap past
    ``tlb_retention_ns`` the link session flushes and re-pays in full.
    """
    reqs = tiny_requests([0.0, 5e9], prompt=64, output=3)
    arena = 2 * 2**20                                # exactly one 2 MB slot
    warm = simulate_disagg(TINY_KV, reqs, n_gpus=16, cfg=_disagg_cfg(None),
                           kv_arena_bytes=arena)
    cold = simulate_disagg(TINY_KV, reqs, n_gpus=16,
                           cfg=_disagg_cfg(1_000_000.0),
                           kv_arena_bytes=arena)
    w = {h.rid: h for h in warm.handoffs}
    c = {h.rid: h for h in cold.handoffs}
    assert w[0].offset == w[1].offset == 0           # same arena region
    assert w[0].walks > 0 and c[0].walks > 0         # first contact walks
    assert w[1].walks == 0                           # retained: warm
    assert c[1].walks == c[0].walks > 0              # flushed: full re-pay
    assert warm.kv_cold_handoffs == 1 and cold.kv_cold_handoffs == 2
    assert cold.kv_excess_total_ns > warm.kv_excess_total_ns


def _disagg_points():
    base = dict(arch=TINY_KV, n_requests=6, steps_cap=80, prompt_mean=16,
                output_mean=3, retention_ns=100_000.0, max_decode_slots=4,
                prefill_chunk_tokens=32)
    return [DisaggPoint(traffic=TrafficPoint(rps=200.0, seed=5, **base)),
            DisaggPoint(traffic=TrafficPoint(rps=200.0, arrival="bursty",
                                             seed=5, burst_size=3, **base),
                        prefill_pods=2, decode_pods=1)]


def _disagg_fingerprint(res):
    return (
        [(h.rid, h.decode_idx, h.offset, h.start_ns, h.transfer_ns,
          h.ideal_ns, h.walks) for h in res.handoffs],
        [(s.t_start, s.t_end, s.comm_ns, s.ideal_comm_ns, s.walks)
         for s in res.steps],
        res.ttft_percentiles(), res.itl_percentiles())


def test_disagg_serial_equals_pooled():
    """sweep_disagg's executors are bit-for-bit identical (DESIGN.md §16.4)."""
    pts = _disagg_points()
    serial = sweep_disagg(pts, workers=0)
    pooled = sweep_disagg(pts, workers=2)
    for pt in pts:
        assert _disagg_fingerprint(serial[pt]) == \
            _disagg_fingerprint(pooled[pt])


def test_disagg_engines_agree():
    """Event and vectorized engines price disagg bit-for-bit (DESIGN.md §16.4)."""
    reqs = tiny_requests([0.0, 500.0, 1500.0], prompt=24, output=4)
    runs = [simulate_disagg(TINY_KV, reqs, n_gpus=16,
                            cfg=_disagg_cfg(engine=eng))
            for eng in ("event", "vectorized")]
    assert _disagg_fingerprint(runs[0]) == _disagg_fingerprint(runs[1])


def test_disagg_off_colocated_bit_for_bit():
    # Regression for the colocated path: pricing a disagg deployment in
    # between must not perturb simulate_traffic (no shared mutable state);
    # the absolute colocated numbers themselves are locked by the goldens
    # (tests/test_golden_figs.py).
    pt = TrafficPoint(arch=TINY_KV, n_requests=5, rps=300.0, seed=9,
                      steps_cap=40, prompt_mean=16, output_mean=3)

    def price():
        res = sweep_traffic([pt], workers=0)[pt]
        return ([(s.t_start, s.t_end, s.comm_ns, s.walks)
                 for s in res.steps], res.ttft_percentiles())

    before = price()
    simulate_disagg(TINY_KV, pt.requests(), n_gpus=16, cfg=_disagg_cfg())
    assert price() == before


class TestDisaggCLI:
    def test_disagg_cli_offline_and_fleet_exclusive(self):
        code = (
            "import sys\n"
            "from repro.serving.__main__ import main\n"
            "rc = main(['--arch', 'granite-moe-1b-a400m', '--rps', '8',\n"
            "           '--disagg', '1:1', '--steps-cap', '40',\n"
            "           '--requests', '3', '--prompt-mean', '64',\n"
            "           '--output-mean', '2'])\n"
            "assert rc == 0, rc\n"
            "assert 'jax' not in sys.modules, 'CLI must stay jax-free'\n"
        )
        root = pathlib.Path(__file__).resolve().parent.parent
        out = subprocess.run(
            [sys.executable, "-c", code], capture_output=True, text=True,
            timeout=300,
            env={**os.environ, "PYTHONPATH": str(root / "src")},
            cwd=str(root))
        assert out.returncode == 0, out.stderr
        assert "# disagg: 1 prefill + 1 decode pods" in out.stdout
        assert "kv_transfer" in out.stdout
        bad = subprocess.run(
            [sys.executable, "-m", "repro.serving", "--arch",
             "granite-moe-1b-a400m", "--disagg", "1:1", "--fleet", "2"],
            capture_output=True, text=True, timeout=60,
            env={**os.environ, "PYTHONPATH": str(root / "src")},
            cwd=str(root))
        assert bad.returncode != 0
        assert "mutually exclusive" in bad.stderr
