"""Doc-reference lint: DESIGN.md section citations must resolve.

Code comments and docstrings cite the design contract by section number
(``DESIGN.md §12``, ``DESIGN.md §10.4``).  DESIGN.md's header warns that
renumbering sections requires updating those references; this test makes
the warning enforceable — it extracts every DESIGN-prefixed citation from
the Python trees (and the top-level READMEs) and fails, with file:line
provenance, when a cited section heading does not exist.

Bare paper references (``§6.1 fused probes`` meaning the *paper's* section
6.1) are deliberately NOT matched: only citations prefixed with
``DESIGN.md`` are claims about this repo's own document.

Standalone-runnable (no pytest needed) so the CI lint job can block on it:

    python tests/test_doc_refs.py
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

# Python trees whose comments/docstrings carry design citations, plus the
# top-level markdown that links into DESIGN.md by section.
PY_TREES = ("src", "tests", "benchmarks", "examples")
MD_FILES = ("README.md", "benchmarks/FIGURES.md")

# "DESIGN.md §12" / "DESIGN.md §10.4" (any whitespace, incl. a line wrap
# between the filename and the section marker).
CITATION = re.compile(r"DESIGN\.md\s+§(\d+(?:\.\d+)?)")
# DESIGN.md headings: "## §12 Title" / "### §10.4 Title".
HEADING = re.compile(r"^#{2,3}\s+§(\d+(?:\.\d+)?)\b", re.MULTILINE)

# Regex-rot guard: the tree is known to carry at least this many
# citations; matching fewer means the extraction broke, not that the
# repo stopped citing its design doc.
MIN_CITATIONS = 40


def design_sections() -> set:
    return set(HEADING.findall((ROOT / "DESIGN.md").read_text()))


def iter_citations():
    """Yield (relpath, lineno, section) for every DESIGN.md citation."""
    files = []
    for top in PY_TREES:
        files.extend(p for p in sorted((ROOT / top).rglob("*.py"))
                     if "__pycache__" not in p.parts)
    files.extend(ROOT / f for f in MD_FILES)
    for path in files:
        text = path.read_text()
        for m in CITATION.finditer(text):
            lineno = text.count("\n", 0, m.start()) + 1
            yield path.relative_to(ROOT), lineno, m.group(1)


def check() -> list:
    """Return a list of human-readable failure strings (empty = clean)."""
    sections = design_sections()
    failures, n = [], 0
    for relpath, lineno, sec in iter_citations():
        n += 1
        if sec not in sections:
            failures.append(f"{relpath}:{lineno}: cites DESIGN.md §{sec} "
                            f"but DESIGN.md has no such heading")
    if n < MIN_CITATIONS:
        failures.append(f"only {n} DESIGN.md citations extracted "
                        f"(expected >= {MIN_CITATIONS}) — the citation "
                        f"regex no longer matches the tree's style")
    return failures


def test_design_headings_parse():
    secs = design_sections()
    assert "1" in secs and "16" in secs, secs
    # subsection headings parse too
    assert "10.4" in secs and "16.1" in secs, secs


def test_design_section_citations_resolve():
    failures = check()
    assert not failures, "\n".join(failures)


if __name__ == "__main__":
    fails = check()
    for f in fails:
        print(f, file=sys.stderr)
    print(f"doc-ref lint: {'FAIL' if fails else 'ok'} "
          f"({len(fails)} unresolved)", file=sys.stderr)
    sys.exit(1 if fails else 0)
