"""Hypothesis property tests for the RAT simulator (repro.core).

Kept separate from ``test_core_sim.py`` so the main suite still collects when
``hypothesis`` is not installed — this module degrades to a skip.
"""
import dataclasses
import math

import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import ratsim, paper_config, simulate, MB  # noqa: E402
from repro.core.config import TLBConfig  # noqa: E402


@settings(max_examples=25, deadline=None)
@given(size_mb=st.sampled_from([1, 2, 4, 8, 16, 64]),
       n=st.sampled_from([8, 16, 32]))
def test_property_baseline_never_faster_than_ideal(size_mb, n):
    c = ratsim.compare(size_mb * MB, n)
    assert c.degradation >= 1.0 - 1e-12


@settings(max_examples=15, deadline=None)
@given(size_mb=st.sampled_from([1, 4, 16]), n=st.sampled_from([8, 16, 32]))
def test_property_request_conservation(size_mb, n):
    r = ratsim.run(size_mb * MB, n)
    ctr = r.counters
    assert sum(ctr.by_class.values()) == ctr.requests
    fab = r.config.fabric
    chunk = (size_mb * MB) // n
    expected = (fab.n_gpus - 1) * math.ceil(chunk / fab.request_bytes)
    assert ctr.requests == expected


@settings(max_examples=10, deadline=None)
@given(entries=st.sampled_from([64, 512, 4096]))
def test_property_bigger_l2_never_hurts(entries):
    cfg = paper_config(16)
    tr = dataclasses.replace(
        cfg.translation,
        l2=TLBConfig(entries=entries, assoc=2, hit_latency_ns=100.0,
                     mshr_entries=512))
    big = simulate(4 * MB, cfg.replace(translation=tr)).completion_ns
    tr_small = dataclasses.replace(
        cfg.translation,
        l2=TLBConfig(entries=16, assoc=2, hit_latency_ns=100.0,
                     mshr_entries=512))
    small = simulate(4 * MB, cfg.replace(translation=tr_small)).completion_ns
    assert big <= small * (1 + 1e-9)


@settings(max_examples=10, deadline=None)
@given(n=st.sampled_from([8, 16, 32, 64]))
def test_property_ideal_completion_is_bandwidth_bound(n):
    size = 64 * MB
    cfg = paper_config(n).ideal()
    r = simulate(size, cfg)
    fab = cfg.fabric
    chunk = size // n
    n_req = math.ceil(chunk / fab.request_bytes)
    stream = (n_req - 1) * fab.request_bytes * (n - 1) / fab.gpu_bw
    expected = fab.oneway_ns + stream + fab.hbm_ns + fab.return_ns
    assert r.completion_ns == pytest.approx(expected, rel=1e-6)


@settings(max_examples=15, deadline=None)
@given(coll=st.sampled_from(["ring_allreduce", "rd_allreduce", "all_gather",
                             "reduce_scatter", "broadcast",
                             "hier_all_to_all"]),
       size_mb=st.sampled_from([1, 4, 16]))
def test_property_patterns_never_faster_than_ideal(coll, size_mb):
    c = ratsim.compare(size_mb * MB, 16, collective=coll)
    assert c.degradation >= 1.0 - 1e-12


# ------------------------------------------------------- session properties
from repro.core import SimSession, simulate  # noqa: E402
from repro.core.config import FabricConfig, PrefetchConfig  # noqa: E402


@settings(max_examples=20, deadline=None)
@given(size_mb=st.sampled_from([1, 2, 4, 16]),
       n=st.sampled_from([8, 16, 32]),
       coll=st.sampled_from(["all_to_all", "ring_allreduce", "all_gather",
                             "broadcast"]))
def test_property_warm_rerun_never_slower(size_mb, n, coll):
    """A second identical collective on a warm session is never slower."""
    s = SimSession(paper_config(n).replace(collective=coll))
    cold = s.run(size_mb * MB)
    warm = s.run(size_mb * MB)
    assert warm.completion_ns <= cold.completion_ns + 1e-9


@settings(max_examples=15, deadline=None)
@given(size_mb=st.sampled_from([1, 4, 16]), k=st.sampled_from([1, 2, 3]),
       n=st.sampled_from([8, 16]))
def test_property_session_replay_equals_iterations(size_mb, k, n):
    """k session runs == one simulate(iterations=k) for the default
    all-to-all, bit for bit."""
    sess = SimSession(paper_config(n))
    for _ in range(k):
        sess.run(size_mb * MB)
    got = sess.result()
    one = simulate(size_mb * MB, paper_config(n).replace(iterations=k))
    assert ([i.completion_ns for i in got.iterations]
            == [i.completion_ns for i in one.iterations])
    assert got.counters.by_class == one.counters.by_class
    assert got.counters.walks == one.counters.walks


@settings(max_examples=10, deadline=None)
@given(size_mb=st.sampled_from([16, 64]), depth=st.sampled_from([1, 2, 3]))
def test_property_prefetch_depth_monotone_under_scarce_buffering(size_mb,
                                                                 depth):
    """Deeper next-page prefetch never slows a scarce-ingress collective:
    disabled >= depth d >= depth d+1 (more pages warmed ahead of the
    stream can only remove port stalls)."""
    fab = FabricConfig(n_gpus=16, ingress_entries=64)
    cfg = paper_config(16).replace(fabric=fab)
    off = simulate(size_mb * MB, cfg).completion_ns
    shallow = simulate(size_mb * MB, cfg.replace(
        prefetch=PrefetchConfig(enabled=True, depth=depth))).completion_ns
    deep = simulate(size_mb * MB, cfg.replace(
        prefetch=PrefetchConfig(enabled=True, depth=depth + 1))).completion_ns
    assert shallow <= off + 1e-9
    assert deep <= shallow + 1e-9
