"""Smoke-run the example scripts (slow CI tier).

Each example must exit 0 and say which collective/topology it ran — the
scripts previously assumed the all-to-all/single-Clos default in their
hard-coded output text.
"""
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script):
    return subprocess.run(
        [sys.executable, script], cwd=ROOT, capture_output=True, text=True,
        timeout=900)


def test_quickstart_smoke():
    r = _run("examples/quickstart.py")
    assert r.returncode == 0, r.stderr[-2000:]
    assert "collective=all_to_all" in r.stdout
    assert "topology=single_clos" in r.stdout
    assert "two_tier" in r.stdout           # the fig14 teaser section


def test_serving_traffic_smoke():
    r = _run("examples/serving_traffic.py")
    assert r.returncode == 0, r.stderr[-2000:]
    assert "bursty serving" in r.stdout
    assert "TTFT p50/p95/p99" in r.stdout
    assert "tlb_retention_ns=50us" in r.stdout


def test_workload_replay_smoke():
    pytest.importorskip("jax")              # arch registry configs need jax
    r = _run("examples/workload_replay.py")
    assert r.returncode == 0, r.stderr[-2000:]
    assert "collective=all_to_all" in r.stdout
    assert "topology=single_clos" in r.stdout
    assert "topology=two_tier" in r.stdout
    assert "collectives: all_gather, all_to_all, reduce_scatter" in r.stdout
