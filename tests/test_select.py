"""Tests for RAT-aware collective algorithm selection (repro.core.select).

Covers the registry side (logical equivalence classes populated by
@register_pattern, feasibility-filtered candidate enumeration), the three
policies (fixed defaults bit-for-bit, exhaustive auto pricing, the
serializable PolicyTable with fixed fallback), the spec-string parser, and
the threading through every consumer layer: sessions (engine and oracle),
ratsim sweeps (eager axis validation), workload derivation (provenance on
every call) and request-level serving.
"""
import json

import pytest

from repro.core import KB, MB, ratsim, simulate
from repro.core.config import FabricConfig, SimConfig, TranslationConfig
from repro.core.patterns import (LOGICAL, PATTERNS, candidates_for,
                                 get_pattern, logical_of, register_pattern)
from repro.core.ref_des import RefSession
from repro.core.select import (FIXED_DEFAULTS, AutoPolicy, FixedPolicy,
                               PolicyTable, Resolution, build_policy_table,
                               get_policy, size_bucket)
from repro.core.session import SimSession


# ---------------------------------------------------------------- registry
class TestRegistry:
    def test_logical_classes_cover_registry(self):
        # Every registered pattern belongs to exactly one logical class.
        members = [n for cls in LOGICAL.values() for n in cls]
        assert sorted(members) == sorted(PATTERNS)
        assert LOGICAL["allreduce"] == ["ring_allreduce", "rd_allreduce"]
        assert LOGICAL["all_to_all"] == ["all_to_all", "hier_all_to_all",
                                         "multipod_all_to_all"]

    def test_logical_of(self):
        assert logical_of("rd_allreduce") == "allreduce"
        assert logical_of("all_to_all") == "all_to_all"
        with pytest.raises(ValueError, match="unknown collective"):
            logical_of("bogus")

    def test_duplicate_registration_rejected(self):
        with pytest.raises(ValueError, match="duplicate"):
            @register_pattern
            class Dup(PATTERNS["all_to_all"]):
                name = "all_to_all"

    def test_get_pattern_error_names_logical_classes(self):
        # A logical name is not a concrete pattern; the error must point
        # the caller at the policy layer rather than dead-end.
        with pytest.raises(ValueError, match="logical classes"):
            get_pattern("allreduce")

    def test_candidates_filtered_by_feasibility(self):
        # Recursive doubling needs power-of-two ranks.
        assert "rd_allreduce" in candidates_for(
            "allreduce", FabricConfig(n_gpus=8))
        assert candidates_for("allreduce", FabricConfig(n_gpus=6)) \
            == ["ring_allreduce"]

    def test_candidates_accept_concrete_name(self):
        fab = FabricConfig(n_gpus=8)
        assert candidates_for("rd_allreduce", fab) \
            == candidates_for("allreduce", fab)

    def test_candidates_unknown_name_raises(self):
        with pytest.raises(ValueError, match="logical classes"):
            candidates_for("bogus", FabricConfig(n_gpus=8))


# ---------------------------------------------------------------- policies
FAB8 = FabricConfig(n_gpus=8)


class TestFixedPolicy:
    def test_resolves_historical_defaults(self):
        pol = FixedPolicy()
        for logical, default in FIXED_DEFAULTS.items():
            res = pol.resolve(logical, 1 * MB, FAB8)
            assert res == Resolution(collective=default, logical=logical,
                                     provenance="fixed")

    def test_concrete_name_passes_through(self):
        res = FixedPolicy().resolve("rd_allreduce", 1 * MB, FAB8)
        assert res.collective == "rd_allreduce"
        assert res.logical == "allreduce"
        assert res.provenance == "explicit"

    def test_override_validated(self):
        pol = FixedPolicy(overrides={"allreduce": "rd_allreduce"})
        assert pol.resolve("allreduce", 1 * MB, FAB8).collective \
            == "rd_allreduce"
        with pytest.raises(ValueError, match="unknown logical class"):
            FixedPolicy(overrides={"bogus": "ring_allreduce"})
        with pytest.raises(ValueError, match="not a member"):
            FixedPolicy(overrides={"allreduce": "all_gather"})

    def test_state_validated(self):
        with pytest.raises(ValueError, match="unknown TLB state"):
            FixedPolicy().resolve("allreduce", 1 * MB, FAB8, state="tepid")

    def test_unknown_collective_raises(self):
        with pytest.raises(ValueError, match="logical classes"):
            FixedPolicy().resolve("bogus", 1 * MB, FAB8)


class TestAutoPolicy:
    def test_picks_scored_minimum_per_state(self):
        auto = AutoPolicy()
        sc = auto.scores("allreduce", 1 * MB, FAB8)
        assert set(sc) == {"ring_allreduce", "rd_allreduce"}
        for si, state in enumerate(("cold", "warm")):
            res = auto.resolve("allreduce", 1 * MB, FAB8, state=state)
            assert res.provenance == f"auto:{state}"
            assert sc[res.collective][si] == min(v[si] for v in sc.values())

    def test_scores_match_direct_simulation(self):
        auto = AutoPolicy()
        sc = auto.scores("allreduce", 1 * MB, FAB8)
        cfg = SimConfig(fabric=FAB8, collective="ring_allreduce",
                        engine="vectorized", iterations=2, symmetric=True,
                        collect_trace=False)
        r = simulate(1 * MB, cfg)
        assert sc["ring_allreduce"] == (r.iterations[0].completion_ns,
                                        r.iterations[1].completion_ns)

    def test_memoizes_per_size_fabric_and_base(self, monkeypatch):
        import repro.core.engine as engine_mod
        calls = []
        orig = engine_mod.simulate

        def counting(nbytes, cfg):
            calls.append(cfg.collective)
            return orig(nbytes, cfg)

        monkeypatch.setattr(engine_mod, "simulate", counting)
        auto = AutoPolicy()
        auto.resolve("allreduce", 256 * KB, FAB8, state="cold")
        auto.resolve("allreduce", 256 * KB, FAB8, state="warm")
        assert len(calls) == 2          # one pricing per candidate, reused

    def test_base_config_changes_pricing(self):
        # The deployment config (here: 4 KB pages) is part of the score —
        # the cold completion pays far more walks than the 2 MB default.
        small = AutoPolicy(base=SimConfig(
            translation=TranslationConfig(page_bytes=4 * KB)))
        default = AutoPolicy()
        s4k = small.scores("allreduce", 1 * MB, FAB8)
        s2m = default.scores("allreduce", 1 * MB, FAB8)
        assert s4k["ring_allreduce"][0] > s2m["ring_allreduce"][0]

    def test_no_feasible_candidate_raises(self):
        # hier/multipod all_to_all need divisible groups; on a 2-GPU flat
        # fabric only the direct form survives — but a logical class can
        # still empty out: allreduce on n=1 has no feasible member.
        with pytest.raises(ValueError, match="no feasible"):
            AutoPolicy().resolve("allreduce", 1 * MB, FabricConfig(n_gpus=1))


def _diverging_table(nbytes=1 * MB, fab=FAB8):
    """A hand-built table: cold -> rd, warm -> ring for one bucket."""
    t = PolicyTable()
    t.entries[t.key("allreduce", nbytes, fab, "cold")] = "rd_allreduce"
    t.entries[t.key("allreduce", nbytes, fab, "warm")] = "ring_allreduce"
    return t


class TestPolicyTable:
    def test_size_bucket(self):
        assert size_bucket(1 * MB) == 20
        assert size_bucket(2 * MB - 1) == 20
        assert size_bucket(2 * MB) == 21
        assert size_bucket(0) == 0

    def test_hit_and_miss_resolution(self):
        t = _diverging_table()
        assert t.resolve("allreduce", 1 * MB, FAB8, "cold") == Resolution(
            "rd_allreduce", "allreduce", "table:cold")
        assert t.resolve("allreduce", 1 * MB, FAB8, "warm") == Resolution(
            "ring_allreduce", "allreduce", "table:warm")
        # Outside the table: fixed defaults, flagged as a miss.
        miss = t.resolve("allreduce", 64 * MB, FAB8, "cold")
        assert miss.collective == FIXED_DEFAULTS["allreduce"]
        assert miss.provenance == "table:miss"
        miss = t.resolve("all_gather", 1 * MB, FAB8, "cold")
        assert miss.provenance == "table:miss"

    def test_save_load_round_trip(self, tmp_path):
        t = build_policy_table([256 * KB, 1 * MB], [8],
                               logicals=("allreduce",))
        path = tmp_path / "table.json"
        t.save(str(path))
        back = PolicyTable.load(str(path))
        assert back.entries == t.entries
        assert back.meta == t.meta
        # get_policy's spec-string form loads the same table.
        spec = get_policy(f"table:{path}")
        assert spec.entries == t.entries

    def test_load_rejects_wrong_schema_and_unknown_collective(self, tmp_path):
        with pytest.raises(ValueError, match="policy-table-v1"):
            PolicyTable.from_json({"schema": "bogus", "entries": []})
        doc = _diverging_table().to_json()
        doc["entries"][0]["collective"] = "bogus"
        path = tmp_path / "bad.json"
        path.write_text(json.dumps(doc))
        with pytest.raises(ValueError, match="unknown collective"):
            PolicyTable.load(str(path))

    def test_builder_caches_auto_optima(self):
        auto = AutoPolicy()
        t = build_policy_table([1 * MB], [8], logicals=("allreduce",),
                               auto=auto)
        for state in ("cold", "warm"):
            assert t.resolve("allreduce", 1 * MB, FAB8, state).collective \
                == auto.resolve("allreduce", 1 * MB, FAB8, state).collective
        assert t.meta["gpu_counts"] == [8]

    def test_builder_skips_infeasible_points(self):
        # n=6: no rd candidate, but ring still prices; n=1: nothing.
        t = build_policy_table([1 * MB], [6, 1], logicals=("allreduce",))
        assert t.entries[t.key("allreduce", 1 * MB, FabricConfig(n_gpus=6),
                               "cold")] == "ring_allreduce"
        assert not any(k[3] == 1 for k in t.entries)


class TestGetPolicy:
    def test_spec_strings(self):
        assert get_policy(None) is None
        pol = FixedPolicy()
        assert get_policy(pol) is pol
        assert isinstance(get_policy("fixed"), FixedPolicy)
        assert isinstance(get_policy("auto"), AutoPolicy)
        with pytest.raises(ValueError, match="unknown policy spec"):
            get_policy("bogus")


# ---------------------------------------------------------------- sessions
class TestSessionPolicy:
    def _cfg(self, **kw):
        return SimConfig(fabric=FAB8, engine="vectorized", **kw)

    def test_fixed_policy_is_bit_for_bit(self):
        # The same call sequence with and without the policy layer: the
        # fixed defaults must reproduce the pre-policy session exactly.
        plain = SimSession(self._cfg())
        fixed = SimSession(self._cfg(), policy="fixed")
        for sess, name in ((plain, "ring_allreduce"), (fixed, "allreduce")):
            for off in (0, 8 * MB):
                sess.run(1 * MB, collective=name, base_offset=off)
        for a, b in zip(plain.records, fixed.records):
            assert a.collective == b.collective == "ring_allreduce"
            assert a.t_end == b.t_end
            assert a.counters.walks == b.counters.walks

    def test_cold_warm_keyed_on_buffer_region(self):
        t = _diverging_table()
        sess = SimSession(self._cfg(), policy=t)
        first = sess.run(1 * MB, collective="allreduce")
        again = sess.run(1 * MB, collective="allreduce")
        other = sess.run(1 * MB, collective="allreduce", base_offset=32 * MB)
        assert first.collective == "rd_allreduce"      # region cold
        assert again.collective == "ring_allreduce"    # region warm
        assert other.collective == "rd_allreduce"      # new region cold

    def test_retention_flush_demotes_to_cold(self):
        t = _diverging_table()
        sess = SimSession(self._cfg(tlb_retention_ns=10_000.0), policy=t)
        assert sess.run(1 * MB, collective="allreduce").collective \
            == "rd_allreduce"
        assert sess.run(1 * MB, collective="allreduce",
                        gap_ns=1_000.0).collective == "ring_allreduce"
        # A gap past retention flushes the TLBs before resolution.
        assert sess.run(1 * MB, collective="allreduce",
                        gap_ns=50_000.0).collective == "rd_allreduce"

    def test_explicit_name_pins_under_any_policy(self):
        sess = SimSession(self._cfg(), policy=_diverging_table())
        rec = sess.run(1 * MB, collective="ring_allreduce")
        assert rec.collective == "ring_allreduce"

    def test_oracle_session_resolves_identically(self):
        # The oracle-equivalence contract extends to policy-chosen
        # algorithms: both sessions pick the same sequence and agree on
        # walks (and closely on completion).
        t = _diverging_table(256 * KB)
        cfg = SimConfig(fabric=FAB8)
        sim = SimSession(cfg, policy=t)
        ref = RefSession(cfg, policy=t)
        for _ in range(2):
            a = sim.run(256 * KB, collective="allreduce")
            b = ref.run(256 * KB, collective="allreduce")
            assert a.collective == b.collective
            assert a.counters.walks == b.counters.walks
            assert a.completion_ns == pytest.approx(b.completion_ns,
                                                    rel=0.05)
        assert [r.collective for r in sim.records] \
            == ["rd_allreduce", "ring_allreduce"]

    def test_ratsim_session_accepts_policy_spec(self):
        s = ratsim.session(8, engine="vectorized", policy="fixed")
        assert s.run(1 * MB, collective="allreduce").collective \
            == "ring_allreduce"


# ------------------------------------------------------- ratsim validation
class TestSweepValidation:
    def test_run_with_policy_matches_concrete(self):
        a = ratsim.run(1 * MB, 8, collective="allreduce", policy="fixed")
        b = ratsim.run(1 * MB, 8, collective="ring_allreduce")
        assert a.completion_ns == b.completion_ns
        assert a.counters.walks == b.counters.walks

    def test_sweep_rejects_unknown_collective_eagerly(self):
        with pytest.raises(ValueError, match="unknown collective 'bogus'"):
            ratsim.sweep([1 * MB], [8], collectives=["bogus"], workers=0)

    def test_sweep_rejects_unknown_topology_eagerly(self):
        with pytest.raises(ValueError, match="unknown topology"):
            ratsim.sweep([1 * MB], [8], topologies=["bogus"], workers=0)

    def test_sweep_rejects_unknown_engine_eagerly(self):
        with pytest.raises(ValueError, match="unknown engine"):
            ratsim.sweep([1 * MB], [8], engine="bogus", workers=0)

    def test_sweep_logical_collective_needs_policy(self):
        with pytest.raises(ValueError, match="needs a policy"):
            ratsim.sweep([1 * MB], [8], collectives=["allreduce"], workers=0)

    def test_sweep_logical_collective_with_policy(self):
        got = ratsim.sweep([1 * MB], [8], collectives=["allreduce"],
                           policy="fixed", workers=0)
        ref = ratsim.sweep([1 * MB], [8], collectives=["ring_allreduce"],
                           workers=0)
        assert got[("allreduce", 8, 1 * MB)].baseline.completion_ns \
            == ref[("ring_allreduce", 8, 1 * MB)].baseline.completion_ns

    def test_run_logical_without_policy_raises(self):
        with pytest.raises(ValueError, match="logical classes"):
            ratsim.run(1 * MB, 8, collective="allreduce")


# ------------------------------------------------------- derivation layer
class TinyMoE:
    """Duck-typed stand-in for ModelConfig (only the fields derive reads)."""
    name = "tiny-moe"
    n_layers = 4
    d_model = 512
    n_heads = 8
    n_kv_heads = 4
    d_head = 64
    d_ff = 0
    n_experts = 16
    top_k = 2
    d_ff_expert = 256
    moe_every = 1
    capacity_factor = 1.25


class TestDerivePolicy:
    def test_default_equals_explicit_fixed(self):
        from repro.workloads import derive_workload
        base = derive_workload(TinyMoE(), "train_4k", n_gpus=16)
        fixed = derive_workload(TinyMoE(), "train_4k", n_gpus=16,
                                policy="fixed")
        assert [(c.collective, c.nbytes, c.label, c.buffer, c.stride)
                for c in base.calls] \
            == [(c.collective, c.nbytes, c.label, c.buffer, c.stride)
                for c in fixed.calls]

    def test_every_call_carries_provenance(self):
        from repro.workloads import derive_workload
        tr = derive_workload(TinyMoE(), "train_4k", n_gpus=16,
                             policy="fixed")
        assert all(c.logical and c.resolved_by for c in tr.calls)
        grads = [c for c in tr.calls if c.logical == "allreduce"]
        assert grads
        assert all(c.collective == "ring_allreduce"
                   and c.resolved_by == "fixed" for c in grads)

    def test_emitter_tracks_buffer_warmth(self):
        from repro.workloads import PodSpec
        from repro.workloads.derive import StepEmitter, resolve_pod
        pod = resolve_pod(PodSpec(n_gpus=8), TinyMoE(), "decode")
        em = StepEmitter(TinyMoE(), pod, policy=_diverging_table())
        em.emit("l0", "allreduce", 1 * MB, pod.n_gpus, 0.0, "grad", 0)
        em.emit("l1", "allreduce", 1 * MB, pod.n_gpus, 0.0, "grad", 0)
        em.mark_cold()
        em.emit("l2", "allreduce", 1 * MB, pod.n_gpus, 0.0, "grad", 0)
        assert [c.collective for c in em.calls] \
            == ["rd_allreduce", "ring_allreduce", "rd_allreduce"]
        assert [c.resolved_by for c in em.calls] \
            == ["table:cold", "table:warm", "table:cold"]


# ---------------------------------------------------------------- serving
class TinyServeMoE(TinyMoE):
    name = "tiny-serve-moe"


class TestServingPolicy:
    def test_fixed_policy_traffic_is_bit_for_bit(self):
        from repro.serving.simulate import TrafficPoint, _traffic_point
        base = dict(arch=TinyServeMoE(), rps=200.0, n_requests=4,
                    steps_cap=16, seed=3, prompt_mean=16, output_mean=3,
                    max_decode_slots=4, prefill_chunk_tokens=32)
        plain = _traffic_point((TrafficPoint(**base),))
        fixed = _traffic_point((TrafficPoint(policy="fixed", **base),))
        assert [s.comm_ns for s in plain.steps] \
            == [s.comm_ns for s in fixed.steps]
        assert plain.ttft_percentiles() == fixed.ttft_percentiles()


# --------------------------------------------------------------- fig (slow)
@pytest.mark.slow
def test_fig17_divergence_and_table_gain():
    """The fig17 acceptance criteria: at least one (collective, size,
    topology) point where the cold optimum differs from the warm optimum,
    and the table policy strictly beating the fixed default end-to-end
    through a policy-threaded session on that point."""
    import benchmarks.paper_figs as pf
    rows = {name: derived for (name, _val, derived)
            in pf.fig17_algorithm_selection()}
    assert "any=True" in rows["fig17/check_cold_warm_optima_diverge"]
    check = rows["fig17/check_table_beats_fixed_default"]
    assert "strict=True" in check
