"""Tests for the Reverse Address Translation simulator (repro.core).

Hypothesis-based property tests live in ``test_core_properties.py`` so this
module collects even when ``hypothesis`` is not installed.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import (ratsim, paper_config, simulate, simulate_ref,
                        KB, MB)
from repro.core.config import (FabricConfig, TranslationConfig, TLBConfig,
                               PreTranslationConfig, PrefetchConfig)
from repro.core.tlb import LRUCache, PTWPool, TranslationState
from repro.core.cost_model import CostModel
from repro.core.scheduler import TranslationAwareScheduler


# ---------------------------------------------------------------- unit: LRU
class TestLRUCache:
    def test_hit_after_fill(self):
        c = LRUCache(entries=4, assoc=0)
        assert not c.lookup("a", t=0.0)
        c.fill("a", fill_time=10.0)
        assert not c.lookup("a", t=5.0)   # fill not landed yet
        assert c.lookup("a", t=10.0)

    def test_lru_eviction_fully_assoc(self):
        c = LRUCache(entries=2, assoc=0)
        c.fill("a", 0.0); c.fill("b", 1.0)
        assert c.lookup("a", 2.0)         # a is now MRU
        c.fill("c", 3.0)
        assert not c.lookup("b", 4.0)     # b was LRU -> evicted
        assert c.lookup("a", 4.0) and c.lookup("c", 4.0)

    def test_set_assoc_conflicts(self):
        c = LRUCache(entries=4, assoc=2)  # 2 sets x 2 ways
        keys = [0, 2, 4]                  # all map to set 0 (ints hash to self)
        for i, k in enumerate(keys):
            c.fill(k, float(i))
        assert not c.lookup(0, 10.0)      # evicted by 4
        assert c.lookup(2, 10.0) and c.lookup(4, 10.0)

    def test_earlier_fill_wins(self):
        c = LRUCache(entries=4, assoc=0)
        c.fill("a", 100.0)
        c.fill("a", 50.0)
        assert c.lookup("a", 60.0)


class TestPTWPool:
    def _walk(self, p, t, busy_ns):
        start = p.start(t)
        p.finish(start + busy_ns)
        return start

    def test_serializes_beyond_capacity(self):
        p = PTWPool(2)
        assert self._walk(p, 0.0, 100.0) == 0.0
        assert self._walk(p, 0.0, 100.0) == 0.0
        assert self._walk(p, 0.0, 100.0) == 100.0  # third walk waits

    def test_parallel_within_capacity(self):
        p = PTWPool(100)
        starts = [self._walk(p, 5.0, 1000.0) for _ in range(100)]
        assert all(s == 5.0 for s in starts)

    def test_walk_latency_computed_from_actual_start(self):
        # A queued walk's PWC lookups must be timestamped at the walker's
        # real start time, not the request time: a PWC fill landing between
        # request and start is visible to the delayed walk.
        cfg = TranslationConfig()
        s = TranslationState(dataclasses.replace(cfg, n_ptw=1),
                             n_stations=16)
        r1 = s.access(0, page=0, t=0.0)          # cold full walk
        # Second walk on a *distinct upper-level region* requested while the
        # single walker is busy: it starts at r1.resolve.  Its PWC lookups
        # happen after r1's fills landed, so upper levels hit.
        r2 = s.access(1, page=1, t=1.0)
        assert r2.klass == "walk"
        walk1_start = 1.0 + cfg.l1.hit_latency_ns + cfg.l2.hit_latency_ns
        assert r1.resolve > walk1_start          # walker genuinely busy
        pwc = cfg.pwc
        warm_lat = (len(pwc.entries) * pwc.lookup_latency_ns
                    + cfg.mem_access_ns)         # all-PWC-hit + leaf read
        assert r2.resolve == pytest.approx(r1.resolve + warm_lat)


# ----------------------------------------------------- unit: hierarchy walk
class TestTranslationState:
    def cfg(self):
        return TranslationConfig()

    def test_cold_walk_then_l1_hit(self):
        s = TranslationState(self.cfg(), n_stations=16)
        r1 = s.access(0, page=7, t=0.0)
        assert r1.klass == "walk"
        # cold: l1 50 + l2 100 + 4x(50+270) PWC misses + 270 leaf = 1700
        assert r1.resolve == pytest.approx(50 + 100 + 4 * 320 + 270)
        r2 = s.access(0, page=7, t=r1.resolve + 1)
        assert r2.klass == "l1_hit"
        assert r2.resolve == pytest.approx(r1.resolve + 1 + 50)

    def test_mshr_hit_under_miss(self):
        s = TranslationState(self.cfg(), n_stations=16)
        r1 = s.access(0, page=7, t=0.0)
        r2 = s.access(0, page=7, t=10.0)
        assert r2.klass == "l1_mshr_hum"
        assert r2.resolve == pytest.approx(r1.resolve)

    def test_l2_coalescing_across_stations(self):
        s = TranslationState(self.cfg(), n_stations=16)
        r1 = s.access(0, page=7, t=0.0)
        r2 = s.access(1, page=7, t=10.0)   # other station, same pending walk
        assert r2.klass == "l2_hum"
        assert r2.resolve == pytest.approx(r1.resolve)
        r3 = s.access(2, page=7, t=r1.resolve + 1)  # after fill: L2 hit
        assert r3.klass == "l2_hit"

    def test_warm_pwc_shortens_walk(self):
        s = TranslationState(self.cfg(), n_stations=16)
        r1 = s.access(0, page=0, t=0.0)
        t2 = r1.resolve + 10
        r2 = s.access(0, page=1, t=t2)     # adjacent page: PWC all hit
        assert r2.klass == "walk"
        assert r2.resolve - t2 == pytest.approx(50 + 100 + 4 * 50 + 270)

    def test_disabled_is_zero_latency(self):
        cfg = dataclasses.replace(self.cfg(), enabled=False)
        s = TranslationState(cfg, n_stations=16)
        r = s.access(0, page=7, t=123.0)
        assert r.resolve == 123.0


# --------------------------------------------- epoch engine vs reference DES
VALIDATION_CASES = [(8, 256 * KB), (8, 1 * MB), (8, 4 * MB),
                    (16, 1 * MB), (16, 4 * MB), (16, 16 * MB)]


@pytest.mark.parametrize("n,size", VALIDATION_CASES)
def test_epoch_engine_matches_reference_des(n, size):
    cfg = paper_config(n)
    a = simulate(size, cfg)
    b = simulate_ref(size, cfg)
    assert a.completion_ns == pytest.approx(b.completion_ns, rel=0.05)
    assert a.counters.walks == b.counters.walks
    assert a.counters.requests == b.counters.requests


@pytest.mark.parametrize("n,size", VALIDATION_CASES)
def test_ideal_matches_reference(n, size):
    # The reference DES models per-station arrival-phase bunching (momentary
    # over-line-rate arrival, ~ns-scale) that the epoch engine smooths over;
    # everything else is identical, so agreement is sub-0.5%.
    cfg = paper_config(n).ideal()
    a = simulate(size, cfg)
    b = simulate_ref(size, cfg)
    assert a.completion_ns == pytest.approx(b.completion_ns, rel=0.005)


# ----------------------------------------------------------- paper's claims
class TestPaperClaims:
    def test_fig4_small_collectives_degrade_up_to_1_4x(self):
        degs = [ratsim.compare(1 * MB, n).degradation for n in (8, 16, 32, 64)]
        assert max(degs) > 1.35
        assert all(1.30 < d < 1.50 for d in degs)

    def test_fig4_16mb_around_1_1x(self):
        degs = [ratsim.compare(16 * MB, n).degradation for n in (8, 16, 32, 64)]
        assert all(1.05 < d < 1.20 for d in degs)

    def test_fig4_overhead_diminishes_with_size(self):
        sizes = [1 * MB, 4 * MB, 16 * MB, 64 * MB, 256 * MB]
        degs = [ratsim.compare(s, 16).degradation for s in sizes]
        assert degs == sorted(degs, reverse=True)
        assert degs[-1] < 1.02

    def test_fig5_mean_rat_latency_declines(self):
        lats = [ratsim.compare(s, 16).baseline.mean_rat_ns
                for s in (1 * MB, 16 * MB, 256 * MB)]
        assert lats[0] > 5 * lats[-1]

    def test_fig6_rat_fraction_high_for_small(self):
        c = ratsim.compare(1 * MB, 16)
        assert 0.2 < c.rat_fraction < 0.5     # paper: ~30% at 1 MB
        c_big = ratsim.compare(64 * MB, 16)
        assert c_big.rat_fraction < c.rat_fraction / 2

    def test_fig7_over_90pct_l1_level_hits(self):
        for s in (1 * MB, 16 * MB, 64 * MB):
            ctr = ratsim.run(s, 16).counters
            l1_level = ctr.by_class["l1_hit"] + ctr.by_class["l1_mshr_hum"]
            assert l1_level / ctr.requests > 0.90

    def test_fig8_l1_hits_dominate_as_size_grows(self):
        fr = []
        for s in (1 * MB, 16 * MB, 64 * MB):
            ctr = ratsim.run(s, 16).counters
            fr.append(ctr.by_class["l1_hit"] / ctr.requests)
        assert fr[0] < fr[1] < fr[2]
        assert fr[2] > 0.9

    def test_fig9_1mb_all_requests_high_latency(self):
        cfg = paper_config(16).replace(collect_trace=True)
        r = simulate(1 * MB, cfg)
        # cold page walks gate (nearly) every request of a 1 MB collective
        assert np.median(r.trace) > 500.0

    def test_fig10_256mb_spikes_only_at_cold_pages(self):
        cfg = paper_config(16).replace(collect_trace=True)
        r = simulate(256 * MB, cfg)
        l1_lat = cfg.translation.l1.hit_latency_ns
        spike_frac = np.mean(r.trace > 4 * l1_lat)
        assert spike_frac < 0.05               # rare spikes
        assert r.trace.max() > 1000.0          # ...but cold walks exist

    def test_fig11_l2_sizing_beyond_gpu_count_useless(self):
        degs = {}
        for entries in (32, 512, 32768):
            cfg = paper_config(32)
            tr = dataclasses.replace(
                cfg.translation,
                l2=TLBConfig(entries=entries, assoc=2, hit_latency_ns=100.0,
                             mshr_entries=512))
            degs[entries] = ratsim.compare(
                16 * MB, 32, cfg=cfg.replace(translation=tr)).degradation
        assert degs[512] == pytest.approx(degs[32], rel=0.01)
        assert degs[32768] == pytest.approx(degs[32], rel=0.01)


# ------------------------------------------------------------- optimizations
class TestOptimizations:
    def test_pretranslation_recovers_small_collectives(self):
        base = ratsim.compare(1 * MB, 16)
        cfg = paper_config(16).replace(pretranslation=PreTranslationConfig(
            enabled=True, lead_time_ns=3000.0, pages_per_flow=0))
        opt = simulate(1 * MB, cfg)
        deg_opt = opt.completion_ns / base.ideal.completion_ns
        assert base.degradation > 1.3
        assert deg_opt < 1.05

    def test_prefetch_helps_under_scarce_buffering(self):
        # With a small ingress buffer, mid-stream page walks stall the port;
        # next-page prefetch hides them (paper §6.2).
        fab = FabricConfig(n_gpus=16, ingress_entries=64)
        cfg = paper_config(16).replace(fabric=fab)
        base = simulate(64 * MB, cfg)
        opt = simulate(64 * MB, cfg.replace(
            prefetch=PrefetchConfig(enabled=True, depth=2)))
        assert opt.completion_ns < base.completion_ns

    def test_probes_do_not_count_as_requests(self):
        cfg = paper_config(16).replace(pretranslation=PreTranslationConfig(
            enabled=True, lead_time_ns=3000.0, pages_per_flow=0))
        base = simulate(1 * MB, paper_config(16))
        opt = simulate(1 * MB, cfg)
        assert opt.counters.requests == base.counters.requests
        assert opt.counters.probes > 0


# ---------------------------------------------------------------- cost model
class TestCostModel:
    def test_tracks_simulator_within_10pct(self):
        m = CostModel(paper_config(16))
        for s, (mod, sim, err) in m.validate(
                [1 * MB, 4 * MB, 16 * MB, 64 * MB]).items():
            assert err < 0.10, f"{s}: model {mod} vs sim {sim}"

    def test_degradation_shape(self):
        m = CostModel(paper_config(16))
        d1, d16 = m.degradation(1 * MB), m.degradation(16 * MB)
        assert d1 > d16 > 1.0


class TestScheduler:
    def test_warmup_plan_for_moe_sized_collective(self):
        s = TranslationAwareScheduler(n_gpus=16, overlap_compute_ns=5e3)
        plan = s.plan_all_to_all(total_bytes=8 * MB)
        assert plan.warmup_chunk_bytes > 0
        assert plan.est_time_ns <= plan.est_time_unscheduled_ns
        assert plan.per_peer_buffer_bytes == 2 * MB   # one page per peer

    def test_no_warmup_without_compute_window(self):
        s = TranslationAwareScheduler(n_gpus=16, overlap_compute_ns=0.0)
        plan = s.plan_all_to_all(total_bytes=8 * MB)
        assert plan.warmup_chunk_bytes == 0
        assert plan.n_chunks >= 1


# -------------------------------------------------------- sweep memoization
class TestSweepMemoization:
    """The dedup bookkeeping of ratsim.sweep: duplicate grid points collapse
    through ``seen_inflight``, a caller-supplied ``cache`` memoizes across
    calls, and the serial and pool paths produce identical keys/values."""

    def _spy(self, monkeypatch):
        calls = []
        real = ratsim._sweep_point

        def spy(task):
            calls.append(task[0])
            return real(task)

        monkeypatch.setattr(ratsim, "_sweep_point", spy)
        return calls

    def test_duplicate_grid_points_priced_once(self, monkeypatch):
        calls = self._spy(monkeypatch)
        out = ratsim.sweep([1 * MB, 1 * MB], [8, 8], workers=0)
        assert set(out) == {(8, 1 * MB)}
        assert calls == [(8, 1 * MB)]          # one simulation, four entries
        assert out[(8, 1 * MB)].baseline.completion_ns > 0

    def test_inflight_dedup_fans_result_to_all_keys(self, monkeypatch):
        # Duplicates within one call share one Comparison object via the
        # seen_inflight bookkeeping (no cache needed).
        calls = self._spy(monkeypatch)
        out = ratsim.sweep([1 * MB], [8, 8, 8], workers=0)
        assert len(calls) == 1
        assert out[(8, 1 * MB)] is not None

    def test_cache_memoizes_across_calls(self, monkeypatch):
        calls = self._spy(monkeypatch)
        cache = {}
        first = ratsim.sweep([1 * MB, 4 * MB], [8], cache=cache, workers=0)
        assert len(calls) == 2 and len(cache) == 2
        for (nbytes, cfg_repr) in cache:       # keyed by (nbytes, repr(cfg))
            assert isinstance(nbytes, int) and isinstance(cfg_repr, str)
        second = ratsim.sweep([1 * MB, 4 * MB], [8], cache=cache, workers=0)
        assert len(calls) == 2                 # nothing re-simulated
        for k in first:
            assert second[k] is first[k]       # the very same objects

    def test_cache_respects_config_identity(self, monkeypatch):
        # Same (n, size) under a different collective is a different point:
        # the cache must not alias them.
        calls = self._spy(monkeypatch)
        cache = {}
        a = ratsim.sweep([1 * MB], [8], cache=cache, workers=0)
        b = ratsim.sweep([1 * MB], [8], collectives=["ring_allreduce"],
                         cache=cache, workers=0)
        assert len(calls) == 2 and len(cache) == 2
        assert (a[(8, 1 * MB)].baseline.completion_ns
                != b[("ring_allreduce", 8, 1 * MB)].baseline.completion_ns)

    def test_serial_and_pool_paths_identical(self):
        sizes, gpus = [1 * MB, 4 * MB], [8, 16]
        serial = ratsim.sweep(sizes, gpus, workers=0)
        pooled = ratsim.sweep(sizes, gpus, workers=2)
        assert set(serial) == set(pooled)
        for k in serial:
            assert (serial[k].baseline.completion_ns
                    == pooled[k].baseline.completion_ns)
            assert (serial[k].ideal.completion_ns
                    == pooled[k].ideal.completion_ns)
            assert (serial[k].baseline.counters.walks
                    == pooled[k].baseline.counters.walks)

    def test_cache_hits_skip_the_pool_entirely(self, monkeypatch):
        cache = {}
        ratsim.sweep([1 * MB], [8], cache=cache, workers=0)

        def boom(task):  # pragma: no cover - must never run
            raise AssertionError("cache hit should not re-simulate")

        monkeypatch.setattr(ratsim, "_sweep_point", boom)
        out = ratsim.sweep([1 * MB], [8], cache=cache, workers=0)
        assert out[(8, 1 * MB)].baseline.completion_ns > 0
