"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracles.

Sweeps shapes and dtypes per the harness requirement; tolerances follow the
compute dtype (kernels accumulate in f32 internally).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_kernel
from repro.kernels.grouped_matmul import grouped_matmul_kernel
from repro.kernels.rmsnorm import rmsnorm_kernel

KEY = jax.random.PRNGKey(42)


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


# ------------------------------------------------------------ flash attention
ATTN_SHAPES = [
    # (B, Sq, Sk, H, KV, Dh, causal)
    (1, 128, 128, 4, 4, 64, True),
    (2, 256, 256, 8, 2, 64, True),      # GQA group=4
    (1, 256, 256, 4, 1, 128, True),     # MQA
    (2, 128, 128, 4, 4, 128, False),    # bidirectional (encoder)
    (1, 512, 512, 2, 2, 64, True),      # multi k-block online softmax
]


@pytest.mark.parametrize("B,Sq,Sk,H,KV,Dh,causal", ATTN_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(B, Sq, Sk, H, KV, Dh, causal, dtype):
    ks = jax.random.split(
        jax.random.fold_in(KEY, abs(hash((B, Sq, H, KV, Dh))) % (2**31)), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, Dh), dtype)
    k = jax.random.normal(ks[1], (B, Sk, KV, Dh), dtype)
    v = jax.random.normal(ks[2], (B, Sk, KV, Dh), dtype)
    out = flash_attention_kernel(q, k, v, causal=causal, block_q=128,
                                 block_k=128, interpret=True)
    expect = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **tol(dtype))


def test_flash_attention_block_shape_sweep():
    q = jax.random.normal(KEY, (1, 256, 2, 64), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (1, 256, 2, 64), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (1, 256, 2, 64), jnp.float32)
    expect = ref.attention_ref(q, k, v, causal=True)
    for bq, bk in [(64, 64), (128, 64), (64, 128), (256, 256), (128, 256)]:
        out = flash_attention_kernel(q, k, v, causal=True, block_q=bq,
                                     block_k=bk, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=2e-5, atol=2e-5,
                                   err_msg=f"block {bq}x{bk}")


# ------------------------------------------------------------ grouped matmul
GMM_SHAPES = [
    # (T, D, F, E)
    (256, 64, 128, 4),
    (512, 128, 256, 8),
    (128, 256, 128, 2),
    (384, 64, 128, 6),      # T not a power of two (3 tiles)
]


@pytest.mark.parametrize("T,D,F,E", GMM_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_grouped_matmul_matches_ref(T, D, F, E, dtype):
    ks = jax.random.split(jax.random.fold_in(KEY, abs(hash((T, D, F, E))) % (2**31)), 3)
    lhs = jax.random.normal(ks[0], (T, D), dtype)
    rhs = jax.random.normal(ks[1], (E, D, F), dtype) / np.sqrt(D)
    # random ragged group sizes summing to T (some possibly empty)
    cuts = np.sort(np.asarray(
        jax.random.randint(ks[2], (E - 1,), 0, T + 1)))
    offs = jnp.asarray(np.concatenate([[0], cuts, [T]]), jnp.int32)
    out = grouped_matmul_kernel(lhs, rhs, offs, block_t=128, block_f=128,
                                interpret=True)
    expect = ref.grouped_matmul_ref(lhs, rhs, offs)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **tol(dtype))


def test_grouped_matmul_empty_groups():
    lhs = jax.random.normal(KEY, (256, 64), jnp.float32)
    rhs = jax.random.normal(jax.random.fold_in(KEY, 1), (4, 64, 128), jnp.float32)
    offs = jnp.asarray([0, 0, 256, 256, 256], jnp.int32)  # all rows -> expert 1
    out = grouped_matmul_kernel(lhs, rhs, offs, interpret=True)
    expect = lhs @ rhs[1]
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-5, atol=2e-5)


def test_grouped_matmul_uncovered_rows_zero_filled():
    """Rows no expert group claims must come back zero, not garbage: the
    accumulator is zero-initialized at e == 0 and written out unconditionally
    at e == E-1, with every non-overlapping expert skipped by pl.when."""
    lhs = jax.random.normal(KEY, (256, 64), jnp.float32)
    rhs = jax.random.normal(jax.random.fold_in(KEY, 1), (4, 64, 128), jnp.float32)
    # all groups empty
    out = np.asarray(grouped_matmul_kernel(
        lhs, rhs, jnp.zeros((5,), jnp.int32), interpret=True))
    assert (out == 0).all()
    # offsets end short of T: the uncovered tail tiles stay zero
    offs = jnp.asarray([0, 64, 64, 64, 64], jnp.int32)
    out = np.asarray(grouped_matmul_kernel(lhs, rhs, offs, interpret=True))
    np.testing.assert_allclose(out[:64], np.asarray(lhs[:64] @ rhs[0]),
                               rtol=2e-5, atol=2e-5)
    assert (out[64:] == 0).all()


# ------------------------------------------------------------------ SSD scan
SSD_SHAPES = [
    # (b, S, H, P, N, chunk)
    (1, 64, 2, 16, 16, 16),
    (2, 128, 4, 32, 64, 32),
    (1, 256, 2, 64, 128, 64),
]


@pytest.mark.parametrize("b,S,H,P,N,chunk", SSD_SHAPES)
def test_ssd_scan_matches_model_oracle(b, S, H, P, N, chunk):
    from repro.models.ssd import ssd_chunked
    ks = jax.random.split(
        jax.random.fold_in(KEY, abs(hash((b, S, H, P, N))) % (2**31)), 5)
    x = jax.random.normal(ks[0], (b, S, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, S, H), jnp.float32))
    A_log = jax.random.normal(ks[2], (H,), jnp.float32) * 0.5
    B = jax.random.normal(ks[3], (b, S, N), jnp.float32) / np.sqrt(N)
    C = jax.random.normal(ks[4], (b, S, N), jnp.float32) / np.sqrt(N)
    y_k, s_k = ops.ssd_scan(x, dt, A_log, B, C, chunk=chunk)
    y_m, s_m = ssd_chunked(x, dt, A_log, B, C, chunk)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_m),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_m),
                               rtol=1e-4, atol=1e-4)


def test_ssd_intra_chunk_kernel_vs_ref():
    from repro.kernels.ssd_scan import ssd_chunk_kernel
    ks = jax.random.split(KEY, 5)
    G, Q, P, N = 6, 32, 16, 24
    x = jax.random.normal(ks[0], (G, Q, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (G, Q), jnp.float32))
    a = -jnp.abs(jax.random.normal(ks[2], (G, Q), jnp.float32))
    B = jax.random.normal(ks[3], (G, Q, N), jnp.float32)
    C = jax.random.normal(ks[4], (G, Q, N), jnp.float32)
    y_k, s_k = ssd_chunk_kernel(x, dt, a, B, C, interpret=True)
    y_r, s_r = ref.ssd_chunk_ref(x, dt, a, B, C)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r),
                               rtol=1e-5, atol=1e-5)


def test_ssd_equivalence_to_sequential_recurrence():
    """Chunked SSD == step-by-step recurrence (ground truth)."""
    ks = jax.random.split(KEY, 5)
    b, S, H, P, N = 1, 32, 2, 8, 8
    x = jax.random.normal(ks[0], (b, S, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, S, H), jnp.float32))
    A_log = jax.random.normal(ks[2], (H,), jnp.float32) * 0.5
    B = jax.random.normal(ks[3], (b, S, N), jnp.float32)
    C = jax.random.normal(ks[4], (b, S, N), jnp.float32)
    y_k, s_k = ops.ssd_scan(x, dt, A_log, B, C, chunk=8)
    # sequential reference
    a = dt * (-jnp.exp(A_log))
    state = jnp.zeros((b, H, P, N))
    ys = []
    for t in range(S):
        state = (jnp.exp(a[:, t])[..., None, None] * state
                 + jnp.einsum("bh,bhp,bn->bhpn", dt[:, t], x[:, t], B[:, t]))
        ys.append(jnp.einsum("bn,bhpn->bhp", C[:, t], state))
    y_seq = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_seq),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(state),
                               rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------------- rmsnorm
@pytest.mark.parametrize("T,D", [(256, 64), (512, 1024), (256, 3072)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_matches_ref(T, D, dtype):
    ks = jax.random.split(jax.random.fold_in(KEY, T * D), 2)
    x = jax.random.normal(ks[0], (T, D), dtype)
    w = jax.random.normal(ks[1], (D,), dtype)
    out = rmsnorm_kernel(x, w, interpret=True)
    expect = ref.rmsnorm_ref(x, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **tol(dtype))


def test_rmsnorm_matches_model_layer():
    from repro.models.layers import rmsnorm as model_rmsnorm
    x = jax.random.normal(KEY, (256, 128), jnp.float32)
    w = jnp.ones((128,))
    np.testing.assert_allclose(
        np.asarray(rmsnorm_kernel(x, w, interpret=True)),
        np.asarray(model_rmsnorm(x, w, 1e-6)), rtol=1e-5, atol=1e-5)
