"""Differential harness: vectorized engine vs event engine vs reference DES.

The vectorized engine (``repro.core.engine_vec``) promises *bit-for-bit*
identical results to the per-epoch event engine — same floats, same counter
values, same traces — so the comparison here is exact equality, never
``approx``.  Three layers of evidence:

* a seed-pinned regression corpus (hypothesis-free, runs in tier-1) that
  replays hand-picked and previously-found counterexample configs
  deterministically, three-way against the reference DES where the
  engine/DES contract is established (DESIGN.md §7 tolerances);
* property-based fuzzing over random ``SimConfig``s — pattern, topology,
  group placement/stride, L1/L2 geometry, PTW width, optimization probes,
  message sizes from sub-page to multi-GB (``tests/test_engine_fuzz.py``,
  skipped when hypothesis is not installed; the CI slow tier raises the
  example budget via ``ENGINE_DIFF_EXAMPLES`` / ``-m slow``);
* session-equivalence replays: heterogeneous collective sequences
  (workload-derived and synthetic) through ``SimSession`` on both engines,
  comparing the per-call ``Counters.delta`` streams — including
  ``tlb_retention_ns`` idle-gap flushes, ``rank_stride`` placements and
  ``base_offset`` buffer moves.

Found a disagreement?  Append the shrunken config to ``CORPUS`` so it
replays forever, then fix the engine.
"""
import numpy as np
import pytest

from repro.core import (RefSession, SimSession, paper_config, simulate,
                        simulate_ref, KB, MB, GB)
from repro.core.config import (FabricConfig, PreTranslationConfig,
                               PrefetchConfig, SimConfig, TLBConfig,
                               TranslationConfig)
from repro.core.patterns import PATTERNS
from repro.workloads import derive_workload, replay

PATTERN_NAMES = sorted(PATTERNS)

# The reference DES is per-request: replaying multi-GB collectives through
# it is prohibitive, and its exact-walk contract with the epoch engines is
# established at paper-default translation parameters (DESIGN.md §7).
REF_MAX_BYTES = 16 * MB


# --------------------------------------------------------------- comparators
def run_both(nbytes: int, cfg: SimConfig):
    """(event, vectorized) RunResults for the same config."""
    return (simulate(nbytes, cfg.replace(engine="event")),
            simulate(nbytes, cfg.replace(engine="vectorized")))


def assert_bit_for_bit(a, b):
    """Event vs vectorized: every observable must be the identical float."""
    assert b.completion_ns == a.completion_ns
    assert ([i.completion_ns for i in b.iterations]
            == [i.completion_ns for i in a.iterations])
    assert b.counters.__dict__ == a.counters.__dict__
    assert b.mean_stall_ns == a.mean_stall_ns
    if a.trace is None:
        assert b.trace is None
    else:
        assert np.array_equal(b.trace, a.trace)
        assert np.array_equal(b.trace_flow_bounds, a.trace_flow_bounds)


def assert_matches_ref(a, ref):
    """Engine vs reference DES: exact counts, established completion
    tolerance (the DES models ns-scale arrival-phase bunching the epoch
    engines smooth over — test_core_sim.py pins the same bound)."""
    assert a.counters.requests == ref.counters.requests
    assert a.counters.walks == ref.counters.walks
    assert a.counters.probes == ref.counters.probes
    assert a.completion_ns == pytest.approx(ref.completion_ns, rel=0.05)


def assert_deltas_equal(recs_a, recs_b):
    """Per-call CollectiveResult streams from two sessions must align."""
    assert len(recs_a) == len(recs_b)
    for ra, rb in zip(recs_a, recs_b):
        assert (rb.collective, rb.nbytes, rb.n_gpus) \
            == (ra.collective, ra.nbytes, ra.n_gpus)
        assert rb.t_start == ra.t_start
        assert rb.t_end == ra.t_end
        assert rb.counters.__dict__ == ra.counters.__dict__


# ------------------------------------------------------------ pinned corpus
def _two_tier(n=8, leaf=4, ov=2.0, **kw) -> SimConfig:
    return SimConfig(fabric=FabricConfig(
        n_gpus=n, topology="two_tier", leaf_size=leaf,
        oversubscription=ov), **kw)


def _multi_pod(n=8, pod=4, **kw) -> SimConfig:
    return SimConfig(fabric=FabricConfig(
        n_gpus=n, topology="multi_pod", pod_size=pod), **kw)


def _tiny_tlbs(n=8, **kw) -> SimConfig:
    """Scarce translation resources: 2-entry L1s, a 16-entry 2-way L2 and
    two walkers force eviction and MSHR-coalescing churn."""
    return paper_config(n).replace(
        translation=TranslationConfig(
            l1=TLBConfig(entries=2, assoc=0, hit_latency_ns=50.0,
                         mshr_entries=256),
            l2=TLBConfig(entries=16, assoc=2, hit_latency_ns=100.0,
                         mshr_entries=512),
            n_ptw=2), **kw)


# (id, nbytes, cfg, compare_ref).  Deterministic — no hypothesis needed —
# so CI replays past counterexamples on every tier-1 run.
CORPUS = [
    ("paper_default", 1 * MB, paper_config(16), True),
    ("sub_page", 4 * KB, paper_config(8), True),
    ("odd_bytes", 768 * KB + 13, paper_config(8), True),
    ("one_request_per_flow", 2 * KB, paper_config(8), True),
    ("multi_page_tail", 24 * MB, paper_config(8), False),
    ("tiny_tlbs", 4 * MB, _tiny_tlbs(8), False),
    ("tiny_tlbs_single_ptw", 1 * MB,
     _tiny_tlbs(8).replace(
         translation=TranslationConfig(
             l1=TLBConfig(entries=2, assoc=2, hit_latency_ns=50.0,
                          mshr_entries=256),
             l2=TLBConfig(entries=16, assoc=0, hit_latency_ns=100.0,
                          mshr_entries=512),
             n_ptw=1)), False),
    ("scarce_ingress", 16 * MB,
     SimConfig(fabric=FabricConfig(n_gpus=16, ingress_entries=64)), False),
    ("two_tier_hier", 4 * MB,
     _two_tier(8).replace(collective="hier_all_to_all"), True),
    ("two_tier_oversub4", 1 * MB, _two_tier(8, ov=4.0), True),
    ("multi_pod_a2a", 4 * MB,
     _multi_pod(8).replace(collective="multipod_all_to_all"), True),
    ("pretranslate", 4 * MB,
     paper_config(8).replace(pretranslation=PreTranslationConfig(
         enabled=True, lead_time_ns=3000.0, pages_per_flow=0)), True),
    ("prefetch", 32 * MB,
     paper_config(8).replace(prefetch=PrefetchConfig(
         enabled=True, depth=2)), False),
    ("ideal", 1 * MB, paper_config(16).ideal(), True),
    ("iterations_trace", 1 * MB,
     paper_config(8).replace(iterations=2, collect_trace=True), False),
    ("asymmetric_broadcast", 1 * MB,
     paper_config(8).replace(collective="broadcast", symmetric=False),
     True),
    ("every_target", 1 * MB,
     paper_config(8).replace(symmetric=False), False),
    ("multi_gb", 2 * GB, paper_config(8), False),
]


@pytest.mark.parametrize("name,nbytes,cfg,with_ref",
                         CORPUS, ids=[c[0] for c in CORPUS])
def test_corpus_point(name, nbytes, cfg, with_ref):
    a, b = run_both(nbytes, cfg)
    assert_bit_for_bit(a, b)
    assert a.counters.requests > 0
    if with_ref:
        assert nbytes <= REF_MAX_BYTES  # keep the corpus tier-1-fast
        assert_matches_ref(a, simulate_ref(nbytes, cfg))


@pytest.mark.parametrize("name", PATTERN_NAMES)
def test_corpus_every_pattern(name):
    """Every registered pattern, three-way (engine x engine x DES)."""
    cfg = paper_config(8).replace(collective=name)
    a, b = run_both(1 * MB, cfg)
    assert_bit_for_bit(a, b)
    assert_matches_ref(a, simulate_ref(1 * MB, cfg))


def test_unknown_engine_rejected():
    with pytest.raises(ValueError, match="unknown engine"):
        SimSession(paper_config(8).replace(engine="warp"))


# -------------------------------------------------------- session sequences
SESSION_SEQ = [
    (256 * KB, {}),
    (256 * KB, {}),                               # warm repeat
    (512 * KB, {"collective": "ring_allreduce"}),
    (1 * MB, {"collective": "all_gather", "n_gpus": 8}),
    (256 * KB, {"n_gpus": 4, "rank_stride": 4}),  # strided DP subgroup
    (256 * KB, {"gap_ns": 2e6}),                  # gap >= retention: flush
    (256 * KB, {"base_offset": 64 * MB}),         # fresh pages, cold again
    (256 * KB, {"gap_ns": 0.5e6}),                # short gap: stays warm
]


def _run_session(cfg: SimConfig):
    sess = SimSession(cfg)
    for nbytes, kw in SESSION_SEQ:
        sess.run(nbytes, **kw)
    return sess


class TestSessionEquivalence:
    def test_heterogeneous_sequence_deltas(self):
        cfg = paper_config(16).replace(tlb_retention_ns=1e6)
        ev = _run_session(cfg.replace(engine="event"))
        vec = _run_session(cfg.replace(engine="vectorized"))
        assert_deltas_equal(ev.records, vec.records)
        a, b = ev.result(), vec.result()
        assert b.completion_ns == a.completion_ns
        assert b.counters.__dict__ == a.counters.__dict__
        assert b.mean_stall_ns == a.mean_stall_ns

    def test_sequence_matches_ref_session(self):
        cfg = paper_config(16).replace(tlb_retention_ns=1e6)
        vec = _run_session(cfg.replace(engine="vectorized"))
        ref = RefSession(cfg)
        for nbytes, kw in SESSION_SEQ:
            ref.run(nbytes, **kw)
        for rv, rr in zip(vec.records, ref.records):
            assert rv.counters.walks == rr.counters.walks
            assert rv.counters.requests == rr.counters.requests
            assert rv.completion_ns == pytest.approx(rr.completion_ns,
                                                     rel=0.05)

    def test_session_trace_first_run_only(self):
        cfg = paper_config(16).replace(collect_trace=True)
        ev = _run_session(cfg.replace(engine="event"))
        vec = _run_session(cfg.replace(engine="vectorized"))
        a, b = ev.result(), vec.result()
        assert a.trace is not None
        assert np.array_equal(b.trace, a.trace)
        assert np.array_equal(b.trace_flow_bounds, a.trace_flow_bounds)


# ------------------------------------------------------- workload sequences
class TinyMoE:
    """Duck-typed ModelConfig stand-in (mirrors test_calibrate.TinyMoE)."""
    name = "tiny-moe"
    n_layers = 4
    d_model = 512
    n_heads = 8
    n_kv_heads = 4
    d_head = 64
    d_ff = 0
    n_experts = 16
    top_k = 2
    d_ff_expert = 256
    moe_every = 1
    capacity_factor = 1.25


def _replay_both(trace, cfg):
    return (replay(trace, cfg=cfg.replace(engine="event")),
            replay(trace, cfg=cfg.replace(engine="vectorized")))


def _assert_replays_equal(ev, vec):
    assert_deltas_equal(ev.calls, vec.calls)
    for sa, sb in zip(ev.steps, vec.steps):
        assert (sb.comm_ns, sb.ideal_comm_ns, sb.walks, sb.requests) \
            == (sa.comm_ns, sa.ideal_comm_ns, sa.walks, sa.requests)


class TestWorkloadReplayEquivalence:
    def test_tiny_moe_decode(self):
        from repro.workloads import pod_fabric
        trace = derive_workload(TinyMoE(), "decode_32k", n_gpus=8,
                                n_steps=3)
        cfg = SimConfig(fabric=pod_fabric(trace.pod))
        _assert_replays_equal(*_replay_both(trace, cfg))

    def test_granite_decode_with_retention(self):
        # Compute gaps between calls exceed retention: the replay's
        # idle-flush path must age both engines' sessions identically.
        from repro.workloads import pod_fabric
        trace = derive_workload("granite-moe-1b-a400m", "decode_32k",
                                n_gpus=16, n_steps=2)
        cfg = SimConfig(fabric=pod_fabric(trace.pod),
                        tlb_retention_ns=50_000.0)
        ev, vec = _replay_both(trace, cfg)
        _assert_replays_equal(ev, vec)
        assert ev.steps[0].walks > 0   # the sequence actually walks

    def test_tiny_moe_two_tier(self):
        from repro.workloads import PodSpec, pod_fabric
        trace = derive_workload(
            TinyMoE(), "decode_32k", n_gpus=8, n_steps=2,
            pod=PodSpec(topology="two_tier", leaf_size=4,
                        oversubscription=2.0))
        cfg = SimConfig(fabric=pod_fabric(trace.pod))
        _assert_replays_equal(*_replay_both(trace, cfg))


# The property-based fuzz over random SimConfigs lives in
# tests/test_engine_fuzz.py: hypothesis is an optional dev dependency and a
# module-level importorskip would take this deterministic corpus with it.
