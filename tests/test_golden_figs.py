"""Golden regression locks for benchmarks/paper_figs.py row values.

Captured from the pre-session (PR 1) engine at the seed configuration; the
session refactor (and anything after it) must reproduce these bit-for-bit —
``completion_ns`` values are exact float equality, ratios are pinned to
1e-12.  If a change legitimately alters the physics, recapture deliberately.
"""
import dataclasses

import pytest

from repro.core import ratsim, paper_config, MB
from repro.core.config import TLBConfig

# (n_gpus, size) -> (baseline_ns, ideal_ns, mean_rat_ns, requests, walks)
FIG45_GOLDEN = {
    (8, 1 * MB): (3890.0, 2762.32, 1413.8399999999995, 3584, 1),
    (16, 1 * MB): (3890.0, 2802.0, 1394.0, 3840, 1),
    (64, 1 * MB): (3890.0, 2825.04, 1382.4799999999975, 4032, 1),
    (16, 16 * MB): (13342.48, 12018.0, 76.39673828124994, 61440, 8),
    (32, 16 * MB): (13642.64, 12343.119999999999, 71.62859248991907, 63488, 8),
}


@pytest.mark.parametrize("n,size", sorted(FIG45_GOLDEN))
def test_fig4_fig5_rows_bit_for_bit(n, size):
    base, ideal, mean_rat, reqs, walks = FIG45_GOLDEN[(n, size)]
    c = ratsim.compare(size, n)
    assert c.baseline.completion_ns == base
    assert c.ideal.completion_ns == ideal
    assert c.baseline.mean_rat_ns == pytest.approx(mean_rat, rel=1e-12)
    assert c.baseline.counters.requests == reqs
    assert c.baseline.counters.walks == walks


# fig11: L2-TLB size sweep at 16 MB / 32 GPUs — flat beyond 32 entries.
FIG11_GOLDEN = {32: 13642.64, 512: 13642.64, 32768: 13642.64}
FIG11_DEG = 1.1052829430484352


@pytest.mark.parametrize("entries", sorted(FIG11_GOLDEN))
def test_fig11_rows_bit_for_bit(entries):
    cfg = paper_config(32)
    tr = dataclasses.replace(
        cfg.translation,
        l2=TLBConfig(entries=entries, assoc=2, hit_latency_ns=100.0,
                     mshr_entries=512))
    c = ratsim.compare(16 * MB, 32, cfg=cfg.replace(translation=tr))
    assert c.baseline.completion_ns == FIG11_GOLDEN[entries]
    assert c.degradation == pytest.approx(FIG11_DEG, rel=1e-12)


def test_sweep_matches_compare_rows():
    # The figure grid is produced through the (parallel) sweep executor;
    # its values must equal the direct compare() calls above.
    grid = ratsim.sweep([1 * MB, 16 * MB], [16])
    for size in (1 * MB, 16 * MB):
        c = ratsim.compare(size, 16)
        g = grid[(16, size)]
        assert g.baseline.completion_ns == c.baseline.completion_ns
        assert g.ideal.completion_ns == c.ideal.completion_ns


# --------------------------------------------------------------------------
# Calibration-off replay goldens (PR 2 values): threading compute_profile
# through derive/replay/SimSession must leave the default path bit-for-bit.
# --------------------------------------------------------------------------

# Pure-simulator lock (no jax): TinyMoE decode_32k on 8 GPUs, 3 steps ->
# (step, comm_ns, ideal_comm_ns, walks, requests).
TINY_REPLAY_GOLDEN = [
    (0, 151804.15999999968, 141288.96000000002, 12, 7168),
    (1, 144488.95999999967, 141288.96000000002, 0, 7168),
    (2, 144488.9600000009, 141288.96000000002, 0, 7168),
]


def test_replay_calibration_off_bit_for_bit():
    from repro.workloads import derive_workload, replay

    class TinyMoE:
        name = "tiny-moe"
        n_layers = 4
        d_model = 512
        n_heads = 8
        n_kv_heads = 4
        d_head = 64
        d_ff = 0
        n_experts = 16
        top_k = 2
        d_ff_expert = 256
        moe_every = 1
        capacity_factor = 1.25

    rep = replay(derive_workload(TinyMoE(), "decode_32k", n_gpus=8,
                                 n_steps=3))
    got = [(s.step, s.comm_ns, s.ideal_comm_ns, s.walks, s.requests)
           for s in rep.steps]
    assert got == TINY_REPLAY_GOLDEN


# fig13 rows exactly as PR 2 emitted them (needs jax: real arch configs).
FIG13_GOLDEN = [
    ("fig13/granite-moe-1b-a400m/token0", 1769.4556799999705,
     "degradation=1.0385;walks=72"),
    ("fig13/granite-moe-1b-a400m/token1", 1744.4236800000135,
     "degradation=1.0238;walks=0"),
    ("fig13/granite-moe-1b-a400m/token2", 1744.4236800000476,
     "degradation=1.0238;walks=0"),
    ("fig13/granite-moe-1b-a400m/token3", 1744.423680000052,
     "degradation=1.0238;walks=0"),
    ("fig13/granite-moe-1b-a400m/check_cold_above_steady", 0.0,
     "cold=1.0385;steady=1.0238;warms_up=True"),
    ("fig13/qwen3-moe-235b-a22b/token0", 7828.577360000854,
     "degradation=1.0265;walks=846"),
    ("fig13/qwen3-moe-235b-a22b/token1", 7826.327200001521,
     "degradation=1.0262;walks=796"),
    ("fig13/qwen3-moe-235b-a22b/check_cold_above_steady", 0.0,
     "cold=1.0265;steady=1.0262;warms_up=True"),
]


def test_fig13_rows_bit_for_bit():
    jax = pytest.importorskip("jax")  # noqa: F841 - arch configs need jax
    from benchmarks.paper_figs import fig13_workload_replay
    assert fig13_workload_replay() == FIG13_GOLDEN


# --------------------------------------------------------------------------
# fig14/fig15 small-grid goldens (PR 6 values): locked on BOTH engines —
# the vectorized engine must reproduce the event engine's floats exactly,
# so one golden table pins the physics of either.
# --------------------------------------------------------------------------

# fig14 topology-scaling rows at 1 MB (the full figure sweeps to 1024
# GPUs; the golden keeps the 16/64-GPU columns, enough to lock the
# degenerate-tier agreement at 16 and the per-topology split at 64):
# (topology, n_gpus) -> (cold_ns, warm_ns, ideal_cold_ns, ideal_warm_ns,
# walks) with the figure's tier parameters (16-GPU leaves, 2x spine
# oversubscription, 16-GPU pods) and iterations=2 (cold then warm).
FIG14_GOLDEN = {
    ("single_clos", 16): (3890.0, 2852.0, 2802.0, 2802.0, 1),
    ("single_clos", 64): (3890.0, 2875.04, 2825.04, 2825.04, 1),
    ("two_tier", 16): (3890.0, 2852.0, 2802.0, 2802.0, 1),
    ("two_tier", 64): (4490.0, 4407.68, 4357.68, 4357.68, 1),
    ("multi_pod", 16): (3890.0, 2852.0, 2802.0, 2802.0, 1),
    ("multi_pod", 64): (5975.360000000001, 5975.359999999999,
                        5925.360000000001, 5925.359999999999, 1),
}


def _fig14_cfg(topo, n, engine):
    from repro.core.config import FabricConfig, SimConfig
    return SimConfig(fabric=FabricConfig(n_gpus=n, topology=topo,
                                         leaf_size=16, oversubscription=2.0,
                                         pod_size=16),
                     iterations=2, engine=engine)


@pytest.mark.parametrize("engine", ["event", "vectorized"])
@pytest.mark.parametrize("topo,n", sorted(FIG14_GOLDEN))
def test_fig14_rows_bit_for_bit(topo, n, engine):
    cold, warm, i_cold, i_warm, walks = FIG14_GOLDEN[(topo, n)]
    c = ratsim.compare(1 * MB, n, cfg=_fig14_cfg(topo, n, engine))
    b, i = c.baseline.iterations, c.ideal.iterations
    assert (b[0].completion_ns, b[1].completion_ns) == (cold, warm)
    assert (i[0].completion_ns, i[1].completion_ns) == (i_cold, i_warm)
    assert c.baseline.counters.walks == walks
    # The figure's headline: warm TLBs never cost more than the cold pass.
    assert b[1].completion_ns <= b[0].completion_ns + 1e-9


# One fig15 bursty serving point (scaled down from _FIG15_BASE: 12
# requests, 60-step cap — the cold-burst tail regime survives intact:
# p99 TTFT degradation well above the mean).
FIG15_POINT = dict(arch="granite-moe-1b-a400m", rps=16.0, arrival="bursty",
                   n_requests=12, seed=7, retention_ns=50_000.0,
                   steps_cap=60, burst_size=4, burstiness=24.0,
                   prompt_mean=128, output_mean=8)
FIG15_GOLDEN = dict(
    p50=2432782.6667737663,
    p95=3432839.485653756,
    p99=3478109.9026029403,
    mean_deg=1.0583494148024755,
    p99_deg=1.1010624819242405,
    cold_comm_ns=7072922.8800069485,
    warm_comm_ns=66063141.120014586,
    cold_steps=4, steps=42, walks=288, served=12,
)


@pytest.mark.parametrize("engine", ["event", "vectorized"])
def test_fig15_bursty_point_bit_for_bit(engine):
    from repro.serving.simulate import TrafficPoint, _traffic_point

    r = _traffic_point((TrafficPoint(engine=engine, **FIG15_POINT),))
    ttft = r.ttft_percentiles()
    assert ttft[50.0] == FIG15_GOLDEN["p50"]
    assert ttft[95.0] == FIG15_GOLDEN["p95"]
    assert ttft[99.0] == FIG15_GOLDEN["p99"]
    assert r.mean_ttft_degradation == FIG15_GOLDEN["mean_deg"]
    assert r.p99_ttft_degradation == FIG15_GOLDEN["p99_deg"]
    assert r.cold_comm_ns == FIG15_GOLDEN["cold_comm_ns"]
    assert r.warm_comm_ns == FIG15_GOLDEN["warm_comm_ns"]
    assert r.cold_steps == FIG15_GOLDEN["cold_steps"]
    assert len(r.steps) == FIG15_GOLDEN["steps"]
    assert sum(s.walks for s in r.steps) == FIG15_GOLDEN["walks"]
    assert len(r.first_token_served) == FIG15_GOLDEN["served"]
    # Bursty cold-miss tail: p99 degradation clears the mean.
    assert r.p99_ttft_degradation > r.mean_ttft_degradation
