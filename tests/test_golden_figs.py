"""Golden regression locks for benchmarks/paper_figs.py row values.

Captured from the pre-session (PR 1) engine at the seed configuration; the
session refactor (and anything after it) must reproduce these bit-for-bit —
``completion_ns`` values are exact float equality, ratios are pinned to
1e-12.  If a change legitimately alters the physics, recapture deliberately.
"""
import dataclasses

import pytest

from repro.core import ratsim, paper_config, MB
from repro.core.config import TLBConfig

# (n_gpus, size) -> (baseline_ns, ideal_ns, mean_rat_ns, requests, walks)
FIG45_GOLDEN = {
    (8, 1 * MB): (3890.0, 2762.32, 1413.8399999999995, 3584, 1),
    (16, 1 * MB): (3890.0, 2802.0, 1394.0, 3840, 1),
    (64, 1 * MB): (3890.0, 2825.04, 1382.4799999999975, 4032, 1),
    (16, 16 * MB): (13342.48, 12018.0, 76.39673828124994, 61440, 8),
    (32, 16 * MB): (13642.64, 12343.119999999999, 71.62859248991907, 63488, 8),
}


@pytest.mark.parametrize("n,size", sorted(FIG45_GOLDEN))
def test_fig4_fig5_rows_bit_for_bit(n, size):
    base, ideal, mean_rat, reqs, walks = FIG45_GOLDEN[(n, size)]
    c = ratsim.compare(size, n)
    assert c.baseline.completion_ns == base
    assert c.ideal.completion_ns == ideal
    assert c.baseline.mean_rat_ns == pytest.approx(mean_rat, rel=1e-12)
    assert c.baseline.counters.requests == reqs
    assert c.baseline.counters.walks == walks


# fig11: L2-TLB size sweep at 16 MB / 32 GPUs — flat beyond 32 entries.
FIG11_GOLDEN = {32: 13642.64, 512: 13642.64, 32768: 13642.64}
FIG11_DEG = 1.1052829430484352


@pytest.mark.parametrize("entries", sorted(FIG11_GOLDEN))
def test_fig11_rows_bit_for_bit(entries):
    cfg = paper_config(32)
    tr = dataclasses.replace(
        cfg.translation,
        l2=TLBConfig(entries=entries, assoc=2, hit_latency_ns=100.0,
                     mshr_entries=512))
    c = ratsim.compare(16 * MB, 32, cfg=cfg.replace(translation=tr))
    assert c.baseline.completion_ns == FIG11_GOLDEN[entries]
    assert c.degradation == pytest.approx(FIG11_DEG, rel=1e-12)


def test_sweep_matches_compare_rows():
    # The figure grid is produced through the (parallel) sweep executor;
    # its values must equal the direct compare() calls above.
    grid = ratsim.sweep([1 * MB, 16 * MB], [16])
    for size in (1 * MB, 16 * MB):
        c = ratsim.compare(size, 16)
        g = grid[(16, size)]
        assert g.baseline.completion_ns == c.baseline.completion_ns
        assert g.ideal.completion_ns == c.ideal.completion_ns


# --------------------------------------------------------------------------
# Calibration-off replay goldens (PR 2 values): threading compute_profile
# through derive/replay/SimSession must leave the default path bit-for-bit.
# --------------------------------------------------------------------------

# Pure-simulator lock (no jax): TinyMoE decode_32k on 8 GPUs, 3 steps ->
# (step, comm_ns, ideal_comm_ns, walks, requests).
TINY_REPLAY_GOLDEN = [
    (0, 151804.15999999968, 141288.96000000002, 12, 7168),
    (1, 144488.95999999967, 141288.96000000002, 0, 7168),
    (2, 144488.9600000009, 141288.96000000002, 0, 7168),
]


def test_replay_calibration_off_bit_for_bit():
    from repro.workloads import derive_workload, replay

    class TinyMoE:
        name = "tiny-moe"
        n_layers = 4
        d_model = 512
        n_heads = 8
        n_kv_heads = 4
        d_head = 64
        d_ff = 0
        n_experts = 16
        top_k = 2
        d_ff_expert = 256
        moe_every = 1
        capacity_factor = 1.25

    rep = replay(derive_workload(TinyMoE(), "decode_32k", n_gpus=8,
                                 n_steps=3))
    got = [(s.step, s.comm_ns, s.ideal_comm_ns, s.walks, s.requests)
           for s in rep.steps]
    assert got == TINY_REPLAY_GOLDEN


# fig13 rows exactly as PR 2 emitted them (needs jax: real arch configs).
FIG13_GOLDEN = [
    ("fig13/granite-moe-1b-a400m/token0", 1769.4556799999705,
     "degradation=1.0385;walks=72"),
    ("fig13/granite-moe-1b-a400m/token1", 1744.4236800000135,
     "degradation=1.0238;walks=0"),
    ("fig13/granite-moe-1b-a400m/token2", 1744.4236800000476,
     "degradation=1.0238;walks=0"),
    ("fig13/granite-moe-1b-a400m/token3", 1744.423680000052,
     "degradation=1.0238;walks=0"),
    ("fig13/granite-moe-1b-a400m/check_cold_above_steady", 0.0,
     "cold=1.0385;steady=1.0238;warms_up=True"),
    ("fig13/qwen3-moe-235b-a22b/token0", 7828.577360000854,
     "degradation=1.0265;walks=846"),
    ("fig13/qwen3-moe-235b-a22b/token1", 7826.327200001521,
     "degradation=1.0262;walks=796"),
    ("fig13/qwen3-moe-235b-a22b/check_cold_above_steady", 0.0,
     "cold=1.0265;steady=1.0262;warms_up=True"),
]


def test_fig13_rows_bit_for_bit():
    jax = pytest.importorskip("jax")  # noqa: F841 - arch configs need jax
    from benchmarks.paper_figs import fig13_workload_replay
    assert fig13_workload_replay() == FIG13_GOLDEN
