"""Golden regression locks for benchmarks/paper_figs.py row values.

Captured from the pre-session (PR 1) engine at the seed configuration; the
session refactor (and anything after it) must reproduce these bit-for-bit —
``completion_ns`` values are exact float equality, ratios are pinned to
1e-12.  If a change legitimately alters the physics, recapture deliberately.
"""
import dataclasses

import pytest

from repro.core import ratsim, paper_config, MB
from repro.core.config import TLBConfig

# (n_gpus, size) -> (baseline_ns, ideal_ns, mean_rat_ns, requests, walks)
FIG45_GOLDEN = {
    (8, 1 * MB): (3890.0, 2762.32, 1413.8399999999995, 3584, 1),
    (16, 1 * MB): (3890.0, 2802.0, 1394.0, 3840, 1),
    (64, 1 * MB): (3890.0, 2825.04, 1382.4799999999975, 4032, 1),
    (16, 16 * MB): (13342.48, 12018.0, 76.39673828124994, 61440, 8),
    (32, 16 * MB): (13642.64, 12343.119999999999, 71.62859248991907, 63488, 8),
}


@pytest.mark.parametrize("n,size", sorted(FIG45_GOLDEN))
def test_fig4_fig5_rows_bit_for_bit(n, size):
    base, ideal, mean_rat, reqs, walks = FIG45_GOLDEN[(n, size)]
    c = ratsim.compare(size, n)
    assert c.baseline.completion_ns == base
    assert c.ideal.completion_ns == ideal
    assert c.baseline.mean_rat_ns == pytest.approx(mean_rat, rel=1e-12)
    assert c.baseline.counters.requests == reqs
    assert c.baseline.counters.walks == walks


# fig11: L2-TLB size sweep at 16 MB / 32 GPUs — flat beyond 32 entries.
FIG11_GOLDEN = {32: 13642.64, 512: 13642.64, 32768: 13642.64}
FIG11_DEG = 1.1052829430484352


@pytest.mark.parametrize("entries", sorted(FIG11_GOLDEN))
def test_fig11_rows_bit_for_bit(entries):
    cfg = paper_config(32)
    tr = dataclasses.replace(
        cfg.translation,
        l2=TLBConfig(entries=entries, assoc=2, hit_latency_ns=100.0,
                     mshr_entries=512))
    c = ratsim.compare(16 * MB, 32, cfg=cfg.replace(translation=tr))
    assert c.baseline.completion_ns == FIG11_GOLDEN[entries]
    assert c.degradation == pytest.approx(FIG11_DEG, rel=1e-12)


def test_sweep_matches_compare_rows():
    # The figure grid is produced through the (parallel) sweep executor;
    # its values must equal the direct compare() calls above.
    grid = ratsim.sweep([1 * MB, 16 * MB], [16])
    for size in (1 * MB, 16 * MB):
        c = ratsim.compare(size, 16)
        g = grid[(16, size)]
        assert g.baseline.completion_ns == c.baseline.completion_ns
        assert g.ideal.completion_ns == c.ideal.completion_ns
