"""Integration tests for the sharded step builders on a local 1x1 mesh.

The 512-device production meshes are exercised by launch/dryrun.py (cached
results in results/dryrun); here we verify the same builders produce
numerically working steps end-to-end on whatever devices exist.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.shapes import ShapeSpec
from repro.launch.steps import make_train_step, make_serve_step, make_prefill_step
from repro.optim import adamw, with_master, cosine_with_warmup


def local_mesh():
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


def smoke_shape(kind, seq, batch):
    return ShapeSpec(name=f"t_{kind}", kind=kind, seq_len=seq,
                     global_batch=batch)


@pytest.fixture(scope="module")
def cfg():
    return configs.get_smoke_config("qwen3-1.7b").replace(n_layers=2)


class TestTrainStep:
    def test_loss_decreases_and_state_shards(self, cfg):
        mesh = local_mesh()
        opt = with_master(adamw(cosine_with_warmup(1e-2, 2, 50)))
        with mesh:
            step, in_sh, _, (params_s, opt_s) = make_train_step(
                cfg, opt, mesh, microbatches=2)
            train_cfg = cfg.replace(param_dtype=cfg.dtype)
            from repro.models import api
            params, _ = api.init(train_cfg, jax.random.PRNGKey(0))
            opt_state = opt.init(params)
            k = jax.random.PRNGKey(1)
            batch = {
                "inputs": jax.random.randint(k, (4, 32), 0, cfg.vocab_size),
                "targets": jax.random.randint(
                    jax.random.fold_in(k, 1), (4, 32), 0, cfg.vocab_size),
            }
            losses = []
            for _ in range(5):
                params, opt_state, metrics = step(params, opt_state, batch)
                losses.append(float(metrics["loss"]))
        assert np.isfinite(losses).all()
        assert losses[-1] < losses[0]          # memorizes a fixed batch
        assert params["tok_embed"].dtype == jnp.bfloat16
        assert opt_state["master"]["tok_embed"].dtype == jnp.float32

    def test_grad_norm_finite(self, cfg):
        mesh = local_mesh()
        opt = with_master(adamw(cosine_with_warmup(1e-3, 2, 50)))
        with mesh:
            step, *_ , (params_s, opt_s) = make_train_step(cfg, opt, mesh)
            from repro.models import api
            params, _ = api.init(cfg.replace(param_dtype=cfg.dtype),
                                 jax.random.PRNGKey(0))
            opt_state = opt.init(params)
            k = jax.random.PRNGKey(2)
            batch = {
                "inputs": jax.random.randint(k, (2, 16), 0, cfg.vocab_size),
                "targets": jax.random.randint(k, (2, 16), 0, cfg.vocab_size),
            }
            _, _, metrics = step(params, opt_state, batch)
            assert np.isfinite(float(metrics["grad_norm"]))


class TestServeSteps:
    def test_prefill_then_serve_runs(self, cfg):
        mesh = local_mesh()
        shape = smoke_shape("decode", seq=64, batch=2)
        with mesh:
            pre, *_ = make_prefill_step(cfg, mesh, shape)
            srv, *_ = make_serve_step(cfg, mesh, shape)
            from repro.models import api
            serve_cfg = cfg.replace(param_dtype=cfg.dtype)
            params, _ = api.init(serve_cfg, jax.random.PRNGKey(0))
            batch = {"inputs": jax.random.randint(
                jax.random.PRNGKey(1), (2, 64), 0, cfg.vocab_size)}
            logits, caches = pre(params, batch)
            assert logits.shape == (2, cfg.vocab_size)
            tok = jnp.argmax(logits, axis=-1)
            logits2, caches = srv(params, tok, caches)
            assert logits2.shape == (2, cfg.vocab_size)
            assert np.isfinite(np.asarray(logits2, np.float32)).all()

    def test_long_decode_rules_apply(self, cfg):
        # global_batch=1 selects LONG_DECODE (cache_seq sharded over data)
        mesh = local_mesh()
        shape = smoke_shape("decode", seq=64, batch=1)
        with mesh:
            srv, in_sh, _, (params_s, cache_s) = make_serve_step(
                cfg, mesh, shape)
            # lowering compiles without allocation
            from repro.launch import specs as sp
            lowered = srv.lower(params_s, sp.token_specs(shape), cache_s)
            compiled = lowered.compile()
            assert compiled.memory_analysis() is not None
