"""Tests for model-derived workload replay (repro.workloads).

Covers the derivation formulas (MoE a2a sizing mirrors moe_block_ep, call
mix per shape kind, pod resolution), the page-aligned buffer layout, the
replay trajectory (token 0 cold, steady state warm — the fig13 acceptance
criterion), and the parallel-sweep executor equivalence.
"""

import pytest

from repro.core import ratsim, paper_config, MB
from repro.workloads import (PodSpec, buffer_layout, derive_workload,
                             moe_a2a_bytes, replay, resolve_pod)

# A tiny in-repo MoE config: keeps these pure-simulator tests independent
# of the real architecture registry.
from repro.workloads.derive import CollectiveCall, WorkloadTrace


class TinyMoE:
    """Duck-typed stand-in for ModelConfig (only the fields derive reads)."""
    name = "tiny-moe"
    n_layers = 4
    d_model = 512
    n_heads = 8
    n_kv_heads = 4
    d_head = 64
    d_ff = 0
    n_experts = 16
    top_k = 2
    d_ff_expert = 256
    moe_every = 1
    capacity_factor = 1.25


class TinyDense(TinyMoE):
    name = "tiny-dense"
    d_ff = 2048
    n_experts = 0
    top_k = 0
    d_ff_expert = 0


# ------------------------------------------------------------- derivation
class TestDerive:
    def test_moe_a2a_bytes_mirror_moe_block_ep(self):
        # moe_block_ep: send buffer [ep, C, D] with
        # C = max(8, T_loc*k*cf/E) * E_loc.
        cfg, ep, t_loc = TinyMoE(), 8, 64
        e_loc = cfg.n_experts // ep
        cap = max(8, int(t_loc * cfg.top_k * cfg.capacity_factor
                         / cfg.n_experts))
        expected = ep * cap * e_loc * cfg.d_model * 2
        assert moe_a2a_bytes(cfg, t_loc, ep, 2) == expected

    def test_decode_mix(self):
        tr = derive_workload(TinyMoE(), "decode_32k", n_gpus=8, n_steps=2)
        assert tr.pod.ep == 8 and tr.pod.tp == 8 and tr.pod.dp == 1
        assert tr.tokens_per_step == 128          # decode: one token/seq
        step0 = tr.step_calls(0)
        # per layer: TP ag + rs around the mixer, a2a dispatch + combine
        assert sum(c.collective == "all_to_all" for c in step0) == 2 * 4
        assert sum(c.collective == "all_gather" for c in step0) == 4
        assert sum(c.collective == "reduce_scatter" for c in step0) == 4
        assert tr.n_steps == 2
        assert [c.label for c in tr.step_calls(1)] \
            == [c.label.replace("s0", "s1") for c in step0]

    def test_dense_has_no_a2a(self):
        tr = derive_workload(TinyDense(), "decode_32k", n_gpus=8)
        assert all(c.collective != "all_to_all" for c in tr.calls)
        assert sum(c.collective == "all_gather" for c in tr.calls) == 2 * 4

    def test_train_adds_dp_grad_allreduce(self):
        tr = derive_workload(TinyMoE(), "train_4k", n_gpus=16)
        pod = tr.pod
        assert pod.tp == 8 and pod.dp == 2
        grads = [c for c in tr.calls if c.collective == "ring_allreduce"]
        assert len(grads) == 4                    # one bucket per layer
        assert all(c.group == pod.dp for c in grads)
        assert len({c.buffer for c in grads}) == 4  # distinct regions
        # microbatching: train_4k is 256 x 4096 tokens in 8192-token chunks
        assert tr.tokens_per_step == 8192
        assert tr.n_microbatches == (256 * 4096) // 8192

    def test_compute_windows_present(self):
        tr = derive_workload(TinyMoE(), "decode_32k", n_gpus=8)
        assert any(c.compute_ns > 0 for c in tr.calls)

    def test_moe_without_ep_group_keeps_ffn_traffic(self):
        # ep == 1 (all experts local): no all-to-all, but the FFN sublayer
        # still shards over TP and its expert compute window survives.
        tr = derive_workload(TinyMoE(), "decode_32k", n_gpus=8,
                             pod=PodSpec(ep=1))
        assert all(c.collective != "all_to_all" for c in tr.calls)
        step0 = tr.step_calls(0)
        assert sum(c.label.endswith("ffn_rs") for c in step0) == 4
        ffn_rs = [c for c in step0 if c.label.endswith("ffn_rs")]
        assert all(c.compute_ns > 0 for c in ffn_rs)

    def test_mixer_compute_sits_between_ag_and_rs(self):
        # Sequence-parallel semantics: ag -> mixer compute -> rs, so the
        # compute window is attached to the rs of the pair.
        tr = derive_workload(TinyMoE(), "decode_32k", n_gpus=8)
        step0 = tr.step_calls(0)
        ags = [c for c in step0 if c.label.endswith("mixer_ag")]
        rss = [c for c in step0 if c.label.endswith("mixer_rs")]
        assert all(c.compute_ns == 0 for c in ags)
        assert all(c.compute_ns > 0 for c in rss)

    def test_pooled_buffer_reuse(self):
        per_layer = derive_workload(TinyMoE(), "decode_32k", n_gpus=8)
        pooled = derive_workload(
            TinyMoE(), "decode_32k", n_gpus=8,
            pod=PodSpec(buffer_reuse="pooled"))
        assert len({c.buffer for c in pooled.calls}) \
            < len({c.buffer for c in per_layer.calls})

    def test_resolve_pod_validates(self):
        with pytest.raises(ValueError, match="!= pod"):
            resolve_pod(PodSpec(n_gpus=16, tp=3), TinyMoE(), "decode")
        with pytest.raises(ValueError, match="does not divide n_experts"):
            resolve_pod(PodSpec(n_gpus=8, ep=3), TinyMoE(), "decode")
        with pytest.raises(ValueError, match="exceeds pod"):
            resolve_pod(PodSpec(n_gpus=8, ep=16), TinyMoE(), "decode")

    def test_tp1_compute_windows_carried_not_dropped(self):
        # With tp == 1 the mixer pair emits no traffic, but its compute
        # window must still age the session: it rides on the next call.
        tp8 = derive_workload(TinyMoE(), "decode_32k", n_gpus=8)
        tp1 = derive_workload(TinyMoE(), "decode_32k", n_gpus=8,
                              pod=PodSpec(tp=1, dp=8))
        total8 = sum(c.compute_ns for c in tp8.step_calls(0))
        total1 = sum(c.compute_ns for c in tp1.step_calls(0))
        # tp=1 does the same attention flops on 1/8th the shards: 8x window.
        assert total1 > total8
        disp = [c for c in tp1.step_calls(0)
                if c.label.endswith("moe_dispatch")]
        assert all(c.compute_ns > 0 for c in disp)   # carried attn window


# ----------------------------------------------------------- buffer layout
def test_buffer_layout_page_aligned_disjoint():
    tr = WorkloadTrace(arch="x", shape="y", pod=PodSpec(n_gpus=8))
    tr.calls = [
        CollectiveCall("a", "all_to_all", 3 * MB, 8, 0.0, "bufA", 0),
        CollectiveCall("b", "all_to_all", 1 * MB, 8, 0.0, "bufB", 0),
        CollectiveCall("c", "all_gather", 5 * MB, 8, 0.0, "bufA", 0),
    ]
    page = 2 * MB
    layout = buffer_layout(tr, page)
    assert set(layout) == {"bufA", "bufB"}
    assert all(off % page == 0 for off in layout.values())
    # bufA spans 2 * 5 MB rounded up -> its region must not reach bufB.
    spans = sorted((off, off + 2 * (5 * MB if b == "bufA" else 1 * MB))
                   for b, off in layout.items())
    assert spans[0][1] <= spans[1][0]


# ----------------------------------------------------------------- replay
class TestReplay:
    def test_cold_token_strictly_above_steady_state(self):
        """The fig13 acceptance criterion on a small-payload MoE decode
        sequence: token 0 (cold Link TLBs) degrades strictly more than the
        steady state, and the steady state stops walking entirely."""
        tr = derive_workload(TinyMoE(), "decode_32k", n_gpus=8, n_steps=3)
        rep = replay(tr)
        assert rep.cold_degradation > rep.steady_degradation
        assert rep.steps[0].walks > 0
        assert all(s.walks == 0 for s in rep.steps[1:])
        assert rep.steps[1].comm_ns == pytest.approx(rep.steps[2].comm_ns)

    def test_replay_rejects_mismatched_pod(self):
        tr = derive_workload(TinyMoE(), "decode_32k", n_gpus=8)
        with pytest.raises(ValueError, match="pod size"):
            replay(tr, cfg=paper_config(16))

    def test_single_step_replay_is_well_defined(self):
        # Regression: --steps 1 used to crash steady_degradation (empty tail).
        tr = derive_workload(TinyMoE(), "decode_32k", n_gpus=8, n_steps=1)
        rep = replay(tr)
        assert rep.steady_degradation == rep.cold_degradation

    def test_retention_erases_warmth(self):
        # With a TLB retention shorter than the compute gaps, every step
        # pays cold walks again: the trajectory flattens at the cold level.
        tr = derive_workload(TinyMoE(), "decode_32k", n_gpus=8, n_steps=2)
        warm = replay(tr)
        cfg = paper_config(8).replace(tlb_retention_ns=1.0)
        aged = replay(tr, cfg=cfg)
        assert warm.steps[1].walks == 0
        assert aged.steps[1].walks > 0
        assert aged.steps[1].comm_ns > warm.steps[1].comm_ns


# ---------------------------------------------------------- parallel sweep
class TestParallelSweep:
    def test_parallel_equals_serial(self):
        # workers=2 forces the pool even though this grid is below the
        # auto-parallel work threshold.
        sizes, gpus = [1 * MB, 4 * MB], [8, 16]
        par = ratsim.sweep(sizes, gpus, collectives=["all_to_all",
                                                     "ring_allreduce"],
                           workers=2)
        ser = ratsim.sweep(sizes, gpus, collectives=["all_to_all",
                                                     "ring_allreduce"],
                           workers=0)
        assert set(par) == set(ser)
        for k in par:
            assert par[k].baseline.completion_ns \
                == ser[k].baseline.completion_ns
            assert par[k].ideal.completion_ns == ser[k].ideal.completion_ns
            assert par[k].baseline.counters.by_class \
                == ser[k].baseline.counters.by_class

    def test_seed_key_shape_preserved(self):
        out = ratsim.sweep([1 * MB], [8, 16])
        assert set(out) == {(8, 1 * MB), (16, 1 * MB)}

    def test_cache_memoizes_across_calls(self):
        cache = {}
        a = ratsim.sweep([1 * MB], [8], cache=cache)
        assert len(cache) == 1
        b = ratsim.sweep([1 * MB], [8], cache=cache)
        assert a[(8, 1 * MB)] is b[(8, 1 * MB)]
        # a different config is a different key
        ratsim.sweep([1 * MB], [8], collectives=["ring_allreduce"],
                     cache=cache)
        assert len(cache) == 2
