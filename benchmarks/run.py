# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
import sys
import time


def main() -> None:
    from . import paper_figs

    print("name,us_per_call,derived")
    failures = 0
    for fn in paper_figs.ALL:
        t0 = time.time()
        try:
            rows = fn()
        except Exception as e:  # noqa: BLE001
            print(f"{fn.__name__},0.0,ERROR:{type(e).__name__}:{e}")
            failures += 1
            continue
        for name, us, derived in rows:
            print(f"{name},{us:.3f},{derived}")
        print(f"#{fn.__name__} done in {time.time()-t0:.1f}s",
              file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == '__main__':
    main()
