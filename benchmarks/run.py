# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV.  ``--bench-engine`` instead times a fixed sweep grid through BOTH
# simulation engines (event and vectorized, which must agree bit-for-bit —
# the bench doubles as a coarse differential check), emits the per-point
# speedup column, and writes BENCH_engine.json (uploaded as a CI artifact
# so the engines' performance trajectory is tracked PR over PR);
# ``--check-against benchmarks/BENCH_baseline.json`` turns that grid into a
# regression gate: any point whose wall time (either engine) exceeds the
# committed baseline by more than ``--tolerance`` fails the run, as does a
# vectorized wall slower than the event wall on the same point (use
# ``--update-baseline`` for intentional resets, ``--current`` to gate a
# pre-measured JSON without re-running the grid).
import argparse
import json
import sys
import time
import traceback

BASELINE_PATH = "benchmarks/BENCH_baseline.json"


def figures() -> int:
    from . import paper_figs

    print("name,us_per_call,derived")
    failures = 0
    for fn in paper_figs.ALL:
        t0 = time.time()
        try:
            rows = fn()
        except Exception as e:  # noqa: BLE001
            # The CSV cell keeps the one-line summary; the full traceback
            # goes to stderr so CI logs are actionable.
            traceback.print_exc(file=sys.stderr)
            print(f"{fn.__name__},0.0,ERROR:{type(e).__name__}:{e}")
            failures += 1
            continue
        for name, us, derived in rows:
            print(f"{name},{us:.3f},{derived}")
        print(f"#{fn.__name__} done in {time.time()-t0:.1f}s",
              file=sys.stderr)
    return 1 if failures else 0


# Fixed micro-benchmark grid: (topology, n_gpus, nbytes).  Serial, one
# simulate pair per point per engine — wall times measure the engine
# itself, not the sweep pool.  Includes the paper-scale 1 GB point (epoch
# expansion), tier-shaped two-tier points, and the pod-scale 512/256-GPU
# points where the O(n^2) flow-materialization cost that motivated the
# vectorized engine dominates (ROADMAP: fig14-scale sweeps).  The special
# ("fleet", 16, 0) point times an autoscaled fleet serving run
# (repro.serving.fleet) on BOTH engines: its tiny decode collectives sat
# below the vectorization-win size until the serving hot path (geometry
# memoization + warm fast path, DESIGN.md §15) made the vectorized engine
# win at serving scale too, so it is now dual-engine and folded into the
# aggregate speedup like any other point.
def _bench_points():
    from repro.core import GB, MB
    return [
        ("single_clos", 16, 16 * MB),
        ("single_clos", 64, 1 * GB),
        ("two_tier", 256, 16 * MB),
        ("two_tier", 256, 256 * MB),
        ("two_tier", 512, 16 * MB),
        ("multi_pod", 64, 64 * MB),
        ("multi_pod", 256, 64 * MB),
        ("fleet", 16, 0),
    ]


def _fleet_bench_point(engine: str):
    from repro.serving import FleetPoint, TrafficPoint
    traffic = TrafficPoint(
        arch="granite-moe-1b-a400m", rps=16.0, arrival="bursty",
        n_requests=10, seed=7, steps_cap=40, burst_size=4,
        burstiness=24.0, prompt_mean=64, output_mean=4, engine=engine)
    return FleetPoint(traffic=traffic, replicas=2, router="least_loaded",
                      autoscale=True, min_replicas=1, max_replicas=2,
                      scale_up_queued=1, scale_down_idle_ns=5e7)


def _measure_fleet(n_gpus: int, reps: int, profile: bool = False) -> dict:
    """Time the fleet serving point on BOTH engines, interleaved best-of.

    Event and vectorized reps alternate so both engines sample the same
    scheduler-noise environment (shared boxes show 20-30% wall drift
    between measurement windows; pairing keeps the recorded speedup
    honest).  The two runs must agree bit-for-bit on every step — the
    serving stack doubles as a coarse differential check, exactly like
    the grid points.
    """
    from repro.serving.fleet import _fleet_point

    walls = {"event": float("inf"), "vectorized": float("inf")}
    results = {}
    for _ in range(reps):
        for eng in ("event", "vectorized"):
            t0 = time.perf_counter()
            results[eng] = _fleet_point((_fleet_bench_point(eng),))
            walls[eng] = min(walls[eng], time.perf_counter() - t0)
    res, vec = results["event"], results["vectorized"]
    key = [(s.t_start, s.t_end, s.comm_ns, s.walks) for s in res.steps]
    if key != [(s.t_start, s.t_end, s.comm_ns, s.walks)
               for s in vec.steps]:
        raise AssertionError(
            "engine disagreement on the fleet serving point")
    if profile:
        for eng in ("event", "vectorized"):
            _profile_point(f"fleet/gpus{n_gpus}/serving [{eng}]",
                           lambda e=eng: _fleet_point(
                               (_fleet_bench_point(e),)))
    comm = sum(s.comm_ns for s in res.steps)
    speedup = walls["event"] / walls["vectorized"]
    print(f"# fleet/gpus{n_gpus}/serving: event {walls['event']:.3f}s, "
          f"vec {walls['vectorized']:.3f}s ({speedup:.1f}x, "
          f"{len(res.steps)} steps, {res.spin_ups} spin-ups, "
          f"fastpath {vec.fastpath_step_fraction:.0%} of steps, "
          f"p99_deg={res.p99_ttft_degradation:.4f})", file=sys.stderr)
    return {"topology": "fleet", "n_gpus": n_gpus, "nbytes": 0,
            "wall_s": round(walls["event"], 4),
            "wall_vec_s": round(walls["vectorized"], 4),
            "speedup": round(speedup, 2),
            "completion_ns": round(comm, 2),
            "degradation": res.p99_ttft_degradation,
            "requests": len(res.requests)}


def _profile_point(name: str, fn) -> None:
    """Run ``fn`` once under cProfile; print the top-15 cumulative table.

    Emitted on stderr as ``#``-prefixed lines so a profiled bench run
    still produces a machine-readable JSON/CSV stream on stdout.
    """
    import cProfile
    import io
    import pstats

    pr = cProfile.Profile()
    pr.enable()
    fn()
    pr.disable()
    buf = io.StringIO()
    st = pstats.Stats(pr, stream=buf)
    st.sort_stats("cumulative").print_stats(15)
    print(f"# --- profile {name}: top 15 by cumulative time ---",
          file=sys.stderr)
    for line in buf.getvalue().splitlines():
        if line.strip():
            print(f"#   {line}", file=sys.stderr)


def measure_engine(reps: int = 3, profile: bool = False) -> dict:
    """Time the fixed grid on both engines; returns the JSON payload.

    Each point is best-of-``reps`` per engine: the minimum wall time is the
    least noise-contaminated estimate of the engine's cost, which is what a
    cross-run regression gate must compare (means absorb scheduler noise
    and flake the gate).  The two engines' results must agree exactly on
    every point — a mismatch aborts the bench, so a published speedup can
    never come from a divergent simulation.
    """
    from repro.core import ratsim
    from repro.core.config import FabricConfig, SimConfig

    points = []
    t_all = time.perf_counter()
    for topo, n, nbytes in _bench_points():
        if topo == "fleet":
            points.append(_measure_fleet(n, reps, profile=profile))
            continue
        fab = FabricConfig(n_gpus=n, topology=topo, leaf_size=16,
                           oversubscription=2.0, pod_size=16)
        walls = {}
        results = {}
        for eng in ("event", "vectorized"):
            cfg = SimConfig(fabric=fab, engine=eng)
            wall = float("inf")
            for _ in range(reps):
                t0 = time.perf_counter()
                c = ratsim.compare(nbytes, n, cfg=cfg)
                wall = min(wall, time.perf_counter() - t0)
            walls[eng] = wall
            results[eng] = c
            if profile:
                _profile_point(
                    f"{topo}/gpus{n}/{nbytes >> 20}MB [{eng}]",
                    lambda: ratsim.compare(nbytes, n, cfg=cfg))
        ce = results["event"].baseline
        cv = results["vectorized"].baseline
        if (ce.completion_ns != cv.completion_ns
                or ce.counters.requests != cv.counters.requests
                or ce.counters.walks != cv.counters.walks
                or ce.counters.by_class != cv.counters.by_class):
            raise AssertionError(
                f"engine disagreement at {topo}/gpus{n}/{nbytes >> 20}MB: "
                f"event {ce.completion_ns} vs vectorized {cv.completion_ns}")
        c = results["event"]
        speedup = walls["event"] / walls["vectorized"]
        points.append({
            "topology": topo, "n_gpus": n, "nbytes": nbytes,
            "wall_s": round(walls["event"], 4),
            "wall_vec_s": round(walls["vectorized"], 4),
            "speedup": round(speedup, 2),
            "completion_ns": c.baseline.completion_ns,
            "degradation": c.degradation,
            "requests": c.baseline.counters.requests,
        })
        print(f"# {topo}/gpus{n}/{nbytes >> 20}MB: "
              f"event {walls['event']:.3f}s, "
              f"vec {walls['vectorized']:.3f}s ({speedup:.1f}x, "
              f"deg={c.degradation:.4f})", file=sys.stderr)
    # Aggregate speedup over every dual-engine point — since the serving
    # hot path this includes the fleet serving point, so the headline now
    # covers scheduler-driven small-collective replay, not just pod-scale
    # collectives (which is why it is lower than the pre-serving 20x).
    dual = [p for p in points if "wall_vec_s" in p]
    tot_e = sum(p["wall_s"] for p in dual)
    tot_v = sum(p["wall_vec_s"] for p in dual)
    agg = tot_e / tot_v if tot_v else float("inf")
    print(f"# aggregate speedup: {tot_e:.3f}s / {tot_v:.3f}s = {agg:.1f}x",
          file=sys.stderr)
    return {"grid": "engine-v2",
            "total_wall_s": round(time.perf_counter() - t_all, 4),
            "speedup": round(agg, 2),
            "points": points}


def _point_key(p: dict) -> tuple:
    return (p["topology"], p["n_gpus"], p["nbytes"])


def _point_name(key: tuple) -> str:
    topo, n, nbytes = key
    if topo == "fleet":
        return f"fleet/gpus{n}/serving"
    return f"{topo}/gpus{n}/{nbytes >> 20}MB"


def check_against(current: dict, baseline: dict, tolerance: float,
                  min_delta_s: float = 0.05) -> list:
    """Per-point wall-time regression gate, both engines.

    Returns the list of failure messages (empty = gate passes) and prints
    the full delta table either way, so CI logs always show the trajectory.
    Per grid point it gates

    * the event wall (``wall_s``) and — when both sides carry it — the
      vectorized wall (``wall_vec_s``) against the committed baseline;
    * the vectorized wall against the event wall *of the same run*: a
      vectorized engine slower than the event engine defeats its purpose
      and fails regardless of what the baseline says.

    ``min_delta_s`` is an absolute floor on every rule: a point only fails
    when it is both ``tolerance`` slower *and* at least that many seconds
    slower — millisecond points jitter past any relative tolerance.  A
    grid mismatch (missing or extra points, e.g. a stale committed
    baseline after the grid changed) also fails — reset intentionally with
    ``--update-baseline``.
    """
    base = {_point_key(p): p for p in baseline.get("points", [])}
    cur = {_point_key(p): p for p in current.get("points", [])}
    failures = []
    print(f"# bench gate: wall-time tolerance +{tolerance:.0%} per point")
    print(f"{'point':<34s} {'base_s':>8s} {'cur_s':>8s} {'delta':>8s}")
    for key, cp in cur.items():
        bp = base.get(key)
        if bp is None:
            print(f"{_point_name(key):<34s} {'-':>8s} "
                  f"{cp['wall_s']:>8.3f} {'new':>8s}")
            failures.append(f"{_point_name(key)}: not in baseline "
                            f"(grid changed? --update-baseline)")
            continue
        for field, tag in (("wall_s", ""), ("wall_vec_s", " [vec]")):
            if field not in cp or field not in bp:
                continue
            name = _point_name(key) + tag
            delta = (cp[field] - bp[field]) / bp[field] \
                if bp[field] else float("inf")
            regressed = (delta > tolerance
                         and cp[field] - bp[field] > min_delta_s)
            flag = " REGRESSED" if regressed else ""
            print(f"{name:<34s} {bp[field]:>8.3f} "
                  f"{cp[field]:>8.3f} {delta:>+7.1%}{flag}")
            if regressed:
                failures.append(
                    f"{name}: {bp[field]:.3f}s -> "
                    f"{cp[field]:.3f}s ({delta:+.1%} > +{tolerance:.0%})")
        if ("wall_vec_s" in cp
                and cp["wall_vec_s"] > cp["wall_s"]
                and cp["wall_vec_s"] - cp["wall_s"] > min_delta_s):
            failures.append(
                f"{_point_name(key)}: vectorized ({cp['wall_vec_s']:.3f}s) "
                f"slower than event ({cp['wall_s']:.3f}s)")
    for key in base:
        if key not in cur:
            failures.append(f"{_point_name(key)}: in baseline but not "
                            f"measured (grid changed? --update-baseline)")
    for msg in failures:
        print(f"# FAIL {msg}", file=sys.stderr)
    return failures


def main() -> None:
    p = argparse.ArgumentParser(prog="python -m benchmarks.run")
    p.add_argument("--bench-engine", action="store_true",
                   help="time the fixed engine grid and write a JSON "
                        "artifact instead of printing the figure CSV")
    p.add_argument("--out", default="BENCH_engine.json",
                   help="output path for --bench-engine")
    p.add_argument("--profile", action="store_true",
                   help="with --bench-engine: run each grid point once "
                        "more under cProfile and print a per-point top-15 "
                        "cumulative hotspot table on stderr, so perf work "
                        "can cite measured hotspots")
    p.add_argument("--check-against", default=None, metavar="BASELINE",
                   help="gate the engine grid against this committed "
                        "baseline JSON (fails on per-point wall-time "
                        "regressions beyond --tolerance)")
    p.add_argument("--current", default=None, metavar="JSON",
                   help="use a pre-measured BENCH_engine.json for "
                        "--check-against / --update-baseline instead of "
                        "re-running the grid")
    p.add_argument("--tolerance", type=float, default=0.35,
                   help="allowed fractional wall-time regression per "
                        "point (default 0.35)")
    p.add_argument("--min-delta-s", type=float, default=0.05,
                   help="absolute wall-time floor: a point fails only "
                        "when it is also at least this many seconds "
                        "slower (default 0.05)")
    p.add_argument("--update-baseline", action="store_true",
                   help="write the measured grid to the baseline path "
                        "(intentional reset); combine with --check-against "
                        "to choose the path")
    args = p.parse_args()

    if not (args.bench_engine or args.check_against
            or args.update_baseline):
        if args.current:
            p.error("--current requires --check-against or "
                    "--update-baseline (it would otherwise be ignored)")
        if args.profile:
            p.error("--profile requires --bench-engine (the figure CSV "
                    "path does not run the grid)")
        sys.exit(figures())

    if args.current:
        if args.profile:
            p.error("--profile needs a live measurement; it cannot "
                    "profile a pre-measured --current JSON")
        with open(args.current) as f:
            payload = json.load(f)
    else:
        payload = measure_engine(profile=args.profile)
        if args.bench_engine:
            with open(args.out, "w") as f:
                json.dump(payload, f, indent=2)
            print(f"# wrote {args.out} (total {payload['total_wall_s']}s)",
                  file=sys.stderr)

    rc = 0
    if args.update_baseline:
        path = args.check_against or BASELINE_PATH
        with open(path, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"# baseline updated: {path}", file=sys.stderr)
    elif args.check_against:
        with open(args.check_against) as f:
            baseline = json.load(f)
        rc = 1 if check_against(payload, baseline, args.tolerance,
                                args.min_delta_s) else 0
    sys.exit(rc)


if __name__ == '__main__':
    main()
