# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV.  ``--bench-engine`` instead times a fixed sweep grid through the
# epoch engine and writes BENCH_engine.json (uploaded as a CI artifact so
# the engine's performance trajectory is tracked PR over PR);
# ``--check-against benchmarks/BENCH_baseline.json`` turns that grid into a
# regression gate: any point whose wall time exceeds the committed baseline
# by more than ``--tolerance`` fails the run (use ``--update-baseline``
# for intentional resets, ``--current`` to gate a pre-measured JSON
# without re-running the grid).
import argparse
import json
import sys
import time
import traceback

BASELINE_PATH = "benchmarks/BENCH_baseline.json"


def figures() -> int:
    from . import paper_figs

    print("name,us_per_call,derived")
    failures = 0
    for fn in paper_figs.ALL:
        t0 = time.time()
        try:
            rows = fn()
        except Exception as e:  # noqa: BLE001
            # The CSV cell keeps the one-line summary; the full traceback
            # goes to stderr so CI logs are actionable.
            traceback.print_exc(file=sys.stderr)
            print(f"{fn.__name__},0.0,ERROR:{type(e).__name__}:{e}")
            failures += 1
            continue
        for name, us, derived in rows:
            print(f"{name},{us:.3f},{derived}")
        print(f"#{fn.__name__} done in {time.time()-t0:.1f}s",
              file=sys.stderr)
    return 1 if failures else 0


# Fixed micro-benchmark grid: (topology, n_gpus, nbytes).  Serial, one
# simulate pair per point — wall times measure the engine itself, not the
# sweep pool.  Includes the paper-scale 1 GB point and a two-tier 256-GPU
# point so both the epoch expansion and the tier-shaping path are priced.
def _bench_points():
    from repro.core import GB, MB
    return [
        ("single_clos", 16, 16 * MB),
        ("single_clos", 64, 1 * GB),
        ("two_tier", 256, 16 * MB),
        ("two_tier", 256, 256 * MB),
        ("multi_pod", 64, 64 * MB),
    ]


def measure_engine(reps: int = 3) -> dict:
    """Time the fixed grid; returns the BENCH_engine.json payload.

    Each point is best-of-``reps``: the minimum wall time is the least
    noise-contaminated estimate of the engine's cost, which is what a
    cross-run regression gate must compare (means absorb scheduler noise
    and flake the gate).
    """
    from repro.core import ratsim
    from repro.core.config import FabricConfig, SimConfig

    points = []
    t_all = time.perf_counter()
    for topo, n, nbytes in _bench_points():
        fab = FabricConfig(n_gpus=n, topology=topo, leaf_size=16,
                           oversubscription=2.0, pod_size=16)
        wall = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            c = ratsim.compare(nbytes, n, cfg=SimConfig(fabric=fab))
            wall = min(wall, time.perf_counter() - t0)
        points.append({
            "topology": topo, "n_gpus": n, "nbytes": nbytes,
            "wall_s": round(wall, 4),
            "completion_ns": c.baseline.completion_ns,
            "degradation": c.degradation,
            "requests": c.baseline.counters.requests,
        })
        print(f"# {topo}/gpus{n}/{nbytes >> 20}MB: {wall:.3f}s "
              f"(deg={c.degradation:.4f})", file=sys.stderr)
    return {"grid": "engine-v1",
            "total_wall_s": round(time.perf_counter() - t_all, 4),
            "points": points}


def _point_key(p: dict) -> tuple:
    return (p["topology"], p["n_gpus"], p["nbytes"])


def _point_name(key: tuple) -> str:
    topo, n, nbytes = key
    return f"{topo}/gpus{n}/{nbytes >> 20}MB"


def check_against(current: dict, baseline: dict, tolerance: float,
                  min_delta_s: float = 0.05) -> list:
    """Per-point wall-time regression gate.

    Returns the list of failure messages (empty = gate passes) and prints
    the full delta table either way, so CI logs always show the trajectory.
    ``min_delta_s`` is an absolute floor: a point only fails when it is
    both ``tolerance`` slower *and* at least that many seconds slower —
    millisecond points jitter past any relative tolerance.  A grid
    mismatch (missing or extra points, e.g. a stale committed baseline
    after the grid changed) also fails — reset intentionally with
    ``--update-baseline``.
    """
    base = {_point_key(p): p for p in baseline.get("points", [])}
    cur = {_point_key(p): p for p in current.get("points", [])}
    failures = []
    print(f"# bench gate: wall-time tolerance +{tolerance:.0%} per point")
    print(f"{'point':<28s} {'base_s':>8s} {'cur_s':>8s} {'delta':>8s}")
    for key, cp in cur.items():
        bp = base.get(key)
        if bp is None:
            print(f"{_point_name(key):<28s} {'-':>8s} "
                  f"{cp['wall_s']:>8.3f} {'new':>8s}")
            failures.append(f"{_point_name(key)}: not in baseline "
                            f"(grid changed? --update-baseline)")
            continue
        delta = (cp["wall_s"] - bp["wall_s"]) / bp["wall_s"] \
            if bp["wall_s"] else float("inf")
        regressed = (delta > tolerance
                     and cp["wall_s"] - bp["wall_s"] > min_delta_s)
        flag = " REGRESSED" if regressed else ""
        print(f"{_point_name(key):<28s} {bp['wall_s']:>8.3f} "
              f"{cp['wall_s']:>8.3f} {delta:>+7.1%}{flag}")
        if regressed:
            failures.append(
                f"{_point_name(key)}: {bp['wall_s']:.3f}s -> "
                f"{cp['wall_s']:.3f}s ({delta:+.1%} > +{tolerance:.0%})")
    for key in base:
        if key not in cur:
            failures.append(f"{_point_name(key)}: in baseline but not "
                            f"measured (grid changed? --update-baseline)")
    for msg in failures:
        print(f"# FAIL {msg}", file=sys.stderr)
    return failures


def main() -> None:
    p = argparse.ArgumentParser(prog="python -m benchmarks.run")
    p.add_argument("--bench-engine", action="store_true",
                   help="time the fixed engine grid and write a JSON "
                        "artifact instead of printing the figure CSV")
    p.add_argument("--out", default="BENCH_engine.json",
                   help="output path for --bench-engine")
    p.add_argument("--check-against", default=None, metavar="BASELINE",
                   help="gate the engine grid against this committed "
                        "baseline JSON (fails on per-point wall-time "
                        "regressions beyond --tolerance)")
    p.add_argument("--current", default=None, metavar="JSON",
                   help="use a pre-measured BENCH_engine.json for "
                        "--check-against / --update-baseline instead of "
                        "re-running the grid")
    p.add_argument("--tolerance", type=float, default=0.35,
                   help="allowed fractional wall-time regression per "
                        "point (default 0.35)")
    p.add_argument("--min-delta-s", type=float, default=0.05,
                   help="absolute wall-time floor: a point fails only "
                        "when it is also at least this many seconds "
                        "slower (default 0.05)")
    p.add_argument("--update-baseline", action="store_true",
                   help="write the measured grid to the baseline path "
                        "(intentional reset); combine with --check-against "
                        "to choose the path")
    args = p.parse_args()

    if not (args.bench_engine or args.check_against
            or args.update_baseline):
        if args.current:
            p.error("--current requires --check-against or "
                    "--update-baseline (it would otherwise be ignored)")
        sys.exit(figures())

    if args.current:
        with open(args.current) as f:
            payload = json.load(f)
    else:
        payload = measure_engine()
        if args.bench_engine:
            with open(args.out, "w") as f:
                json.dump(payload, f, indent=2)
            print(f"# wrote {args.out} (total {payload['total_wall_s']}s)",
                  file=sys.stderr)

    rc = 0
    if args.update_baseline:
        path = args.check_against or BASELINE_PATH
        with open(path, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"# baseline updated: {path}", file=sys.stderr)
    elif args.check_against:
        with open(args.check_against) as f:
            baseline = json.load(f)
        rc = 1 if check_against(payload, baseline, args.tolerance,
                                args.min_delta_s) else 0
    sys.exit(rc)


if __name__ == '__main__':
    main()
