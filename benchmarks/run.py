# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV.  ``--bench-engine`` instead times a fixed sweep grid through the
# epoch engine and writes BENCH_engine.json (uploaded as a CI artifact so
# the engine's performance trajectory is tracked PR over PR).
import argparse
import json
import sys
import time


def figures() -> int:
    from . import paper_figs

    print("name,us_per_call,derived")
    failures = 0
    for fn in paper_figs.ALL:
        t0 = time.time()
        try:
            rows = fn()
        except Exception as e:  # noqa: BLE001
            print(f"{fn.__name__},0.0,ERROR:{type(e).__name__}:{e}")
            failures += 1
            continue
        for name, us, derived in rows:
            print(f"{name},{us:.3f},{derived}")
        print(f"#{fn.__name__} done in {time.time()-t0:.1f}s",
              file=sys.stderr)
    return 1 if failures else 0


# Fixed micro-benchmark grid: (topology, n_gpus, nbytes).  Serial, one
# simulate pair per point — wall times measure the engine itself, not the
# sweep pool.  Includes the paper-scale 1 GB point and a two-tier 256-GPU
# point so both the epoch expansion and the tier-shaping path are priced.
def _bench_points():
    from repro.core import GB, MB
    return [
        ("single_clos", 16, 16 * MB),
        ("single_clos", 64, 1 * GB),
        ("two_tier", 256, 16 * MB),
        ("two_tier", 256, 256 * MB),
        ("multi_pod", 64, 64 * MB),
    ]


def bench_engine(out_path: str) -> int:
    from repro.core import ratsim
    from repro.core.config import FabricConfig, SimConfig

    points = []
    t_all = time.perf_counter()
    for topo, n, nbytes in _bench_points():
        fab = FabricConfig(n_gpus=n, topology=topo, leaf_size=16,
                           oversubscription=2.0, pod_size=16)
        t0 = time.perf_counter()
        c = ratsim.compare(nbytes, n, cfg=SimConfig(fabric=fab))
        wall = time.perf_counter() - t0
        points.append({
            "topology": topo, "n_gpus": n, "nbytes": nbytes,
            "wall_s": round(wall, 4),
            "completion_ns": c.baseline.completion_ns,
            "degradation": c.degradation,
            "requests": c.baseline.counters.requests,
        })
        print(f"# {topo}/gpus{n}/{nbytes >> 20}MB: {wall:.3f}s "
              f"(deg={c.degradation:.4f})", file=sys.stderr)
    payload = {"grid": "engine-v1",
               "total_wall_s": round(time.perf_counter() - t_all, 4),
               "points": points}
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {out_path} (total {payload['total_wall_s']}s)",
          file=sys.stderr)
    return 0


def main() -> None:
    p = argparse.ArgumentParser(prog="python -m benchmarks.run")
    p.add_argument("--bench-engine", action="store_true",
                   help="time the fixed engine grid and write a JSON "
                        "artifact instead of printing the figure CSV")
    p.add_argument("--out", default="BENCH_engine.json",
                   help="output path for --bench-engine")
    args = p.parse_args()
    sys.exit(bench_engine(args.out) if args.bench_engine else figures())


if __name__ == '__main__':
    main()
