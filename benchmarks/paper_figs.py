"""Benchmarks reproducing every figure of the paper, one function per figure.

Each returns a list of CSV rows ``(name, us_per_call, derived)`` where
``us_per_call`` is the simulated collective completion time and ``derived``
carries the figure's headline metric (degradation ratio, latency, fraction,
hit-rates...).  ``check_*`` fields assert the paper's claims.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

from repro.core import ratsim, paper_config, simulate, KB, MB, GB
from repro.core.config import (TLBConfig, PreTranslationConfig,
                               PrefetchConfig, FabricConfig, SimConfig,
                               TranslationConfig)

SIZES = [1 * MB, 4 * MB, 16 * MB, 64 * MB, 256 * MB, 1 * GB, 4 * GB]
GPUS = [8, 16, 32, 64]

Row = Tuple[str, float, str]

# Shared sweep memo: fig4 and fig5 read the same (n_gpus, size) grid, and
# ratsim.sweep fans it out over a process pool exactly once.
_GRID_CACHE: dict = {}


def _grid():
    return ratsim.sweep(SIZES, GPUS, cache=_GRID_CACHE)


def fig4_overhead() -> List[Row]:
    """Fig 4: RAT performance degradation vs ideal, 8-64 GPUs x 1MB-4GB."""
    grid = _grid()
    rows = []
    for n in GPUS:
        for s in SIZES:
            c = grid[(n, s)]
            rows.append((f"fig4/gpus{n}/size{s//MB}MB",
                         c.baseline.completion_ns / 1e3,
                         f"degradation={c.degradation:.4f}"))
    # headline claims
    d1 = max(grid[(n, 1 * MB)].degradation for n in GPUS)
    d16 = np.mean([grid[(n, 16 * MB)].degradation for n in GPUS])
    rows.append(("fig4/check_1MB_up_to_1.4x", 0.0,
                 f"max_deg={d1:.3f} in(1.3,1.5)={1.3 < d1 < 1.5}"))
    rows.append(("fig4/check_16MB_about_1.1x", 0.0,
                 f"mean_deg={d16:.3f} in(1.05,1.2)={1.05 < d16 < 1.2}"))
    return rows


def fig5_latency() -> List[Row]:
    """Fig 5: mean RAT latency per request, same sweep (memoized: the grid
    is priced once, by whichever of fig4/fig5 runs first)."""
    grid = _grid()
    rows = []
    for n in GPUS:
        for s in SIZES:
            r = grid[(n, s)].baseline
            rows.append((f"fig5/gpus{n}/size{s//MB}MB",
                         r.completion_ns / 1e3,
                         f"mean_rat_ns={r.mean_rat_ns:.1f}"))
    return rows


def fig6_breakdown() -> List[Row]:
    """Fig 6: round-trip latency fraction spent in RAT (16 GPUs)."""
    rows = []
    for s in SIZES:
        c = ratsim.compare(s, 16)
        b = c.baseline.breakdown()
        rows.append((f"fig6/size{s//MB}MB", c.baseline.completion_ns / 1e3,
                     f"rat_frac={c.rat_fraction:.3f};"
                     f"oneway={b['oneway_ns']:.0f};rat={b['rat_ns']:.0f};"
                     f"hbm={b['hbm_ns']:.0f};return={b['return_ns']:.0f}"))
    f1 = ratsim.compare(1 * MB, 16).rat_fraction
    rows.append(("fig6/check_1MB_rat_fraction", 0.0,
                 f"frac={f1:.3f} paper~0.30 in(0.2,0.5)={0.2 < f1 < 0.5}"))
    return rows


def fig7_hier() -> List[Row]:
    """Fig 7: hit/miss breakdown at target translation modules (16 GPUs)."""
    rows = []
    for s in SIZES:
        ctr = ratsim.run(s, 16).counters
        t = ctr.requests
        fr = {k: v / t for k, v in ctr.by_class.items()}
        l1lvl = fr["l1_hit"] + fr["l1_mshr_hum"]
        rows.append((f"fig7/size{s//MB}MB", 0.0,
                     f"l1={fr['l1_hit']:.3f};l1_mshr={fr['l1_mshr_hum']:.3f};"
                     f"l2={fr['l2_hit']:.4f};l2_hum={fr['l2_hum']:.4f};"
                     f"walk={fr['walk']:.4f};l1_level={l1lvl:.3f}"
                     f";check_gt90pct={l1lvl > 0.9}"))
    return rows


def fig8_hum() -> List[Row]:
    """Fig 8: L1-level decomposition (hits vs hit-under-miss) vs size."""
    rows = []
    prev = 0.0
    for s in SIZES:
        ctr = ratsim.run(s, 16).counters
        fr_hit = ctr.by_class["l1_hit"] / ctr.requests
        fr_hum = ctr.by_class["l1_mshr_hum"] / ctr.requests
        rows.append((f"fig8/size{s//MB}MB", 0.0,
                     f"l1_hit={fr_hit:.3f};hum={fr_hum:.3f};"
                     f"hits_grow={fr_hit >= prev}"))
        prev = fr_hit
    return rows


def fig9_10_traces() -> List[Row]:
    """Figs 9/10: per-request RAT latency traces, 1MB and 256MB (16 GPUs)."""
    rows = []
    for s, name in [(1 * MB, "fig9_1MB"), (256 * MB, "fig10_256MB")]:
        cfg = paper_config(16).replace(collect_trace=True)
        r = simulate(s, cfg)
        tr = r.trace
        spikes = float(np.mean(tr > 4 * 50.0))
        rows.append((f"{name}/trace", r.completion_ns / 1e3,
                     f"median_ns={np.median(tr):.0f};"
                     f"p99_ns={np.percentile(tr, 99):.0f};"
                     f"max_ns={tr.max():.0f};spike_frac={spikes:.4f}"))
    return rows


def fig11_l2_sweep() -> List[Row]:
    """Fig 11: L2-TLB size sweep at 16MB / 32 GPUs."""
    rows = []
    base = None
    for entries in (16, 32, 64, 512, 32768):
        cfg = paper_config(32)
        tr = dataclasses.replace(
            cfg.translation,
            l2=TLBConfig(entries=entries, assoc=2, hit_latency_ns=100.0,
                         mshr_entries=512))
        c = ratsim.compare(16 * MB, 32, cfg=cfg.replace(translation=tr))
        if entries == 32:
            base = c.degradation
        rows.append((f"fig11/l2_{entries}", c.baseline.completion_ns / 1e3,
                     f"degradation={c.degradation:.4f}"))
    big = rows[-1][2]
    rows.append(("fig11/check_flat_beyond_32", 0.0,
                 f"deg32={base:.4f};{big};flat={'degradation=%.4f' % base == big}"))
    return rows


def opt_pretranslation() -> List[Row]:
    """Paper §6.1 evaluated: fused pre-translation recovers small collectives."""
    rows = []
    for n in (16, 64):
        for s in (1 * MB, 4 * MB, 16 * MB):
            base = ratsim.compare(s, n)
            cfg = paper_config(n).replace(
                pretranslation=PreTranslationConfig(
                    enabled=True, lead_time_ns=3000.0, pages_per_flow=0))
            opt = simulate(s, cfg)
            deg = opt.completion_ns / base.ideal.completion_ns
            rows.append((f"opt_pretrans/gpus{n}/size{s//MB}MB",
                         opt.completion_ns / 1e3,
                         f"base_deg={base.degradation:.3f};opt_deg={deg:.3f};"
                         f"recovers={deg < 1.05}"))
    return rows


def opt_prefetch() -> List[Row]:
    """Paper §6.2 evaluated: software TLB prefetch under scarce ingress
    buffering (mid-stream walks stall the port; prefetch hides them)."""
    rows = []
    for s in (16 * MB, 64 * MB, 256 * MB):
        fab = FabricConfig(n_gpus=16, ingress_entries=64)
        cfg = paper_config(16).replace(fabric=fab)
        base = simulate(s, cfg)
        opt = simulate(s, cfg.replace(
            prefetch=PrefetchConfig(enabled=True, depth=2)))
        speedup = base.completion_ns / opt.completion_ns
        rows.append((f"opt_prefetch/size{s//MB}MB", opt.completion_ns / 1e3,
                     f"speedup={speedup:.3f};helps={speedup > 1.0}"))
    return rows


# multipod_all_to_all is deliberately absent: on fig12's flat default
# topology it coincides with hier_all_to_all (pod group == node group); its
# figure lives in fig14, on an actual multi_pod fabric.
COLLECTIVES = ("all_to_all", "ring_allreduce", "rd_allreduce", "all_gather",
               "reduce_scatter", "broadcast", "hier_all_to_all")


def fig12_collective_sweep() -> List[Row]:
    """Fig 12 (ours, beyond the paper): Fig-4-style RAT degradation sweep
    across collective patterns — one run answers which collectives are
    RAT-sensitive at which sizes and GPU counts."""
    rows = []
    degs_small = {}
    for coll in COLLECTIVES:
        for n in (16, 64):
            for s in (1 * MB, 16 * MB, 256 * MB):
                c = ratsim.compare(s, n, collective=coll)
                if n == 16 and s == 1 * MB:
                    degs_small[coll] = c.degradation
                rows.append((f"fig12/{coll}/gpus{n}/size{s//MB}MB",
                             c.baseline.completion_ns / 1e3,
                             f"degradation={c.degradation:.4f};"
                             f"mean_rat_ns={c.baseline.mean_rat_ns:.1f}"))
    # Headline: all-pairs pays n-1 cold walks concurrently on one step's
    # critical path (worst), broadcast trees re-pay the cold working set at
    # every hop (close behind); bandwidth-optimal rings amortize a single
    # cold walk over 2(n-1) steps (best).
    worst = max(degs_small, key=degs_small.get)
    best = min(degs_small, key=degs_small.get)
    rows.append(("fig12/check_1MB_16gpu_sensitivity_spread", 0.0,
                 f"worst={worst}:{degs_small[worst]:.3f};"
                 f"best={best}:{degs_small[best]:.3f};"
                 f"spread={degs_small[worst] - degs_small[best]:.3f}"))
    return rows


def fig13_workload_replay() -> List[Row]:
    """Fig 13 (ours, beyond the paper): per-token RAT degradation trajectory
    of a real MoE decode loop replayed through a persistent-TLB session.

    Token 0 pays the cold Link-TLB walks of every layer's dispatch/combine
    all-to-all; later tokens reuse the warmed entries — the paper's
    warm-vs-cold claim evaluated on the workload it matters for.  The large
    qwen3-moe rows show the contrasting regime: its per-layer buffer
    working set exceeds L2 Link-TLB reach, so even steady-state tokens keep
    walking (capacity, not cold, misses).
    """
    from repro.workloads import derive_workload, replay

    rows = []
    for arch, n_tok in (("granite-moe-1b-a400m", 4),
                        ("qwen3-moe-235b-a22b", 2)):
        trace = derive_workload(arch, "decode_32k", n_gpus=16, n_steps=n_tok)
        rep = replay(trace)
        for s in rep.steps:
            rows.append((f"fig13/{arch}/token{s.step}", s.comm_ns / 1e3,
                         f"degradation={s.degradation:.4f};walks={s.walks}"))
        cold, steady = rep.cold_degradation, rep.steady_degradation
        rows.append((f"fig13/{arch}/check_cold_above_steady", 0.0,
                     f"cold={cold:.4f};steady={steady:.4f};"
                     f"warms_up={cold > steady}"))
    return rows


def fig13_workload_replay_calibrated() -> List[Row]:
    """Fig 13 (calibrated): the same decode replays with compute windows
    *measured* on the repaired Pallas kernel tier instead of the roofline
    guess (repro.workloads.calibrate), plus the paper-§6.1 question those
    windows finally make answerable: how much of each token-0 cold-RAT
    excess could a fused pre-translation pass hide inside the calibrated
    compute window that precedes the collective — reported per phase
    (per-layer windows) and per arch.
    """
    from repro.workloads import (calibrate, default_cache_path,
                                 derive_workload, replay)

    rows = []
    for arch, n_tok in (("granite-moe-1b-a400m", 4),
                        ("qwen3-moe-235b-a22b", 2)):
        prof = calibrate(arch, "decode_32k", n_gpus=16,
                         cache_path=default_cache_path(arch, "decode_32k",
                                                       16))
        trace = derive_workload(arch, "decode_32k", n_gpus=16,
                                n_steps=n_tok, compute_profile=prof)
        rep = replay(trace, compute_profile=prof)
        for s in rep.steps:
            rows.append((f"fig13cal/{arch}/token{s.step}", s.comm_ns / 1e3,
                         f"degradation={s.degradation:.4f};walks={s.walks};"
                         f"compute_us={s.compute_ns/1e3:.2f}"))
        # Fused pre-translation headroom: a pre-translation pass issued with
        # the producing compute hides at most min(window, cold excess) of
        # each collective's RAT overhead.  Token 0 only — that is where the
        # cold walks live.
        ideal_ns = {(r.collective, r.nbytes, r.n_gpus): r.completion_ns
                    for r in rep.ideal_calls}
        by_phase: dict = {}
        for c, rec in zip(trace.calls, rep.calls):
            if c.step != 0:
                continue
            ex = rec.completion_ns - ideal_ns[(c.collective, c.nbytes,
                                               c.group)]
            if ex <= 0:
                continue
            key = c.phase or "untagged"
            agg = by_phase.setdefault(key, [0.0, 0.0])
            agg[0] += ex
            agg[1] += min(c.compute_ns, ex)
        tot_ex = sum(v[0] for v in by_phase.values())
        tot_hide = sum(v[1] for v in by_phase.values())
        for ph, (ex, hide) in sorted(by_phase.items()):
            rows.append((f"fig13cal/{arch}/hide/{ph}", 0.0,
                         f"cold_excess_us={ex/1e3:.2f};"
                         f"hideable_us={hide/1e3:.2f};"
                         f"frac={hide/ex:.3f}"))
        rows.append((f"fig13cal/{arch}/pretrans_hiding", 0.0,
                     f"cold_excess_us={tot_ex/1e3:.2f};"
                     f"hideable_us={tot_hide/1e3:.2f};"
                     f"frac={tot_hide/tot_ex if tot_ex else 0.0:.3f}"))
    return rows


GPUS14 = (16, 64, 256, 1024)
TOPOS14 = ("single_clos", "two_tier", "multi_pod")


def fig14_topology_scaling() -> List[Row]:
    """Fig 14 (ours, beyond the paper): pod-scale RAT degradation, 16 -> 1024
    GPUs, per topology, cold vs warm Link TLBs.

    One sweep (fanned over the process pool) prices every (topology, pod
    size, buffer size) point with ``iterations=2``: iteration 0 is the cold
    collective, iteration 1 reruns it on the warmed TLBs — so each point
    yields the cold and the warm degradation against the same zero-RAT
    ideal.  Tier parameters: 16-GPU leaves under a 2x-oversubscribed spine
    (``two_tier``) and 16-GPU Clos pods over a 4x-oversubscribed scale-out
    hop (``multi_pod``); at 16 GPUs both degenerate to the single Clos, so
    the three curves share their leftmost point by construction.
    """
    sizes = (1 * MB, 16 * MB)
    base = SimConfig(fabric=FabricConfig(leaf_size=16, oversubscription=2.0,
                                         pod_size=16),
                     iterations=2)
    grid = ratsim.sweep(sizes, GPUS14, topologies=TOPOS14, base_cfg=base)
    rows = []
    for topo in TOPOS14:
        for n in GPUS14:
            for s in sizes:
                c = grid[(topo, n, s)]
                b, i = c.baseline.iterations, c.ideal.iterations
                cold = b[0].completion_ns / i[0].completion_ns
                warm = b[1].completion_ns / i[1].completion_ns
                rows.append((f"fig14/{topo}/gpus{n}/size{s//MB}MB",
                             b[0].completion_ns / 1e3,
                             f"cold_deg={cold:.4f};warm_deg={warm:.4f}"))
    # Headline checks: the 16-GPU points coincide across topologies
    # (degenerate tiers), and warm TLBs erase (almost all of) the cold tax
    # at every scale and topology.
    agree = all(
        grid[(t, 16, s)].baseline.completion_ns
        == grid[("single_clos", 16, s)].baseline.completion_ns
        for t in TOPOS14 for s in sizes)
    rows.append(("fig14/check_16gpu_topologies_degenerate", 0.0,
                 f"agree={agree}"))
    warm_ok = all(
        (grid[(t, n, s)].baseline.iterations[1].completion_ns
         <= grid[(t, n, s)].baseline.iterations[0].completion_ns + 1e-9)
        for t in TOPOS14 for n in GPUS14 for s in sizes)
    rows.append(("fig14/check_warm_never_worse_than_cold", 0.0,
                 f"ok={warm_ok}"))
    # Pattern choice on the scale-out hop: pod-staged multipod_all_to_all
    # vs direct all-to-all on the same multi_pod fabric — staging trades
    # 2x volume for (pods-1) oversubscribed crossings per GPU instead of
    # (n - n/pods).
    for n in (64, 256):
        fab = FabricConfig(n_gpus=n, topology="multi_pod", pod_size=16)
        direct = ratsim.compare(16 * MB, n, cfg=SimConfig(fabric=fab))
        staged = ratsim.compare(
            16 * MB, n,
            cfg=SimConfig(fabric=fab, collective="multipod_all_to_all"))
        rows.append((f"fig14/multipod_vs_direct/gpus{n}", 0.0,
                     f"direct_us={direct.baseline.completion_ns/1e3:.2f};"
                     f"staged_us={staged.baseline.completion_ns/1e3:.2f};"
                     f"direct_deg={direct.degradation:.4f};"
                     f"staged_deg={staged.degradation:.4f}"))
    return rows


# fig15 serving grid: one arch (the small latency-sensitive MoE — the
# paper's 1.4x regime), short outputs so several busy/idle cycles fit the
# step cap, retention well under the inter-burst gaps so every quiet period
# flushes the warmed Link TLBs.
_FIG15_BASE = dict(arch="granite-moe-1b-a400m", n_requests=24, seed=7,
                   retention_ns=50_000.0, steps_cap=120, burst_size=4,
                   burstiness=24.0, prompt_mean=128, output_mean=8)


def fig15_serving_tail_latency() -> List[Row]:
    """Fig 15 (ours, beyond the paper): request-level serving tail latency.

    Bursty arrivals drive a continuous-batching serving simulation
    (repro.serving) in which idle gaps between bursts outlive
    ``tlb_retention_ns``: each burst's leading steps re-pay the cold
    Link-TLB walks, so RAT degradation concentrates in the TTFT *tail*
    (p99 > mean) — the paper's small-collective cold-miss regime expressed
    as what it does to serving SLOs.  The §6 optimizations are measured on
    the same stream: fused pre-translation (§6.1) claws tail latency back,
    software prefetch (§6.2) is reported for completeness (decode
    collectives are too small to build mid-stream walk queues).
    """
    from repro.serving import TrafficPoint, sweep_traffic

    pts = {
        "bursty/single_clos/l2_512/rps16": TrafficPoint(
            rps=16.0, arrival="bursty", **_FIG15_BASE),
        "bursty/two_tier/l2_512/rps16": TrafficPoint(
            rps=16.0, arrival="bursty", topology="two_tier", leaf_size=8,
            oversubscription=2.0, **_FIG15_BASE),
        "bursty/single_clos/l2_64/rps16": TrafficPoint(
            rps=16.0, arrival="bursty", l2_entries=64, **_FIG15_BASE),
        "bursty/single_clos/l2_512/rps4": TrafficPoint(
            rps=4.0, arrival="bursty", **_FIG15_BASE),
        "poisson/single_clos/l2_512/rps16": TrafficPoint(
            rps=16.0, arrival="poisson", **_FIG15_BASE),
        "bursty/single_clos/l2_512/rps16/pretrans": TrafficPoint(
            rps=16.0, arrival="bursty", pretranslation=True, **_FIG15_BASE),
        "bursty/single_clos/l2_512/rps16/prefetch": TrafficPoint(
            rps=16.0, arrival="bursty", prefetch=True, **_FIG15_BASE),
    }
    grid = sweep_traffic(list(pts.values()))
    rows = []
    res = {name: grid[pt] for name, pt in pts.items()}
    for name, r in res.items():
        ttft = r.ttft_percentiles()
        cold, warm = r.cold_comm_ns, r.warm_comm_ns
        rows.append((f"fig15/{name}", ttft[50.0] / 1e3,
                     f"mean_deg={r.mean_ttft_degradation:.4f};"
                     f"p99_deg={r.p99_ttft_degradation:.4f};"
                     f"ttft_p99_us={ttft[99.0]/1e3:.1f};"
                     f"cold_steps={r.cold_steps};"
                     f"cold_frac={cold/(cold+warm or 1):.4f}"))
    bursty = [n for n in res if n.startswith("bursty") and "/pre" not in n]
    tails = {n: (res[n].p99_ttft_degradation, res[n].mean_ttft_degradation)
             for n in bursty}
    rows.append(("fig15/check_bursty_tail_concentration", 0.0,
                 "p99_exceeds_mean="
                 + str(all(p > m for p, m in tails.values()))
                 + ";" + ";".join(f"{n.split('/', 1)[0]}_{i}="
                                  f"{p:.3f}>{m:.3f}"
                                  for i, (n, (p, m))
                                  in enumerate(tails.items()))))
    base = res["bursty/single_clos/l2_512/rps16"]
    pre = res["bursty/single_clos/l2_512/rps16/pretrans"]
    pf = res["bursty/single_clos/l2_512/rps16/prefetch"]
    rows.append(("fig15/check_pretranslation_claws_back_tail", 0.0,
                 f"base_p99={base.p99_ttft_degradation:.4f};"
                 f"pretrans_p99={pre.p99_ttft_degradation:.4f};"
                 f"claws_back="
                 f"{pre.p99_ttft_degradation < base.p99_ttft_degradation}"))
    rows.append(("fig15/prefetch_delta", 0.0,
                 f"base_p99={base.p99_ttft_degradation:.4f};"
                 f"prefetch_p99={pf.p99_ttft_degradation:.4f}"))
    return rows


# fig16 fleet grid: the fig15 serving regime served by a *fleet* of pod
# replicas.  No TLB retention — a warmed replica stays warm forever, so the
# only cold-RAT events after the initial warmup are replicas *born* cold by
# the autoscaler.  Steady-state percentiles discard the first quarter of
# the stream (both fleets start cold at t=0; the comparison isolates the
# spin-up tax, not the shared warmup).
_FIG16_BASE = dict(arch="granite-moe-1b-a400m", n_requests=32, seed=7,
                   steps_cap=400, burst_size=4, burstiness=24.0,
                   prompt_mean=128, output_mean=8, rps=16.0,
                   arrival="bursty")
_FIG16_SLO = 1.25          # p99 TTFT degradation the fleet must hold


def _steady_p99_deg(res, after_ns: float) -> float:
    d = [r.ttft_degradation for r in res.first_token_served
         if r.req.arrival_ns >= after_ns
         and r.ttft_degradation is not None]
    return float(np.percentile(d, 99.0)) if d else float("nan")


def fig16_fleet_scaling() -> List[Row]:
    """Fig 16 (ours, beyond the paper): fleet provisioning vs the RAT tax.

    The same bursty stream served by fleets of pod replicas
    (repro.serving.fleet): replica counts and routing policies answer
    "what holds p99 TTFT degradation under the SLO at this rps", and a
    queue-depth autoscaler at *equal aggregate capacity* shows the cost of
    elasticity — every replica it spins up starts with stone-cold Link
    TLBs, so scale-up events re-inject the cold-walk warmup into the
    steady-state tail that a statically provisioned (once-warmed) fleet
    has already paid off.
    """
    from repro.serving import FleetPoint, TrafficPoint, sweep_fleet

    traffic = TrafficPoint(**_FIG16_BASE)
    reqs = traffic.requests()
    cut_ns = reqs[len(reqs) // 4].arrival_ns
    churn = dict(autoscale=True, min_replicas=1, scale_up_queued=1,
                 scale_down_idle_ns=5e7)
    pts = {
        "static/r1/round_robin": FleetPoint(traffic=traffic, replicas=1),
        "static/r2/round_robin": FleetPoint(traffic=traffic, replicas=2),
        "static/r4/round_robin": FleetPoint(traffic=traffic, replicas=4),
        "static/r2/least_loaded": FleetPoint(
            traffic=traffic, replicas=2, router="least_loaded"),
        "static/r2/affinity": FleetPoint(
            traffic=traffic, replicas=2, router="affinity"),
        "auto/r2/churn": FleetPoint(
            traffic=traffic, replicas=2, max_replicas=2, **churn),
        "auto/r2/churn_slow_spin": FleetPoint(
            traffic=traffic, replicas=2, max_replicas=2,
            spinup_latency_ns=2e7, **churn),
    }
    grid = sweep_fleet(list(pts.values()))
    res = {name: grid[pt] for name, pt in pts.items()}
    rows = []
    for name, r in res.items():
        p99 = _steady_p99_deg(r, cut_ns)
        ttft = r.ttft_percentiles()
        rows.append((f"fig16/{name}", ttft[50.0] / 1e3,
                     f"steady_p99_deg={p99:.4f};"
                     f"mean_deg={r.mean_ttft_degradation:.4f};"
                     f"ttft_p99_us={ttft[99.0]/1e3:.1f};"
                     f"spin_ups={r.spin_ups};retired={r.retired};"
                     f"rejected={len(r.rejected)};"
                     f"cold_steps={r.cold_steps};"
                     f"holds_slo={p99 < _FIG16_SLO}"))
    static = res["static/r2/round_robin"]
    auto = res["auto/r2/churn"]
    s_p99 = _steady_p99_deg(static, cut_ns)
    a_p99 = _steady_p99_deg(auto, cut_ns)
    rows.append(("fig16/check_cold_spinup_tax", 0.0,
                 f"static_p99={s_p99:.4f};auto_p99={a_p99:.4f};"
                 f"spin_ups={auto.spin_ups};"
                 f"equal_capacity={auto.peak_replicas <= 2};"
                 f"taxed={bool(auto.spin_ups >= 1 and a_p99 > s_p99)}"))
    # The provisioning answer: smallest static fleet holding the SLO.
    fits = [n.split("/")[1] for n in
            ("static/r1/round_robin", "static/r2/round_robin",
             "static/r4/round_robin")
            if _steady_p99_deg(res[n], cut_ns) < _FIG16_SLO]
    rows.append(("fig16/check_static_provisioning", 0.0,
                 f"rps={_FIG16_BASE['rps']};slo={_FIG16_SLO};"
                 f"smallest_fit={fits[0] if fits else 'none'};"
                 f"any_fit={bool(fits)}"))
    return rows


# fig17 deployment: 4 KB translation granules (the host-page regime) on the
# 16-GPU Clos.  Under Table 1's 2 MB pages the cold-walk tax is a ~1 us
# additive constant that never flips the algorithm choice; at 4 KB the tax
# scales with the page count AND with how the algorithm's step structure
# exposes it (a 2(n-1)-step ring re-pays a walk tail at every step barrier,
# recursive doubling concentrates all walks in step 0), so cold and warm
# completions rank the candidates differently near the ring/rd bandwidth
# crossover.  Sizes are bucket-unique (one per power-of-two bucket) so each
# prices its own PolicyTable row; 33 MB sits inside the crossover band.
_FIG17_SIZES = (8 * MB, 16 * MB, 33 * MB, 64 * MB, 128 * MB)
_FIG17_N = 16


def fig17_algorithm_selection() -> List[Row]:
    """Fig 17 (ours, beyond the paper): RAT-aware algorithm selection.

    The policy layer (repro.core.select, DESIGN.md §14) prices every
    registered candidate of a logical collective per (size, fabric, cold |
    warm Link-TLB state).  This figure shows the selection surface for
    ``allreduce`` on small translation pages: recursive doubling wins the
    latency-bound sizes, the ring wins bandwidth-bound sizes, and in the
    crossover band the *cold* optimum (rd — one concentrated walk storm)
    differs from the *warm* optimum (ring — cheaper steady-state bytes).
    A PolicyTable built from the same pricing then beats the fixed default
    end-to-end through a persistent-TLB session: cold call resolved to rd,
    warm re-issue of the same buffer resolved back to ring.
    """
    from repro.core.select import AutoPolicy, FixedPolicy, build_policy_table
    from repro.core.session import SimSession

    base = SimConfig(translation=TranslationConfig(page_bytes=4 * KB),
                     engine="vectorized")
    fab = FabricConfig(n_gpus=_FIG17_N)
    auto = AutoPolicy(base=base)
    rows = []
    diverging = []
    for s in _FIG17_SIZES:
        sc = auto.scores("allreduce", s, fab)
        cold = min(sc, key=lambda c: sc[c][0])
        warm = min(sc, key=lambda c: sc[c][1])
        if cold != warm:
            diverging.append(s)
        for cand in sorted(sc):
            c_ns, w_ns = sc[cand]
            rows.append((f"fig17/allreduce/size{s//MB}MB/{cand}",
                         c_ns / 1e3,
                         f"cold_us={c_ns/1e3:.2f};warm_us={w_ns/1e3:.2f};"
                         f"cold_pick={cand == cold};"
                         f"warm_pick={cand == warm}"))
    rows.append(("fig17/check_cold_warm_optima_diverge", 0.0,
                 f"page_kb=4;gpus={_FIG17_N};topology=single_clos;"
                 f"diverging_sizes_mb={[s // MB for s in diverging]};"
                 f"any={bool(diverging)}"))

    # The deployable artifact: a PolicyTable cached from the same pricing
    # (the AutoPolicy memo is shared, so nothing is simulated twice).
    table = build_policy_table(_FIG17_SIZES, [_FIG17_N],
                               logicals=("allreduce",), base=base, auto=auto)
    sz = diverging[0] if diverging else 33 * MB
    for state in ("cold", "warm"):
        res = table.resolve("allreduce", sz, fab, state=state)
        rows.append((f"fig17/table/size{sz//MB}MB/{state}", 0.0,
                     f"collective={res.collective};"
                     f"provenance={res.provenance}"))

    # End-to-end on the diverging point, replayed through SimSession with
    # the policy threaded (the same path derivation and serving use), in
    # the regime where the cold-state entry matters: idle gaps past
    # ``tlb_retention_ns`` flush the warmth between calls (fig15's bursty
    # re-entry), so every call resolves in cold state — the table rides rd
    # where the fixed default re-pays ring's per-step walk tails.
    n_calls = 3
    cfg = base.replace(fabric=fab, tlb_retention_ns=500_000.0)
    totals = {}
    for name, pol in (("fixed", FixedPolicy()), ("table", table)):
        sess = SimSession(cfg, policy=pol)
        recs = [sess.run(sz, collective="allreduce",
                         gap_ns=0.0 if i == 0 else 1e6, label=f"call{i}")
                for i in range(n_calls)]
        totals[name] = sum(r.completion_ns for r in recs)
        rows.append((f"fig17/session/flushed/{name}", totals[name] / 1e3,
                     ";".join(f"{r.label}={r.collective}:"
                              f"{r.completion_ns/1e3:.2f}us"
                              for r in recs)))
    gain = totals["fixed"] - totals["table"]
    rows.append(("fig17/check_table_beats_fixed_default", 0.0,
                 f"size_mb={sz//MB};calls={n_calls};"
                 f"fixed_us={totals['fixed']/1e3:.2f};"
                 f"table_us={totals['table']/1e3:.2f};"
                 f"gain_us={gain/1e3:.2f};strict={gain > 0}"))
    # The steady-warm counterpoint, reported for honesty: switching
    # algorithms also switches which stations hold the warm L1 entries, so
    # a cold rd -> warm ring transition re-fills L1s from L2 once — in a
    # never-flushed steady loop the table's warm entry (ring, the fixed
    # choice) is what keeps it from paying that transition repeatedly.
    warm_cfg = base.replace(fabric=fab)
    for name, pol in (("fixed", FixedPolicy()), ("table", table)):
        sess = SimSession(warm_cfg, policy=pol)
        recs = [sess.run(sz, collective="allreduce", label=f"call{i}")
                for i in range(3)]
        rows.append((f"fig17/session/steady/{name}",
                     sum(r.completion_ns for r in recs) / 1e3,
                     ";".join(f"{r.label}={r.collective}:"
                              f"{r.completion_ns/1e3:.2f}us"
                              for r in recs)))
    return rows


# fig18 disaggregation grid: the fig15 arch with prompts pinned near the
# 4096-token cap (prompt_mean far above it, so ~80% of shards are the full
# ~12.6MB and the decode pod's KV arena ring wraps within the stream —
# transfers reach their steady-state regime), short outputs so the decode
# pods drain within the step budget.  The vectorized engine is bit-for-bit
# the event engine (proven by the tier-1 differential tests) and ~10x
# faster for a benchmark this wide.
_FIG18_BASE = dict(arch="granite-moe-1b-a400m", n_requests=32, seed=7,
                   prompt_mean=16384, output_mean=8, engine="vectorized")
_FIG18_TOPOS = {
    "single_clos": {},
    "two_tier": dict(topology="two_tier", leaf_size=8, oversubscription=2.0),
}
_FIG18_RPS = (4.0, 16.0)
_FIG18_SMALL_L2 = 8
# L2-axis arena: 6 full-prompt slots (84MB) — several ring laps within 32
# requests.  At the Table-1 L2 (512 x 2MB = 1GB reach) the whole arena
# stays resident after lap 1; at 8 entries (16MB reach) steady-state
# transfers keep re-walking.
_FIG18_ARENA = 6 * 14 * MB


def fig18_disaggregation() -> List[Row]:
    """Fig 18 (ours, beyond the paper): prefill/decode disaggregation.

    Disaggregated serving (repro.serving.disagg, DESIGN.md §16) routes
    every request through an explicit KV-cache transfer across the
    ``multi_pod`` scale-out hop, priced at the decode pod's Link-MMU —
    TTFT gains a reverse-translation term the colocated deployment never
    pays.  The grid crosses rps x topology for colocated-vs-disagg TTFT
    and ITL percentiles (the crossover is reported as measured — disagg
    wins only where prefill/decode interference outweighs the hop), and
    an L2-reach axis isolates the two-regime claim: with the Table-1 L2
    the transfer working set stays resident and the cold-RAT excess is
    <2% of TTFT; shrinking the L2 below the KV shard's page footprint
    makes every transfer re-walk, and the excess becomes visible in the
    TTFT decomposition.
    """
    from repro.serving import TrafficPoint, sweep_traffic
    from repro.serving.disagg import DisaggPoint, sweep_disagg

    co_pts, dg_pts = {}, {}
    for rps in _FIG18_RPS:
        for topo, kw in _FIG18_TOPOS.items():
            t = TrafficPoint(rps=rps, **kw, **_FIG18_BASE)
            name = f"{topo}/rps{rps:g}"
            co_pts[name] = t
            dg_pts[name] = DisaggPoint(traffic=t)
    for l2, tag in ((_FIG18_SMALL_L2, f"l2_{_FIG18_SMALL_L2}"),
                    (0, "l2_default")):
        dg_pts[f"{tag}/rps16"] = DisaggPoint(
            traffic=TrafficPoint(rps=16.0, l2_entries=l2, **_FIG18_BASE),
            kv_arena_bytes=_FIG18_ARENA)
    co = sweep_traffic(list(co_pts.values()))
    dg = sweep_disagg(list(dg_pts.values()))

    def steady_cold(r):
        # Handoffs landing at an already-visited arena offset: their pages
        # were translated a lap ago, so any walk is a reach/retention
        # re-walk, not first-contact warmup.
        seen, cold = set(), 0
        for h in sorted(r.handoffs, key=lambda h: h.start_ns):
            if h.offset in seen and h.walks > 0:
                cold += 1
            seen.add(h.offset)
        return cold

    rows = []
    frac = {}
    for name, dp in dg_pts.items():
        r = dg[dp]
        ttft = r.ttft_percentiles()
        itl = r.itl_percentiles()
        bd = r.ttft_breakdown()
        frac[name] = bd["kv_excess_ns"] / bd["ttft_ns"]
        rows.append((f"fig18/disagg/{name}", ttft[50.0] / 1e3,
                     f"ttft_p99_us={ttft[99.0]/1e3:.1f};"
                     f"itl_p50_us={itl[50.0]/1e3:.2f};"
                     f"prefill_us={bd['prefill_ns']/1e3:.1f};"
                     f"kv_transfer_us={bd['kv_transfer_ns']/1e3:.2f};"
                     f"kv_excess_us={bd['kv_excess_ns']/1e3:.2f};"
                     f"decode_wait_us={bd['decode_wait_ns']/1e3:.1f};"
                     f"kv_excess_frac={frac[name]:.5f};"
                     f"cold_handoffs={r.kv_cold_handoffs};"
                     f"steady_cold={steady_cold(r)};"
                     f"kv_walks={r.kv_walks}"))
    for name, tp in co_pts.items():
        r = co[tp]
        ttft = r.ttft_percentiles()
        itl = r.itl_percentiles()
        d = dg[dg_pts[name]].ttft_percentiles()
        rows.append((f"fig18/colocated/{name}", ttft[50.0] / 1e3,
                     f"ttft_p99_us={ttft[99.0]/1e3:.1f};"
                     f"itl_p50_us={itl[50.0]/1e3:.2f};"
                     f"disagg_ttft_p50_us={d[50.0]/1e3:.1f};"
                     f"disagg_wins_p50={d[50.0] < ttft[50.0]}"))
    # Two-regime split: at default L2 reach the whole arena is resident
    # after lap 1 — repeat-offset transfers never walk again; at small
    # reach the steady state keeps re-walking and the cold excess recurs.
    # (The excess stays a tiny fraction of TTFT in both regimes: a multi-MB
    # KV transfer amortizes its walks exactly like the paper's large
    # collectives — the split is in the *recurrence*, and the warm-reach
    # fraction bound is the honest "vanishes" criterion.)
    small_r = dg[dg_pts[f"l2_{_FIG18_SMALL_L2}/rps16"]]
    default_r = dg[dg_pts["l2_default/rps16"]]
    small, default = frac[f"l2_{_FIG18_SMALL_L2}/rps16"], \
        frac["l2_default/rps16"]
    rows.append(("fig18/check_two_regime_split", 0.0,
                 f"small_l2_excess_frac={small:.6f};"
                 f"default_excess_frac={default:.6f};"
                 f"small_l2_steady_cold={steady_cold(small_r)};"
                 f"default_steady_cold={steady_cold(default_r)};"
                 f"small_l2_walks={small_r.kv_walks};"
                 f"default_walks={default_r.kv_walks};"
                 f"cold_recurs_at_small_reach="
                 f"{steady_cold(small_r) > steady_cold(default_r)};"
                 f"vanishes_at_default_reach={default < 0.02}"))
    return rows


def sched_costmodel() -> List[Row]:
    """Framework integration: cost model accuracy + warm-up chunk plans."""
    from repro.core.cost_model import CostModel
    from repro.core.scheduler import TranslationAwareScheduler
    rows = []
    m = CostModel(paper_config(16))
    for s, (mod, sim, err) in m.validate(
            [1 * MB, 4 * MB, 16 * MB, 64 * MB, 256 * MB]).items():
        rows.append((f"costmodel/size{s//MB}MB", sim / 1e3,
                     f"model_us={mod/1e3:.2f};err={err:.3f};ok={err < 0.1}"))
    sch = TranslationAwareScheduler(n_gpus=16, overlap_compute_ns=5e3)
    for s in (1 * MB, 8 * MB, 64 * MB):
        plan = sch.plan_all_to_all(s)
        rows.append((f"scheduler/size{s//MB}MB", plan.est_time_ns / 1e3,
                     f"warmup_B={plan.warmup_chunk_bytes};chunks={plan.n_chunks};"
                     f"est_speedup={plan.est_speedup:.3f}"))
    return rows


ALL = [fig4_overhead, fig5_latency, fig6_breakdown, fig7_hier, fig8_hum,
       fig9_10_traces, fig11_l2_sweep, fig12_collective_sweep,
       fig13_workload_replay, fig13_workload_replay_calibrated,
       fig14_topology_scaling, fig15_serving_tail_latency,
       fig16_fleet_scaling, fig17_algorithm_selection,
       fig18_disaggregation, opt_pretranslation, opt_prefetch,
       sched_costmodel]
