"""Shared smoke-config reduction: same family, tiny dimensions."""
from __future__ import annotations

from ..models.spec import ModelConfig


def reduce_config(cfg: ModelConfig, **overrides) -> ModelConfig:
    period = cfg.block_size
    kw = dict(
        n_layers=period * 2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_head=16,
        d_ff=(128 if cfg.d_ff > 0 else 0),
        vocab_size=256,
        n_experts=(8 if cfg.n_experts > 0 else 0),
        top_k=(2 if cfg.n_experts > 0 else 0),
        d_ff_expert=(64 if cfg.n_experts > 0 else 0),
        ssm_state=(16 if cfg.ssm_state > 0 else 0),
        ssm_head_dim=8,
        ssm_chunk=16,
        n_enc_layers=(2 if cfg.is_encoder_decoder else 0),
        enc_frames=(32 if cfg.is_encoder_decoder else cfg.enc_frames),
        n_img_tokens=(8 if cfg.n_img_tokens > 0 else 0),
        sliding_window=(16 if cfg.sliding_window > 0 else 0),
        name=cfg.name + "-smoke",
    )
    kw.update(overrides)
    return cfg.replace(**kw)
