"""granite-moe-1b-a400m: 32-expert top-8 MoE.

[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]  24L d_model=1024 16H
(GQA kv=8) expert d_ff=512 vocab=49155, MoE 32e top-8 on every layer.
"""
from ..models.spec import ModelConfig
from ._smoke import reduce_config

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_head=64,
    d_ff=0,                      # every FFN is MoE
    vocab_size=49155,
    rope_theta=10_000.0,
    n_experts=32,
    top_k=8,
    d_ff_expert=512,
)


def smoke() -> ModelConfig:
    return reduce_config(CONFIG)
