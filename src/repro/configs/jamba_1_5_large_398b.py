"""jamba-1.5-large-398b: hybrid Mamba+attention (1:7) with 16e top-2 MoE.

[arXiv:2403.19887; hf]  72L d_model=8192 64H (GQA kv=8) d_ff=24576
vocab=65536, MoE 16e top-2 on alternating layers; attention every 8th
layer.  SSM layers use our unified SSD formulation (d_state=16 per the
Jamba paper; DESIGN.md notes the Mamba-1 -> SSD adaptation).  Attention
layers use a 4096 sliding window for the long_500k shape (sub-quadratic).
"""
from ..models.spec import ModelConfig
from ._smoke import reduce_config

PATTERN = ("mamba", "mamba", "mamba", "mamba", "attn", "mamba", "mamba",
           "mamba")

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=24576,
    vocab_size=65536,
    rope_theta=1_000_000.0,
    n_experts=16,
    top_k=2,
    d_ff_expert=24576,
    moe_every=2,
    layer_pattern=PATTERN,
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=128,
    sliding_window=0,            # long_500k variant sets 4096
    ffn_chunks=8,
    ssm_scan_groups=8,
)


def smoke() -> ModelConfig:
    return reduce_config(CONFIG, n_layers=len(PATTERN))
