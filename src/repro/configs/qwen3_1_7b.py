"""qwen3-1.7b: dense GQA with per-head qk-norm.

[hf:Qwen/Qwen3-1.7B; hf]  28L d_model=2048 16H (GQA kv=8) d_ff=6144
vocab=151936, qk_norm.
"""
from ..models.spec import ModelConfig
from ._smoke import reduce_config

CONFIG = ModelConfig(
    name="qwen3-1.7b",
    family="dense",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_head=128,
    d_ff=6144,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
)


def smoke() -> ModelConfig:
    return reduce_config(CONFIG)
