"""Assigned input shapes (identical for every LM-family architecture).

``train_*`` lowers ``train_step``; ``prefill_*`` lowers the prefill forward;
``decode_*`` / ``long_*`` lower ``serve_step`` (one new token against a KV /
SSM cache of ``seq_len``).
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int
    sub_quadratic_only: bool = False


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1,
                           sub_quadratic_only=True),
}


def shape_applicable(cfg, spec: ShapeSpec) -> bool:
    """long_500k only runs for sub-quadratic (SSM / hybrid) archs."""
    if not spec.sub_quadratic_only:
        return True
    return any(k != "attn" for k in cfg.pattern)
