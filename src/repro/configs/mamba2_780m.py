"""mamba2-780m: attention-free SSD (state-space duality).

[arXiv:2405.21060; unverified]  48L d_model=1536, d_state=128, expand=2,
head_dim=64, vocab=50280.  No attention, no FFN (the SSD mixer is the
whole block).
"""
from ..models.spec import ModelConfig
from ._smoke import reduce_config

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=0,
    n_kv_heads=0,
    d_head=0,
    d_ff=0,
    vocab_size=50280,
    layer_pattern=("mamba",),
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
)


def smoke() -> ModelConfig:
    return reduce_config(CONFIG, n_heads=0, n_kv_heads=0, d_head=0)
