"""qwen3-14b: dense GQA with per-head qk-norm.

[hf:Qwen/Qwen3-14B; hf]  40L d_model=5120 40H (GQA kv=8) d_ff=17408
vocab=151936, qk_norm.
"""
from ..models.spec import ModelConfig
from ._smoke import reduce_config

CONFIG = ModelConfig(
    name="qwen3-14b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_head=128,
    d_ff=17408,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
)


def smoke() -> ModelConfig:
    return reduce_config(CONFIG)
