"""Architecture config registry: one module per assigned architecture."""
from __future__ import annotations

import importlib
from typing import List

from ..models.spec import ModelConfig
# Re-exported shape registry: consumers reach SHAPES/ShapeSpec through
# repro.configs alongside the architecture registry.
from .shapes import SHAPES, ShapeSpec, shape_applicable  # noqa: F401

ARCHS: List[str] = [
    "phi_3_vision_4_2b",
    "granite_moe_1b_a400m",
    "qwen3_moe_235b_a22b",
    "mistral_large_123b",
    "qwen2_1_5b",
    "qwen3_14b",
    "qwen3_1_7b",
    "jamba_1_5_large_398b",
    "whisper_medium",
    "mamba2_780m",
]

# CLI ids as assigned (dashes/dots) -> module names.
ALIASES = {
    "phi-3-vision-4.2b": "phi_3_vision_4_2b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "mistral-large-123b": "mistral_large_123b",
    "qwen2-1.5b": "qwen2_1_5b",
    "qwen3-14b": "qwen3_14b",
    "qwen3-1.7b": "qwen3_1_7b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "whisper-medium": "whisper_medium",
    "mamba2-780m": "mamba2_780m",
}


def _module(name: str):
    mod = ALIASES.get(name, name).replace("-", "_").replace(".", "_")
    return importlib.import_module(f".{mod}", __package__)


def get_config(name: str) -> ModelConfig:
    """Full-size (paper-exact) config for an assigned architecture."""
    return _module(name).CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    return _module(name).smoke()


def list_archs() -> List[str]:
    return list(ALIASES.keys())


def param_count(cfg: ModelConfig) -> int:
    """Analytic parameter count (no allocation)."""
    import jax
    import numpy as np
    from ..models import api
    shapes = jax.eval_shape(lambda k: api.init(cfg, k)[0],
                            jax.ShapeDtypeStruct((2,), "uint32"))
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(shapes))


def active_param_count(cfg: ModelConfig) -> int:
    """Active params per token (MoE: top_k of n_experts)."""
    total = param_count(cfg)
    if cfg.n_experts <= 0:
        return total
    # expert weights: 3 matrices per MoE layer
    n_moe_layers = sum(1 for i in range(cfg.n_layers)
                       if i % cfg.moe_every == cfg.moe_every - 1)
    per_expert = 3 * cfg.d_model * cfg.d_ff_expert
    expert_total = n_moe_layers * cfg.n_experts * per_expert
    expert_active = n_moe_layers * cfg.top_k * per_expert
    return total - expert_total + expert_active
