"""whisper-medium: encoder-decoder; conv/mel frontend stubbed.

[arXiv:2212.04356; unverified]  24 encoder + 24 decoder layers,
d_model=1024 16H (kv=16) d_ff=4096 vocab=51865.  ``input_specs`` provides
precomputed frame embeddings [B, 1500, d_model].
"""
from ..models.spec import ModelConfig
from ._smoke import reduce_config

CONFIG = ModelConfig(
    name="whisper-medium",
    family="encdec",
    n_layers=24,                 # decoder layers
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_head=64,
    d_ff=4096,
    vocab_size=51865,
    rope_theta=10_000.0,
    is_encoder_decoder=True,
    n_enc_layers=24,
    enc_frames=1500,
)


def smoke() -> ModelConfig:
    return reduce_config(CONFIG)
