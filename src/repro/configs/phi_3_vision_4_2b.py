"""phi-3-vision-4.2b: phi3-mini backbone + CLIP frontend (stub).

[hf:microsoft/Phi-3-vision-128k-instruct; hf]  32L d_model=3072 32H
(GQA kv=32 => MHA) d_ff=8192 vocab=32064.  The vision frontend is a stub:
``input_specs`` provides precomputed patch embeddings [B, 256, d_model].
"""
from ..models.spec import ModelConfig
from ._smoke import reduce_config

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_head=96,
    d_ff=8192,
    vocab_size=32064,
    rope_theta=10_000.0,
    n_img_tokens=256,
)


def smoke() -> ModelConfig:
    return reduce_config(CONFIG)
