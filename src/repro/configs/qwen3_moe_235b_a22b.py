"""qwen3-moe-235b-a22b: 128-expert top-8 MoE with qk-norm.

[hf:Qwen/Qwen3-30B-A3B family; hf]  94L d_model=4096 64H (GQA kv=4)
expert d_ff=1536 vocab=151936, MoE 128e top-8, per-head qk RMSNorm.
"""
from ..models.spec import ModelConfig
from ._smoke import reduce_config

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    d_head=128,
    d_ff=0,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    n_experts=128,
    top_k=8,
    d_ff_expert=1536,
)


def smoke() -> ModelConfig:
    return reduce_config(CONFIG, n_kv_heads=2)
