"""Logical->physical sharding rules per workload (train / prefill / decode).

Mesh axes: ``("pod", "data", "model")`` multi-pod or ``("data", "model")``
single-pod.  Parallelism mapping:

  * ``pod``+``data`` — data parallel over the global batch, and FSDP: weight
    matrices are *also* sharded on their row (embed/mlp input) axis over the
    data axis, so parameters + optimizer state are fully sharded 2-D
    (data x model) like MaxText FSDP+TP.  GSPMD inserts the per-layer
    all-gathers / reduce-scatters.
  * ``model`` — tensor parallel (attention heads, MLP columns, vocab) and
    expert parallel (the MoE "experts" axis) — the collective the paper
    studies rides this axis.
  * decode shapes re-map: KV-cache head_dim shards over ``model`` (kv_heads
    can be < 16) and ``long_500k`` (batch=1) shards the cache sequence over
    ``data`` instead of the batch.

A logical name maps to at most one mesh axis per array; duplicate physical
axes within one array resolve to replication for the later name
(``logical_to_pspec`` drops them), which is what makes a single rule table
serve parameters and activations at once.
"""
from __future__ import annotations

import enum
from typing import Any, Dict

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Re-exported jax-version shims: every shard_map context in the repo (the
# overlap primitives, moe_block_ep callers, tests) resolves the function
# through here so the namespace/kwarg renames live in exactly one file.
from ..kernels.compat import make_mesh, shard_map  # noqa: F401
from ..models.base import logical_to_pspec


class WorkloadKind(str, enum.Enum):
    TRAIN = "train"
    PREFILL = "prefill"
    DECODE = "decode"
    LONG_DECODE = "long_decode"


def rules_for(kind: WorkloadKind, multi_pod: bool = False,
              fsdp: bool = True, seq_shard: bool = False) -> Dict[str, Any]:
    data = ("pod", "data") if multi_pod else ("data",)
    rules: Dict[str, Any] = {
        "batch": data,
        "embed": (data if fsdp else None),   # FSDP row-shard of weights
        "heads": "model",
        "kv_heads": "model",
        "head_dim": None,
        "mlp": "model",
        "vocab": "model",
        "experts": "model",                  # expert parallelism
        "expert_embed": data,                # FSDP rows of expert weights
        "expert_mlp": None,
        "ssm_inner": "model",
        "cache_seq": None,
        # flattened [batch*seq, d] token tensors (MoE dispatch path)
        "tokens": data + ("model",),
        # Sequence parallelism: sharding activations' seq dim over `model`
        # bounds residual/attention memory when heads don't divide the TP
        # axis and shrinks the saved scan carries of deep stacks.
        "seq": ("model" if seq_shard else None),
        "layers": None,
    }
    if kind in (WorkloadKind.DECODE, WorkloadKind.LONG_DECODE):
        rules["tokens"] = data
        # (A weight-stationary expert layout — expert_embed=None,
        # expert_mlp=data — was measured in the Perf hillclimb and refuted:
        # GSPMD still gathers the weights; see EXPERIMENTS.md Perf cell 3.)
        # Serving keeps FSDP rows (`embed` over data): the big archs
        # (jamba-398B, qwen3-moe-235B) exceed per-pod HBM under TP-only even
        # at bf16, so weights are gathered per layer during decode (the
        # standard capacity/latency trade at this scale).
        rules["kv_heads"] = None
        rules["head_dim"] = "model"          # shards any GQA cache (kv>=1)
    if kind == WorkloadKind.LONG_DECODE:
        rules["batch"] = None                # global_batch=1
        rules["cache_seq"] = data            # sequence-sharded cache
    return rules


def param_pspecs(specs, rules) -> Any:
    """Map a logical-axes pytree to PartitionSpecs."""
    return jax.tree.map(lambda ax: logical_to_pspec(ax, rules), specs,
                        is_leaf=lambda x: isinstance(x, tuple))


def _axis_size(mesh: Mesh, part) -> int:
    if part is None:
        return 1
    parts = part if isinstance(part, (tuple, list)) else (part,)
    n = 1
    for p in parts:
        n *= mesh.shape[p]
    return n


def fit_pspec(spec: P, shape, mesh: Mesh) -> P:
    """Drop partitions whose mesh-axis size does not divide the dim size
    (e.g. kv_heads=2 cannot shard over model=16 -> replicate that dim)."""
    parts = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, part in zip(shape, parts[:len(shape)]):
        out.append(part if part is None or dim % _axis_size(mesh, part) == 0
                   else None)
    return P(*out)


def fit_tree(spec_tree, shape_tree, mesh: Mesh):
    """fit_pspec over parallel (specs, shapes) pytrees."""
    return jax.tree.map(
        lambda s, x: fit_pspec(s, x.shape, mesh), spec_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, P))


def batch_pspec(rules, ndim: int = 2) -> P:
    """[B, S, ...] batches: shard batch dim, replicate the rest."""
    return P(rules.get("batch"), *([None] * (ndim - 1)))


def make_shardings(mesh: Mesh, spec_tree) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


# -------------------------------------------------------------- cache specs
def cache_pspecs(cfg, cache_shapes, rules) -> Any:
    """PartitionSpecs for a decode-cache pytree (by leaf shape pattern).

    Caches are built by ``api.prefill``: KVCache leaves are
    [blocks, B, S, KV, Dh], SSM conv [blocks, B, K-1, C], SSM state
    [blocks, B, H, P, N], lengths [blocks]; enc-dec cross-KV are
    [blocks, B, F, KV, Dh].  We map axes by position.
    """
    data = rules.get("batch")
    cseq = rules.get("cache_seq")
    hd = rules.get("head_dim")
    kv = rules.get("kv_heads")

    def spec_for(leaf):
        nd = len(leaf.shape)
        if nd == 5:                      # [L, B, S, KV, Dh]
            return P(None, data, cseq, kv, hd)
        if nd == 4:                      # [L, B, K-1, x|B|C] conv cache
            # channel dim replicated: it concatenates a sharded (x) and two
            # replicated (B, C) streams, so boundaries are shard-misaligned
            # (and the cache is tiny: [K-1, d_inner+2N] per sequence).
            return P(None, data, None, None)
        if nd == 3:
            return P(None, data, None)
        if nd == 1 or nd == 0:           # lengths
            return P(*([None] * nd))
        if nd == 2:
            return P(None, data)
        return P(*([None] * nd))

    def spec_for_state(leaf):
        # SSM state [L, B, H, P, N]
        return P(None, data, None, None, None)

    from ..models.layers import KVCache
    from ..models.ssd import SSMCache

    def map_cache(c):
        if isinstance(c, KVCache):
            return KVCache(k=spec_for(c.k), v=spec_for(c.v),
                           length=P(None))
        if isinstance(c, SSMCache):
            return SSMCache(conv=spec_for(c.conv),
                            state=spec_for_state(c.state))
        return spec_for(c)   # raw leaves (e.g. enc-dec cross-attention KV)

    return jax.tree.map(
        map_cache, cache_shapes,
        is_leaf=lambda x: isinstance(x, (KVCache, SSMCache)))
