from .sharding import (rules_for, param_pspecs, batch_pspec, cache_pspecs,
                       make_shardings, WorkloadKind)

__all__ = ["rules_for", "param_pspecs", "batch_pspec", "cache_pspecs",
           "make_shardings", "WorkloadKind"]
