"""Grouped (ragged) matmul kernel for MoE expert GEMM on TPU (Pallas).

``lhs`` rows are sorted by expert; ``group_offsets`` (scalar-prefetched into
SMEM) give each expert's [start, end) row range; ``rhs`` holds one weight
matrix per expert.  Grid = (T/block_t, F/block_f, E) with the expert axis
innermost so each output tile accumulates over the (few) experts that
overlap it; non-overlapping experts are skipped with ``pl.when``.

This is the megablocks-style gmm adapted to the MXU: block_t x block_f output
tiles (128-aligned), full-depth K panels resident in VMEM (fine up to
d_model ~8k in f32; larger models use bf16 operands).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams, PrefetchScalarGridSpec, block_spec


def _gmm_kernel(offs_ref, lhs_ref, rhs_ref, out_ref, acc_ref, *,
                block_t: int, n_experts: int):
    t = pl.program_id(0)
    e = pl.program_id(2)

    @pl.when(e == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    start = offs_ref[e]
    end = offs_ref[e + 1]
    row0 = t * block_t
    overlap = jnp.logical_and(end > row0, start < row0 + block_t)

    @pl.when(overlap)
    def _body():
        rows = row0 + jax.lax.broadcasted_iota(
            jnp.int32, (block_t, 1), 0)
        mask = jnp.logical_and(rows >= start, rows < end)
        lhs = jnp.where(mask, lhs_ref[...].astype(jnp.float32), 0.0)
        acc_ref[...] += jax.lax.dot_general(
            lhs, rhs_ref[...].astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(e == n_experts - 1)
    def _finish():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


def grouped_matmul_kernel(lhs: jnp.ndarray, rhs: jnp.ndarray,
                          group_offsets: jnp.ndarray, *,
                          block_t: int = 128, block_f: int = 128,
                          interpret: bool = True) -> jnp.ndarray:
    """lhs: [T, D] (rows sorted by expert), rhs: [E, D, F],
    group_offsets: [E+1] int32 -> out [T, F]."""
    T, D = lhs.shape
    E, _, F = rhs.shape
    block_t = min(block_t, T)
    block_f = min(block_f, F)
    assert T % block_t == 0 and F % block_f == 0, (T, F, block_t, block_f)

    grid_spec = PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(T // block_t, F // block_f, E),
        in_specs=[
            block_spec((block_t, D), lambda t, f, e, offs: (t, 0)),
            block_spec((None, D, block_f), lambda t, f, e, offs: (e, 0, f)),
        ],
        out_specs=block_spec((block_t, block_f),
                             lambda t, f, e, offs: (t, f)),
        scratch_shapes=[pltpu.VMEM((block_t, block_f), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_gmm_kernel, block_t=block_t, n_experts=E),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((T, F), lhs.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(group_offsets.astype(jnp.int32), lhs, rhs)
