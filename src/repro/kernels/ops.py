"""Jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True off-TPU (this container is CPU-only; TPU v5e
is the *target*): the kernel body executes in Python for correctness
validation, while ``interpret=False`` on real hardware compiles to Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention_kernel
from .grouped_matmul import grouped_matmul_kernel
from .ssd_scan import ssd_chunk_kernel
from .rmsnorm import rmsnorm_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128):
    return flash_attention_kernel(q, k, v, causal=causal, block_q=block_q,
                                  block_k=block_k, interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("block_t", "block_f"))
def grouped_matmul(lhs, rhs, group_offsets, *, block_t: int = 128,
                   block_f: int = 128):
    return grouped_matmul_kernel(lhs, rhs, group_offsets, block_t=block_t,
                                 block_f=block_f, interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("eps", "block_rows"))
def rmsnorm(x, w, *, eps: float = 1e-6, block_rows: int = 256):
    return rmsnorm_kernel(x, w, eps=eps, block_rows=block_rows,
                          interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(x, dt, A_log, B, C, *, chunk: int = 256):
    """Full SSD scan built on the intra-chunk Pallas kernel.

    x: [b,S,H,P]; dt: [b,S,H] (post-softplus); A_log: [H]; B,C: [b,S,N].
    Returns (y [b,S,H,P] f32, final_state [b,H,P,N] f32).  Mirrors
    repro.models.ssd.ssd_chunked (the jnp oracle path).
    """
    b, S, H, P = x.shape
    N = B.shape[-1]
    Q = min(chunk, S)
    while S % Q:
        Q -= 1
    nc = S // Q
    f32 = jnp.float32

    a = (dt.astype(f32) * (-jnp.exp(A_log.astype(f32))))          # [b,S,H]
    # Flatten (b, chunk, head) into the kernel grid.
    xg = (x.reshape(b, nc, Q, H, P).transpose(0, 1, 3, 2, 4)
          .reshape(b * nc * H, Q, P))
    dtg = (dt.reshape(b, nc, Q, H).transpose(0, 1, 3, 2)
           .reshape(b * nc * H, Q))
    ag = (a.reshape(b, nc, Q, H).transpose(0, 1, 3, 2)
          .reshape(b * nc * H, Q))
    Bg = jnp.broadcast_to(B.reshape(b, nc, 1, Q, N),
                          (b, nc, H, Q, N)).reshape(b * nc * H, Q, N)
    Cg = jnp.broadcast_to(C.reshape(b, nc, 1, Q, N),
                          (b, nc, H, Q, N)).reshape(b * nc * H, Q, N)

    y_diag, states = ssd_chunk_kernel(xg, dtg, ag, Bg, Cg,
                                      interpret=not _on_tpu())
    y_diag = (y_diag.reshape(b, nc, H, Q, P).transpose(0, 1, 3, 2, 4))
    states = states.reshape(b, nc, H, P, N)

    # Cross-chunk recurrence (cheap): S_{c} = g_c S_{c-1} + states_c.
    a_cum = jnp.cumsum(ag.reshape(b, nc, H, Q), axis=-1)          # [b,nc,H,Q]
    g = jnp.exp(a_cum[..., -1])                                    # [b,nc,H]

    def combine(c1, c2):
        g1, s1 = c1
        g2, s2 = c2
        return g1 * g2, s2 + g2[..., None, None] * s1

    _, ss = jax.lax.associative_scan(combine, (g, states), axis=1)
    prev = jnp.concatenate([jnp.zeros_like(ss[:, :1]), ss[:, :-1]], axis=1)

    # Off-diagonal: y += C_t exp(a_cum_t) S_prev.
    Cc = C.reshape(b, nc, Q, N).astype(f32)
    y_off = jnp.einsum("bcqn,bchq,bchpn->bcqhp",
                       Cc, jnp.exp(a_cum), prev)
    y = (y_diag + y_off).reshape(b, S, H, P)
    return y, ss[:, -1]
