"""Flash attention forward kernel for TPU (Pallas, online-softmax).

Tiling: grid = (batch*q_heads, Sq/block_q, Sk/block_k); the k dimension is the
innermost (sequential) grid axis so the output block is revisited
consecutively while running max/sum/accumulator live in VMEM scratch.
Block sizes default to 128x128 — MXU-aligned on both matmul dims, and the
VMEM working set (q, k, v tiles + f32 accumulator) stays ~<2 MB.

GQA is handled in the index map: kv block index = q_head // group, so K/V are
never materialized per-q-head.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams, block_spec

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 sm_scale: float, causal: bool, block_q: int, block_k: int,
                 nk: int):
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Causal: skip blocks entirely above the diagonal.
    run = True
    if causal:
        run = (j * block_k) <= (i * block_q + block_q - 1)

    @pl.when(run)
    def _body():
        q = q_ref[...].astype(jnp.float32)            # [Bq, Dh]
        k = k_ref[...].astype(jnp.float32)            # [Bk, Dh]
        v = v_ref[...].astype(jnp.float32)            # [Bk, Dh]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s *= sm_scale
        if causal:
            qi = i * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            kj = j * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(kj <= qi, s, NEG_INF)
        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = alpha * l_prev + jnp.sum(p, axis=-1)
        m_ref[...] = m_new
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))

    @pl.when(j == nk - 1)
    def _finish():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[...] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_kernel(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                           causal: bool = True, sm_scale: float | None = None,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = True) -> jnp.ndarray:
    """q: [B, Sq, H, Dh]; k, v: [B, Sk, KV, Dh] -> [B, Sq, H, Dh]."""
    B, Sq, H, Dh = q.shape
    _, Sk, KV, _ = k.shape
    assert H % KV == 0
    group = H // KV
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(Dh)
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, Sk, block_q, block_k)
    nq, nk = Sq // block_q, Sk // block_k

    qh = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, Dh)
    kh = k.transpose(0, 2, 1, 3).reshape(B * KV, Sk, Dh)
    vh = v.transpose(0, 2, 1, 3).reshape(B * KV, Sk, Dh)

    def kv_index(b, i, j):
        return (b // H) * KV + (b % H) // group, j, 0

    out = pl.pallas_call(
        functools.partial(_attn_kernel, sm_scale=sm_scale, causal=causal,
                          block_q=block_q, block_k=block_k, nk=nk),
        grid=(B * H, nq, nk),
        in_specs=[
            block_spec((None, block_q, Dh), lambda b, i, j: (b, i, 0)),
            block_spec((None, block_k, Dh), kv_index),
            block_spec((None, block_k, Dh), kv_index),
        ],
        out_specs=block_spec((None, block_q, Dh), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, Dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, Dh), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qh, kh, vh)
    return out.reshape(B, H, Sq, Dh).transpose(0, 2, 1, 3)
