# Pallas kernel tier: the compute hot-spots of the model zoo, written
# against the jax-version shim in `compat.py` (CompilerParams naming,
# shard_map location, BlockSpec order) so the whole tier tracks one file
# across jax upgrades.  `ops` holds the jit'd public wrappers (interpret
# mode off-TPU); `ref` the pure-jnp oracles; `repro.workloads.calibrate`
# times these kernels to produce measured compute windows for replay.
from . import compat  # noqa: F401  (import-time version probes)
from .ops import flash_attention, grouped_matmul, rmsnorm, ssd_scan

__all__ = ["compat", "flash_attention", "grouped_matmul", "rmsnorm",
           "ssd_scan"]
