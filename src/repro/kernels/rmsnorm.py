"""Fused RMSNorm kernel for TPU (Pallas).

Bandwidth-bound: one pass over [block_rows, D] tiles in VMEM, f32 reduction,
fused scale multiply.  Saves the extra HBM round-trips of the unfused
mean-square / rsqrt / multiply chain.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .compat import CompilerParams, block_spec


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(ms + eps)
    o_ref[...] = (y * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm_kernel(x: jnp.ndarray, w: jnp.ndarray, *, eps: float = 1e-6,
                   block_rows: int = 256, interpret: bool = True):
    """x: [T, D]; w: [D] -> [T, D]."""
    T, D = x.shape
    block_rows = min(block_rows, T)
    assert T % block_rows == 0, (T, block_rows)
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(T // block_rows,),
        in_specs=[
            block_spec((block_rows, D), lambda t: (t, 0)),
            block_spec((D,), lambda t: (0,)),
        ],
        out_specs=block_spec((block_rows, D), lambda t: (t, 0)),
        out_shape=jax.ShapeDtypeStruct((T, D), x.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x, w)
