"""Pure-jnp oracles for every Pallas kernel (shannon/kernels pattern)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True,
                  sm_scale: float | None = None) -> jnp.ndarray:
    """q: [B,Sq,H,Dh]; k,v: [B,Sk,KV,Dh] -> [B,Sq,H,Dh] (GQA semantics)."""
    B, Sq, H, Dh = q.shape
    _, Sk, KV, _ = k.shape
    group = H // KV
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(Dh)
    qf = q.astype(jnp.float32).reshape(B, Sq, KV, group, Dh)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qf, kf) * sm_scale
    if causal:
        qi = jnp.arange(Sq)[:, None] + (Sk - Sq)
        kj = jnp.arange(Sk)[None, :]
        s = jnp.where((kj <= qi)[None, None, None], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", w, vf)
    return o.reshape(B, Sq, H, Dh).astype(q.dtype)


def grouped_matmul_ref(lhs, rhs, group_offsets) -> jnp.ndarray:
    """lhs: [T,D] sorted by group; rhs: [E,D,F]; offsets: [E+1] -> [T,F]."""
    T = lhs.shape[0]
    E = rhs.shape[0]
    rows = jnp.arange(T)
    gid = jnp.sum(rows[:, None] >= group_offsets[None, 1:], axis=1)  # [T]
    gid = jnp.clip(gid, 0, E - 1)
    w = rhs[gid]                                       # [T, D, F]
    return jnp.einsum("td,tdf->tf", lhs.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(lhs.dtype)


def ssd_chunk_ref(x, dt, a, B, C):
    """Intra-chunk SSD oracle.  x:[G,Q,P] dt,a:[G,Q] B,C:[G,Q,N].

    Returns (y_diag [G,Q,P] f32, states [G,P,N] f32)."""
    f32 = jnp.float32
    x, dt, a = x.astype(f32), dt.astype(f32), a.astype(f32)
    B, C = B.astype(f32), C.astype(f32)
    Q = x.shape[1]
    cs = jnp.cumsum(a, axis=1)                         # [G,Q]
    diff = cs[:, :, None] - cs[:, None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.exp(jnp.where(mask, diff, -jnp.inf))
    CB = jnp.einsum("gqn,gkn->gqk", C, B)
    y = jnp.einsum("gqk,gk,gkp->gqp", CB * L, dt, x)
    decay = jnp.exp(cs[:, -1:] - cs)                   # [G,Q]
    states = jnp.einsum("gq,gq,gqp,gqn->gpn", decay, dt, x, B)
    return y, states


def rmsnorm_ref(x, w, *, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (y * w.astype(jnp.float32)).astype(x.dtype)
