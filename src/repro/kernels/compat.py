"""Single jax-version shim for the kernel / sharding tier.

The Pallas and shard_map APIs have been renamed repeatedly across the jax
versions this repo must run on (>= 0.4.31):

* ``pltpu.TPUCompilerParams`` (<= 0.6) became ``pltpu.CompilerParams``;
* ``shard_map`` moved from ``jax.experimental.shard_map`` to the top-level
  ``jax.shard_map`` namespace, and its replication-check kwarg was renamed
  ``check_rep`` -> ``check_vma``;
* ``pl.BlockSpec`` swapped its positional argument order from
  ``(index_map, block_shape)`` to ``(block_shape, index_map)`` around
  0.4.31-0.4.33;
* ``pltpu.PrefetchScalarGridSpec`` is slated to fold into ``pl.GridSpec``.

Every kernel (``rmsnorm``/``flash_attention``/``grouped_matmul``/
``ssd_scan``/``ops``), the sharding rules (``repro.parallel.sharding``) and
the overlap-primitive call sites import the resolved names from here, so a
jax upgrade is a one-file change.  Resolution happens once at import time;
the probes are pure introspection (no arrays, no device access).
"""
from __future__ import annotations

import inspect
from typing import Any, Callable, Optional, Sequence

import jax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["CompilerParams", "PrefetchScalarGridSpec", "block_spec",
           "shard_map", "make_mesh"]


# ------------------------------------------------------------- CompilerParams
# New spelling first: on versions that carry both, TPUCompilerParams is the
# deprecated alias and warns.
if hasattr(pltpu, "CompilerParams"):
    CompilerParams = pltpu.CompilerParams
elif hasattr(pltpu, "TPUCompilerParams"):
    CompilerParams = pltpu.TPUCompilerParams
else:  # pragma: no cover - jax < 0.4.31
    raise ImportError(
        "jax.experimental.pallas.tpu exposes neither CompilerParams nor "
        "TPUCompilerParams; jax >= 0.4.31 is required")


# ----------------------------------------------------- PrefetchScalarGridSpec
if hasattr(pltpu, "PrefetchScalarGridSpec"):
    PrefetchScalarGridSpec = pltpu.PrefetchScalarGridSpec
else:  # pragma: no cover - future jax: folded into pl.GridSpec
    def PrefetchScalarGridSpec(*, num_scalar_prefetch: int, grid, in_specs,
                               out_specs, scratch_shapes=()):
        return pl.GridSpec(grid=grid, in_specs=in_specs, out_specs=out_specs,
                           num_scalar_prefetch=num_scalar_prefetch,
                           scratch_shapes=scratch_shapes)


# ------------------------------------------------------------------ BlockSpec
def _blockspec_old_order() -> bool:  # pragma: no cover - version probe
    try:
        params = list(inspect.signature(pl.BlockSpec).parameters)
    except (TypeError, ValueError):
        return False
    return bool(params) and params[0] == "index_map"


_OLD_BLOCKSPEC = _blockspec_old_order()


def block_spec(block_shape: Optional[Sequence[Optional[int]]] = None,
               index_map: Optional[Callable[..., Any]] = None,
               **kwargs) -> pl.BlockSpec:
    """``pl.BlockSpec`` in the modern ``(block_shape, index_map)`` order."""
    if _OLD_BLOCKSPEC:  # pragma: no cover - old jax only
        return pl.BlockSpec(index_map, block_shape, **kwargs)
    return pl.BlockSpec(block_shape, index_map, **kwargs)


# ------------------------------------------------------------------ shard_map
def _resolve_shard_map():
    fn = getattr(jax, "shard_map", None)
    if fn is None:
        from jax.experimental.shard_map import shard_map as fn
    params = inspect.signature(fn).parameters
    check_kw = "check_vma" if "check_vma" in params else (
        "check_rep" if "check_rep" in params else None)
    return fn, check_kw


_SHARD_MAP, _CHECK_KW = _resolve_shard_map()


def shard_map(f: Callable, *, mesh, in_specs, out_specs,
              check_vma: Optional[bool] = None, **kwargs) -> Callable:
    """``jax.shard_map`` across its namespace / kwarg renames.

    ``check_vma`` follows the newest spelling and is translated to
    ``check_rep`` on older jax; ``None`` leaves the version default.
    """
    kw = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs)
    if check_vma is not None and _CHECK_KW is not None:
        kw[_CHECK_KW] = check_vma
    return _SHARD_MAP(f, **kw)


# ------------------------------------------------------------------ make_mesh
def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str]):
    """``jax.make_mesh`` (>= 0.4.35) with a mesh_utils fallback."""
    if hasattr(jax, "make_mesh"):
        return jax.make_mesh(tuple(axis_shapes), tuple(axis_names))
    from jax.experimental import mesh_utils  # pragma: no cover - old jax
    devices = mesh_utils.create_device_mesh(tuple(axis_shapes))
    return jax.sharding.Mesh(devices, tuple(axis_names))
