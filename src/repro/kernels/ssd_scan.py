"""Mamba2 SSD intra-chunk kernel for TPU (Pallas).

Computes, per (batch, chunk, head) grid cell, the quadratic-within-chunk SSD
terms that dominate compute:

    y_diag[q, p]  = sum_{k<=q} C_q.B_k * exp(Acum_q - Acum_k) * dt_k * x[k, p]
    state[p, n]   = sum_k exp(Acum_Q - Acum_k) * dt_k * x[k, p] * B[k, n]

The chunk-decay matrix L = exp(segsum(a)) lives entirely in VMEM
([Q, Q] f32, 256 KB at Q=256) and both contractions are MXU matmuls
([Q,N]x[N,Q] and [Q,Q]x[Q,P]).  The cross-chunk recurrence (cheap,
O(chunks)) is composed around this kernel in ops.py with an associative
scan, exactly mirroring the pure-jnp oracle in ref.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .compat import CompilerParams, block_spec

NEG_INF = -1e30


def _ssd_chunk_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, s_ref, *,
                      q_len: int):
    # Blocks: x [Q, P]; dt, a [1, Q]; b, c [Q, N]; y [Q, P]; s [P, N].
    x = x_ref[...].astype(jnp.float32)
    dt = dt_ref[0].astype(jnp.float32)          # [Q]
    a = a_ref[0].astype(jnp.float32)            # [Q]
    B = b_ref[...].astype(jnp.float32)               # [Q, N]
    C = c_ref[...].astype(jnp.float32)               # [Q, N]

    a_cum = jnp.cumsum(a)                          # [Q]
    # L[q, k] = exp(a_cum[q] - a_cum[k]) for k <= q else 0.
    diff = a_cum[:, None] - a_cum[None, :]
    qi = jax.lax.broadcasted_iota(jnp.int32, (q_len, q_len), 0)
    kj = jax.lax.broadcasted_iota(jnp.int32, (q_len, q_len), 1)
    L = jnp.exp(jnp.where(kj <= qi, diff, NEG_INF))

    CB = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [Q, Q]
    M = CB * L * dt[None, :]
    xdt = x * dt[:, None]
    y_ref[...] = jax.lax.dot_general(
        M, x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(y_ref.dtype)

    decay = jnp.exp(a_cum[-1] - a_cum)             # [Q]
    xw = x * (decay * dt)[:, None]                 # [Q, P]
    s_ref[...] = jax.lax.dot_general(
        xw, B, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(s_ref.dtype)


def ssd_chunk_kernel(x: jnp.ndarray, dt: jnp.ndarray, a: jnp.ndarray,
                     B: jnp.ndarray, C: jnp.ndarray, *,
                     interpret: bool = True):
    """Intra-chunk SSD terms.

    x: [G, Q, P]; dt, a: [G, Q]; B, C: [G, Q, N] where G = batch*chunks*heads
    flattened grid.  Returns (y_diag [G, Q, P] f32, states [G, P, N] f32).
    """
    G, Q, P = x.shape
    N = B.shape[-1]
    y, s = pl.pallas_call(
        functools.partial(_ssd_chunk_kernel, q_len=Q),
        grid=(G,),
        in_specs=[
            block_spec((None, Q, P), lambda g: (g, 0, 0)),
            block_spec((None, 1, Q), lambda g: (g, 0, 0)),
            block_spec((None, 1, Q), lambda g: (g, 0, 0)),
            block_spec((None, Q, N), lambda g: (g, 0, 0)),
            block_spec((None, Q, N), lambda g: (g, 0, 0)),
        ],
        out_specs=[
            block_spec((None, Q, P), lambda g: (g, 0, 0)),
            block_spec((None, P, N), lambda g: (g, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((G, Q, P), jnp.float32),
            jax.ShapeDtypeStruct((G, P, N), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(x, dt[:, None, :], a[:, None, :], B, C)
    return y, s
