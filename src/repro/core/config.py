"""Configuration dataclasses for the Reverse Address Translation (RAT) simulator.

Defaults follow Table 1 of the paper ("Analyzing Reverse Address Translation
Overheads in Multi-GPU Scale-Up Pods"): a UALink single-level Clos pod with
16 stations per GPU (800 Gbps per station), a per-station L1 Link TLB, a
shared per-GPU L2 Link TLB, page-walk caches and a shared pool of parallel
page-table walkers.  All times are nanoseconds, sizes are bytes.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional

KB = 1024
MB = 1024 * KB
GB = 1024 * MB


@dataclass(frozen=True)
class TLBConfig:
    """A single TLB level (L1 per-station or L2 per-GPU)."""

    entries: int
    assoc: int  # 0 => fully associative
    hit_latency_ns: float
    mshr_entries: int = 0  # 0 => no MSHR at this level


@dataclass(frozen=True)
class PWCConfig:
    """Page-walk caches: one cache per upper page-table level.

    ``entries[i]`` caches the pointer produced by walk step ``i``; coverage[i]
    is the address span one entry maps (bytes).  With 2 MB pages the leaf PTE
    read always goes to memory (it fills the Link TLBs, not the PWC), so for a
    5-level x86-style table a 2 MB walk performs ``len(entries)`` cached
    lookups plus one uncached leaf read.
    """

    entries: tuple = (16, 32, 64, 128)
    assoc: int = 2
    lookup_latency_ns: float = 50.0
    # Root / PML5E / PML4E / PDPTE pointer coverage for a 5-level table with
    # 2 MB pages; the leaf PDE read (the translation itself) is never PWC
    # cached — it fills the Link TLBs.
    coverage_bytes: tuple = (1 << 57, 1 << 48, 1 << 39, 1 << 30)

    def __post_init__(self):
        assert len(self.entries) == len(self.coverage_bytes)


@dataclass(frozen=True)
class TranslationConfig:
    """The Reverse Address Translation hierarchy at the target GPU."""

    l1: TLBConfig = TLBConfig(entries=32, assoc=0, hit_latency_ns=50.0,
                              mshr_entries=256)
    l2: TLBConfig = TLBConfig(entries=512, assoc=2, hit_latency_ns=100.0,
                              mshr_entries=512)
    pwc: PWCConfig = PWCConfig()
    n_ptw: int = 100              # parallel page-table walkers (shared/GPU)
    page_bytes: int = 2 * MB
    mem_access_ns: float = 270.0  # local fabric (120) + HBM (150) per PT read
    enabled: bool = True          # False => ideal (zero-overhead) translation


@dataclass(frozen=True)
class FabricConfig:
    """UALink pod: per-station bandwidth, latencies, and the pod topology.

    The paper's fabric is a single-level Clos (``topology="single_clos"``,
    the default — every pair sees :attr:`oneway_ns`).  The topology layer
    (:mod:`repro.core.topology`) generalizes this to hierarchical pods:
    ``"two_tier"`` (leaf/spine with an oversubscribed uplink) and
    ``"multi_pod"`` (Clos pods joined over a scale-out hop), parameterized
    by the tier fields below.
    """

    n_gpus: int = 16
    gpus_per_node: int = 4
    stations_per_gpu: int = 16
    station_gbps: float = 800.0        # 4 lanes x 200 Gbps
    switch_latency_ns: float = 300.0   # single-level Clos ULS
    d2d_latency_ns: float = 300.0      # die-to-die (NIC/station crossing)
    local_fabric_ns: float = 120.0     # CU -> NoC (paper: constant, all-miss)
    hbm_ns: float = 150.0              # HBM access at the target
    request_bytes: int = 256           # UALink flit-batched remote store
    # -- topology (repro.core.topology) ------------------------------------
    topology: str = "single_clos"      # registry name of the pod topology
    leaf_size: int = 0                 # two_tier: GPUs per leaf switch
                                       # (0 => gpus_per_node)
    spine_latency_ns: float = 300.0    # two_tier: spine-switch crossing
    oversubscription: float = 1.0      # two_tier: leaf->spine uplink
                                       # oversubscription factor
    pod_size: int = 0                  # multi_pod: GPUs per pod (0 => all)
    interpod_latency_ns: float = 900.0      # multi_pod: scale-out hop
    interpod_oversubscription: float = 4.0  # multi_pod: pod egress scarcity
    # Per-station ingress buffering at the target (requests resident from
    # arrival until their translation resolves).  When a pending walk holds
    # more than this many requests the station exerts credit backpressure
    # upstream, stalling the whole port (UALink credit-based flow control).
    # Default equals the paper's 256-entry L1 MSHR: the MSHR target slots are
    # exactly the resource that holds untranslated in-flight requests.
    ingress_entries: int = 256

    @property
    def station_bw(self) -> float:
        """Bytes/ns of one station."""
        return self.station_gbps / 8.0  # Gbps -> bytes/ns  (100 GB/s)

    @property
    def gpu_bw(self) -> float:
        """Aggregate bytes/ns of one GPU (requests stripe over stations)."""
        return self.station_bw * self.stations_per_gpu

    @property
    def oneway_ns(self) -> float:
        """Source CU -> target station: local fabric + d2d + switch + d2d."""
        return (self.local_fabric_ns + self.d2d_latency_ns
                + self.switch_latency_ns + self.d2d_latency_ns)

    @property
    def return_ns(self) -> float:
        """Target -> source ack path (symmetric, minus the CU hop)."""
        return (self.d2d_latency_ns + self.switch_latency_ns
                + self.d2d_latency_ns + self.local_fabric_ns)


@dataclass(frozen=True)
class PreTranslationConfig:
    """Paper §6.1: fused pre-translation kernels.

    Translation-only probe requests are issued during the compute phase that
    precedes the collective, warming Link TLBs before data arrives.
    ``lead_time_ns`` is how long before the collective the fused kernel starts
    issuing probes; ``pages_per_flow`` limits how deep it warms each flow
    (0 => all pages of the collective)."""

    enabled: bool = False
    lead_time_ns: float = 2000.0
    pages_per_flow: int = 1
    probe_issue_interval_ns: float = 10.0


@dataclass(frozen=True)
class PrefetchConfig:
    """Paper §6.2: software-guided TLB prefetching.

    When a flow first touches page ``k`` the prefetcher requests translation of
    pages ``k+1 .. k+depth`` (next-page prediction from the static layout of
    the collective's buffers)."""

    enabled: bool = False
    depth: int = 1


@dataclass(frozen=True)
class SimConfig:
    fabric: FabricConfig = field(default_factory=FabricConfig)
    translation: TranslationConfig = field(default_factory=TranslationConfig)
    pretranslation: PreTranslationConfig = field(
        default_factory=PreTranslationConfig)
    prefetch: PrefetchConfig = field(default_factory=PrefetchConfig)
    # Collective traffic pattern, by registry name (repro.core.patterns):
    # "all_to_all" (the paper's workload, default), "ring_allreduce",
    # "rd_allreduce", "all_gather", "reduce_scatter", "broadcast",
    # "hier_all_to_all", "multipod_all_to_all".
    collective: str = "all_to_all"
    iterations: int = 1          # back-to-back collective iterations
    # Session replay (repro.core.session): an inter-collective idle gap of at
    # least this many ns flushes all cached translations, modelling eviction
    # by competing traffic while the pod is quiet.  None => TLB entries
    # survive arbitrarily long gaps (the hierarchy has no self-decay).
    tlb_retention_ns: Optional[float] = None
    symmetric: bool = True       # simulate a single target GPU (symmetric
                                 # patterns load every GPU identically);
                                 # False simulates every target
    collect_trace: bool = False  # keep per-request latency arrays (figs 9/10)
    # Simulation engine: "event" (the reference per-epoch Python loop) or
    # "vectorized" (repro.core.engine_vec — batched numpy arithmetic with a
    # minimal sequential TLB core; bit-for-bit identical results, ~10x+
    # faster on sweep-scale points).  Threaded through ratsim, sessions,
    # workload replay and serving.
    engine: str = "event"

    def replace(self, **kw) -> "SimConfig":
        return dataclasses.replace(self, **kw)

    def ideal(self) -> "SimConfig":
        return self.replace(
            translation=dataclasses.replace(self.translation, enabled=False))


def paper_config(n_gpus: int = 16, **kw) -> SimConfig:
    """The paper's Table-1 baseline for a given pod size."""
    fab = FabricConfig(n_gpus=n_gpus)
    return SimConfig(fabric=fab, **kw)
