"""RAT-aware collective algorithm selection (DESIGN.md §14).

The pattern registry (:mod:`repro.core.patterns`) holds several algorithms
per *logical* collective (``allreduce`` -> ring or recursive doubling,
``all_to_all`` -> direct, hierarchical or pod-granular), but until this
layer existed every caller hard-coded one concrete choice.  An
:class:`AlgorithmPolicy` resolves ``(logical name, nbytes, fabric, TLB
state)`` to a concrete registry name, so derivation, replay and serving can
request collectives by what they *do* and let the policy pick how.

The RAT twist (the paper's Fig. 4/5 mechanism): cold Link-TLB misses tax
algorithms by how many distinct pages each *step* touches, warm runs only by
bandwidth — so the completion-optimal algorithm for a small collective
differs between cold and warm state.  Policies therefore key on
``state in ("cold", "warm")``; callers that track buffer warmth (sessions
per ``base_offset``, :class:`~repro.workloads.derive.StepEmitter` per
logical buffer) pass the state each call observes.

Three policies:

* :class:`FixedPolicy` — maps each logical class to its historical default
  (ring allreduce, direct all-to-all, ...), state-independent.  This is the
  default everywhere, reproducing the pre-policy traces bit-for-bit.
* :class:`AutoPolicy` — exhaustive simulate-and-pick: prices every feasible
  candidate with the vectorized engine (two back-to-back iterations: the
  first is the cold completion, the second the warm one) and picks the
  minimum for the requested state.  Memoized per (candidate, size, fabric).
* :class:`PolicyTable` — a cached resolution table keyed by
  ``(logical, size bucket, topology, n_gpus, state)``, JSON-serializable
  (:meth:`PolicyTable.save`) and loadable without importing jax or pricing
  anything (:meth:`PolicyTable.load`) — the form serving sweeps consume.

``python -m repro.core.select --out table.json`` builds a table over a grid
(the CI artifact); :func:`get_policy` parses the CLI/sweep spec strings
``"fixed" | "auto" | "table:<path>"``.
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from .config import FabricConfig, SimConfig
from .patterns import LOGICAL, PATTERNS, candidates_for, logical_of

STATES = ("cold", "warm")

# Historical hard-coded choice per logical class: what derivation/serving
# emitted before the policy layer existed.  FixedPolicy resolves to these,
# which is what keeps the default bit-for-bit.
FIXED_DEFAULTS: Dict[str, str] = {
    "all_to_all": "all_to_all",
    "allreduce": "ring_allreduce",
    "all_gather": "all_gather",
    "reduce_scatter": "reduce_scatter",
    "broadcast": "broadcast",
    "kv_transfer": "kv_transfer",
}


def size_bucket(nbytes: int) -> int:
    """Power-of-two size bucket (floor log2) a byte count falls into."""
    return max(0, int(nbytes).bit_length() - 1)


@dataclass(frozen=True)
class Resolution:
    """One policy decision: the concrete algorithm plus its provenance."""

    collective: str     # concrete registry name to run
    logical: str        # logical class that was requested
    provenance: str     # e.g. "fixed", "auto:cold", "table:warm", "explicit"


def _check_state(state: str) -> None:
    if state not in STATES:
        raise ValueError(f"unknown TLB state {state!r}; known: {STATES}")


class AlgorithmPolicy:
    """Resolves a logical collective to a concrete registered algorithm.

    ``resolve`` accepts either a *logical* class name (selected among its
    feasible candidates) or a *concrete* registry name (an explicit request
    — always honored unchanged, so traces that pin an algorithm replay that
    algorithm under any policy).  Names that are both (a logical class named
    after its canonical member, e.g. ``all_to_all``) resolve as logical.
    """

    name = "abstract"

    def resolve(self, logical: str, nbytes: int, fab: FabricConfig,
                state: str = "cold") -> Resolution:
        raise NotImplementedError

    def _classify(self, name: str) -> Tuple[Optional[str], Optional[Resolution]]:
        """(logical_class, explicit_resolution): exactly one is non-None."""
        if name in LOGICAL:
            return name, None
        if name in PATTERNS:
            return None, Resolution(collective=name, logical=logical_of(name),
                                    provenance="explicit")
        raise ValueError(
            f"unknown collective {name!r}; known: {sorted(PATTERNS)}"
            f"; logical classes: {sorted(LOGICAL)}")


class FixedPolicy(AlgorithmPolicy):
    """The historical defaults, state-independent (bit-for-bit baseline)."""

    name = "fixed"

    def __init__(self, overrides: Optional[Dict[str, str]] = None):
        self.choices = dict(FIXED_DEFAULTS)
        for logical, concrete in (overrides or {}).items():
            if logical not in LOGICAL:
                raise ValueError(f"unknown logical class {logical!r}; "
                                 f"known: {sorted(LOGICAL)}")
            if concrete not in LOGICAL[logical]:
                raise ValueError(
                    f"{concrete!r} is not a member of logical class "
                    f"{logical!r} ({LOGICAL[logical]})")
            self.choices[logical] = concrete

    def resolve(self, logical, nbytes, fab, state="cold"):
        _check_state(state)
        cls, explicit = self._classify(logical)
        if explicit is not None:
            return explicit
        return Resolution(collective=self.choices[cls], logical=cls,
                          provenance="fixed")


class AutoPolicy(AlgorithmPolicy):
    """Exhaustive simulate-and-pick over the feasible candidates.

    Every candidate is priced once per (size, fabric) with a two-iteration
    run — iteration 0 completes against stone-cold TLBs, iteration 1
    against the warmth iteration 0 left — giving the (cold, warm)
    completion pair the selection keys on.  Ties break toward the fixed
    default, then registration order, so resolution is deterministic.
    """

    name = "auto"

    def __init__(self, engine: str = "vectorized",
                 base: Optional[SimConfig] = None):
        # ``base`` is the deployment config candidates are priced under
        # (page size, TLB geometry, pre-translation/prefetch, ...); its
        # fabric/collective/engine/iterations fields are overridden per
        # candidate.  None prices under the Table-1 defaults.
        self.engine = engine
        self.base = base
        self._scores: Dict[tuple, Dict[str, Tuple[float, float]]] = {}

    def scores(self, logical: str, nbytes: int,
               fab: FabricConfig) -> Dict[str, Tuple[float, float]]:
        """(cold_ns, warm_ns) completion per feasible candidate."""
        key = (logical, nbytes, repr(fab), repr(self.base))
        cached = self._scores.get(key)
        if cached is not None:
            return cached
        from .engine import simulate
        out: Dict[str, Tuple[float, float]] = {}
        base = self.base if self.base is not None else SimConfig()
        for cand in candidates_for(logical, fab):
            cfg = base.replace(fabric=fab, collective=cand,
                               engine=self.engine, iterations=2,
                               symmetric=True, collect_trace=False)
            res = simulate(nbytes, cfg)
            out[cand] = (res.iterations[0].completion_ns,
                         res.iterations[1].completion_ns)
        self._scores[key] = out
        return out

    def resolve(self, logical, nbytes, fab, state="cold"):
        _check_state(state)
        cls, explicit = self._classify(logical)
        if explicit is not None:
            return explicit
        scores = self.scores(cls, nbytes, fab)
        if not scores:
            raise ValueError(
                f"no feasible algorithm for {cls!r} on {fab.n_gpus} GPUs "
                f"({fab.topology})")
        default = FIXED_DEFAULTS.get(cls)
        order = LOGICAL[cls]
        si = 0 if state == "cold" else 1
        best = min(scores, key=lambda c: (scores[c][si], c != default,
                                          order.index(c)))
        return Resolution(collective=best, logical=cls,
                          provenance=f"auto:{state}")


class PolicyTable(AlgorithmPolicy):
    """Cached resolution table (the serializable form serving consumes).

    Keyed by ``(logical, size_bucket, topology, n_gpus, state)``; lookups
    outside the table fall back to the fixed defaults, so a table built
    over a partial grid is always safe to deploy.  ``save``/``load`` use a
    flat JSON schema (``policy-table-v1``) and import nothing heavier than
    the pattern registry — loading is jax-free by construction, matching
    the serving CLI contract.
    """

    name = "table"
    SCHEMA = "policy-table-v1"

    def __init__(self, entries: Optional[Dict[tuple, str]] = None,
                 meta: Optional[dict] = None):
        self.entries: Dict[tuple, str] = dict(entries or {})
        self.meta = dict(meta or {})
        self._fallback = FixedPolicy()

    def key(self, logical: str, nbytes: int, fab: FabricConfig,
            state: str) -> tuple:
        return (logical, size_bucket(nbytes), fab.topology, fab.n_gpus,
                state)

    def resolve(self, logical, nbytes, fab, state="cold"):
        _check_state(state)
        cls, explicit = self._classify(logical)
        if explicit is not None:
            return explicit
        choice = self.entries.get(self.key(cls, nbytes, fab, state))
        if choice is None:
            res = self._fallback.resolve(cls, nbytes, fab, state)
            return dataclasses.replace(res, provenance="table:miss")
        return Resolution(collective=choice, logical=cls,
                          provenance=f"table:{state}")

    # -- serialization -------------------------------------------------------
    def to_json(self) -> dict:
        rows = [dict(logical=k[0], size_bucket=k[1], topology=k[2],
                     n_gpus=k[3], state=k[4], collective=v)
                for k, v in sorted(self.entries.items())]
        return dict(schema=self.SCHEMA, meta=self.meta, entries=rows)

    def save(self, path: str) -> None:
        with open(path, "w") as fh:
            json.dump(self.to_json(), fh, indent=1, sort_keys=True)
            fh.write("\n")

    @classmethod
    def from_json(cls, doc: dict) -> "PolicyTable":
        if doc.get("schema") != cls.SCHEMA:
            raise ValueError(f"not a {cls.SCHEMA} document: "
                             f"schema={doc.get('schema')!r}")
        entries = {}
        for row in doc["entries"]:
            if row["collective"] not in PATTERNS:
                raise ValueError(
                    f"table names unknown collective {row['collective']!r}")
            entries[(row["logical"], row["size_bucket"], row["topology"],
                     row["n_gpus"], row["state"])] = row["collective"]
        return cls(entries=entries, meta=doc.get("meta"))

    @classmethod
    def load(cls, path: str) -> "PolicyTable":
        with open(path) as fh:
            return cls.from_json(json.load(fh))


def build_policy_table(
        sizes, gpu_counts, *,
        logicals=("all_to_all", "allreduce", "all_gather", "reduce_scatter"),
        topologies=("single_clos",),
        leaf_size: int = 0, oversubscription: float = 1.0, pod_size: int = 0,
        engine: str = "vectorized", base: Optional[SimConfig] = None,
        auto: Optional[AutoPolicy] = None) -> PolicyTable:
    """Exhaustively price the grid and cache the per-state optima.

    One entry per ``(logical, bucket(size), topology, n_gpus, state)``;
    sizes falling into the same bucket are priced at their own byte count
    but the later size wins the bucket (pass bucket-aligned sizes — powers
    of two — to avoid the ambiguity).  The builder reuses one
    :class:`AutoPolicy` so candidate completions are priced exactly once.
    """
    auto = auto or AutoPolicy(engine=engine, base=base)
    entries: Dict[tuple, str] = {}
    table = PolicyTable()
    for topo in topologies:
        for n in gpu_counts:
            fab = FabricConfig(n_gpus=n, topology=topo, leaf_size=leaf_size,
                               oversubscription=oversubscription,
                               pod_size=pod_size)
            for logical in logicals:
                for nbytes in sizes:
                    if not candidates_for(logical, fab):
                        continue
                    for state in STATES:
                        res = auto.resolve(logical, nbytes, fab, state)
                        entries[table.key(logical, nbytes, fab,
                                          state)] = res.collective
    meta = dict(engine=engine, sizes=[int(s) for s in sizes],
                gpu_counts=[int(n) for n in gpu_counts],
                topologies=list(topologies), logicals=list(logicals))
    if auto.base is not None:
        meta["page_bytes"] = auto.base.translation.page_bytes
    return PolicyTable(entries=entries, meta=meta)


def get_policy(spec) -> Optional[AlgorithmPolicy]:
    """Parse a policy spec: ``None``/instance pass through, strings are
    ``"fixed" | "auto" | "table:<path>"`` (the CLI/sweep-point form)."""
    if spec is None or isinstance(spec, AlgorithmPolicy):
        return spec
    if spec == "fixed":
        return FixedPolicy()
    if spec == "auto":
        return AutoPolicy()
    if isinstance(spec, str) and spec.startswith("table:"):
        return PolicyTable.load(spec[len("table:"):])
    raise ValueError(
        f"unknown policy spec {spec!r}; expected 'fixed', 'auto' or "
        f"'table:<path>'")


def session_collective(policy: Optional[AlgorithmPolicy], cfg: SimConfig,
                       nbytes: int, collective: Optional[str],
                       n_gpus: Optional[int], warm: bool) -> Optional[str]:
    """Per-call policy resolution shared by SimSession and RefSession.

    One helper so the engine session and the oracle mirror resolve
    identically (the oracle-equivalence contract extends to policies).
    ``warm`` is the caller's view of the target region's TLB state.
    Returns the concrete name to run (or the untouched ``collective`` when
    no policy is attached).
    """
    if policy is None:
        return collective
    name = collective if collective is not None else cfg.collective
    fab = cfg.fabric
    fab_n = (fab if n_gpus is None or n_gpus == fab.n_gpus
             else dataclasses.replace(fab, n_gpus=n_gpus))
    return policy.resolve(name, nbytes, fab_n,
                          state="warm" if warm else "cold").collective


def main(argv=None) -> int:
    """CLI: build a policy table JSON over a size/pod grid (CI artifact)."""
    import argparse

    from .config import KB, MB, TranslationConfig
    from .topology import TOPOLOGIES

    p = argparse.ArgumentParser(
        prog="python -m repro.core.select",
        description="Build a RAT-aware algorithm-selection table: price "
                    "every registered candidate per (logical collective, "
                    "size, topology, pod size, cold|warm) and cache the "
                    "optima as JSON (loadable jax-free).")
    p.add_argument("--out", required=True, metavar="JSON",
                   help="output table path")
    p.add_argument("--sizes-mb", default="0.25,1,4,16",
                   help="comma list of collective sizes in MB")
    p.add_argument("--gpus", default="8,16",
                   help="comma list of pod/group sizes")
    p.add_argument("--topologies", default="single_clos",
                   help=f"comma list from {sorted(TOPOLOGIES)}")
    p.add_argument("--logicals",
                   default="all_to_all,allreduce,all_gather,reduce_scatter",
                   help=f"comma list of logical classes {sorted(LOGICAL)}")
    p.add_argument("--engine", default="vectorized",
                   choices=("event", "vectorized"))
    p.add_argument("--page-kb", type=int, default=0,
                   help="translation page size in KB candidates are priced "
                        "under (0: Table-1 default, 2 MB).  Small pages are "
                        "where cold/warm optima diverge (fig17)")
    args = p.parse_args(argv)

    sizes = [int(float(s) * MB) for s in args.sizes_mb.split(",")]
    gpus = [int(g) for g in args.gpus.split(",")]
    topos = [t for t in args.topologies.split(",") if t]
    for t in topos:
        if t not in TOPOLOGIES:
            p.error(f"unknown topology {t!r}; known: {sorted(TOPOLOGIES)}")
    logicals = [c for c in args.logicals.split(",") if c]
    for c in logicals:
        if c not in LOGICAL:
            p.error(f"unknown logical class {c!r}; known: {sorted(LOGICAL)}")

    base = None
    if args.page_kb:
        base = SimConfig(translation=TranslationConfig(
            page_bytes=args.page_kb * KB))
    table = build_policy_table(sizes, gpus, logicals=logicals,
                               topologies=topos, engine=args.engine,
                               base=base)
    table.save(args.out)
    fixed = FixedPolicy()
    diverging = sum(
        1 for (logical, bucket, topo, n, state), coll in table.entries.items()
        if state == "cold"
        and coll != table.entries[(logical, bucket, topo, n, "warm")])
    non_default = sum(1 for (logical, *_rest), coll in table.entries.items()
                      if coll != fixed.choices[logical])
    print(f"# wrote {args.out}: {len(table.entries)} entries, "
          f"{non_default} off the fixed default, "
          f"{diverging} cold/warm-diverging points")
    print("logical,size_bucket,topology,n_gpus,state,collective")
    for (logical, bucket, topo, n, state), coll in sorted(
            table.entries.items()):
        print(f"{logical},{bucket},{topo},{n},{state},{coll}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
