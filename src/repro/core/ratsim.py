"""Top-level API of the Reverse Address Translation simulator.

Typical use::

    from repro.core import ratsim
    r = ratsim.compare(1 << 20, n_gpus=16)       # baseline vs ideal
    print(r.degradation, r.baseline.mean_rat_ns)
    r = ratsim.compare(1 << 20, 16, collective="ring_allreduce")

    s = ratsim.session(16)                       # persistent-TLB session
    cold = s.run(1 << 20)                        # cold Link TLBs
    warm = s.run(1 << 20)                        # same pages, warm TLBs

All figures of the paper are produced through this module (see benchmarks/).
The ``collective=`` axis selects any registered traffic pattern
(:mod:`repro.core.patterns`); the default is the paper's all-pairs AllToAll.
``sweep`` fans its grid out over a process pool (``workers=0`` forces the
serial path; results are keyed and valued identically either way) and
optionally memoizes points in a caller-supplied cache mapping.
"""
from __future__ import annotations

import dataclasses
import multiprocessing
import os
import sys
from concurrent.futures import ProcessPoolExecutor
from concurrent.futures.process import BrokenProcessPool
from dataclasses import dataclass
from typing import Dict, Iterable, List, MutableMapping, Optional, Tuple

from .config import SimConfig, FabricConfig, paper_config, MB
from .engine import simulate, RunResult
from .patterns import LOGICAL, PATTERNS
from .select import get_policy
from .session import ENGINES, SimSession
from .topology import TOPOLOGIES


@dataclass
class Comparison:
    baseline: RunResult
    ideal: RunResult

    @property
    def degradation(self) -> float:
        """Completion-time ratio vs the zero-RAT-overhead ideal (Fig. 4)."""
        return self.baseline.completion_ns / self.ideal.completion_ns

    @property
    def rat_fraction(self) -> float:
        """Fraction of mean round-trip latency spent on RAT (+ induced
        ingress stalls) — paper Fig. 6."""
        b = self.baseline.breakdown()
        total = sum(b.values())
        return (b["rat_ns"] + b["stall_ns"]) / total


def _resolve_cfg(n_gpus: int, collective: Optional[str],
                 cfg: Optional[SimConfig], cfg_kw,
                 topology: Optional[str] = None,
                 engine: Optional[str] = None,
                 policy=None, nbytes: Optional[int] = None) -> SimConfig:
    cfg = cfg or paper_config(n_gpus, **cfg_kw)
    if collective is not None:
        cfg = cfg.replace(collective=collective)
    if topology is not None:
        cfg = cfg.replace(
            fabric=dataclasses.replace(cfg.fabric, topology=topology))
    if engine is not None:
        cfg = cfg.replace(engine=engine)
    if policy is not None:
        # Free-standing runs start against stone-cold TLBs: the policy
        # resolves a logical collective for the cold state (sessions track
        # per-region warmth themselves — see SimSession).
        pol = get_policy(policy)
        cfg = cfg.replace(collective=pol.resolve(
            cfg.collective, nbytes if nbytes is not None else 0,
            cfg.fabric, state="cold").collective)
    return cfg


def run(nbytes: int, n_gpus: int = 16, *, collective: Optional[str] = None,
        topology: Optional[str] = None, engine: Optional[str] = None,
        policy=None, cfg: Optional[SimConfig] = None, **cfg_kw) -> RunResult:
    return simulate(nbytes, _resolve_cfg(n_gpus, collective, cfg, cfg_kw,
                                         topology, engine, policy, nbytes))


def compare(nbytes: int, n_gpus: int = 16, *,
            collective: Optional[str] = None,
            topology: Optional[str] = None, engine: Optional[str] = None,
            policy=None, cfg: Optional[SimConfig] = None,
            **cfg_kw) -> Comparison:
    cfg = _resolve_cfg(n_gpus, collective, cfg, cfg_kw, topology, engine,
                       policy, nbytes)
    return Comparison(baseline=simulate(nbytes, cfg),
                      ideal=simulate(nbytes, cfg.ideal()))


def session(n_gpus: int = 16, *, collective: Optional[str] = None,
            topology: Optional[str] = None, engine: Optional[str] = None,
            policy=None, cfg: Optional[SimConfig] = None,
            **cfg_kw) -> SimSession:
    """A persistent-TLB session on a fresh pod (repro.core.session).

    ``policy`` is attached to the session (per-run cold/warm resolution),
    not applied to ``cfg.collective`` up front — each ``run`` resolves with
    the warmth its target region actually has at that point.
    """
    return SimSession(_resolve_cfg(n_gpus, collective, cfg, cfg_kw,
                                   topology, engine), policy=policy)


# ---------------------------------------------------------------- sweeps
# Aggregate grid bytes below which sweep() stays serial: worker spawn costs
# hundreds of ms each, which only the paper's large grids amortize.
_PARALLEL_MIN_BYTES = 64 * MB


def _cache_key(nbytes: int, cfg: SimConfig) -> Tuple[int, str]:
    """Stable fingerprint of one sweep point.

    ``SimConfig`` is a tree of frozen dataclasses of primitives/tuples, so
    its repr is deterministic and total — two configs compare equal iff
    their reprs do.
    """
    return (nbytes, repr(cfg))


def _sweep_point(task) -> Tuple[tuple, Comparison]:
    key, nbytes, cfg = task
    return key, Comparison(baseline=simulate(nbytes, cfg),
                           ideal=simulate(nbytes, cfg.ideal()))


def _spawnable() -> bool:
    """Whether spawn-context workers can bootstrap from this parent.

    Spawn re-imports ``__main__`` in the child; a parent run from stdin or
    an embedded interpreter (``python - <<EOF``) has no importable main and
    every worker would die at bootstrap — stay serial instead.
    """
    main = sys.modules.get("__main__")
    if main is None:
        return False
    if getattr(main, "__spec__", None) is not None:   # python -m ...
        return True
    path = getattr(main, "__file__", None)
    return bool(path) and os.path.exists(path)


def _validate_sweep_axes(colls, topos, engine, policy) -> None:
    """Fail fast on bad axis names, before any pool dispatch.

    A typo'd collective/engine/topology used to surface as a worker
    traceback deep inside the process pool; every name is checked here
    against its registry so the error happens eagerly in the caller, with
    the registry contents in the message.
    """
    if engine is not None and engine not in ENGINES:
        raise ValueError(f"unknown engine {engine!r}; known: {ENGINES}")
    for topo in topos:
        if topo is not None and topo not in TOPOLOGIES:
            raise ValueError(f"unknown topology {topo!r}; known: "
                             f"{sorted(TOPOLOGIES)}")
    for coll in colls:
        if coll is None or coll in PATTERNS:
            continue
        if coll in LOGICAL:
            if policy is None:
                raise ValueError(
                    f"logical collective {coll!r} needs a policy= to pick "
                    f"among its candidates {LOGICAL[coll]}; pass "
                    f"policy='fixed'|'auto'|'table:<path>' or a concrete "
                    f"name")
            continue
        raise ValueError(
            f"unknown collective {coll!r}; known: {sorted(PATTERNS)}"
            f"; logical classes: {sorted(LOGICAL)}")


def sweep(sizes, gpu_counts, *, collectives: Optional[Iterable[str]] = None,
          topologies: Optional[Iterable[str]] = None,
          base_cfg: Optional[SimConfig] = None,
          engine: Optional[str] = None,
          policy=None,
          workers: Optional[int] = None,
          cache: Optional[MutableMapping] = None,
          **cfg_kw) -> Dict[tuple, Comparison]:
    """The paper's main sweep (Figs. 4 and 5), per collective / topology.

    Without ``collectives`` the result keys are ``(n_gpus, size)`` as in the
    seed API; with a list of pattern names they grow a leading axis:
    ``(collective, n_gpus, size)``.  ``topologies`` (registry names from
    :mod:`repro.core.topology`) adds a further leading axis the same way —
    with both, keys are ``(topology, collective, n_gpus, size)``.  Tier
    parameters (leaf size, oversubscription, pod size) come from
    ``base_cfg``'s fabric when given, else the ``FabricConfig`` defaults.
    ``engine`` overrides ``SimConfig.engine`` on every point (bit-for-bit
    identical numbers; ``"vectorized"`` prices large grids ~10x faster —
    note the two engines memoize under distinct cache keys).  ``policy``
    (see :func:`repro.core.select.get_policy`) resolves each point's
    collective — which may then be a *logical* class name like
    ``"allreduce"`` — to a concrete algorithm before dispatch; axis names
    are validated eagerly either way, so typos fail here rather than as a
    worker traceback.

    Points are independent, so large grids fan out over a
    ``concurrent.futures`` process pool — ``workers=None`` sizes the pool to
    the host (capped by the task count) but stays serial below a total-work
    threshold (worker spawn costs dwarf small grids); an explicit
    ``workers>=2`` always uses the pool, ``workers=0`` forces the serial
    in-process path.  All paths produce identical keys and identical
    numbers (each point is one deterministic ``simulate`` pair).  ``cache``
    is an optional mapping memoizing points across calls, keyed by
    ``(nbytes, repr(cfg))``; pass the same dict to successive sweeps (or
    figure scripts) to never price the same point twice.

    Standard spawn semantics apply: a *script* that calls ``sweep()`` at
    top level must guard it with ``if __name__ == "__main__":`` (workers
    re-import the main module); stdin/embedded parents with no importable
    main fall back to the serial path automatically.
    """
    out: Dict[tuple, Comparison] = {}
    tasks: List[tuple] = []
    seen_inflight: Dict[tuple, tuple] = {}
    colls = list(collectives) if collectives is not None else [None]
    topos = list(topologies) if topologies is not None else [None]
    _validate_sweep_axes(colls, topos, engine, policy)
    pol = get_policy(policy)
    for topo in topos:
        for coll in colls:
            for n in gpu_counts:
                for s in sizes:
                    # Rescale only the GPU count; every other fabric field
                    # of base_cfg (gpus_per_node, stations, buffering, tier
                    # parameters...) is kept — pattern shape depends on
                    # them.
                    cfg = (base_cfg.replace(fabric=dataclasses.replace(
                               base_cfg.fabric, n_gpus=n))
                           if base_cfg is not None
                           else paper_config(n, **cfg_kw))
                    if coll is not None:
                        cfg = cfg.replace(collective=coll)
                    if topo is not None:
                        cfg = cfg.replace(fabric=dataclasses.replace(
                            cfg.fabric, topology=topo))
                    if engine is not None:
                        cfg = cfg.replace(engine=engine)
                    if pol is not None:
                        # Per-point resolution (cold state: each sweep
                        # point is a free-standing run on a fresh pod).
                        # Resolution happens in the parent, so the cache
                        # key and the worker both see the concrete name.
                        cfg = cfg.replace(collective=pol.resolve(
                            cfg.collective, s, cfg.fabric,
                            state="cold").collective)
                    key = (n, s)
                    if collectives is not None:
                        key = (coll,) + key
                    if topologies is not None:
                        key = (topo,) + key
                    ck = _cache_key(s, cfg)
                    if cache is not None and ck in cache:
                        out[key] = cache[ck]
                    elif ck in seen_inflight:
                        seen_inflight[ck] += (key,)
                    else:
                        seen_inflight[ck] = (key,)
                        tasks.append((key, s, cfg, ck))

    results: List[Tuple[tuple, Comparison]] = []
    pool_tasks = [(key, s, cfg) for (key, s, cfg, _ck) in tasks]
    n_workers = (min(len(pool_tasks), os.cpu_count() or 1)
                 if workers is None else workers)
    # Spawning workers costs interpreter+numpy startup each; only grids with
    # enough simulation work amortize it.  An explicit workers= request
    # always gets the pool.
    big_enough = (workers is not None
                  or sum(s for (_k, s, _c) in pool_tasks) >= _PARALLEL_MIN_BYTES)
    if n_workers >= 2 and len(pool_tasks) > 1 and big_enough and _spawnable():
        try:
            # Spawned (not forked) workers: the parent process may have jax
            # (multithreaded) loaded, and forking a threaded process can
            # deadlock.  Workers only import repro.core (numpy-only).
            ctx = multiprocessing.get_context("spawn")
            with ProcessPoolExecutor(max_workers=n_workers,
                                     mp_context=ctx) as pool:
                results = list(pool.map(_sweep_point, pool_tasks))
        except (OSError, BrokenProcessPool):
            # No usable subprocess support (sandboxed spawn, killed
            # bootstrap...): fall back to the serial path below.
            results = []
    if not results and pool_tasks:
        results = [_sweep_point(t) for t in pool_tasks]

    for (key, cmp_), (_k, s, cfg, ck) in zip(results, tasks):
        for k in seen_inflight[ck]:
            out[k] = cmp_
        if cache is not None:
            cache[ck] = cmp_
    return out
