"""Top-level API of the Reverse Address Translation simulator.

Typical use::

    from repro.core import ratsim
    r = ratsim.compare(1 << 20, n_gpus=16)       # baseline vs ideal
    print(r.degradation, r.baseline.mean_rat_ns)
    r = ratsim.compare(1 << 20, 16, collective="ring_allreduce")

All figures of the paper are produced through this module (see benchmarks/).
The ``collective=`` axis selects any registered traffic pattern
(:mod:`repro.core.patterns`); the default is the paper's all-pairs AllToAll.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Iterable, Optional

from .config import (SimConfig, FabricConfig, TranslationConfig, TLBConfig,
                     PreTranslationConfig, PrefetchConfig, paper_config,
                     KB, MB, GB)
from .engine import simulate, RunResult


@dataclass
class Comparison:
    baseline: RunResult
    ideal: RunResult

    @property
    def degradation(self) -> float:
        """Completion-time ratio vs the zero-RAT-overhead ideal (Fig. 4)."""
        return self.baseline.completion_ns / self.ideal.completion_ns

    @property
    def rat_fraction(self) -> float:
        """Fraction of mean round-trip latency spent on RAT (+ induced
        ingress stalls) — paper Fig. 6."""
        b = self.baseline.breakdown()
        total = sum(b.values())
        return (b["rat_ns"] + b["stall_ns"]) / total


def _resolve_cfg(n_gpus: int, collective: Optional[str],
                 cfg: Optional[SimConfig], cfg_kw) -> SimConfig:
    cfg = cfg or paper_config(n_gpus, **cfg_kw)
    if collective is not None:
        cfg = cfg.replace(collective=collective)
    return cfg


def run(nbytes: int, n_gpus: int = 16, *, collective: Optional[str] = None,
        cfg: Optional[SimConfig] = None, **cfg_kw) -> RunResult:
    return simulate(nbytes, _resolve_cfg(n_gpus, collective, cfg, cfg_kw))


def compare(nbytes: int, n_gpus: int = 16, *,
            collective: Optional[str] = None,
            cfg: Optional[SimConfig] = None, **cfg_kw) -> Comparison:
    cfg = _resolve_cfg(n_gpus, collective, cfg, cfg_kw)
    return Comparison(baseline=simulate(nbytes, cfg),
                      ideal=simulate(nbytes, cfg.ideal()))


def sweep(sizes, gpu_counts, *, collectives: Optional[Iterable[str]] = None,
          base_cfg: Optional[SimConfig] = None,
          **cfg_kw) -> Dict[tuple, Comparison]:
    """The paper's main sweep (Figs. 4 and 5), optionally per collective.

    Without ``collectives`` the result keys are ``(n_gpus, size)`` as in the
    seed API; with a list of pattern names they grow a leading axis:
    ``(collective, n_gpus, size)``.
    """
    out = {}
    colls = list(collectives) if collectives is not None else [None]
    for coll in colls:
        for n in gpu_counts:
            for s in sizes:
                # Rescale only the GPU count; every other fabric field of
                # base_cfg (gpus_per_node, stations, buffering...) is kept —
                # pattern shape depends on them.
                cfg = (base_cfg.replace(fabric=dataclasses.replace(
                           base_cfg.fabric, n_gpus=n))
                       if base_cfg is not None else paper_config(n, **cfg_kw))
                cmp_ = compare(s, n, collective=coll, cfg=cfg)
                out[(n, s) if collectives is None else (coll, n, s)] = cmp_
    return out
