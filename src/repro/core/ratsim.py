"""Top-level API of the Reverse Address Translation simulator.

Typical use::

    from repro.core import ratsim
    r = ratsim.compare(1 << 20, n_gpus=16)       # baseline vs ideal
    print(r.degradation, r.baseline.mean_rat_ns)

All figures of the paper are produced through this module (see benchmarks/).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

from .config import (SimConfig, FabricConfig, TranslationConfig, TLBConfig,
                     PreTranslationConfig, PrefetchConfig, paper_config,
                     KB, MB, GB)
from .engine import simulate, RunResult


@dataclass
class Comparison:
    baseline: RunResult
    ideal: RunResult

    @property
    def degradation(self) -> float:
        """Completion-time ratio vs the zero-RAT-overhead ideal (Fig. 4)."""
        return self.baseline.completion_ns / self.ideal.completion_ns

    @property
    def rat_fraction(self) -> float:
        """Fraction of mean round-trip latency spent on RAT (+ induced
        ingress stalls) — paper Fig. 6."""
        b = self.baseline.breakdown()
        total = sum(b.values())
        return (b["rat_ns"] + b["stall_ns"]) / total


def run(nbytes: int, n_gpus: int = 16, *, cfg: Optional[SimConfig] = None,
        **cfg_kw) -> RunResult:
    cfg = cfg or paper_config(n_gpus, **cfg_kw)
    return simulate(nbytes, cfg)


def compare(nbytes: int, n_gpus: int = 16, *,
            cfg: Optional[SimConfig] = None, **cfg_kw) -> Comparison:
    cfg = cfg or paper_config(n_gpus, **cfg_kw)
    return Comparison(baseline=simulate(nbytes, cfg),
                      ideal=simulate(nbytes, cfg.ideal()))


def sweep(sizes, gpu_counts, *, base_cfg: Optional[SimConfig] = None,
          **cfg_kw) -> Dict[tuple, Comparison]:
    """The paper's main sweep (Figs. 4 and 5)."""
    out = {}
    for n in gpu_counts:
        for s in sizes:
            cfg = (base_cfg.replace(fabric=FabricConfig(n_gpus=n))
                   if base_cfg is not None else paper_config(n, **cfg_kw))
            out[(n, s)] = compare(s, n, cfg=cfg)
    return out
