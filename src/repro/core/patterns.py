"""Collective traffic patterns for the RAT simulator (DESIGN.md §5).

The paper evaluates Reverse Address Translation only on the all-pairs
AllToAll schedule; this module generalizes the simulator to the collective
algorithms that dominate real training/inference traffic.  A
:class:`CollectivePattern` emits, for each *step* of the algorithm, the set
of (src, dst) flows arriving at every target GPU — the engine and the
reference DES replay exactly these flow sets, so oracle-equivalence tests
bind for every pattern.

Semantics shared by all patterns (DESIGN.md §5.1):

  * ``nbytes`` is the per-GPU buffer size of the collective (the amount of
    data each participant holds/ends with), so sizes are comparable across
    patterns.  Chunked algorithms move ``nbytes // n_gpus`` per chunk.
  * A *step* is a dependency barrier: every flow of step ``k+1`` starts only
    after all flows of step ``k`` complete (ring/tree algorithms forward data
    they received in the previous step).
  * ``FlowSpec.offset`` is the byte offset inside the destination GPU's
    receive region; it determines which pages (and hence which Link-TLB
    entries) the flow touches.  Patterns that revisit the same region across
    steps (e.g. recursive doubling) hit warm TLB entries after step 0 —
    exactly the locality difference this abstraction exists to expose.
  * Patterns with ``symmetric=True`` load every GPU identically in every
    step, so simulating a single representative target is exact; asymmetric
    patterns (broadcast) force the engine into every-target mode regardless
    of ``SimConfig.symmetric``.

Only addresses and byte counts matter to the translation model, so
reduction semantics are not modelled: ring ReduceScatter and ring AllGather
emit identical flow sets, and "AllReduce" costs are pure communication time
(no reduction FLOPs).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Type

import numpy as np

from .config import FabricConfig
from .topology import get_topology


@dataclass(frozen=True)
class FlowSpec:
    """One (src -> dst) stream within a single collective step.

    ``offset`` addresses the flow inside dst's receive region; the engine
    turns it into an NPA by adding the per-GPU region base.
    """

    src: int
    dst: int
    nbytes: int
    offset: int


@dataclass
class StepArrays:
    """One collective step as parallel columns (vectorized engine form).

    Row ``i`` is exactly ``steps()[k][i]`` — same flows, same order — so the
    event engine and the vectorized engine consume the *same* schedule, just
    materialized as arrays instead of per-flow objects.  ``out_deg``/
    ``tier_deg`` are lazily cached per-step aggregates (they depend only on
    the step, not on the simulated target).
    """

    src: np.ndarray       # int64
    dst: np.ndarray       # int64
    nbytes: np.ndarray    # int64
    offset: np.ndarray    # int64
    _out_deg: Optional[np.ndarray] = field(default=None, repr=False)
    _tier_cache: Optional[tuple] = field(default=None, repr=False)

    @classmethod
    def from_specs(cls, step: List[FlowSpec]) -> "StepArrays":
        n = len(step)
        return cls(
            src=np.fromiter((s.src for s in step), np.int64, n),
            dst=np.fromiter((s.dst for s in step), np.int64, n),
            nbytes=np.fromiter((s.nbytes for s in step), np.int64, n),
            offset=np.fromiter((s.offset for s in step), np.int64, n))

    def with_stride(self, stride: int) -> "StepArrays":
        """Logical ranks placed on strided pod GPUs (resolve_collective)."""
        return StepArrays(src=self.src * stride, dst=self.dst * stride,
                          nbytes=self.nbytes, offset=self.offset)

    def out_deg(self) -> np.ndarray:
        """Per-source concurrent-flow count of this step (ALL flows — the
        event engine counts zero-byte flows toward the bandwidth split)."""
        if self._out_deg is None:
            self._out_deg = np.bincount(
                self.src, minlength=int(self.src.max()) + 1 if len(self.src)
                else 1)
        return self._out_deg


# Concrete pattern registry and logical equivalence classes, populated at
# class-definition site by @register_pattern (DESIGN.md §14).  A *logical*
# collective names the communication result ("allreduce"); its class lists
# the registered algorithms that produce it, in registration order.  Four of
# the five logical classes are named after their canonical member, so those
# names are simultaneously a concrete registry key and a logical class key —
# resolution order is defined by the policy layer (repro.core.select).
PATTERNS: Dict[str, Type["CollectivePattern"]] = {}
LOGICAL: Dict[str, List[str]] = {}


def register_pattern(cls=None, *, logical: Optional[str] = None):
    """Class decorator registering a :class:`CollectivePattern`.

    Registers ``cls`` under ``cls.name`` in :data:`PATTERNS` and appends it
    to the ``logical`` equivalence class in :data:`LOGICAL` (default: its
    own name forms a singleton class).  Registry and class membership live
    at the definition site, so adding an algorithm is one decorated class.
    """
    def _register(cls):
        name = cls.name
        if name in PATTERNS:
            raise ValueError(f"duplicate collective pattern {name!r}")
        PATTERNS[name] = cls
        LOGICAL.setdefault(logical or name, []).append(name)
        return cls
    return _register(cls) if cls is not None else _register


def logical_of(name: str) -> str:
    """The logical equivalence class a concrete pattern belongs to."""
    for logical, members in LOGICAL.items():
        if name in members:
            return logical
    raise ValueError(
        f"unknown collective {name!r}; known: {sorted(PATTERNS)}")


def candidates_for(logical: str, fab: FabricConfig) -> List[str]:
    """Concrete algorithms that can produce ``logical`` on this fabric.

    ``fab`` carries the topology *and* the participating GPU count, which is
    what per-pattern feasibility depends on (power-of-two ranks for
    recursive doubling, group divisibility for the hierarchical variants).
    Registration order; a concrete name is accepted and answers with the
    rest of its own equivalence class.
    """
    if logical not in LOGICAL:
        if logical in PATTERNS:
            logical = logical_of(logical)
        else:
            raise ValueError(
                f"unknown collective {logical!r}; known: {sorted(PATTERNS)}"
                f"; logical classes: {sorted(LOGICAL)}")
    return [name for name in LOGICAL[logical]
            if PATTERNS[name].feasible(fab)]


class CollectivePattern:
    """Base class: a collective algorithm as per-step flow sets."""

    name: str = "abstract"
    symmetric: bool = True

    @classmethod
    def feasible(cls, fab: FabricConfig) -> bool:
        """Whether this algorithm can run on ``fab`` (group size/topology
        preconditions); infeasible patterns are excluded from
        :func:`candidates_for` instead of raising inside :meth:`steps`."""
        return fab.n_gpus >= 2

    def steps(self, nbytes: int, fab: FabricConfig) -> List[List[FlowSpec]]:
        """Flow sets of each dependency step, in execution order."""
        raise NotImplementedError

    def steps_arrays(self, nbytes: int,
                     fab: FabricConfig) -> List[StepArrays]:
        """The same schedule as :meth:`steps`, as :class:`StepArrays`.

        The base fallback converts the object form row-for-row (exact for
        every pattern); hot patterns override with native array
        construction that never materializes per-flow objects.
        """
        return [StepArrays.from_specs(step)
                for step in self.steps(nbytes, fab)]

    def total_bytes(self, nbytes: int, fab: FabricConfig) -> int:
        """Total bytes crossing the fabric (all steps, all pairs)."""
        return sum(s.nbytes for step in self.steps(nbytes, fab) for s in step)

    def representative_dst(self, fab: FabricConfig) -> int:
        """The target GPU simulated in symmetric mode."""
        return 0


@register_pattern(logical="all_to_all")
class AllToAll(CollectivePattern):
    """All-pairs/direct AllToAll (MSCCLang): the paper's workload.

    One step; every GPU streams one ``nbytes // n`` chunk to every peer
    concurrently.  This is the seed engine's hard-wired schedule, kept
    bit-for-bit identical as the default pattern.
    """

    name = "all_to_all"

    def steps(self, nbytes, fab):
        n = fab.n_gpus
        chunk = nbytes // n  # self-chunk stays local
        step = [FlowSpec(src=src, dst=dst, nbytes=chunk, offset=src * chunk)
                for dst in range(n) for src in range(n) if src != dst]
        return [step]

    def steps_arrays(self, nbytes, fab):
        # Native array construction preserving steps()'s dst-major order
        # (``for dst ... for src ... if src != dst``) — the O(n^2) listcomp
        # dominates pod-scale sweep points, so the vectorized engine never
        # pays it.
        n = fab.n_gpus
        chunk = nbytes // n
        r = np.arange(n, dtype=np.int64)
        dst = np.repeat(r, n)
        src = np.tile(r, n)
        keep = src != dst
        src, dst = src[keep], dst[keep]
        return [StepArrays(src=src, dst=dst,
                           nbytes=np.full(len(src), chunk, dtype=np.int64),
                           offset=src * chunk)]


@register_pattern(logical="allreduce")
class RingAllReduce(CollectivePattern):
    """Bandwidth-optimal ring AllReduce: reduce-scatter then allgather.

    2(n-1) steps; in every step each GPU sends one ``nbytes // n`` chunk to
    its ring successor.  The chunk index rotates, so each step touches a
    different slice of the target's buffer — for buffers smaller than
    ``n_gpus`` pages, successive chunks share pages and warm the TLBs.
    """

    name = "ring_allreduce"

    def steps(self, nbytes, fab):
        n = fab.n_gpus
        chunk = nbytes // n
        steps = []
        # Reduce-scatter phase: step s, GPU r forwards chunk (r - s) mod n.
        for s in range(n - 1):
            steps.append([
                FlowSpec(src=r, dst=(r + 1) % n, nbytes=chunk,
                         offset=((r - s) % n) * chunk)
                for r in range(n)])
        # Allgather phase: GPU r owns reduced chunk (r + 1) mod n and
        # circulates it; step s forwards chunk (r + 1 - s) mod n.
        for s in range(n - 1):
            steps.append([
                FlowSpec(src=r, dst=(r + 1) % n, nbytes=chunk,
                         offset=((r + 1 - s) % n) * chunk)
                for r in range(n)])
        return steps


@register_pattern(logical="allreduce")
class RecursiveDoublingAllReduce(CollectivePattern):
    """Latency-optimal recursive-doubling AllReduce (power-of-two pods).

    log2(n) steps; in step s each GPU exchanges the *full* buffer with
    partner ``rank XOR 2**s``.  Every step rewrites the same region, so all
    pages are warm after step 0 — but the partner (and hence the station
    striping) changes each step, exercising the per-station L1 / shared L2
    split of the hierarchy.
    """

    name = "rd_allreduce"

    @classmethod
    def feasible(cls, fab):
        n = fab.n_gpus
        return n >= 2 and not (n & (n - 1))

    def steps(self, nbytes, fab):
        n = fab.n_gpus
        if n < 2 or n & (n - 1):
            raise ValueError(
                f"rd_allreduce requires a power-of-two GPU count, got {n}")
        return [[FlowSpec(src=r, dst=r ^ (1 << s), nbytes=nbytes, offset=0)
                 for r in range(n)]
                for s in range(n.bit_length() - 1)]


@register_pattern(logical="all_gather")
class RingAllGather(CollectivePattern):
    """Ring AllGather: each GPU ends with the ``nbytes`` concatenation.

    n-1 steps; GPU r starts owning chunk r (``nbytes // n``) and forwards
    chunk (r - s) mod n to its successor in step s.
    """

    name = "all_gather"

    def steps(self, nbytes, fab):
        n = fab.n_gpus
        chunk = nbytes // n
        return [[FlowSpec(src=r, dst=(r + 1) % n, nbytes=chunk,
                          offset=((r - s) % n) * chunk)
                 for r in range(n)]
                for s in range(n - 1)]


@register_pattern(logical="reduce_scatter")
class RingReduceScatter(RingAllGather):
    """Ring ReduceScatter: traffic-identical to ring AllGather.

    The translation model only sees addresses and bytes, so the reduction
    on arrival is free; kept as a distinct named pattern for API clarity
    (and so reduction-aware extensions have a seam to hook into).
    """

    name = "reduce_scatter"


@register_pattern(logical="broadcast")
class BinomialBroadcast(CollectivePattern):
    """Binomial-tree broadcast from root 0 (any GPU count).

    ceil(log2(n)) steps; in step s every rank below ``2**s`` that has the
    data forwards the full buffer to ``rank + 2**s``.  Asymmetric: each
    non-root GPU receives exactly once, so the engine simulates every
    receiving target and the step barrier models the forwarding dependency.
    """

    name = "broadcast"
    symmetric = False

    def steps(self, nbytes, fab):
        n = fab.n_gpus
        steps = []
        s = 0
        while (1 << s) < n:
            step = [FlowSpec(src=r, dst=r + (1 << s), nbytes=nbytes, offset=0)
                    for r in range(1 << s) if r + (1 << s) < n]
            if step:
                steps.append(step)
            s += 1
        return steps


@register_pattern(logical="all_to_all")
class HierarchicalAllToAll(CollectivePattern):
    """Two-level AllToAll: intra-group gather, then inter-group exchange.

    The group is derived from the fabric topology
    (:meth:`~repro.core.topology.Topology.local_group`): the historical
    ``gpus_per_node`` node split on the flat default, the *leaf* on
    ``two_tier`` — so the intra phase stays on the cheap tier and only the
    aggregated exchange crosses the spine.

    Phase 1: within each ``g``-GPU group, GPU i hands local peer p the
    chunks destined for p's rail (one ``nbytes // n`` chunk per group) —
    (g-1) flows of ``nbytes // g`` per GPU into a staging region above the
    final buffer.  Phase 2: each GPU exchanges aggregated group-chunks with
    its (n/g - 1) rail counterparts — flows of ``g * nbytes // n`` landing
    at the final buffer offset of the sender's group.  Fewer, larger flows
    per step than direct AllToAll: fewer cold pages per step at the cost of
    2x fabric volume (approximately; exactly (g-1)/g + (m-1)/m of nbytes
    per GPU vs (n-1)/n).
    """

    name = "hier_all_to_all"

    def _group(self, fab: FabricConfig) -> int:
        return get_topology(fab).local_group()

    @classmethod
    def feasible(cls, fab):
        if fab.n_gpus < 2:
            return False
        g = cls()._group(fab)
        return g > 0 and fab.n_gpus % g == 0

    def steps(self, nbytes, fab):
        n, g = fab.n_gpus, self._group(fab)
        if g <= 0 or n % g:
            raise ValueError(
                f"{self.name} needs n_gpus divisible by the topology group "
                f"(got {n} / {g})")
        m = n // g  # nodes
        chunk = nbytes // n
        steps = []
        if g > 1:
            intra = []
            for src in range(n):
                node = src // g
                for p in range(g):
                    dst = node * g + p
                    if dst != src:
                        intra.append(FlowSpec(
                            src=src, dst=dst, nbytes=m * chunk,
                            offset=nbytes + (src % g) * m * chunk))
            steps.append(intra)
        if m > 1:
            inter = []
            for src in range(n):
                p, node = src % g, src // g
                for k in range(m):
                    if k != node:
                        inter.append(FlowSpec(
                            src=src, dst=k * g + p, nbytes=g * chunk,
                            offset=node * g * chunk))
            steps.append(inter)
        return steps


@register_pattern(logical="all_to_all")
class MultiPodAllToAll(HierarchicalAllToAll):
    """Pod-granular two-phase AllToAll for ``multi_pod`` topologies.

    Same two-phase structure as :class:`HierarchicalAllToAll` but grouped
    at the *pod* (:meth:`~repro.core.topology.Topology.pod_group`): phase 1
    stages chunks with intra-pod rail peers on the cheap Clos tier, phase 2
    exchanges pod-aggregated chunks with rail counterparts across the
    scale-out hop — exactly (pods - 1) oversubscribed crossings per GPU
    instead of the (n - n/pods) a direct AllToAll would pay.  On the flat
    default topology the pod group degenerates to ``gpus_per_node`` and the
    pattern coincides with ``hier_all_to_all``.
    """

    name = "multipod_all_to_all"

    def _group(self, fab: FabricConfig) -> int:
        return get_topology(fab).pod_group()


def kv_block(fab: FabricConfig) -> int:
    """Prefill-side GPU count of the KV-transfer pair (DESIGN.md §16).

    On ``multi_pod`` this is the pod: the transfer crosses the scale-out
    hop from pod 0 to pod 1.  Topologies without a real pod boundary split
    the fabric in half, so the pattern stays runnable (and comparable)
    everywhere — it just doesn't cross an oversubscribed tier there.
    """
    pods = get_topology(fab).n_pods()
    return fab.n_gpus // pods if pods >= 2 else fab.n_gpus // 2


@register_pattern(logical="kv_transfer")
class KVTransfer(CollectivePattern):
    """Rail-aligned KV-cache push across the ``multi_pod`` scale-out hop.

    The disaggregated-serving handoff (DESIGN.md §16): the KV cache a
    prefill pod produced, sharded one ``nbytes`` slice per prefill GPU,
    moves to the decode pod that will generate tokens against it.  Rank i
    of pod 0 streams its full shard to rank i of pod 1 — one step,
    ``pod_size`` concurrent flows, every one crossing the oversubscribed
    inter-pod tier and paying reverse translation at the *decode* pod's
    Link-MMU.  Each decode GPU receives into offset 0 of its KV arena, so
    the first transfer after a flush walks every page of the shard and
    later transfers into the same arena run warm — the two-regime
    mechanism fig18 measures.

    Asymmetric by construction (prefill ranks receive nothing), so the
    engine simulates every receiving decode target.
    """

    name = "kv_transfer"
    symmetric = False

    @classmethod
    def feasible(cls, fab):
        return fab.n_gpus >= 2 and kv_block(fab) >= 1 \
            and 2 * kv_block(fab) <= fab.n_gpus

    def steps(self, nbytes, fab):
        block = kv_block(fab)
        return [[FlowSpec(src=i, dst=block + i, nbytes=nbytes, offset=0)
                 for i in range(block)]]


@register_pattern(logical="kv_transfer")
class KVTransferStriped(KVTransfer):
    """Re-sharding KV push: every prefill rank stripes to every decode rank.

    Same payload as :class:`KVTransfer` (``block * nbytes`` total) but
    each prefill rank splits its shard into ``block`` stripes, one per
    decode rank — the layout changes pods, which is what a decode pod with
    a different TP split needs.  Each decode GPU receives ``block``
    small flows instead of one large one: same pages walked, finer-grained
    arrival, more concurrent flows per source splitting the inter-pod
    capacity — the trade the selection policy (DESIGN.md §14) prices.
    """

    name = "kv_transfer_striped"

    def steps(self, nbytes, fab):
        block = kv_block(fab)
        stripe = nbytes // block
        return [[FlowSpec(src=i, dst=block + j, nbytes=stripe,
                          offset=i * stripe)
                 for i in range(block) for j in range(block)]]


def get_pattern(name: str) -> CollectivePattern:
    """Instantiate a registered pattern by name."""
    try:
        return PATTERNS[name]()
    except KeyError:
        raise ValueError(
            f"unknown collective {name!r}; known: {sorted(PATTERNS)}"
            f"; logical classes: {sorted(LOGICAL)} (logical names resolve "
            f"through a policy — repro.core.select)") from None


def simulated_dsts(pattern: CollectivePattern, step_specs, symmetric: bool,
                   fab: FabricConfig) -> List[int]:
    """Target GPUs a simulator must model for this pattern.

    Shared by the epoch engine and the reference DES — oracle-equivalence
    tests only bind if both sides simulate the same target set.
    """
    if symmetric and pattern.symmetric:
        return [pattern.representative_dst(fab)]
    return sorted({s.dst for step in step_specs for s in step}) or [0]


def simulated_dsts_arrays(pattern: CollectivePattern,
                          step_arrays: List[StepArrays], symmetric: bool,
                          fab: FabricConfig) -> List[int]:
    """:func:`simulated_dsts` for the :class:`StepArrays` schedule form."""
    if symmetric and pattern.symmetric:
        return [pattern.representative_dst(fab)]
    ds: set = set()
    for st in step_arrays:
        ds.update(np.unique(st.dst).tolist())
    return sorted(ds) or [0]


def analytic_volume(name: str, nbytes: int, fab: FabricConfig) -> int:
    """Closed-form total fabric bytes of a collective (conservation oracle).

    Independent of :meth:`CollectivePattern.steps` so tests can check the
    emitted flow sets against it.
    """
    n = fab.n_gpus
    chunk = nbytes // n
    if name == "all_to_all":
        return n * (n - 1) * chunk
    if name == "ring_allreduce":
        return 2 * (n - 1) * n * chunk
    if name == "rd_allreduce":
        return (n.bit_length() - 1) * n * nbytes
    if name in ("all_gather", "reduce_scatter"):
        return (n - 1) * n * chunk
    if name == "broadcast":
        return (n - 1) * nbytes
    if name in ("hier_all_to_all", "multipod_all_to_all"):
        topo = get_topology(fab)
        g = (topo.local_group() if name == "hier_all_to_all"
             else topo.pod_group())
        m = n // g
        return n * ((g - 1) * m * chunk + (m - 1) * g * chunk)
    if name in ("kv_transfer", "kv_transfer_striped"):
        block = kv_block(fab)
        if name == "kv_transfer":
            return block * nbytes
        return block * block * (nbytes // block)
    raise ValueError(f"no analytic volume for {name!r}")
