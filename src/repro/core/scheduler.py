"""Translation-aware collective scheduling (the paper's insight, applied).

The framework emits collectives (MoE dispatch/combine all-to-all above all);
this module decides, per collective, the *schedule*:

  * ``warmup_chunk_bytes`` — a small head chunk issued early, overlapped with
    the producing compute, so destination-side cold-start cost (RAT walks on
    GPU fabrics; route/DMA setup on TPU ICI) is off the critical path.  This
    is the TPU-idiomatic analogue of the paper's fused pre-translation
    kernels (DESIGN.md §6).
  * ``n_chunks`` — double-buffered pipelining depth of the main transfer
    against expert compute (the analogue of software TLB prefetch).
  * ``per_peer_buffer_bytes`` — in-flight buffering per peer.  The paper's
    L2-TLB sizing result (working set = one active page per peer; Fig. 11)
    maps to: one in-flight chunk per peer suffices, over-buffering only
    wastes HBM.

Decisions are priced with :class:`repro.core.cost_model.CostModel`; the
simulator itself never runs inside a training step.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from .config import SimConfig, paper_config
from .cost_model import CostModel


@dataclass(frozen=True)
class CollectivePlan:
    total_bytes: int
    n_peers: int
    warmup_chunk_bytes: int
    n_chunks: int
    per_peer_buffer_bytes: int
    est_time_ns: float
    est_time_unscheduled_ns: float

    @property
    def est_speedup(self) -> float:
        return self.est_time_unscheduled_ns / max(self.est_time_ns, 1e-9)


class TranslationAwareScheduler:
    """Plans collective schedules from the paper's cost model."""

    def __init__(self, n_gpus: int, cfg: Optional[SimConfig] = None,
                 overlap_compute_ns: float = 0.0):
        self.cfg = cfg or paper_config(n_gpus)
        self.model = CostModel(self.cfg)
        self.overlap_compute_ns = overlap_compute_ns

    def plan_all_to_all(self, total_bytes: int,
                        compute_ns: Optional[float] = None) -> CollectivePlan:
        """Schedule an all-to-all of ``total_bytes`` per participant."""
        fab = self.cfg.fabric
        tr = self.cfg.translation
        n = fab.n_gpus
        compute_ns = (self.overlap_compute_ns
                      if compute_ns is None else compute_ns)

        base = self.model.collective_time_ns(total_bytes, with_rat=True)

        # Warm-up chunk: one translation working-set unit per peer — exactly
        # one page (the paper's Fig. 10 insight: each peer has one active
        # page at a time).  Issued early iff there is compute to hide it in.
        warmup = 0
        if compute_ns > 0 and tr.enabled:
            warmup = min(tr.page_bytes * n, max(total_bytes // 8, 0))
            warmup = min(warmup, total_bytes)

        # Pipelining depth: chunks sized so per-chunk time stays above the
        # fixed alpha cost (don't shred the transfer into latency-bound
        # slivers), but enough chunks to overlap with compute.
        alpha = fab.oneway_ns + fab.hbm_ns + fab.return_ns
        per_byte = 1.0 / fab.gpu_bw * (n - 1) / n
        min_chunk = max(int(alpha / per_byte), fab.request_bytes * n)
        n_chunks = max(1, min(8, (total_bytes - warmup) // max(min_chunk, 1)))

        # Scheduled time: cold-start cost hidden under compute (up to the
        # available window), remainder pipelined.
        cold = self.model._terms(total_bytes, True)["cold"]
        hidden = min(cold, compute_ns) if warmup else 0.0
        est = base - hidden

        return CollectivePlan(
            total_bytes=total_bytes,
            n_peers=n - 1,
            warmup_chunk_bytes=warmup,
            n_chunks=int(n_chunks),
            per_peer_buffer_bytes=tr.page_bytes,  # Fig. 11: one page per peer
            est_time_ns=est,
            est_time_unscheduled_ns=base,
        )
