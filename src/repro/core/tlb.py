"""Reverse Address Translation hierarchy model (target-GPU side).

Implements the paper's baseline hierarchy (Fig. 3): per-station L1 Link TLBs
with MSHRs -> shared L2 Link TLB (with its own pending-walk coalescing) ->
page-walk caches -> shared pool of parallel page-table walkers.  Fill policy
is mostly-inclusive: a completed walk populates both the L2 and the
requesting station's L1; L2 evictions do not back-invalidate L1s.

The model is event-free: callers (the page-epoch engine and the request-level
reference DES) invoke :meth:`TranslationState.access` in non-decreasing time
order and the state machine returns the translation-resolve time plus the
classification used for the paper's Fig. 7/8 breakdowns.  Determinism of the
streaming workloads makes this exact: arrival times never depend on
translation outcomes (the fabric model is latency-additive; see DESIGN.md §2).
"""
from __future__ import annotations

import heapq
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, Tuple

from .config import TranslationConfig

INF = float("inf")

# Request classification (paper Figs. 7 and 8).
L1_HIT = "l1_hit"
L1_HUM = "l1_mshr_hum"      # hit-under-miss in the station's MSHR
L2_HIT = "l2_hit"
L2_HUM = "l2_hum"           # pending walk already launched by another station
WALK = "walk"               # L2 miss -> page walk (PWC hits may shorten it)
CLASSES = (L1_HIT, L1_HUM, L2_HIT, L2_HUM, WALK)


class LRUCache:
    """Set-associative (or fully-associative) LRU cache of hashable keys.

    Fills are committed lazily: :meth:`fill` records (key, fill_time) and a
    later :meth:`lookup` at ``t >= fill_time`` observes the entry.  This lets
    callers process accesses in arrival order while fills complete in the
    future.
    """

    def __init__(self, entries: int, assoc: int):
        self.entries = entries
        self.assoc = assoc if assoc > 0 else entries
        self.n_sets = max(1, entries // self.assoc)
        self._sets = [OrderedDict() for _ in range(self.n_sets)]
        self._staged: Dict[object, float] = {}

    def _set_for(self, key) -> OrderedDict:
        return self._sets[hash(key) % self.n_sets]

    def _commit(self, t: float) -> None:
        if not self._staged:
            return
        # Commit in fill-time order (stable on ties, so simultaneous fills
        # keep their staging order): LRU recency — and therefore eviction
        # order — must reflect when entries actually landed, not the order
        # callers happened to stage them in.
        ready = [(ft, k) for k, ft in self._staged.items() if ft <= t]
        ready.sort(key=lambda e: e[0])
        for ft, k in ready:
            del self._staged[k]
            s = self._set_for(k)
            if k in s:
                s.move_to_end(k)
            else:
                if len(s) >= self.assoc:
                    s.popitem(last=False)  # LRU eviction
                s[k] = ft

    def lookup(self, key, t: float) -> bool:
        self._commit(t)
        s = self._set_for(key)
        if key in s:
            s.move_to_end(key)
            return True
        return False

    def fill(self, key, fill_time: float) -> None:
        prev = self._staged.get(key)
        if prev is None or fill_time < prev:
            self._staged[key] = fill_time


@dataclass
class Counters:
    """Aggregate statistics for one simulation run."""

    requests: int = 0
    by_class: Dict[str, int] = field(
        default_factory=lambda: {c: 0 for c in CLASSES})
    rat_ns_sum: float = 0.0
    rat_ns_max: float = 0.0
    walks: int = 0
    walk_mem_reads: int = 0
    pwc_hits: int = 0
    pwc_misses: int = 0
    probes: int = 0               # pre-translation / prefetch probes issued
    mshr_stall_ns: float = 0.0

    def add_request(self, klass: str, rat_ns: float, n: int = 1) -> None:
        self.requests += n
        self.by_class[klass] += n
        self.rat_ns_sum += rat_ns
        # rat_ns is the sum over n requests; max tracked by callers per-epoch.

    def note_max(self, rat_ns: float) -> None:
        if rat_ns > self.rat_ns_max:
            self.rat_ns_max = rat_ns

    def merge(self, other: "Counters") -> None:
        """Accumulate another target GPU's counters into this one."""
        self.requests += other.requests
        for k in self.by_class:
            self.by_class[k] += other.by_class[k]
        self.rat_ns_sum += other.rat_ns_sum
        self.rat_ns_max = max(self.rat_ns_max, other.rat_ns_max)
        self.walks += other.walks
        self.walk_mem_reads += other.walk_mem_reads
        self.pwc_hits += other.pwc_hits
        self.pwc_misses += other.pwc_misses
        self.probes += other.probes
        self.mshr_stall_ns += other.mshr_stall_ns

    def copy(self) -> "Counters":
        c = Counters(
            requests=self.requests, by_class=dict(self.by_class),
            rat_ns_sum=self.rat_ns_sum, rat_ns_max=self.rat_ns_max,
            walks=self.walks, walk_mem_reads=self.walk_mem_reads,
            pwc_hits=self.pwc_hits, pwc_misses=self.pwc_misses,
            probes=self.probes, mshr_stall_ns=self.mshr_stall_ns)
        return c

    def delta(self, since: "Counters") -> "Counters":
        """Counters accumulated after the ``since`` snapshot was taken.

        ``rat_ns_max`` is cumulative, not differentiable: the returned value
        is the running max (exact when the max occurred after the snapshot).
        """
        return Counters(
            requests=self.requests - since.requests,
            by_class={k: self.by_class[k] - since.by_class[k]
                      for k in self.by_class},
            rat_ns_sum=self.rat_ns_sum - since.rat_ns_sum,
            rat_ns_max=self.rat_ns_max,
            walks=self.walks - since.walks,
            walk_mem_reads=self.walk_mem_reads - since.walk_mem_reads,
            pwc_hits=self.pwc_hits - since.pwc_hits,
            pwc_misses=self.pwc_misses - since.pwc_misses,
            probes=self.probes - since.probes,
            mshr_stall_ns=self.mshr_stall_ns - since.mshr_stall_ns)

    @property
    def mean_rat_ns(self) -> float:
        return self.rat_ns_sum / self.requests if self.requests else 0.0


class PTWPool:
    """Shared pool of ``n`` parallel page-table walkers (min-heap of free times).

    Two-phase protocol: :meth:`start` claims the earliest-free walker and
    returns the actual walk start time (``max(t, free)``); the caller
    computes the walk latency *from that start time* — PWC lookups are
    timestamped when the walker actually issues them, not when the request
    arrived — and then :meth:`finish` returns the walker to the pool.
    Every ``start`` must be paired with exactly one ``finish``.
    """

    def __init__(self, n: int):
        self._free = [0.0] * n
        heapq.heapify(self._free)

    def start(self, t: float) -> float:
        """Claim a walker for a walk requested at ``t``; returns start time."""
        free = heapq.heappop(self._free)
        return max(t, free)

    def finish(self, busy_until: float) -> None:
        """Release the claimed walker, busy until ``busy_until``."""
        heapq.heappush(self._free, busy_until)


@dataclass
class AccessResult:
    resolve: float        # time the NPA->SPA translation is available
    klass: str            # one of CLASSES
    l1_fill: float        # time this station's L1 holds the entry (INF never)


class TranslationState:
    """Full Reverse Address Translation state for ONE target GPU."""

    def __init__(self, cfg: TranslationConfig, n_stations: int):
        self.cfg = cfg
        self.n_stations = n_stations
        self.l1 = [LRUCache(cfg.l1.entries, cfg.l1.assoc)
                   for _ in range(n_stations)]
        self.l2 = LRUCache(cfg.l2.entries, cfg.l2.assoc)
        self.pwc = [LRUCache(e, cfg.pwc.assoc) for e in cfg.pwc.entries]
        self.ptw = PTWPool(cfg.n_ptw)
        # page -> walk completion time while a walk is in flight (L2-level
        # coalescing); entries are pruned lazily.
        self.l2_pending: Dict[int, float] = {}
        # (station, page) -> L1 fill time for in-flight entries (MSHR).
        self.l1_pending: Dict[Tuple[int, int], float] = {}
        self.counters = Counters()

    def flush(self) -> None:
        """Invalidate all cached translations (TLBs, PWCs, pending walks).

        Models long inter-collective idle gaps in a replay session: competing
        traffic (local CUDA graphs, other tenants' collectives) evicts the
        Link-TLB working set while the pod is quiet.  Counters and walker-pool
        occupancy are preserved — only cached state is lost.
        """
        cfg = self.cfg
        self.l1 = [LRUCache(cfg.l1.entries, cfg.l1.assoc)
                   for _ in range(self.n_stations)]
        self.l2 = LRUCache(cfg.l2.entries, cfg.l2.assoc)
        self.pwc = [LRUCache(e, cfg.pwc.assoc) for e in cfg.pwc.entries]
        self.l2_pending.clear()
        self.l1_pending.clear()

    # -- page walk ---------------------------------------------------------
    def _walk_latency(self, page: int, t: float) -> float:
        """Latency of a page walk starting at ``t`` (PWC lookups + PT reads).

        Upper levels probe their PWC (hit: lookup latency only; miss: lookup
        + memory read, then fill).  The leaf PTE read always goes to memory.
        """
        c = self.cfg
        lat = 0.0
        addr = page * c.page_bytes
        for lvl, cache in enumerate(self.pwc):
            region = addr // c.pwc.coverage_bytes[lvl]
            lat += c.pwc.lookup_latency_ns
            if cache.lookup((lvl, region), t + lat):
                self.counters.pwc_hits += 1
            else:
                self.counters.pwc_misses += 1
                lat += c.mem_access_ns
                self.counters.walk_mem_reads += 1
                cache.fill((lvl, region), t + lat)
        # Leaf PTE fetch.
        lat += c.mem_access_ns
        self.counters.walk_mem_reads += 1
        return lat

    # -- main entry point ---------------------------------------------------
    def access(self, station: int, page: int, t: float,
               is_probe: bool = False) -> AccessResult:
        """One translation request arriving at ``station`` at time ``t``.

        Returns the resolve time and classification.  Mutates TLB/PWC/PTW
        state.  Callers must invoke in non-decreasing ``t`` order per GPU.
        """
        c = self.cfg
        if not c.enabled:
            return AccessResult(resolve=t, klass=L1_HIT, l1_fill=-INF)

        t1 = t + c.l1.hit_latency_ns
        if self.l1[station].lookup(page, t1):
            return AccessResult(resolve=t1, klass=L1_HIT, l1_fill=-INF)

        key = (station, page)
        pend = self.l1_pending.get(key)
        if pend is not None:
            if pend <= t1:
                del self.l1_pending[key]  # lazily retire; entry is in L1 now
                # (the lazy LRU commit in lookup() above would have hit if the
                # fill landed; landing exactly between lookup and now counts
                # as an MSHR hit resolving immediately)
                return AccessResult(resolve=max(t1, pend), klass=L1_HUM,
                                    l1_fill=pend)
            return AccessResult(resolve=max(t1, pend), klass=L1_HUM,
                                l1_fill=pend)

        # L1 miss -> allocate MSHR, go to L2.
        t2 = t1 + c.l2.hit_latency_ns
        if self.l2.lookup(page, t2):
            self.l1[station].fill(page, t2)
            self.l1_pending[key] = t2
            return AccessResult(resolve=t2, klass=L2_HIT, l1_fill=t2)

        walk_done = self.l2_pending.get(page)
        if walk_done is not None and walk_done > t2:
            # Another station already launched the walk: coalesce at L2.
            self.l1[station].fill(page, walk_done)
            self.l1_pending[key] = walk_done
            return AccessResult(resolve=walk_done, klass=L2_HUM,
                                l1_fill=walk_done)
        if walk_done is not None:
            del self.l2_pending[page]

        # Full miss: launch a page walk on the shared walker pool.  The
        # walker may start later than the request time (pool saturation);
        # PWC lookups and PT reads are timed from the actual walk start.
        start = self.ptw.start(t2)
        walk_lat = self._walk_latency(page, start)
        self.ptw.finish(start + walk_lat)
        done = start + walk_lat
        self.counters.walks += 1
        self.l2_pending[page] = done
        self.l2.fill(page, done)
        self.l1[station].fill(page, done)
        self.l1_pending[key] = done
        return AccessResult(resolve=done, klass=WALK, l1_fill=done)
