"""Fabric topologies for the RAT simulator (DESIGN.md §10).

The paper's pod is a single-level Clos: every src→dst pair sees one constant
fabric latency (``FabricConfig.oneway_ns``) and every source's flows share
one flat station pool.  Emerging scale-up fabrics are hierarchical — leaf
switches under an (often oversubscribed) spine tier, or several Clos pods
joined over a scale-out hop — and both the extra tier latency and the
tier-shared bandwidth reshape where translation stalls land.

A :class:`Topology` answers, for a given :class:`~repro.core.config.
FabricConfig`, three questions the flow-materialization layer asks:

* ``path_latency_ns(src, dst)`` / ``return_latency_ns(dst, src)`` — the
  one-way request and ack latencies of the (src, dst) pair.  Single source
  of truth for the epoch engine *and* the reference DES, so per-topology
  oracle equivalence holds by construction.
* ``tier(src, dst)`` + ``tier_capacity(tier)`` — which latency/bandwidth
  tier the pair crosses, and the per-source byte/ns capacity of that tier
  (``None`` = unconstrained beyond the flat station pool).  A source's
  concurrent flows crossing a capacity-limited tier split *that tier's*
  bandwidth; the engine takes the max of the station-pool share and the
  tier share (DESIGN.md §10.2).
* ``tier0_group()`` / ``local_group()`` / ``pod_group()`` — GPU-group sizes
  hierarchical collective patterns and the EP/TP/DP placement logic derive
  their phase structure from.

``single_clos`` is the bit-for-bit default: tier 0 everywhere, latencies
exactly ``FabricConfig.oneway_ns``/``return_ns``, no tier capacity — the
engine's arithmetic reduces to the pre-topology expressions.
"""
from __future__ import annotations

import functools
from typing import TYPE_CHECKING, Dict, Optional, Type

import numpy as np

if TYPE_CHECKING:  # pragma: no cover - typing only
    from .config import FabricConfig


class Topology:
    """One pod topology bound to a concrete :class:`FabricConfig`.

    ``flat`` marks the degenerate single-tier case: the engine skips tier
    bookkeeping entirely on flat topologies, which is what keeps the
    ``single_clos`` default bit-for-bit identical to the pre-topology code.
    """

    name: str = "abstract"
    flat: bool = False

    def __init__(self, fab: "FabricConfig"):
        self.fab = fab

    # -- latency -----------------------------------------------------------
    def path_latency_ns(self, src: int, dst: int) -> float:
        """Source CU -> target station latency of one request."""
        raise NotImplementedError

    def return_latency_ns(self, dst: int, src: int) -> float:
        """Target -> source ack latency (symmetric path, minus the CU hop)."""
        raise NotImplementedError

    # -- bandwidth tiers ---------------------------------------------------
    def tier(self, src: int, dst: int) -> int:
        """Bandwidth/latency tier the (src, dst) pair crosses (0 = lowest)."""
        return 0

    def tier_capacity(self, tier: int) -> Optional[float]:
        """Per-source bytes/ns capacity of ``tier``; None = unconstrained.

        Tier 0 is never constrained beyond the flat station pool; an
        oversubscribed upper tier divides the source GPU's aggregate
        bandwidth by its oversubscription factor.
        """
        return None

    # -- vectorized forms (repro.core.engine_vec) --------------------------
    # The scalar methods above stay the single source of truth: the base
    # fallbacks evaluate them per element, so a custom topology is correct
    # (if slow) by construction, and the overrides below are pure selects on
    # the same precomputed values — never re-derived arithmetic.
    def tier_arr(self, src: np.ndarray, dst) -> np.ndarray:
        """``tier(src[i], dst[i])`` for paired index arrays (or scalar dst)."""
        dst_b = np.broadcast_to(np.asarray(dst, dtype=np.int64), src.shape)
        return np.fromiter((self.tier(int(s), int(d))
                            for s, d in zip(src, dst_b)),
                           dtype=np.int64, count=len(src))

    def path_latency_arr(self, src: np.ndarray, dst) -> np.ndarray:
        """``path_latency_ns(src[i], dst[i])`` (scalar dst broadcasts)."""
        dst_b = np.broadcast_to(np.asarray(dst, dtype=np.int64), src.shape)
        return np.fromiter((self.path_latency_ns(int(s), int(d))
                            for s, d in zip(src, dst_b)),
                           dtype=np.float64, count=len(src))

    def return_latency_arr(self, dst, src: np.ndarray) -> np.ndarray:
        """``return_latency_ns(dst[i], src[i])`` (scalar dst broadcasts)."""
        dst_b = np.broadcast_to(np.asarray(dst, dtype=np.int64), src.shape)
        return np.fromiter((self.return_latency_ns(int(d), int(s))
                            for d, s in zip(dst_b, src)),
                           dtype=np.float64, count=len(src))

    # -- group structure ---------------------------------------------------
    def tier0_group(self) -> int:
        """Largest GPU group whose all-pairs traffic stays tier-0.

        This is the group tensor-parallel collectives should be mapped onto
        (:func:`repro.workloads.derive.resolve_pod`).
        """
        return self.fab.n_gpus

    def local_group(self) -> int:
        """Intra phase group of :class:`~repro.core.patterns.
        HierarchicalAllToAll` (the historical ``gpus_per_node`` node split
        on the flat default; the leaf on ``two_tier``)."""
        return self.fab.gpus_per_node

    def pod_group(self) -> int:
        """Pod group of :class:`~repro.core.patterns.MultiPodAllToAll`."""
        return self.fab.gpus_per_node

    # -- pod partition (disaggregated placement, DESIGN.md §16) ------------
    # Only ``multi_pod`` has a real pod boundary; every other topology is
    # one pod, so cross-pod placement questions degenerate to "rank 0's
    # pod" and the KV-transfer pattern reports itself infeasible.
    def n_pods(self) -> int:
        """Number of scale-out pods the fabric is partitioned into."""
        return 1

    def pod_of(self, rank: int) -> int:
        """Pod index a GPU rank lives in (0 on single-pod topologies)."""
        return 0

    def describe(self) -> str:
        return self.name


class SingleClos(Topology):
    """The paper's single-level Clos: one tier, one constant latency."""

    name = "single_clos"
    flat = True

    def path_latency_ns(self, src: int, dst: int) -> float:
        return self.fab.oneway_ns

    def return_latency_ns(self, dst: int, src: int) -> float:
        return self.fab.return_ns

    def tier_arr(self, src, dst):
        return np.zeros(len(src), dtype=np.int64)

    def path_latency_arr(self, src, dst):
        return np.full(len(src), self.fab.oneway_ns)

    def return_latency_arr(self, dst, src):
        return np.full(len(src), self.fab.return_ns)


class _BlockTopology(Topology):
    """Two-tier block partition: GPUs `r // block` share the cheap tier.

    Both registered hierarchical topologies are block partitions — a leaf
    under a spine, or a Clos pod behind a scale-out hop — differing only in
    which config fields supply the block size, the extra inter-block
    latency, and the per-source oversubscription of the crossing.
    Subclasses set those three in ``_params``.  Ack paths re-cross the same
    switches and the CU/d2d hops are symmetric, so per tier the return sum
    equals the request sum.
    """

    def _params(self, fab: "FabricConfig"):
        """(block_size, extra_inter_latency_ns, oversubscription)."""
        raise NotImplementedError

    def __init__(self, fab: "FabricConfig"):
        super().__init__(fab)
        block, extra_ns, oversub = self._params(fab)
        # A group smaller than one block fits inside it (session subgroups).
        self.block = min(block, fab.n_gpus) if block > 0 else fab.n_gpus
        if self.block <= 0 or fab.n_gpus % self.block:
            raise ValueError(
                f"{self.name} needs n_gpus divisible by the block size "
                f"(got {fab.n_gpus} / {self.block})")
        self._inter_ns = fab.oneway_ns + extra_ns
        self._cross_cap = fab.gpu_bw / oversub

    def tier(self, src: int, dst: int) -> int:
        return 0 if src // self.block == dst // self.block else 1

    def path_latency_ns(self, src: int, dst: int) -> float:
        return (self.fab.oneway_ns
                if src // self.block == dst // self.block
                else self._inter_ns)

    def return_latency_ns(self, dst: int, src: int) -> float:
        return (self.fab.return_ns
                if src // self.block == dst // self.block
                else self._inter_ns)

    def tier_capacity(self, tier: int) -> Optional[float]:
        return self._cross_cap if tier == 1 else None

    def tier_arr(self, src, dst):
        dst_b = np.asarray(dst, dtype=np.int64)
        return (src // self.block != dst_b // self.block).astype(np.int64)

    def path_latency_arr(self, src, dst):
        dst_b = np.asarray(dst, dtype=np.int64)
        intra = src // self.block == dst_b // self.block
        return np.where(intra, self.fab.oneway_ns, self._inter_ns)

    def return_latency_arr(self, dst, src):
        dst_b = np.asarray(dst, dtype=np.int64)
        intra = src // self.block == dst_b // self.block
        return np.where(intra, self.fab.return_ns, self._inter_ns)

    def tier0_group(self) -> int:
        return self.block


class TwoTier(_BlockTopology):
    """Leaf/spine pod: ``leaf_size`` GPUs per leaf switch under a spine.

    Intra-leaf pairs cross one leaf switch (tier 0: the flat latency).
    Inter-leaf pairs climb to the spine and back down through the target's
    leaf — two extra switch crossings (``spine_latency_ns`` for the spine,
    ``switch_latency_ns`` for the second leaf) — and a source's inter-leaf
    flows share its leaf-uplink capacity ``gpu_bw / oversubscription``
    instead of the full station pool.
    """

    name = "two_tier"

    def _params(self, fab):
        leaf = fab.leaf_size if fab.leaf_size > 0 else fab.gpus_per_node
        return (leaf, fab.spine_latency_ns + fab.switch_latency_ns,
                fab.oversubscription)

    def local_group(self) -> int:
        return self.block

    def describe(self) -> str:
        return (f"two_tier(leaf={self.block}, "
                f"oversub={self.fab.oversubscription:g})")


class MultiPod(_BlockTopology):
    """Several single-Clos pods joined over a scale-out hop.

    Intra-pod pairs see the flat single-Clos behavior; inter-pod pairs add
    ``interpod_latency_ns`` (the scale-out switch + longer reach) and a
    source's cross-pod flows share ``gpu_bw / interpod_oversubscription``
    (the pod's egress ports are far scarcer than its internal links).
    """

    name = "multi_pod"

    def _params(self, fab):
        return (fab.pod_size, fab.interpod_latency_ns,
                fab.interpod_oversubscription)

    def pod_group(self) -> int:
        return self.block

    def n_pods(self) -> int:
        return self.fab.n_gpus // self.block

    def pod_of(self, rank: int) -> int:
        return rank // self.block

    def describe(self) -> str:
        return (f"multi_pod(pod={self.block}, "
                f"oversub={self.fab.interpod_oversubscription:g})")


TOPOLOGIES: Dict[str, Type[Topology]] = {
    cls.name: cls for cls in (SingleClos, TwoTier, MultiPod)
}


@functools.lru_cache(maxsize=512)
def _build(fab: "FabricConfig") -> Topology:
    try:
        cls = TOPOLOGIES[fab.topology]
    except KeyError:
        raise ValueError(
            f"unknown topology {fab.topology!r}; "
            f"known: {sorted(TOPOLOGIES)}") from None
    return cls(fab)


def get_topology(fab: "FabricConfig") -> Topology:
    """The (cached) :class:`Topology` instance of a fabric config.

    ``FabricConfig`` is frozen/hashable, and topologies are immutable after
    construction, so instances are shared freely across engines, sessions
    and sweep points of the same config.
    """
    return _build(fab)
