"""JAX-native latency-hiding collectives (TPU adaptation of paper §6).

The paper proposes (1) fused pre-translation kernels and (2) software TLB
prefetching to hide destination-side cold-start latency.  On TPU there is no
Link MMU, but collectives still pay a cold-start/latency term that dominates
small transfers.  The same two ideas map to (DESIGN.md §6):

  * :func:`warmup_all_to_all` — issue a tiny head chunk of the all-to-all
    *before* (and data-dependency-free of) the producing compute, so XLA's
    latency-hiding scheduler overlaps the cold-start with compute.  This is
    the "fused pre-translation kernel": the warm-up chunk touches one
    translation-working-set unit per peer.
  * :func:`pipelined_all_to_all` — chunk the transfer and software-pipeline
    it against per-chunk consumer compute inside ``lax.scan``
    (double-buffering = "prefetch depth" in the paper's terms).

Both are pure ``jax.lax`` programs: under ``shard_map`` they lower to real
``all-to-all`` HLO collectives that the dry-run roofline accounts for.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax

from .scheduler import CollectivePlan


def _a2a(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """All-to-all along the leading (peer) dimension of ``x``."""
    return lax.all_to_all(x, axis_name, split_axis=0, concat_axis=0,
                          tiled=True)


def warmup_all_to_all(x: jnp.ndarray, axis_name: str, *,
                      warmup_rows: int,
                      compute_fn: Callable[[jnp.ndarray], jnp.ndarray],
                      compute_arg: jnp.ndarray):
    """All-to-all of ``x`` with a warm-up head chunk overlapped with compute.

    ``x``: [rows, ...] with rows divisible among peers (leading dim is the
    peer-partitioned dim).  ``compute_fn(compute_arg)`` is the producing
    compute the transfer tail depends on; the warm-up chunk has no data
    dependency on it, so XLA schedules the small collective concurrently
    (hiding the fabric cold-start exactly as a fused pre-translation kernel
    hides Link-TLB walks).

    Returns ``(a2a(x), compute_fn(compute_arg))``.
    """
    n = lax.psum(1, axis_name)
    rows = x.shape[0]
    # Round the warm-up to a whole number of rows per peer.
    per_peer = max(1, warmup_rows // n)
    head_rows = min(per_peer * n, rows)
    head = _a2a(x[:head_rows], axis_name)          # no dep on compute_fn
    y = compute_fn(compute_arg)                    # overlaps with `head`
    tail = _a2a(x[head_rows:], axis_name) if head_rows < rows else None
    out = head if tail is None else jnp.concatenate([head, tail], axis=0)
    return out, y


def pipelined_all_to_all(x: jnp.ndarray, axis_name: str, *, n_chunks: int,
                         per_chunk_fn: Optional[Callable] = None):
    """Chunked all-to-all software-pipelined against per-chunk compute.

    Splits the leading dim into ``n_chunks`` equal chunks; chunk ``k+1``'s
    transfer is issued while ``per_chunk_fn`` consumes chunk ``k`` (XLA
    overlaps the independent collective with the compute inside the scan).
    With ``per_chunk_fn=None`` this degenerates to a chunked transfer whose
    chunks can still overlap each other's latency.
    """
    rows = x.shape[0]
    n_chunks = max(1, min(n_chunks, rows))
    while rows % n_chunks:
        n_chunks -= 1
    xs = x.reshape(n_chunks, rows // n_chunks, *x.shape[1:])

    def step(carry, xc):
        yc = _a2a(xc, axis_name)
        if per_chunk_fn is not None:
            yc = per_chunk_fn(yc)
        return carry, yc

    _, ys = lax.scan(step, 0, xs)
    return ys.reshape(n_chunks * (rows // n_chunks), *ys.shape[2:])


def scheduled_all_to_all(x: jnp.ndarray, axis_name: str,
                         plan: CollectivePlan, *,
                         compute_fn: Optional[Callable] = None,
                         compute_arg=None):
    """Execute an all-to-all under a :class:`CollectivePlan`.

    Applies the warm-up chunk when the plan requested one (and compute is
    available to hide it in), then pipelines the remainder.
    """
    itemsize = x.dtype.itemsize
    row_bytes = max(1, int(x.size // max(1, x.shape[0])) * itemsize)
    if plan.warmup_chunk_bytes and compute_fn is not None:
        warmup_rows = max(1, plan.warmup_chunk_bytes // row_bytes)
        out, y = warmup_all_to_all(x, axis_name, warmup_rows=warmup_rows,
                                   compute_fn=compute_fn,
                                   compute_arg=compute_arg)
        return out, y
    out = pipelined_all_to_all(x, axis_name, n_chunks=plan.n_chunks)
    y = compute_fn(compute_arg) if compute_fn is not None else None
    return out, y
