"""Request-level reference DES for the RAT simulator (the oracle).

Simulates every individual request through the same
:class:`~repro.core.tlb.TranslationState` machinery as the page-epoch engine,
but with explicit per-station in-order FIFOs and slot-accurate ingress
buffering instead of closed-form epoch expansion.  Replays exactly the flow
sets the pattern layer (:mod:`repro.core.patterns`) emits — one station-queue
episode per collective step, barriered on the previous step's completion —
and, when the latency-hiding optimizations are enabled, issues the *same*
pre-translation / prefetch probe schedule the engine issues (built from the
shared :func:`~repro.core.engine.epoch_spans` /
:func:`~repro.core.engine.probe_station` helpers), so oracle-equivalence
tests bind for the optimization paths too.

:class:`RefSession` mirrors :class:`repro.core.session.SimSession` — a
persistent-TLB session replaying a sequence of collectives — and
:func:`simulate_ref` is the single-collective wrapper over it.  Too slow for
the paper's 4 GB sweeps (that is the point of the epoch engine).
"""
from __future__ import annotations

import heapq
import math
from typing import Dict, List, Optional, Tuple

import numpy as np

from .config import SimConfig
from .engine import (Flow, IterationResult, RunResult, epoch_spans,
                     flows_for_dst, pretranslate_probes, probe_station)
from .select import get_policy, session_collective
from .session import CollectiveResult, resolve_collective
from .tlb import Counters, TranslationState


class _StationQueue:
    """In-order ingress FIFO of one target station with B buffer slots.

    Requests are admitted in arrival order; each occupies a slot from
    admission until its translation resolves (slots can free out of order —
    MSHR hit-under-miss requests outlast younger already-translated ones)."""

    def __init__(self, entries: int, svc_ns: float):
        self.entries = entries
        self.svc = svc_ns             # link-rate service spacing of the port
        self.reqs: List[tuple] = []   # (nominal_arrival, flow_idx, page, req_idx)
        self.ptr = 0
        self.prev_adm = -float("inf")
        self.retires: List[float] = []  # min-heap of outstanding retire times

    def push(self, item):
        self.reqs.append(item)

    def sort(self):
        self.reqs.sort()

    def next_candidate(self) -> Optional[float]:
        if self.ptr >= len(self.reqs):
            return None
        nom = self.reqs[self.ptr][0]
        # Ingress delivers at most one request per svc (the port's line rate),
        # so a stall can never be re-absorbed by over-rate draining.
        adm = max(nom, self.prev_adm + self.svc)
        if len(self.retires) >= self.entries:
            adm = max(adm, self.retires[0])
        return adm

    def admit(self, adm: float, retire: float):
        self.ptr += 1
        self.prev_adm = adm
        while self.retires and self.retires[0] <= adm:
            heapq.heappop(self.retires)
        heapq.heappush(self.retires, retire)


def _probe_schedule(flows: List[Flow], cfg: SimConfig,
                    first_step: bool) -> List[Tuple[float, int, int]]:
    """(t, station, page) probes for one step, identical to the engine's.

    Pre-translation probes (paper §6.1) fire only on the first step of a
    collective, during the preceding compute window; prefetch probes (§6.2)
    fire at each page-epoch's first arrival for the following ``depth``
    pages.  Stations are aligned to each page's first data request
    (:func:`probe_station`).
    """
    fab = cfg.fabric
    ns = fab.stations_per_gpu
    rb = fab.request_bytes
    page_bytes = cfg.translation.page_bytes
    probes: List[Tuple[float, int, int]] = []
    if not cfg.translation.enabled:
        return probes

    if cfg.pretranslation.enabled and first_step:
        probes.extend(pretranslate_probes(flows, cfg))

    if cfg.prefetch.enabled:
        for (t_first, fi, page, _i0, _i1) in epoch_spans(
                flows, rb, page_bytes):
            f = flows[fi]
            last_page = (f.base_addr + f.nbytes - 1) // page_bytes
            for j in range(1, cfg.prefetch.depth + 1):
                p = page + j
                if p > last_page:
                    break
                probes.append((t_first,
                               probe_station(f, p, page_bytes, rb, ns), p))

    probes.sort()
    return probes


class _RefTarget:
    """One target GPU's DES state (translation persists across steps)."""

    def __init__(self, cfg: SimConfig):
        self.cfg = cfg
        self.state = TranslationState(cfg.translation,
                                      cfg.fabric.stations_per_gpu)
        self.stall_sum = 0.0

    def run_step(self, flows: List[Flow], first_step: bool,
                 trace: Optional[np.ndarray],
                 bounds: Optional[List[int]], fi_base: int) -> float:
        """Replay one step's flows request-by-request; returns completion.

        Fresh station queues per step: the previous step's translations all
        resolved before its completion barrier, so every ingress slot is
        free again by the time the next step's head requests arrive.
        """
        cfg = self.cfg
        fab = cfg.fabric
        rb = fab.request_bytes
        ns = fab.stations_per_gpu
        page_bytes = cfg.translation.page_bytes
        svc = rb / fab.station_bw
        stations = [_StationQueue(fab.ingress_entries, svc)
                    for _ in range(ns)]
        state = self.state

        probes = _probe_schedule(flows, cfg, first_step)
        pi = 0

        for fi, f in enumerate(flows):
            n_req = max(1, math.ceil(f.nbytes / rb))
            a0 = f.t_start + f.oneway_ns
            for i in range(n_req):
                st = (i + f.stripe) % ns
                page = (f.base_addr + i * rb) // page_bytes
                stations[st].push((a0 + i * f.delta_ns, fi, page, i))
        for st in stations:
            st.sort()

        # Global event loop in admission-time order (translation state must
        # observe accesses in non-decreasing time).  Probes interleave by
        # issue time: every probe at or before the next admission fires
        # first, exactly as the engine issues them ahead of the stream.
        heap = []
        for si, st in enumerate(stations):
            c = st.next_candidate()
            if c is not None:
                heapq.heappush(heap, (c, si))
        completion = 0.0
        while heap:
            adm, si = heapq.heappop(heap)
            st = stations[si]
            cur = st.next_candidate()
            if cur is None:
                continue
            if cur > adm + 1e-9:
                heapq.heappush(heap, (cur, si))  # stale entry; re-key
                continue
            while pi < len(probes) and probes[pi][0] <= cur:
                pt, pst, ppage = probes[pi]
                state.access(pst, ppage, pt, is_probe=True)
                state.counters.probes += 1
                pi += 1
            nom, fi, page, i = st.reqs[st.ptr]
            res = state.access(si, page, cur)
            state.counters.add_request(res.klass, res.resolve - cur)
            state.counters.note_max(res.resolve - cur)
            self.stall_sum += max(0.0, cur - nom)
            if trace is not None:
                trace[bounds[fi_base + fi] + i] = res.resolve - cur
            st.admit(cur, res.resolve)
            done = res.resolve + fab.hbm_ns + flows[fi].return_ns
            completion = max(completion, done)
            c = st.next_candidate()
            if c is not None:
                heapq.heappush(heap, (c, si))
        # Probes scheduled beyond the last admission still fire (they warm
        # state for subsequent steps/collectives of the session).
        while pi < len(probes):
            pt, pst, ppage = probes[pi]
            state.access(pst, ppage, pt, is_probe=True)
            state.counters.probes += 1
            pi += 1
        return completion


class RefSession:
    """Oracle mirror of :class:`repro.core.session.SimSession`.

    Same public surface (``run`` / ``idle`` / ``result`` / ``records``),
    request-level physics — including per-call ``policy`` resolution with
    the same cold/warm region keying (via the shared
    :func:`~repro.core.select.session_collective`), so the
    oracle-equivalence contract extends to policy-chosen algorithms.
    Session-equivalence tests replay identical call sequences through both
    and compare.
    """

    def __init__(self, cfg: SimConfig, *, policy=None):
        self.cfg = cfg
        self.policy = get_policy(policy)
        self._warm_regions: set = set()
        self.t = 0.0
        self.records: List[CollectiveResult] = []
        self._targets: Dict[int, _RefTarget] = {}
        self._trace: Optional[np.ndarray] = None
        self._bounds: Optional[List[int]] = None

    def idle(self, gap_ns: float) -> None:
        if gap_ns <= 0:
            return
        self.t += gap_ns
        retention = self.cfg.tlb_retention_ns
        if retention is not None and gap_ns >= retention:
            for tg in self._targets.values():
                tg.state.flush()
            self._warm_regions.clear()

    def _target(self, dst: int) -> _RefTarget:
        tg = self._targets.get(dst)
        if tg is None:
            tg = self._targets[dst] = _RefTarget(self.cfg)
        return tg

    def _counters_total(self) -> Counters:
        total = Counters()
        for tg in self._targets.values():
            total.merge(tg.state.counters)
        return total

    def run(self, nbytes: int, *, collective: Optional[str] = None,
            n_gpus: Optional[int] = None, rank_stride: int = 1,
            gap_ns: float = 0.0,
            base_offset: int = 0, label: str = "") -> CollectiveResult:
        cfg = self.cfg
        fab = cfg.fabric
        if gap_ns:
            self.idle(gap_ns)
        collective = session_collective(
            self.policy, cfg, nbytes, collective, n_gpus,
            warm=base_offset in self._warm_regions)
        self._warm_regions.add(base_offset)
        name, fab_n, step_specs, dsts = resolve_collective(
            cfg, nbytes, collective, n_gpus, rank_stride)
        rb = fab.request_bytes

        # Trace only the first collective of the session, representative
        # target, same rule as the engine session.
        collect = cfg.collect_trace and not self.records
        step_nflows: List[int] = []
        if collect:
            self._bounds = [0]
            for specs in step_specs:
                flows = flows_for_dst(specs, cfg, dsts[0], 0.0)
                step_nflows.append(len(flows))
                for f in flows:
                    self._bounds.append(
                        self._bounds[-1] + max(1, math.ceil(f.nbytes / rb)))
            self._trace = np.zeros(self._bounds[-1])

        before = self._counters_total()
        t0 = self.t
        t = t0
        fi_base = 0
        for si, specs in enumerate(step_specs):
            comp = t
            for d in dsts:
                flows = flows_for_dst(specs, cfg, d, t_start=t)
                if base_offset:
                    for f in flows:
                        f.base_addr += base_offset
                if not flows:
                    continue
                trace_this = collect and d == dsts[0]
                comp = max(comp, self._target(d).run_step(
                    flows, si == 0,
                    self._trace if trace_this else None,
                    self._bounds, fi_base))
            t = comp
            if collect:
                fi_base += step_nflows[si]
        self.t = t

        rec = CollectiveResult(
            label=label or name, collective=name, nbytes=nbytes,
            n_gpus=fab_n.n_gpus, t_start=t0, t_end=t,
            counters=self._counters_total().delta(before))
        self.records.append(rec)
        return rec

    def result(self, collective_bytes: Optional[int] = None) -> RunResult:
        ctr = self._counters_total()
        stall_sum = sum(tg.stall_sum for tg in self._targets.values())
        nbytes = (collective_bytes if collective_bytes is not None
                  else (self.records[0].nbytes if self.records else 0))
        return RunResult(
            iterations=[IterationResult(completion_ns=r.completion_ns)
                        for r in self.records],
            counters=ctr, config=self.cfg, collective_bytes=nbytes,
            trace=self._trace, trace_flow_bounds=self._bounds,
            mean_stall_ns=stall_sum / max(1, ctr.requests))


def simulate_ref(nbytes: int, cfg: SimConfig) -> RunResult:
    """Oracle simulation of ``cfg.collective`` (same flow sets as the engine)."""
    sess = RefSession(cfg)
    for _ in range(cfg.iterations):
        sess.run(nbytes)
    return sess.result(collective_bytes=nbytes)
