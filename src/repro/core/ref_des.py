"""Request-level reference DES for the RAT simulator (the oracle).

Simulates every individual request through the same
:class:`~repro.core.tlb.TranslationState` machinery as the page-epoch engine,
but with explicit per-station in-order FIFOs and slot-accurate ingress
buffering instead of closed-form epoch expansion.  Replays exactly the flow
sets the pattern layer (:mod:`repro.core.patterns`) emits — one station-queue
episode per collective step, barriered on the previous step's completion — so
oracle-equivalence tests bind for every collective, not just the paper's
all-pairs AllToAll.  Used by the test suite to validate
:mod:`repro.core.engine` at small collective sizes; too slow for the paper's
4 GB sweeps (that is the point of the epoch engine).
"""
from __future__ import annotations

import heapq
import math
from typing import Dict, List, Optional, Tuple

import numpy as np

from .config import SimConfig
from .engine import Flow, RunResult, IterationResult, flows_for_dst
from .patterns import get_pattern, simulated_dsts
from .tlb import TranslationState


class _StationQueue:
    """In-order ingress FIFO of one target station with B buffer slots.

    Requests are admitted in arrival order; each occupies a slot from
    admission until its translation resolves (slots can free out of order —
    MSHR hit-under-miss requests outlast younger already-translated ones)."""

    def __init__(self, entries: int, svc_ns: float):
        self.entries = entries
        self.svc = svc_ns             # link-rate service spacing of the port
        self.reqs: List[tuple] = []   # (nominal_arrival, flow_idx, page, req_idx)
        self.ptr = 0
        self.prev_adm = -float("inf")
        self.retires: List[float] = []  # min-heap of outstanding retire times

    def push(self, item):
        self.reqs.append(item)

    def sort(self):
        self.reqs.sort()

    def next_candidate(self) -> Optional[float]:
        if self.ptr >= len(self.reqs):
            return None
        nom = self.reqs[self.ptr][0]
        # Ingress delivers at most one request per svc (the port's line rate),
        # so a stall can never be re-absorbed by over-rate draining.
        adm = max(nom, self.prev_adm + self.svc)
        if len(self.retires) >= self.entries:
            adm = max(adm, self.retires[0])
        return adm

    def admit(self, adm: float, retire: float):
        self.ptr += 1
        self.prev_adm = adm
        while self.retires and self.retires[0] <= adm:
            heapq.heappop(self.retires)
        heapq.heappush(self.retires, retire)


class _RefTarget:
    """One target GPU's DES state (translation persists across steps)."""

    def __init__(self, cfg: SimConfig):
        self.cfg = cfg
        self.state = TranslationState(cfg.translation,
                                      cfg.fabric.stations_per_gpu)
        self.stall_sum = 0.0

    def run_step(self, flows: List[Flow], trace: Optional[np.ndarray],
                 bounds: Optional[List[int]], fi_base: int) -> float:
        """Replay one step's flows request-by-request; returns completion.

        Fresh station queues per step: the previous step's translations all
        resolved before its completion barrier, so every ingress slot is
        free again by the time the next step's head requests arrive.
        """
        cfg = self.cfg
        fab = cfg.fabric
        rb = fab.request_bytes
        ns = fab.stations_per_gpu
        page_bytes = cfg.translation.page_bytes
        svc = rb / fab.station_bw
        stations = [_StationQueue(fab.ingress_entries, svc)
                    for _ in range(ns)]
        state = self.state

        for fi, f in enumerate(flows):
            n_req = max(1, math.ceil(f.nbytes / rb))
            a0 = f.t_start + fab.oneway_ns
            for i in range(n_req):
                st = (i + f.stripe) % ns
                page = (f.base_addr + i * rb) // page_bytes
                stations[st].push((a0 + i * f.delta_ns, fi, page, i))
        for st in stations:
            st.sort()

        # Global event loop in admission-time order (translation state must
        # observe accesses in non-decreasing time).
        heap = []
        for si, st in enumerate(stations):
            c = st.next_candidate()
            if c is not None:
                heapq.heappush(heap, (c, si))
        completion = 0.0
        while heap:
            adm, si = heapq.heappop(heap)
            st = stations[si]
            cur = st.next_candidate()
            if cur is None:
                continue
            if cur > adm + 1e-9:
                heapq.heappush(heap, (cur, si))  # stale entry; re-key
                continue
            nom, fi, page, i = st.reqs[st.ptr]
            res = state.access(si, page, cur)
            state.counters.add_request(res.klass, res.resolve - cur)
            state.counters.note_max(res.resolve - cur)
            self.stall_sum += max(0.0, cur - nom)
            if trace is not None:
                trace[bounds[fi_base + fi] + i] = res.resolve - cur
            st.admit(cur, res.resolve)
            done = res.resolve + fab.hbm_ns + fab.return_ns
            completion = max(completion, done)
            c = st.next_candidate()
            if c is not None:
                heapq.heappush(heap, (c, si))
        return completion


def simulate_ref(nbytes: int, cfg: SimConfig) -> RunResult:
    """Oracle simulation of ``cfg.collective`` (same flow sets as the engine)."""
    fab = cfg.fabric
    rb = fab.request_bytes
    pattern = get_pattern(cfg.collective)
    step_specs = pattern.steps(nbytes, fab)
    dsts = simulated_dsts(pattern, step_specs, cfg.symmetric, fab)
    targets: Dict[int, _RefTarget] = {d: _RefTarget(cfg) for d in dsts}

    # Per-step flow counts of the representative target (for trace indexing)
    # and the trace bounds, computed once — flow timing is rebuilt per step,
    # the schedule shape never changes.
    step_nflows = [len(flows_for_dst(specs, cfg, dsts[0], 0.0))
                   for specs in step_specs]
    trace = None
    bounds: Optional[List[int]] = None
    if cfg.collect_trace:
        bounds = [0]
        for specs in step_specs:
            for f in flows_for_dst(specs, cfg, dsts[0], 0.0):
                bounds.append(bounds[-1] + max(1, math.ceil(f.nbytes / rb)))
        trace = np.zeros(bounds[-1])

    results: List[IterationResult] = []
    t = 0.0
    for it in range(cfg.iterations):
        t_iter = t
        collect = cfg.collect_trace and it == 0
        fi_base = 0
        for si, specs in enumerate(step_specs):
            comp = t
            for d in dsts:
                flows = flows_for_dst(specs, cfg, d, t_start=t)
                if not flows:
                    continue
                trace_this = collect and d == dsts[0]
                comp = max(comp, targets[d].run_step(
                    flows,
                    trace if trace_this else None,
                    bounds, fi_base))
            t = comp
            fi_base += step_nflows[si]
        results.append(IterationResult(completion_ns=t - t_iter))

    ctr = targets[dsts[0]].state.counters
    for d in dsts[1:]:
        ctr.merge(targets[d].state.counters)
    stall_sum = sum(tg.stall_sum for tg in targets.values())

    return RunResult(iterations=results, counters=ctr, config=cfg,
                     collective_bytes=nbytes, trace=trace,
                     trace_flow_bounds=bounds,
                     mean_stall_ns=stall_sum / max(1, ctr.requests))
