"""Request-level reference DES for the RAT simulator (the oracle).

Simulates every individual request through the same
:class:`~repro.core.tlb.TranslationState` machinery as the page-epoch engine,
but with explicit per-station in-order FIFOs and slot-accurate ingress
buffering instead of closed-form epoch expansion.  Used by the test suite to
validate :mod:`repro.core.engine` at small collective sizes; too slow for the
paper's 4 GB sweeps (that is the point of the epoch engine).
"""
from __future__ import annotations

import heapq
import math
from collections import deque
from typing import List, Optional

import numpy as np

from .config import SimConfig
from .engine import Flow, RunResult, IterationResult, _build_flows
from .tlb import TranslationState


class _StationQueue:
    """In-order ingress FIFO of one target station with B buffer slots.

    Requests are admitted in arrival order; each occupies a slot from
    admission until its translation resolves (slots can free out of order —
    MSHR hit-under-miss requests outlast younger already-translated ones)."""

    def __init__(self, entries: int, svc_ns: float):
        self.entries = entries
        self.svc = svc_ns             # link-rate service spacing of the port
        self.reqs: List[tuple] = []   # (nominal_arrival, flow_idx, page, req_idx)
        self.ptr = 0
        self.prev_adm = -float("inf")
        self.retires: List[float] = []  # min-heap of outstanding retire times

    def push(self, item):
        self.reqs.append(item)

    def sort(self):
        self.reqs.sort()

    def next_candidate(self) -> Optional[float]:
        if self.ptr >= len(self.reqs):
            return None
        nom = self.reqs[self.ptr][0]
        # Ingress delivers at most one request per svc (the port's line rate),
        # so a stall can never be re-absorbed by over-rate draining.
        adm = max(nom, self.prev_adm + self.svc)
        if len(self.retires) >= self.entries:
            adm = max(adm, self.retires[0])
        return adm

    def admit(self, adm: float, retire: float):
        self.ptr += 1
        self.prev_adm = adm
        while self.retires and self.retires[0] <= adm:
            heapq.heappop(self.retires)
        heapq.heappush(self.retires, retire)


def simulate_ref(nbytes: int, cfg: SimConfig) -> RunResult:
    """Oracle simulation of one target GPU (symmetric all-pairs)."""
    fab = cfg.fabric
    rb = fab.request_bytes
    ns = fab.stations_per_gpu
    page_bytes = cfg.translation.page_bytes
    state = TranslationState(cfg.translation, ns)
    results = []
    t_iter = 0.0
    trace = None
    bounds = None
    stall_sum = 0.0

    for it in range(cfg.iterations):
        flows = _build_flows(cfg, nbytes, dst=0, t_start=t_iter)
        svc = rb / fab.station_bw
        stations = [_StationQueue(fab.ingress_entries, svc) for _ in range(ns)]
        per_flow = max(1, math.ceil(flows[0].nbytes / rb))
        collect = cfg.collect_trace and it == 0
        if collect:
            trace = np.zeros(len(flows) * per_flow)
            bounds = [per_flow * i for i in range(len(flows) + 1)]

        for fi, f in enumerate(flows):
            n_req = max(1, math.ceil(f.nbytes / rb))
            a0 = f.t_start + fab.oneway_ns
            for i in range(n_req):
                st = (i + f.stripe) % ns
                page = (f.base_addr + i * rb) // page_bytes
                stations[st].push((a0 + i * f.delta_ns, fi, page, i))
        for st in stations:
            st.sort()

        # Global event loop in admission-time order (translation state must
        # observe accesses in non-decreasing time).
        heap = []
        for si, st in enumerate(stations):
            c = st.next_candidate()
            if c is not None:
                heapq.heappush(heap, (c, si))
        completion = 0.0
        while heap:
            adm, si = heapq.heappop(heap)
            st = stations[si]
            cur = st.next_candidate()
            if cur is None:
                continue
            if cur > adm + 1e-9:
                heapq.heappush(heap, (cur, si))  # stale entry; re-key
                continue
            nom, fi, page, i = st.reqs[st.ptr]
            res = state.access(si, page, cur)
            state.counters.add_request(res.klass, res.resolve - cur)
            state.counters.note_max(res.resolve - cur)
            stall_sum += max(0.0, cur - nom)
            if collect:
                trace[fi * per_flow + i] = res.resolve - cur
            st.admit(cur, res.resolve)
            done = res.resolve + fab.hbm_ns + fab.return_ns
            completion = max(completion, done)
            c = st.next_candidate()
            if c is not None:
                heapq.heappush(heap, (c, si))

        results.append(IterationResult(completion_ns=completion - t_iter))
        t_iter = completion

    return RunResult(iterations=results, counters=state.counters, config=cfg,
                     collective_bytes=nbytes, trace=trace,
                     trace_flow_bounds=bounds,
                     mean_stall_ns=stall_sum / max(1, state.counters.requests))
