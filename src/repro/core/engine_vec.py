"""Vectorized batch engine: the page-epoch model as array arithmetic.

Drop-in replacement for :class:`repro.core.engine.EpochEngine` selected via
``SimConfig.engine="vectorized"`` (DESIGN.md §12).  The event engine spends
its time in two places: materializing O(n^2) per-flow Python objects at
pod scale, and walking a Python loop over every (epoch, station) head of
large collectives.  This engine removes both:

* flow/epoch/head geometry — spacing, arrival times, page spans, station
  striping, ingress totals — is precomputed as numpy arrays
  (:func:`flows_from_specs` plus the span construction in
  :meth:`VecEngine.run_iteration`);
* only the inherently sequential part remains a Python loop: one
  :meth:`VecTranslationState.access` state-machine call per epoch head (the
  TLB hierarchy is stateful — each access's outcome depends on every prior
  access), reading pre-converted native scalars;
* all per-head tail expansion (hit-under-miss counts, latency sums, trace
  rows, completion) is deferred to vectorized postprocessing.

Bit-for-bit equivalence with the event engine is a hard contract, enforced
by ``tests/test_engine_diff.py``.  It holds because every float expression
keeps the event engine's exact operand order (elementwise numpy float64 ops
are IEEE-identical to scalar Python), accumulations use ``np.cumsum`` (a
strict left fold, matching the scalar ``+=`` chain — the terms the event
engine skips contribute exact-zero no-ops), and the optimized LRU below
reproduces the original's lazy-commit order exactly.

:class:`VecTranslationState` is an operation-for-operation port of
:class:`repro.core.tlb.TranslationState` with two structural speedups that
provably preserve the observable sequence of cache operations:

* ``_VLRU`` commits staged fills from a min-heap ordered by
  ``(fill_time, staging_index)`` instead of re-scanning and stably sorting
  the staged dict on every lookup.  The original's order is fill-time with
  dict-insertion tie-break, and dict position is preserved when a fill is
  re-staged earlier — exactly the ``(fill_time, first_staging_index)``
  order the heap pops in (stale heap entries are skipped by generation
  check).
* ``l1_maybe``/``l2_maybe`` record every page ever fill-staged per cache
  since the last flush.  A page absent from the set cannot be resident, so
  its lookup is a guaranteed miss and is skipped entirely.  Deferring the
  skipped lookup's lazy commits is safe: commits are totally ordered by
  ``(fill_time, staging_index)`` and every *taken* lookup first commits all
  fills up to its own time, so the interleaving of commits, hits
  (recency updates) and evictions that the caches observe is unchanged.
"""
from __future__ import annotations

import heapq
from collections import OrderedDict
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from .config import SimConfig, TranslationConfig
from .patterns import StepArrays
from .tlb import CLASSES, Counters, INF, L1_HIT, L1_HUM, PTWPool
from .topology import get_topology

# Integer class codes for the hot path (postprocessing maps them back to
# the string keys of Counters.by_class).  Order matches tlb.CLASSES.
_L1_HIT, _L1_HUM, _L2_HIT, _L2_HUM, _WALK = range(5)


class _VLRU:
    """Set-associative lazy-commit LRU, heap-committed.

    Same observable semantics as :class:`repro.core.tlb.LRUCache` (see the
    module docstring for the order argument); O(log staged) per commit
    instead of an O(staged) scan-and-sort per lookup.
    """

    __slots__ = ("entries", "assoc", "n_sets", "_sets", "_staged", "_heap",
                 "_seq")

    def __init__(self, entries: int, assoc: int):
        self.entries = entries
        self.assoc = assoc if assoc > 0 else entries
        self.n_sets = max(1, entries // self.assoc)
        self._sets = [OrderedDict() for _ in range(self.n_sets)]
        self._staged: Dict[object, Tuple[float, int]] = {}
        self._heap: List[Tuple[float, int, object]] = []
        self._seq = 0

    def _commit(self, t: float) -> None:
        h = self._heap
        staged = self._staged
        sets = self._sets
        n_sets = self.n_sets
        assoc = self.assoc
        while h and h[0][0] <= t:
            ft, seq, k = heapq.heappop(h)
            if staged.get(k) != (ft, seq):
                continue                   # superseded by an earlier re-fill
            del staged[k]
            s = sets[hash(k) % n_sets]
            if k in s:
                s.move_to_end(k)
            else:
                if len(s) >= assoc:
                    s.popitem(last=False)  # LRU eviction
                s[k] = ft

    def lookup(self, key, t: float) -> bool:
        h = self._heap
        if h and h[0][0] <= t:
            self._commit(t)
        s = self._sets[hash(key) % self.n_sets]
        if key in s:
            s.move_to_end(key)
            return True
        return False

    def fill(self, key, fill_time: float) -> None:
        prev = self._staged.get(key)
        if prev is None:
            seq = self._seq
            self._seq = seq + 1
            self._staged[key] = (fill_time, seq)
            heapq.heappush(self._heap, (fill_time, seq, key))
        elif fill_time < prev[0]:
            # Earlier re-fill keeps the original staging index, exactly as
            # a dict value update keeps the key's position.
            self._staged[key] = (fill_time, prev[1])
            heapq.heappush(self._heap, (fill_time, prev[1], key))


class VecTranslationState:
    """Optimized port of :class:`repro.core.tlb.TranslationState`.

    Identical decision tree and float arithmetic; hot-path accesses return a
    plain ``(resolve, class_code, l1_fill)`` tuple instead of an
    ``AccessResult``.  Interface used by :class:`~repro.core.session.
    SimSession` (``counters``, ``flush``) is preserved.
    """

    def __init__(self, cfg: TranslationConfig, n_stations: int):
        self.cfg = cfg
        self.n_stations = n_stations
        self._l1_lat = cfg.l1.hit_latency_ns
        self._l2_lat = cfg.l2.hit_latency_ns
        self.l1 = [_VLRU(cfg.l1.entries, cfg.l1.assoc)
                   for _ in range(n_stations)]
        self.l2 = _VLRU(cfg.l2.entries, cfg.l2.assoc)
        self.pwc = [_VLRU(e, cfg.pwc.assoc) for e in cfg.pwc.entries]
        self.ptw = PTWPool(cfg.n_ptw)
        self.l2_pending: Dict[int, float] = {}
        # MSHR fills keyed (station, page) in the original; split per
        # station here (same key space, no tuple hashing on the hot path).
        self.l1_pending: List[Dict[int, float]] = [
            {} for _ in range(n_stations)]
        self.counters = Counters()
        # Pages ever fill-staged per cache since the last flush: absence
        # proves a miss, so the lookup (and its deferred-safe lazy commit)
        # is skipped.
        self.l1_maybe = [set() for _ in range(n_stations)]
        self.l2_maybe: set = set()

    def flush(self) -> None:
        """Invalidate cached translations; keep counters and PTW occupancy
        (mirrors :meth:`repro.core.tlb.TranslationState.flush`)."""
        cfg = self.cfg
        self.l1 = [_VLRU(cfg.l1.entries, cfg.l1.assoc)
                   for _ in range(self.n_stations)]
        self.l2 = _VLRU(cfg.l2.entries, cfg.l2.assoc)
        self.pwc = [_VLRU(e, cfg.pwc.assoc) for e in cfg.pwc.entries]
        self.l2_pending.clear()
        self.l1_pending = [{} for _ in range(self.n_stations)]
        self.l1_maybe = [set() for _ in range(self.n_stations)]
        self.l2_maybe = set()

    def _walk_latency(self, page: int, t: float) -> float:
        c = self.cfg
        ctr = self.counters
        lat = 0.0
        addr = page * c.page_bytes
        for lvl, cache in enumerate(self.pwc):
            region = addr // c.pwc.coverage_bytes[lvl]
            lat += c.pwc.lookup_latency_ns
            if cache.lookup((lvl, region), t + lat):
                ctr.pwc_hits += 1
            else:
                ctr.pwc_misses += 1
                lat += c.mem_access_ns
                ctr.walk_mem_reads += 1
                cache.fill((lvl, region), t + lat)
        lat += c.mem_access_ns
        ctr.walk_mem_reads += 1
        return lat

    def access(self, station: int, page: int,
               t: float) -> Tuple[float, int, float]:
        """One translation request; callers gate on ``cfg.enabled``."""
        t1 = t + self._l1_lat
        maybe = self.l1_maybe[station]
        if page in maybe and self.l1[station].lookup(page, t1):
            return (t1, _L1_HIT, -INF)

        pending = self.l1_pending[station]
        pend = pending.get(page)
        if pend is not None:
            if pend <= t1:
                del pending[page]
                return (t1, _L1_HUM, pend)       # max(t1, pend) == t1
            return (pend, _L1_HUM, pend)         # max(t1, pend) == pend

        t2 = t1 + self._l2_lat
        if page in self.l2_maybe and self.l2.lookup(page, t2):
            self.l1[station].fill(page, t2)
            maybe.add(page)
            pending[page] = t2
            return (t2, _L2_HIT, t2)

        walk_done = self.l2_pending.get(page)
        if walk_done is not None:
            if walk_done > t2:
                self.l1[station].fill(page, walk_done)
                maybe.add(page)
                pending[page] = walk_done
                return (walk_done, _L2_HUM, walk_done)
            del self.l2_pending[page]

        start = self.ptw.start(t2)
        walk_lat = self._walk_latency(page, start)
        self.ptw.finish(start + walk_lat)
        done = start + walk_lat
        self.counters.walks += 1
        self.l2_pending[page] = done
        self.l2.fill(page, done)
        self.l2_maybe.add(page)
        self.l1[station].fill(page, done)
        maybe.add(page)
        pending[page] = done
        return (done, _WALK, done)


@dataclass
class FlowArrays:
    """One step's flows at one target as parallel columns.

    Row ``i`` carries exactly the fields of the ``i``-th
    :class:`~repro.core.engine.Flow` that :func:`~repro.core.engine.
    flows_for_dst` would build (same order: spec order filtered to this
    target).
    """

    src: np.ndarray        # int64
    base_addr: np.ndarray  # int64, NPA region base + spec offset
    nbytes: np.ndarray     # int64, all > 0
    t_start: float
    delta: np.ndarray      # float64 inter-request spacing
    stripe: np.ndarray     # int64 station striping offset
    oneway: np.ndarray     # float64 request-path latency
    ret: np.ndarray        # float64 ack-path latency

    def __len__(self) -> int:
        return len(self.src)


def flows_from_specs(step: StepArrays, cfg: SimConfig, dst: int,
                     t_start: float) -> Optional[FlowArrays]:
    """Vectorized :func:`repro.core.engine.flows_for_dst`.

    Bandwidth shares count *all* of the step's flows (zero-byte and
    other-target flows included), matching the event engine; only flows
    landing at ``dst`` with positive bytes are materialized.  Returns
    ``None`` for an empty flow set (the event path's ``[]``).
    """
    fab = cfg.fabric
    topo = get_topology(fab)
    sel = (step.dst == dst) & (step.nbytes > 0)
    if not sel.any():
        return None
    src = step.src[sel]
    nb = step.nbytes[sel]
    off = step.offset[sel]
    rb = fab.request_bytes
    delta = (rb * step.out_deg()[src]) / fab.gpu_bw
    if topo.flat:
        oneway = np.full(len(src), fab.oneway_ns)
        ret = np.full(len(src), fab.return_ns)
    else:
        # Per-(source, tier) degrees are a per-step aggregate over ALL
        # specs; cached on the StepArrays (steps are built per run, under
        # one fabric config, so the cache never crosses topologies).
        if step._tier_cache is None:
            tier_all = topo.tier_arr(step.src, step.dst)
            ntier = int(tier_all.max()) + 1 if len(tier_all) else 1
            step._tier_cache = (ntier,
                                np.bincount(step.src * ntier + tier_all))
        ntier, tdeg = step._tier_cache
        tier_sel = topo.tier_arr(src, dst)
        for tv in np.unique(tier_sel):
            cap = topo.tier_capacity(int(tv))
            if cap is None:
                continue
            m = tier_sel == tv
            shaped = (rb * tdeg[src[m] * ntier + tv]) / cap
            delta[m] = np.maximum(delta[m], shaped)
        oneway = topo.path_latency_arr(src, dst)
        ret = topo.return_latency_arr(dst, src)
    return FlowArrays(src=src, base_addr=((dst + 1) << 42) + off, nbytes=nb,
                      t_start=t_start, delta=delta,
                      stripe=src % fab.stations_per_gpu,
                      oneway=oneway, ret=ret)


def request_counts(fa: FlowArrays, rb: int) -> List[int]:
    """Per-flow request counts (``max(1, ceil(nbytes / rb))``, exact)."""
    return np.maximum(1, np.ceil(fa.nbytes / rb).astype(np.int64)).tolist()


class VecEngine:
    """Vectorized twin of :class:`repro.core.engine.EpochEngine`.

    Same construction signature and the same surface
    :class:`~repro.core.session.SimSession` drives (``state``,
    ``stall_sum``/``stall_n``, ``trace_chunks``, ``run_iteration``), but
    ``run_iteration`` consumes a :class:`FlowArrays` instead of a
    ``List[Flow]``.
    """

    def __init__(self, cfg: SimConfig, dst: int = 0):
        self.cfg = cfg
        self.dst = dst
        fab = cfg.fabric
        self.state = VecTranslationState(cfg.translation,
                                         fab.stations_per_gpu)
        self.page_bytes = cfg.translation.page_bytes
        self.svc = fab.request_bytes / fab.station_bw
        self.buffer_cover = fab.ingress_entries * self.svc
        self.trace_chunks: List[Tuple[int, int, np.ndarray]] = []
        self.stall_sum = 0.0
        self.stall_n = 0

    # -- optimizations -------------------------------------------------------
    def _pretranslate(self, fa: FlowArrays) -> None:
        """Vectorized probe construction; sequential replay in issue order
        (same (t, station, page) stream as ``pretranslate_probes``)."""
        pre = self.cfg.pretranslation
        fab = self.cfg.fabric
        ns = fab.stations_per_gpu
        rb = fab.request_bytes
        pb = self.page_bytes
        base = fa.base_addr
        first_page = base // pb
        n_pages = (base + fa.nbytes - 1) // pb - first_page + 1
        ppf = pre.pages_per_flow
        limit = n_pages if ppf <= 0 else np.minimum(n_pages, ppf)
        total = int(limit.sum())
        if not total:
            return
        pf = np.repeat(np.arange(len(fa)), limit)
        cum = np.concatenate(([0], np.cumsum(limit)))
        j = np.arange(total) - cum[:-1][pf]
        pg = first_page[pf] + j
        b = base[pf]
        st = ((np.maximum(b, pg * pb) - b) // rb + fa.stripe[pf]) % ns
        t0 = fa.t_start - pre.lead_time_ns
        times = t0 + np.arange(total) * pre.probe_issue_interval_ns
        access = self.state.access
        for s, p, t in zip(st.tolist(), pg.tolist(), times.tolist()):
            access(s, p, t)
        self.state.counters.probes += total

    # -- core ----------------------------------------------------------------
    def run_iteration(self, fa: FlowArrays, collect_trace: bool,
                      fi_base: int = 0, first_step: bool = True) -> float:
        """Price one step's flow set; returns absolute completion time.

        Semantics identical to ``EpochEngine.run_iteration``: translation
        state persists across calls, per-station ingress bookkeeping
        resets, pre-translation probes fire only on ``first_step``.
        """
        cfg = self.cfg
        fab = cfg.fabric
        rb = fab.request_bytes
        ns = fab.stations_per_gpu
        pb = self.page_bytes
        enabled = cfg.translation.enabled
        l1_lat = cfg.translation.l1.hit_latency_ns if enabled else 0.0
        ctr = self.state.counters

        base = fa.base_addr
        nb = fa.nbytes
        delta = fa.delta
        stripe = fa.stripe
        n_req = np.maximum(1, np.ceil(nb / rb).astype(np.int64))
        a0 = fa.t_start + fa.oneway

        if cfg.pretranslation.enabled and enabled and first_step and len(fa):
            self._pretranslate(fa)

        # ---- epoch spans: vectorized epoch_spans(), same sort order ------
        first_page = base // pb
        last_page = (base + nb - 1) // pb
        npages = last_page - first_page + 1
        cum = np.concatenate(([0], np.cumsum(npages)))
        e_fi = np.repeat(np.arange(len(fa)), npages)
        page = first_page[e_fi] + (np.arange(int(cum[-1])) - cum[:-1][e_fi])
        b_f = base[e_fi]
        lo = np.maximum(b_f, page * pb)
        hi = np.minimum(b_f + nb[e_fi], (page + 1) * pb)
        i0 = (lo - b_f) // rb
        i1 = np.minimum(n_req[e_fi],
                        np.ceil((hi - b_f) / rb).astype(np.int64))
        keep = i1 > i0
        e_fi, page, i0, i1 = e_fi[keep], page[keep], i0[keep], i1[keep]
        t_first = a0[e_fi] + i0 * delta[e_fi]
        # Tuple sort (t_first, fi, page): (fi, page) pairs are unique, so
        # the lexsort total order equals the event engine's list.sort().
        order = np.lexsort((page, e_fi, t_first))
        e_fi, page, i0, i1, t_first = (
            e_fi[order], page[order], i0[order], i1[order], t_first[order])
        E = len(e_fi)

        # ---- heads: per-(epoch, station) sub-series geometry -------------
        e_nh = np.minimum(ns, i1 - i0)
        hcum = np.concatenate(([0], np.cumsum(e_nh)))
        H = int(hcum[-1])
        h_e = np.repeat(np.arange(E), e_nh)
        h_is0 = i0[h_e] + (np.arange(H) - hcum[:-1][h_e])
        h_fi = e_fi[h_e]
        h_st = (h_is0 + stripe[h_fi]) % ns
        h_ns = (i1[h_e] - h_is0 + ns - 1) // ns
        h_t0b = a0[h_fi] + h_is0 * delta[h_fi]   # head arrival before skew
        h_stride = ns * delta[h_fi]
        h_ret = fa.ret[h_fi]

        if not enabled:
            # Ideal translation: every request resolves instantly; no
            # sequential state at all.  resolve == t0, rat == 0, no stalls.
            n_tot = int(h_ns.sum())
            ctr.requests += n_tot
            ctr.by_class[L1_HIT] += n_tot
            tail = h_ns > 1
            last = h_t0b.copy()
            last[tail] = np.maximum(
                last[tail],
                h_t0b[tail] + (h_ns[tail] - 1) * h_stride[tail] + l1_lat)
            completion = float((last + fab.hbm_ns + h_ret).max()) if H else 0.0
            if completion < 0.0:
                completion = 0.0
            if collect_trace:
                self._write_trace(fi_base, e_fi, i0, i1, hcum, h_is0, h_ns,
                                  h_t0b, np.zeros(H), np.full(H, -INF),
                                  h_stride, ns, l1_lat)
            return completion

        # ---- prefetch probe targets (paper §6.2), per epoch --------------
        pf_cols = []
        if cfg.prefetch.enabled:
            b_e = base[e_fi]
            lp_e = last_page[e_fi]
            stripe_e = stripe[e_fi]
            for j in range(1, cfg.prefetch.depth + 1):
                pj = page + j
                valid = pj <= lp_e
                st_j = ((np.maximum(b_e, pj * pb) - b_e) // rb
                        + stripe_e) % ns
                pf_cols.append((valid.tolist(), st_j.tolist(), pj.tolist()))

        # ---- per-station ingress totals ----------------------------------
        totals = np.zeros(ns, dtype=np.int64)
        bq, extra = np.divmod(n_req, ns)
        soff = np.arange(ns)
        np.add.at(totals, (soff[None, :] + stripe[:, None]) % ns,
                  bq[:, None] + (soff[None, :] < extra[:, None]))

        # ---- sequential core: one state-machine access per head ----------
        access = self.state.access
        skew = [0.0] * ns
        release = [-INF] * ns
        consumed = [0] * ns
        totals_l = totals.tolist()
        ingress = fab.ingress_entries
        cover = self.buffer_cover
        stall_sum = self.stall_sum
        stall_n = self.stall_n
        st_l = h_st.tolist()
        t0b_l = h_t0b.tolist()
        ns_l = h_ns.tolist()
        hpage_l = page[h_e].tolist()
        # Heads run strictly in flat order (epoch-sorted, station sub-order
        # inside each epoch), so per-head outputs are append-only.
        res_l: List[float] = []
        fill_l: List[float] = []
        t0_l: List[float] = []
        cls_l: List[int] = []
        res_app, fill_app = res_l.append, fill_l.append
        t0_app, cls_app = t0_l.append, cls_l.append
        probes = 0
        if pf_cols:
            # Epoch-structured walk: each epoch's prefetch probes fire at
            # its first arrival, before its heads.
            h0_l = hcum[:-1].tolist()
            h1_l = hcum[1:].tolist()
            tf_l = t_first.tolist()
            for e in range(E):
                tf = tf_l[e]
                for (valid, stj, pj) in pf_cols:
                    if valid[e]:
                        access(stj[e], pj[e], tf)
                        probes += 1
                for h in range(h0_l[e], h1_l[e]):
                    s = st_l[h]
                    t0 = t0b_l[h] + skew[s]
                    resolve, kls, fill = access(s, hpage_l[h], t0)
                    res_app(resolve)
                    fill_app(fill)
                    t0_app(t0)
                    cls_app(kls)
                    # Ingress-buffer backpressure (same predicate
                    # expressions as the event engine, term for term).
                    if (resolve - (t0 + l1_lat) > 0
                            and totals_l[s] - consumed[s] >= ingress):
                        block_from = t0 + cover
                        r = release[s]
                        if r > block_from:
                            block_from = r
                        if resolve > block_from:
                            bubble = resolve - block_from
                            skew[s] += bubble
                            release[s] = resolve
                            stall_sum += bubble
                            stall_n += 1
                    consumed[s] += ns_l[h]
        else:
            for s, pg, t0b, nsh in zip(st_l, hpage_l, t0b_l, ns_l):
                t0 = t0b + skew[s]
                resolve, kls, fill = access(s, pg, t0)
                res_app(resolve)
                fill_app(fill)
                t0_app(t0)
                cls_app(kls)
                if (resolve - (t0 + l1_lat) > 0
                        and totals_l[s] - consumed[s] >= ingress):
                    block_from = t0 + cover
                    r = release[s]
                    if r > block_from:
                        block_from = r
                    if resolve > block_from:
                        bubble = resolve - block_from
                        skew[s] += bubble
                        release[s] = resolve
                        stall_sum += bubble
                        stall_n += 1
                consumed[s] += nsh
        self.stall_sum = stall_sum
        self.stall_n = stall_n
        if probes:
            ctr.probes += probes

        # ---- deferred vectorized tail expansion --------------------------
        res = np.asarray(res_l)
        fill = np.asarray(fill_l)
        t0 = np.asarray(t0_l)
        rat0 = res - t0
        tail = h_ns > 1
        finite = fill > -INF
        fill_safe = np.where(finite, fill, 0.0)
        # k_hum = max(0, min(n_s - 1, ceil((fill - l1_lat - t0)/stride) - 1))
        # computed in float (exact: the clamp bounds are far below 2^53).
        kf = np.ceil((fill_safe - l1_lat - t0) / h_stride) - 1.0
        kf = np.maximum(np.minimum(kf, (h_ns - 1).astype(np.float64)), 0.0)
        k_hum = np.where(tail & finite, kf, 0.0).astype(np.int64)
        hum = k_hum * (fill_safe - t0) - h_stride * k_hum * (k_hum + 1) / 2
        hum = np.where(k_hum > 0, hum, 0.0)
        n_hit = np.where(tail, h_ns - 1 - k_hum, 0)
        hits = n_hit * l1_lat

        s_hum = int(k_hum.sum())
        s_hit = int(n_hit.sum())
        kcnt = np.bincount(np.asarray(cls_l, dtype=np.int64), minlength=5)
        ctr.requests += H + s_hum + s_hit
        by = ctr.by_class
        for idx, name in enumerate(CLASSES):
            if kcnt[idx]:
                by[name] += int(kcnt[idx])
        by[L1_HUM] += s_hum
        by[L1_HIT] += s_hit

        # rat_ns_sum: strict left fold over [rat0, hum, hits] per head, in
        # head order, seeded with the running value — cumsum is sequential,
        # and the zero terms the event engine skips are exact no-ops.
        contrib = np.empty(3 * H + 1)
        contrib[0] = ctr.rat_ns_sum
        contrib[1::3] = rat0
        contrib[2::3] = hum
        contrib[3::3] = hits
        ctr.rat_ns_sum = float(np.cumsum(contrib)[-1])

        if H:
            m = max(ctr.rat_ns_max, float(rat0.max()))
            hmax = float(np.where(k_hum > 0,
                                  fill_safe - (t0 + h_stride), -INF).max())
            if hmax > m:
                m = hmax
            ctr.rat_ns_max = m

        last = res.copy()
        khm = k_hum > 0
        last[khm] = np.maximum(last[khm], fill[khm])
        nhm = n_hit > 0
        last[nhm] = np.maximum(
            last[nhm],
            t0[nhm] + (h_ns[nhm] - 1) * h_stride[nhm] + l1_lat)
        completion = float((last + fab.hbm_ns + h_ret).max()) if H else 0.0
        if completion < 0.0:
            completion = 0.0

        if collect_trace:
            self._write_trace(fi_base, e_fi, i0, i1, hcum, h_is0, h_ns,
                              t0, rat0, fill, h_stride, ns, l1_lat,
                              res=None)
        return completion

    # -- tracing -------------------------------------------------------------
    def _write_trace(self, fi_base, e_fi, i0, i1, hcum, h_is0, h_ns, t0,
                     rat0, fill, h_stride, ns, l1_lat, res=None) -> None:
        """Per-epoch trace rows, same expressions as the event engine."""
        for e in range(len(e_fi)):
            tr = np.empty(int(i1[e] - i0[e]))
            for h in range(int(hcum[e]), int(hcum[e + 1])):
                pos = int(h_is0[h] - i0[e])
                tr[pos] = rat0[h]
                nsh = int(h_ns[h])
                if nsh > 1:
                    ks = np.arange(1, nsh)
                    arr = t0[h] + ks * h_stride[h]
                    f = fill[h]
                    lat = np.maximum(arr + l1_lat,
                                     f if f > -INF else 0.0) - arr
                    tr[pos + ks * ns] = np.maximum(lat, l1_lat)
            self.trace_chunks.append((fi_base + int(e_fi[e]), int(i0[e]), tr))
