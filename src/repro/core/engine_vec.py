"""Vectorized batch engine: the page-epoch model as array arithmetic.

Drop-in replacement for :class:`repro.core.engine.EpochEngine` selected via
``SimConfig.engine="vectorized"`` (DESIGN.md §12).  The event engine spends
its time in two places: materializing O(n^2) per-flow Python objects at
pod scale, and walking a Python loop over every (epoch, station) head of
large collectives.  This engine removes both:

* flow/epoch/head geometry — spacing, arrival times, page spans, station
  striping, ingress totals — is precomputed as numpy arrays
  (:func:`flows_from_specs` plus the span construction in
  :meth:`VecEngine.run_iteration`);
* only the inherently sequential part remains a Python loop: one
  :meth:`VecTranslationState.access` state-machine call per epoch head (the
  TLB hierarchy is stateful — each access's outcome depends on every prior
  access), reading pre-converted native scalars;
* all per-head tail expansion (hit-under-miss counts, latency sums, trace
  rows, completion) is deferred to vectorized postprocessing.

Two serving-scale optimizations sit on top (DESIGN.md §15):

* **Geometry memoization** — everything ``run_iteration`` derives from a
  :class:`FlowArrays` except the arrival *times* is invariant under the
  call's ``t_start``: page spans, the epoch sort order, head striping,
  ingress totals.  The first call caches it as a :class:`_Geom` on the
  ``FlowArrays``; later calls only re-add the new start time.  Arrival
  times enter the epoch sort, and float addition is monotone but not
  strictly so — the build records the *hazard* pairs (adjacent epochs
  whose relative order could collapse or separate under a different
  offset) and every reuse re-checks exactly those pairs, falling back to
  a full re-sort when one trips.  Bit-for-bit holds because every reused
  expression keeps the original operand order (``a0[fi] + i0*delta[fi]``
  becomes ``a0[fi] + cached_rel`` with identical operands).
* **Warm fast path** — when a call's every (station, page) head is
  L1-resident (an exact ``resident`` mirror set on :class:`_VLRU`) and no
  staged fill commits inside the call's time window, every ``access`` is a
  first-branch L1 hit that mutates nothing but LRU recency.  The per-head
  Python loop is then replaced by an all-hit vectorized expansion plus a
  batched recency update in last-occurrence order (the order an
  ``OrderedDict`` ends up in after the per-head ``move_to_end`` sequence) —
  bit-for-bit by construction.  Engagements are counted on
  ``VecEngine.fastpath_calls`` and surfaced through ``RunResult``.

Bit-for-bit equivalence with the event engine is a hard contract, enforced
by ``tests/test_engine_diff.py``.  It holds because every float expression
keeps the event engine's exact operand order (elementwise numpy float64 ops
are IEEE-identical to scalar Python), accumulations use ``np.cumsum`` (a
strict left fold, matching the scalar ``+=`` chain — the terms the event
engine skips contribute exact-zero no-ops), and the optimized LRU below
reproduces the original's lazy-commit order exactly.

:class:`VecTranslationState` is an operation-for-operation port of
:class:`repro.core.tlb.TranslationState` with two structural speedups that
provably preserve the observable sequence of cache operations:

* ``_VLRU`` commits staged fills from a min-heap ordered by
  ``(fill_time, staging_index)`` instead of re-scanning and stably sorting
  the staged dict on every lookup.  The original's order is fill-time with
  dict-insertion tie-break, and dict position is preserved when a fill is
  re-staged earlier — exactly the ``(fill_time, first_staging_index)``
  order the heap pops in (stale heap entries are skipped by generation
  check).
* ``l1_maybe``/``l2_maybe`` record every page ever fill-staged per cache
  since the last flush.  A page absent from the set cannot be resident, so
  its lookup is a guaranteed miss and is skipped entirely.  Deferring the
  skipped lookup's lazy commits is safe: commits are totally ordered by
  ``(fill_time, staging_index)`` and every *taken* lookup first commits all
  fills up to its own time, so the interleaving of commits, hits
  (recency updates) and evictions that the caches observe is unchanged.
"""
from __future__ import annotations

import heapq
import math
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from .config import SimConfig, TranslationConfig
from .patterns import StepArrays
from .tlb import CLASSES, Counters, INF, L1_HIT, L1_HUM, PTWPool
from .topology import get_topology

# Integer class codes for the hot path (postprocessing maps them back to
# the string keys of Counters.by_class).  Order matches tlb.CLASSES.
_L1_HIT, _L1_HUM, _L2_HIT, _L2_HUM, _WALK = range(5)


class _VLRU:
    """Set-associative lazy-commit LRU, heap-committed.

    Same observable semantics as :class:`repro.core.tlb.LRUCache` (see the
    module docstring for the order argument); O(log staged) per commit
    instead of an O(staged) scan-and-sort per lookup.

    ``resident`` mirrors the union of the set dicts' keys exactly (updated
    only where membership changes: commit-insert and evict).  It answers
    "would this lookup hit, given no commits fire first?" in O(1) without
    touching recency — the predicate the warm fast path batches over.
    """

    __slots__ = ("entries", "assoc", "n_sets", "_sets", "_staged", "_heap",
                 "_seq", "resident", "_mut")

    def __init__(self, entries: int, assoc: int,
                 mut: Optional[List[int]] = None):
        self.entries = entries
        self.assoc = assoc if assoc > 0 else entries
        self.n_sets = max(1, entries // self.assoc)
        self._sets = [OrderedDict() for _ in range(self.n_sets)]
        self._staged: Dict[object, Tuple[float, int]] = {}
        self._heap: List[Tuple[float, int, object]] = []
        self._seq = 0
        self.resident: set = set()
        # Shared mutation-epoch cell (one per owning state): bumped on
        # every staging and on every commit batch, i.e. whenever residency
        # or the heap can change.  Recency moves deliberately do NOT bump
        # it — they never change a fast-path verdict.
        self._mut = mut if mut is not None else [0]

    def _commit(self, t: float) -> None:
        h = self._heap
        if not (h and h[0][0] <= t):
            return
        self._mut[0] += 1
        staged = self._staged
        sets = self._sets
        n_sets = self.n_sets
        assoc = self.assoc
        resident = self.resident
        pop = heapq.heappop
        while h and h[0][0] <= t:
            ft, seq, k = pop(h)
            if staged.get(k) != (ft, seq):
                continue                   # superseded by an earlier re-fill
            del staged[k]
            s = sets[hash(k) % n_sets]
            if k in s:
                s.move_to_end(k)
            else:
                if len(s) >= assoc:
                    old, _ = s.popitem(last=False)  # LRU eviction
                    resident.discard(old)
                s[k] = ft
                resident.add(k)

    def lookup(self, key, t: float) -> bool:
        h = self._heap
        if h and h[0][0] <= t:
            self._commit(t)
        s = self._sets[hash(key) % self.n_sets]
        if key in s:
            s.move_to_end(key)
            return True
        return False

    def fill(self, key, fill_time: float) -> None:
        self._mut[0] += 1
        prev = self._staged.get(key)
        if prev is None:
            seq = self._seq
            self._seq = seq + 1
            self._staged[key] = (fill_time, seq)
            heapq.heappush(self._heap, (fill_time, seq, key))
        elif fill_time < prev[0]:
            # Earlier re-fill keeps the original staging index, exactly as
            # a dict value update keeps the key's position.
            self._staged[key] = (fill_time, prev[1])
            heapq.heappush(self._heap, (fill_time, prev[1], key))


class VecTranslationState:
    """Optimized port of :class:`repro.core.tlb.TranslationState`.

    Identical decision tree and float arithmetic; hot-path accesses return a
    plain ``(resolve, class_code, l1_fill)`` tuple instead of an
    ``AccessResult``.  Interface used by :class:`~repro.core.session.
    SimSession` (``counters``, ``flush``) is preserved.
    """

    def __init__(self, cfg: TranslationConfig, n_stations: int):
        self.cfg = cfg
        self.n_stations = n_stations
        self._l1_lat = cfg.l1.hit_latency_ns
        self._l2_lat = cfg.l2.hit_latency_ns
        # One mutation-epoch cell shared by every cache of this state: any
        # staging or commit anywhere bumps it, so an unchanged epoch proves
        # every L1's residency set *and* heap are exactly as last observed.
        self.mut: List[int] = [0]
        self.l1 = [_VLRU(cfg.l1.entries, cfg.l1.assoc, self.mut)
                   for _ in range(n_stations)]
        self.l2 = _VLRU(cfg.l2.entries, cfg.l2.assoc, self.mut)
        self.pwc = [_VLRU(e, cfg.pwc.assoc, self.mut)
                    for e in cfg.pwc.entries]
        self.ptw = PTWPool(cfg.n_ptw)
        self.l2_pending: Dict[int, float] = {}
        # MSHR fills keyed (station, page) in the original; split per
        # station here (same key space, no tuple hashing on the hot path).
        self.l1_pending: List[Dict[int, float]] = [
            {} for _ in range(n_stations)]
        self.counters = Counters()
        # Pages ever fill-staged per cache since the last flush: absence
        # proves a miss, so the lookup (and its deferred-safe lazy commit)
        # is skipped.
        self.l1_maybe = [set() for _ in range(n_stations)]
        self.l2_maybe: set = set()

    def flush(self) -> None:
        """Invalidate cached translations; keep counters and PTW occupancy
        (mirrors :meth:`repro.core.tlb.TranslationState.flush`)."""
        cfg = self.cfg
        self.mut[0] += 1
        self.l1 = [_VLRU(cfg.l1.entries, cfg.l1.assoc, self.mut)
                   for _ in range(self.n_stations)]
        self.l2 = _VLRU(cfg.l2.entries, cfg.l2.assoc, self.mut)
        self.pwc = [_VLRU(e, cfg.pwc.assoc, self.mut)
                    for e in cfg.pwc.entries]
        self.l2_pending.clear()
        self.l1_pending = [{} for _ in range(self.n_stations)]
        self.l1_maybe = [set() for _ in range(self.n_stations)]
        self.l2_maybe = set()

    def _walk_latency(self, page: int, t: float) -> float:
        c = self.cfg
        ctr = self.counters
        lat = 0.0
        addr = page * c.page_bytes
        for lvl, cache in enumerate(self.pwc):
            region = addr // c.pwc.coverage_bytes[lvl]
            lat += c.pwc.lookup_latency_ns
            if cache.lookup((lvl, region), t + lat):
                ctr.pwc_hits += 1
            else:
                ctr.pwc_misses += 1
                lat += c.mem_access_ns
                ctr.walk_mem_reads += 1
                cache.fill((lvl, region), t + lat)
        lat += c.mem_access_ns
        ctr.walk_mem_reads += 1
        return lat

    def access(self, station: int, page: int,
               t: float) -> Tuple[float, int, float]:
        """One translation request; callers gate on ``cfg.enabled``."""
        t1 = t + self._l1_lat
        maybe = self.l1_maybe[station]
        if page in maybe and self.l1[station].lookup(page, t1):
            return (t1, _L1_HIT, -INF)

        pending = self.l1_pending[station]
        pend = pending.get(page)
        if pend is not None:
            if pend <= t1:
                del pending[page]
                return (t1, _L1_HUM, pend)       # max(t1, pend) == t1
            return (pend, _L1_HUM, pend)         # max(t1, pend) == pend

        t2 = t1 + self._l2_lat
        if page in self.l2_maybe and self.l2.lookup(page, t2):
            self.l1[station].fill(page, t2)
            maybe.add(page)
            pending[page] = t2
            return (t2, _L2_HIT, t2)

        walk_done = self.l2_pending.get(page)
        if walk_done is not None:
            if walk_done > t2:
                self.l1[station].fill(page, walk_done)
                maybe.add(page)
                pending[page] = walk_done
                return (walk_done, _L2_HUM, walk_done)
            del self.l2_pending[page]

        start = self.ptw.start(t2)
        walk_lat = self._walk_latency(page, start)
        self.ptw.finish(start + walk_lat)
        done = start + walk_lat
        self.counters.walks += 1
        self.l2_pending[page] = done
        self.l2.fill(page, done)
        self.l2_maybe.add(page)
        self.l1[station].fill(page, done)
        maybe.add(page)
        pending[page] = done
        return (done, _WALK, done)


def _fp_structs(st_l: List[int], hpage_l: List[int]):
    """Warm-fast-path precomputation over the head sequence.

    Returns ``(stations, pairs)``:

    * ``stations`` — the distinct stations the call touches (page-free, so
      shifted clones share it);
    * ``pairs`` — the distinct (station, page) touches ordered by *last*
      occurrence in the head sequence.  Applying ``move_to_end`` in that
      order leaves each L1 set's ``OrderedDict`` in exactly the state the
      per-head loop's all-hit lookup sequence would (earlier touches of a
      re-touched page are overtaken by its last touch; distinct sets
      never interleave)."""
    seen = set()
    pairs: List[Tuple[int, int]] = []
    for sp in zip(reversed(st_l), reversed(hpage_l)):
        if sp not in seen:
            seen.add(sp)
            pairs.append(sp)
    pairs.reverse()
    stations = list({s: None for s, _ in pairs})
    return stations, pairs


def _qg_structs(st_l: List[int], hpage_l: List[int], H: int):
    """Group heads by (station, page) for the quiet-window path.

    Returns ``(h2g, gfirst, order_last, gst, sts)``: per-head group index,
    each group's first head index, group indices sorted by *last* head
    index (the batched-recency order), per-group station, and the distinct
    stations.  Everything here is invariant under a uniform page shift
    (groups are defined by equality, and translation preserves equality),
    so shifted clones share it; only the per-group page ids differ."""
    d: Dict[Tuple[int, int], int] = {}
    h2g = np.empty(H, dtype=np.int64)
    gfirst: List[int] = []
    glast: List[int] = []
    gst: List[int] = []
    i = 0
    for sp in zip(st_l, hpage_l):
        gi = d.get(sp)
        if gi is None:
            gi = len(gfirst)
            d[sp] = gi
            gfirst.append(i)
            glast.append(i)
            gst.append(sp[0])
        else:
            glast[gi] = i
        h2g[i] = gi
        i += 1
    order_last = np.argsort(np.asarray(glast, dtype=np.int64),
                            kind="stable")
    sts = list({s: None for s in gst})
    return (h2g, np.asarray(gfirst, dtype=np.int64), order_last, gst, sts)


class _Geom:
    """t_start-invariant geometry of one :class:`FlowArrays` (sorted order).

    Everything :meth:`VecEngine.run_iteration` derives from the flow set
    except the absolute arrival times: epoch spans in the event engine's
    sort order, head geometry, ingress totals, prefetch targets, and the
    warm-fast-path structures.  Times are reconstructed per call as
    ``a0[fi] + rel`` with the *same* operands the uncached expression used,
    so reuse is bit-for-bit.

    The cached sort order was produced under one ``t_start``.  Under
    another, IEEE float-add monotonicity guarantees relative arrival order
    can only change at the recorded ``hazards`` (uniform-latency flows) or
    where the per-call strictness check fails (``tie_ok``, mixed-latency
    flows); both trigger a rebuild at the new ``t_start``.
    """

    __slots__ = ("e_fi", "page", "i0", "i1", "e_rel", "tie_ok", "hazards",
                 "uniform", "ow_c", "E", "hcum", "H", "h_e", "h_is0",
                 "h_fi", "h_ns", "h_ns_m1", "h_ns_m1f", "h_rel",
                 "h_stride", "h_ret", "tail", "tail_all", "tail_prod",
                 "n_tot",
                 "totals_l", "st_l", "ns_l", "hpage_l", "h0_l", "h1_l",
                 "pf_cols", "rel_max", "rel_min", "no_bp", "sc_lists",
                 "fp_enabled", "fp_sts", "fp_pairs", "fp_s_hit",
                 "fp_hits", "fp_tail_add", "fp_scalars", "fp_src",
                 "qg", "qg2", "qg_pages", "fp_epoch", "fp_hmin", "fp_mutc",
                 "fp_chk")

    def shifted(self, dp: int) -> "_Geom":
        """This geometry translated by ``dp`` pages (a page-aligned
        ``base_addr`` shift).  Page spans, request indexing, arrival
        spacing and the sort order are invariant under a uniform page
        translation — only the page *ids* (and the structures keyed on
        them: prefetch targets, fast-path sets whose L1 set index is
        ``hash(page) % n_sets``) change."""
        g = _Geom.__new__(_Geom)
        g.e_fi = self.e_fi
        g.page = self.page + dp
        g.i0 = self.i0
        g.i1 = self.i1
        g.e_rel = self.e_rel
        g.tie_ok = self.tie_ok
        g.hazards = self.hazards
        g.uniform = self.uniform
        g.ow_c = self.ow_c
        g.E = self.E
        g.hcum = self.hcum
        g.H = self.H
        g.h_e = self.h_e
        g.h_is0 = self.h_is0
        g.h_fi = self.h_fi
        g.h_ns = self.h_ns
        g.h_ns_m1 = self.h_ns_m1
        g.h_ns_m1f = self.h_ns_m1f
        g.h_rel = self.h_rel
        g.h_stride = self.h_stride
        g.h_ret = self.h_ret
        g.tail = self.tail
        g.tail_all = self.tail_all
        g.tail_prod = self.tail_prod
        g.n_tot = self.n_tot
        g.totals_l = self.totals_l
        g.st_l = self.st_l
        g.ns_l = self.ns_l
        g.h0_l = self.h0_l
        g.h1_l = self.h1_l
        g.rel_max = self.rel_max
        g.rel_min = self.rel_min
        g.no_bp = self.no_bp
        g.sc_lists = self.sc_lists
        g.fp_enabled = self.fp_enabled
        g.fp_s_hit = self.fp_s_hit
        g.fp_hits = self.fp_hits
        g.fp_tail_add = self.fp_tail_add
        g.fp_scalars = self.fp_scalars
        g.fp_sts = self.fp_sts
        g.qg = self.qg
        g.qg2 = self.qg2
        # Page-keyed caches stay lazy on clones: the per-head page list is
        # rebuilt on demand (a numpy gather beats shifting the list), and
        # the fast-path pairs materialize on the clone's first fast-path
        # attempt — from the parent's pairs when it has built them (a
        # listcomp over the distinct touches), else from the head arrays.
        # Eagerly shifting here charged every clone for a structure most
        # prefill clones only ever decline against.
        g.hpage_l = None
        g.fp_pairs = None
        g.fp_src = (self, dp)
        g.qg_pages = None
        g.fp_epoch = -1
        g.fp_hmin = -INF
        g.fp_mutc = None
        g.fp_chk = None
        g.pf_cols = self.pf_cols
        if self.pf_cols:
            g.pf_cols = [(valid, stj, [p + dp for p in pj])
                         for (valid, stj, pj) in self.pf_cols]
        return g


@dataclass
class FlowArrays:
    """One step's flows at one target as parallel columns.

    Row ``i`` carries exactly the fields of the ``i``-th
    :class:`~repro.core.engine.Flow` that :func:`~repro.core.engine.
    flows_for_dst` would build (same order: spec order filtered to this
    target).  ``geom`` is the lazily built t_start-invariant
    :class:`_Geom` cache; sessions reuse ``FlowArrays`` across calls by
    re-assigning ``t_start`` only.
    """

    src: np.ndarray        # int64
    base_addr: np.ndarray  # int64, NPA region base + spec offset
    nbytes: np.ndarray     # int64, all > 0
    t_start: float
    delta: np.ndarray      # float64 inter-request spacing
    stripe: np.ndarray     # int64 station striping offset
    oneway: np.ndarray     # float64 request-path latency
    ret: np.ndarray        # float64 ack-path latency
    geom: Optional[_Geom] = field(default=None, repr=False, compare=False)

    def __len__(self) -> int:
        return len(self.src)


def flows_from_specs(step: StepArrays, cfg: SimConfig, dst: int,
                     t_start: float) -> Optional[FlowArrays]:
    """Vectorized :func:`repro.core.engine.flows_for_dst`.

    Bandwidth shares count *all* of the step's flows (zero-byte and
    other-target flows included), matching the event engine; only flows
    landing at ``dst`` with positive bytes are materialized.  Returns
    ``None`` for an empty flow set (the event path's ``[]``).
    """
    fab = cfg.fabric
    topo = get_topology(fab)
    sel = (step.dst == dst) & (step.nbytes > 0)
    if not sel.any():
        return None
    src = step.src[sel]
    nb = step.nbytes[sel]
    off = step.offset[sel]
    rb = fab.request_bytes
    delta = (rb * step.out_deg()[src]) / fab.gpu_bw
    if topo.flat:
        oneway = np.full(len(src), fab.oneway_ns)
        ret = np.full(len(src), fab.return_ns)
    else:
        # Per-(source, tier) degrees are a per-step aggregate over ALL
        # specs; cached on the StepArrays (steps are built per run, under
        # one fabric config, so the cache never crosses topologies).
        if step._tier_cache is None:
            tier_all = topo.tier_arr(step.src, step.dst)
            ntier = int(tier_all.max()) + 1 if len(tier_all) else 1
            step._tier_cache = (ntier,
                                np.bincount(step.src * ntier + tier_all))
        ntier, tdeg = step._tier_cache
        tier_sel = topo.tier_arr(src, dst)
        for tv in np.unique(tier_sel):
            cap = topo.tier_capacity(int(tv))
            if cap is None:
                continue
            m = tier_sel == tv
            shaped = (rb * tdeg[src[m] * ntier + tv]) / cap
            delta[m] = np.maximum(delta[m], shaped)
        oneway = topo.path_latency_arr(src, dst)
        ret = topo.return_latency_arr(dst, src)
    return FlowArrays(src=src, base_addr=((dst + 1) << 42) + off, nbytes=nb,
                      t_start=t_start, delta=delta,
                      stripe=src % fab.stations_per_gpu,
                      oneway=oneway, ret=ret)


def flows_from_specs_multi(step: StepArrays, cfg: SimConfig,
                           dsts: List[int],
                           t_start: float = 0.0) -> Dict[int,
                                                         Optional[FlowArrays]]:
    """Batched :func:`flows_from_specs` over every simulated target.

    One vectorized pass — bandwidth shares, tier shaping and per-path
    latencies are computed once over the union of all targets' flows and
    split per destination afterwards (row order within a destination is
    spec order, exactly as the per-dst build), so the per-target
    ``FlowArrays`` are element-for-element identical to ``len(dsts)``
    separate :func:`flows_from_specs` calls at a fraction of the passes.
    """
    fab = cfg.fabric
    topo = get_topology(fab)
    out: Dict[int, Optional[FlowArrays]] = {int(d): None for d in dsts}
    sel = (step.nbytes > 0) & np.isin(step.dst,
                                      np.asarray(list(dsts), dtype=np.int64))
    if not sel.any():
        return out
    src = step.src[sel]
    dstv = step.dst[sel]
    nb = step.nbytes[sel]
    off = step.offset[sel]
    rb = fab.request_bytes
    delta = (rb * step.out_deg()[src]) / fab.gpu_bw
    if topo.flat:
        oneway = np.full(len(src), fab.oneway_ns)
        ret = np.full(len(src), fab.return_ns)
    else:
        if step._tier_cache is None:
            tier_all = topo.tier_arr(step.src, step.dst)
            ntier = int(tier_all.max()) + 1 if len(tier_all) else 1
            step._tier_cache = (ntier,
                                np.bincount(step.src * ntier + tier_all))
        ntier, tdeg = step._tier_cache
        tier_sel = topo.tier_arr(src, dstv)
        for tv in np.unique(tier_sel):
            cap = topo.tier_capacity(int(tv))
            if cap is None:
                continue
            m = tier_sel == tv
            shaped = (rb * tdeg[src[m] * ntier + tv]) / cap
            delta[m] = np.maximum(delta[m], shaped)
        oneway = topo.path_latency_arr(src, dstv)
        ret = topo.return_latency_arr(dstv, src)
    stripe = src % fab.stations_per_gpu
    base = ((dstv + 1) << 42) + off
    for d in dsts:
        idx = np.flatnonzero(dstv == d)
        if len(idx):
            out[int(d)] = FlowArrays(
                src=src[idx], base_addr=base[idx], nbytes=nb[idx],
                t_start=t_start, delta=delta[idx], stripe=stripe[idx],
                oneway=oneway[idx], ret=ret[idx])
    return out


def rebase_flow_arrays(fa: FlowArrays, delta_addr: int,
                       page_bytes: int) -> FlowArrays:
    """Clone ``fa`` with ``base_addr`` shifted by ``delta_addr`` bytes.

    Integer address adds are exact, so the clone is what
    :func:`flows_from_specs` would have built at the shifted region.  When
    the shift is page-aligned the (expensive) cached geometry carries over
    via :meth:`_Geom.shifted`; otherwise it is rebuilt on first use.
    """
    out = FlowArrays(src=fa.src, base_addr=fa.base_addr + delta_addr,
                     nbytes=fa.nbytes, t_start=fa.t_start, delta=fa.delta,
                     stripe=fa.stripe, oneway=fa.oneway, ret=fa.ret)
    dp, rem = divmod(delta_addr, page_bytes)
    if rem == 0 and fa.geom is not None:
        out.geom = fa.geom.shifted(dp)
    return out


def request_counts(fa: FlowArrays, rb: int) -> List[int]:
    """Per-flow request counts (``max(1, ceil(nbytes / rb))``, exact)."""
    return np.maximum(1, np.ceil(fa.nbytes / rb).astype(np.int64)).tolist()


def run_step_group(engines: dict, grp: List[tuple], t: float,
                   first_step: bool) -> float:
    """Price one step's per-destination flow sets in a single invocation.

    Destinations are independent between step barriers — every target has
    its own stations, TLB state and counters — so the step completion is a
    pure max over per-destination completions and the destination fold can
    live here instead of in :meth:`SimSession.run`'s inner loop.  The
    group call is the serving hot path: it skips the per-destination trace
    bookkeeping (the caller keeps the explicit loop for the one traced
    call per session) and amortizes the loop overhead of thousands of
    decode steps.
    """
    comp = t
    for d, fa in grp:
        fa.t_start = t
        c = engines[d].run_iteration(fa, False, first_step=first_step)
        if c > comp:
            comp = c
    return comp


class VecEngine:
    """Vectorized twin of :class:`repro.core.engine.EpochEngine`.

    Same construction signature and the same surface
    :class:`~repro.core.session.SimSession` drives (``state``,
    ``stall_sum``/``stall_n``, ``trace_chunks``, ``run_iteration``), but
    ``run_iteration`` consumes a :class:`FlowArrays` instead of a
    ``List[Flow]``.  ``fastpath_calls`` counts ``run_iteration`` calls the
    warm fast path fully served (DESIGN.md §15.2).
    """

    def __init__(self, cfg: SimConfig, dst: int = 0):
        self.cfg = cfg
        self.dst = dst
        fab = cfg.fabric
        self.state = VecTranslationState(cfg.translation,
                                         fab.stations_per_gpu)
        self.page_bytes = cfg.translation.page_bytes
        self.svc = fab.request_bytes / fab.station_bw
        self.buffer_cover = fab.ingress_entries * self.svc
        self.trace_chunks: List[Tuple[int, int, np.ndarray]] = []
        self.stall_sum = 0.0
        self.stall_n = 0
        self.fastpath_calls = 0
        # Per-call prologue constants (configs are frozen dataclasses, so
        # hoisting the attribute chains out of run_iteration is safe).
        self._fab = fab
        self._ns = fab.stations_per_gpu
        self._enabled = cfg.translation.enabled
        self._l1lat = (cfg.translation.l1.hit_latency_ns
                       if self._enabled else 0.0)
        self._pre_en = cfg.pretranslation.enabled and self._enabled

    # -- optimizations -------------------------------------------------------
    def _pretranslate(self, fa: FlowArrays) -> None:
        """Vectorized probe construction; sequential replay in issue order
        (same (t, station, page) stream as ``pretranslate_probes``)."""
        pre = self.cfg.pretranslation
        fab = self.cfg.fabric
        ns = fab.stations_per_gpu
        rb = fab.request_bytes
        pb = self.page_bytes
        base = fa.base_addr
        first_page = base // pb
        n_pages = (base + fa.nbytes - 1) // pb - first_page + 1
        ppf = pre.pages_per_flow
        limit = n_pages if ppf <= 0 else np.minimum(n_pages, ppf)
        total = int(limit.sum())
        if not total:
            return
        pf = np.repeat(np.arange(len(fa)), limit)
        cum = np.concatenate(([0], np.cumsum(limit)))
        j = np.arange(total) - cum[:-1][pf]
        pg = first_page[pf] + j
        b = base[pf]
        st = ((np.maximum(b, pg * pb) - b) // rb + fa.stripe[pf]) % ns
        t0 = fa.t_start - pre.lead_time_ns
        times = t0 + np.arange(total) * pre.probe_issue_interval_ns
        access = self.state.access
        for s, p, t in zip(st.tolist(), pg.tolist(), times.tolist()):
            access(s, p, t)
        self.state.counters.probes += total

    # -- geometry cache ------------------------------------------------------
    def _build_geom(self, fa: FlowArrays) -> _Geom:
        """Build the t_start-invariant :class:`_Geom` of ``fa``.

        The epoch sort uses the *current* ``fa.t_start`` (the cached order
        is exact for it by construction); reuses under other start times
        validate against ``hazards``/``tie_ok`` first.
        """
        cfg = self.cfg
        fab = cfg.fabric
        rb = fab.request_bytes
        ns = fab.stations_per_gpu
        pb = self.page_bytes
        base = fa.base_addr
        nb = fa.nbytes
        delta = fa.delta
        stripe = fa.stripe
        n_req = np.maximum(1, np.ceil(nb / rb).astype(np.int64))
        a0 = fa.t_start + fa.oneway

        # ---- epoch spans: vectorized epoch_spans(), same sort order ------
        first_page = base // pb
        last_page = (base + nb - 1) // pb
        npages = last_page - first_page + 1
        cum = np.concatenate(([0], np.cumsum(npages)))
        e_fi = np.repeat(np.arange(len(fa)), npages)
        page = first_page[e_fi] + (np.arange(int(cum[-1])) - cum[:-1][e_fi])
        b_f = base[e_fi]
        lo = np.maximum(b_f, page * pb)
        hi = np.minimum(b_f + nb[e_fi], (page + 1) * pb)
        i0 = (lo - b_f) // rb
        i1 = np.minimum(n_req[e_fi],
                        np.ceil((hi - b_f) / rb).astype(np.int64))
        keep = i1 > i0
        e_fi, page, i0, i1 = e_fi[keep], page[keep], i0[keep], i1[keep]
        e_rel = i0 * delta[e_fi]
        t_first = a0[e_fi] + e_rel
        # Tuple sort (t_first, fi, page): (fi, page) pairs are unique, so
        # the lexsort total order equals the event engine's list.sort().
        order = np.lexsort((page, e_fi, t_first))
        e_fi, page, i0, i1, e_rel = (
            e_fi[order], page[order], i0[order], i1[order], e_rel[order])
        E = len(e_fi)

        g = _Geom()
        g.e_fi, g.page, g.i0, g.i1, g.e_rel = e_fi, page, i0, i1, e_rel
        g.E = E

        # ---- order-stability metadata ------------------------------------
        ow = fa.oneway
        g.uniform = bool((ow == ow[0]).all()) if len(ow) else True
        g.ow_c = float(ow[0]) if len(ow) else 0.0
        g.tie_ok = None
        g.hazards = []
        if E > 1:
            tie_lt = ((e_fi[:-1] < e_fi[1:]) |
                      ((e_fi[:-1] == e_fi[1:]) & (page[:-1] < page[1:])))
            if g.uniform:
                # With one shared path latency, arrival order tracks the
                # relative offsets: a pair can only misorder where strict
                # offsets collapse to a float tie against the tiebreak
                # (rel< but key>) or a build-time tie separates (rel>).
                bad = (((e_rel[:-1] < e_rel[1:]) & ~tie_lt)
                       | (e_rel[:-1] > e_rel[1:]))
                g.hazards = [(float(e_rel[i]), float(e_rel[i + 1]),
                              bool(tie_lt[i]))
                             for i in np.flatnonzero(bad)]
            else:
                g.tie_ok = tie_lt

        # ---- heads: per-(epoch, station) sub-series geometry -------------
        e_nh = np.minimum(ns, i1 - i0)
        hcum = np.concatenate(([0], np.cumsum(e_nh)))
        H = int(hcum[-1])
        h_e = np.repeat(np.arange(E), e_nh)
        h_is0 = i0[h_e] + (np.arange(H) - hcum[:-1][h_e])
        h_fi = e_fi[h_e]
        g.hcum, g.H = hcum, H
        g.h_e = h_e
        g.h_is0, g.h_fi = h_is0, h_fi
        h_st = (h_is0 + stripe[h_fi]) % ns
        g.h_ns = (i1[h_e] - h_is0 + ns - 1) // ns
        g.h_ns_m1 = g.h_ns - 1
        g.h_ns_m1f = g.h_ns_m1.astype(np.float64)
        g.h_rel = h_is0 * delta[h_fi]
        g.h_stride = ns * delta[h_fi]
        g.h_ret = fa.ret[h_fi]
        g.tail = g.h_ns > 1
        g.tail_all = bool(g.tail.all())
        g.tail_prod = g.h_ns_m1 * g.h_stride
        g.n_tot = int(g.h_ns.sum())
        g.st_l = h_st.tolist()
        g.ns_l = g.h_ns.tolist()
        g.hpage_l = page[h_e].tolist()
        g.h0_l = hcum[:-1].tolist()
        g.h1_l = hcum[1:].tolist()

        # ---- per-station ingress totals ----------------------------------
        totals = np.zeros(ns, dtype=np.int64)
        bq, extra = np.divmod(n_req, ns)
        soff = np.arange(ns)
        np.add.at(totals, (soff[None, :] + stripe[:, None]) % ns,
                  bq[:, None] + (soff[None, :] < extra[:, None]))
        g.totals_l = totals.tolist()

        # ---- prefetch probe targets (paper §6.2), per epoch --------------
        g.pf_cols = []
        if cfg.prefetch.enabled:
            b_e = base[e_fi]
            lp_e = last_page[e_fi]
            stripe_e = stripe[e_fi]
            for j in range(1, cfg.prefetch.depth + 1):
                pj = page + j
                valid = pj <= lp_e
                st_j = ((np.maximum(b_e, pj * pb) - b_e) // rb
                        + stripe_e) % ns
                g.pf_cols.append((valid.tolist(), st_j.tolist(),
                                  pj.tolist()))

        # ---- warm-fast-path structures -----------------------------------
        # Page-keyed parts (station_pages/pairs) and the scalar-loop lists
        # are built lazily on first fast-path attempt; everything here is
        # page-free, so page-shifted clones share it by reference.
        g.rel_max = float(g.h_rel.max()) if H else 0.0
        g.rel_min = float(g.h_rel.min()) if H else 0.0
        # With every station's ingress total below the buffer depth, the
        # backpressure predicate (totals - consumed >= ingress) can never
        # fire: skew stays exactly 0.0 and the skew/consumed bookkeeping
        # is droppable wholesale (t0b + 0.0 == t0b for the nonnegative
        # arrival times flows produce).
        g.no_bp = all(t < fab.ingress_entries for t in g.totals_l)
        g.sc_lists = None
        g.fp_sts = None
        g.fp_pairs = None
        g.fp_src = None
        g.qg = None
        g.qg2 = None
        g.qg_pages = None
        g.fp_scalars = None
        g.fp_s_hit = 0
        g.fp_hits = None
        g.fp_tail_add = None
        g.fp_epoch = -1
        g.fp_hmin = -INF
        g.fp_mutc = None
        g.fp_chk = None
        g.fp_enabled = bool(cfg.translation.enabled and not g.pf_cols)
        if g.fp_enabled:
            l1_lat = cfg.translation.l1.hit_latency_ns
            g.fp_s_hit = int(g.h_ns_m1.sum())
            g.fp_hits = g.h_ns_m1 * l1_lat
            g.fp_tail_add = np.where(g.tail, g.tail_prod, 0.0)
        return g

    # -- core ----------------------------------------------------------------
    def run_iteration(self, fa: FlowArrays, collect_trace: bool,
                      fi_base: int = 0, first_step: bool = True) -> float:
        """Price one step's flow set; returns absolute completion time.

        Semantics identical to ``EpochEngine.run_iteration``: translation
        state persists across calls, per-station ingress bookkeeping
        resets, pre-translation probes fire only on ``first_step``.
        """
        fab = self._fab
        ns = self._ns
        enabled = self._enabled
        l1_lat = self._l1lat
        ctr = self.state.counters

        if first_step and self._pre_en and len(fa):
            self._pretranslate(fa)

        # Uniform-latency geometries defer materializing the h_t0b array
        # (h_t0b is None, k0 set): the scalar fast path never needs it, and
        # every consumer below reconstructs it as ``g.h_rel + k0`` — the
        # same expression, so laziness is observationally free.
        g = fa.geom
        if g is None:
            g = fa.geom = self._build_geom(fa)
            t_first = None
            if g.uniform:
                k0 = fa.t_start + g.ow_c
                h_t0b = None
            else:
                a0 = fa.t_start + fa.oneway
                t_first = a0[g.e_fi] + g.e_rel
                h_t0b = a0[g.h_fi] + g.h_rel
        elif g.uniform:
            # a0 is one shared value; the sort key is a monotone function
            # of the cached rel offsets, so only the recorded hazard pairs
            # can invalidate the cached order at this start time.
            k0 = fa.t_start + g.ow_c
            for r0, r1, tok in g.hazards:
                x0 = k0 + r0
                x1 = k0 + r1
                if not (x0 < x1 or (x0 == x1 and tok)):
                    g = fa.geom = self._build_geom(fa)
                    k0 = fa.t_start + g.ow_c
                    break
            t_first = None
            h_t0b = None
        else:
            a0 = fa.t_start + fa.oneway
            t_first = a0[g.e_fi] + g.e_rel
            if g.E > 1:
                d = np.diff(t_first)
                if not bool(np.all((d > 0) | ((d == 0) & g.tie_ok))):
                    g = fa.geom = self._build_geom(fa)
                    t_first = a0[g.e_fi] + g.e_rel
            h_t0b = a0[g.h_fi] + g.h_rel
        H = g.H

        if not enabled:
            if h_t0b is None:
                h_t0b = g.h_rel + k0
            # Ideal translation: every request resolves instantly; no
            # sequential state at all.  resolve == t0, rat == 0, no stalls.
            ctr.requests += g.n_tot
            ctr.by_class[L1_HIT] += g.n_tot
            tail = g.tail
            last = h_t0b.copy()
            last[tail] = np.maximum(
                last[tail], h_t0b[tail] + g.tail_prod[tail] + l1_lat)
            completion = (float((last + fab.hbm_ns + g.h_ret).max())
                          if H else 0.0)
            if completion < 0.0:
                completion = 0.0
            if collect_trace:
                self._write_trace(fi_base, g.e_fi, g.i0, g.i1, g.hcum,
                                  g.h_is0, g.h_ns, h_t0b, np.zeros(H),
                                  np.full(H, -INF), g.h_stride, ns, l1_lat)
            return completion

        # ---- warm fast path (DESIGN.md §15.2) ----------------------------
        # Every head is a first-branch L1 hit iff (a) no staged fill
        # commits at or before any head's lookup time and (b) every
        # (station, page) the call touches is resident.  Then the access
        # loop's only state change is LRU recency, applied batched below;
        # outputs are the all-hit expansion with zero skew and no stalls.
        if g.fp_enabled and H and not collect_trace:
            # max/min of h_t0b without the array: addition is commutative
            # and fl(k0 + rel) is monotone in rel, achieved at the argmax,
            # so fl(k0 + rel_max) IS max_i fl(k0 + rel_i) (same for min).
            if h_t0b is None:
                t1_max = (k0 + g.rel_max) + l1_lat
            else:
                t1_max = float(h_t0b.max()) + l1_lat
            l1s = self.state.l1
            mut_c = self.state.mut
            if (g.fp_mutc is mut_c and g.fp_epoch == mut_c[0]
                    and t1_max < g.fp_hmin):
                # Epoch skip: no staging and no commit happened anywhere in
                # this state since the last full check, so every L1's
                # resident set and heap are exactly as observed then — the
                # same pages are still resident and the (unchanged)
                # earliest staged commit still lies beyond this window.
                # Recency moves don't bump the epoch; they can't change
                # either fact.  Verdict carries over without the loops.
                rows = g.fp_chk[1]
                ok = True
            else:
                pairs = g.fp_pairs
                if pairs is None:
                    src = g.fp_src
                    if src is not None and src[0].fp_pairs is not None:
                        parent, dp = src
                        g.fp_sts = parent.fp_sts
                        pairs = [(s, p + dp) for s, p in parent.fp_pairs]
                    else:
                        hpage_l = g.hpage_l
                        if hpage_l is None:
                            hpage_l = g.hpage_l = g.page[g.h_e].tolist()
                        g.fp_sts, pairs = _fp_structs(g.st_l, hpage_l)
                    g.fp_pairs = pairs
                # Pre-resolved probe rows (cache, heap, resident set,
                # L1 set dict, page) per distinct touch, keyed on the
                # state's l1 list identity: a flush replaces that list, and
                # heaps / resident sets / set dicts are mutated in place,
                # never swapped, for a given _VLRU.
                chk = g.fp_chk
                if chk is None or chk[0] is not l1s:
                    rows = [(c, c._heap, c.resident,
                             c._sets[hash(p) % c.n_sets], p)
                            for s, p in pairs for c in (l1s[s],)]
                    g.fp_chk = (l1s, rows)
                else:
                    rows = chk[1]
                t1_min = None
                hmin = INF
                ok = True
                for c, hp, res_set, sd, p in rows:
                    if hp and hp[0][0] <= t1_max:
                        # Staged fills commit inside the window.  Those due
                        # before the *earliest* lookup can be committed now:
                        # the first access at this station commits exactly
                        # them, in the same heap order, before its own
                        # lookup — and no hit can touch this station's
                        # recency before that access.  So the drain is
                        # unobservable even if the fast path is then
                        # declined.
                        if t1_min is None:
                            t1_min = ((k0 + g.rel_min) + l1_lat
                                      if h_t0b is None
                                      else float(h_t0b.min()) + l1_lat)
                        if hp[0][0] <= t1_min:
                            c._commit(t1_min)
                        if hp and hp[0][0] <= t1_max:
                            ok = False
                            break
                    if p not in res_set:
                        ok = False
                        break
                    if hp and hp[0][0] < hmin:
                        hmin = hp[0][0]
                if ok:
                    g.fp_mutc = mut_c
                    g.fp_epoch = mut_c[0]
                    g.fp_hmin = hmin
            if ok:
                self.fastpath_calls += 1
                for row in rows:
                    row[3].move_to_end(row[4])
                n_all = H + g.fp_s_hit
                ctr.requests += n_all
                ctr.by_class[L1_HIT] += n_all
                if h_t0b is None and H <= 64:
                    # Scalar body for small uniform calls: the per-head
                    # expressions below are the numpy branch's, one float
                    # at a time with identical operand order, so the two
                    # bodies are interchangeable bit-for-bit.
                    sc = g.fp_scalars
                    if sc is None:
                        sc = g.fp_scalars = (
                            g.h_rel.tolist(), g.fp_hits.tolist(),
                            g.fp_tail_add.tolist(), g.h_ret.tolist())
                    rel_l, hits_l, tl_l, ret_l = sc
                    run = ctr.rat_ns_sum
                    m = -INF
                    comp = -INF
                    hbm = fab.hbm_ns
                    for i in range(H):
                        t0b = k0 + rel_l[i]
                        rat0 = (t0b + l1_lat) - t0b
                        run = run + rat0
                        run = run + hits_l[i]
                        if rat0 > m:
                            m = rat0
                        cand = (((t0b + tl_l[i]) + l1_lat) + hbm) + ret_l[i]
                        if cand > comp:
                            comp = cand
                    ctr.rat_ns_sum = run
                    if m > ctr.rat_ns_max:
                        ctr.rat_ns_max = m
                    if comp < 0.0:
                        comp = 0.0
                    return comp
                if h_t0b is None:
                    h_t0b = g.h_rel + k0
                res = h_t0b + l1_lat
                rat0 = res - h_t0b
                # Same left fold as the slow path with the exact-zero
                # hit-under-miss terms dropped (x + 0.0 == x).
                contrib = np.empty(2 * H + 1)
                contrib[0] = ctr.rat_ns_sum
                contrib[1::2] = rat0
                contrib[2::2] = g.fp_hits
                ctr.rat_ns_sum = float(np.cumsum(contrib)[-1])
                m = float(rat0.max())
                if m > ctr.rat_ns_max:
                    ctr.rat_ns_max = m
                # last = max(res, t0 + tail_prod + l1) elementwise; the
                # tail term dominates wherever it exists (tail_prod >= 0
                # and float add is monotone), and adding exact 0.0 on
                # non-tail heads reproduces res, so one fused expression
                # equals the slow path's masked maximum.
                last = (h_t0b + g.fp_tail_add) + l1_lat
                completion = float((last + fab.hbm_ns + g.h_ret).max())
                if completion < 0.0:
                    completion = 0.0
                return completion

        pf_cols = g.pf_cols
        if pf_cols and t_first is None:
            t_first = g.e_rel + k0
        if h_t0b is None:
            h_t0b = g.h_rel + k0

        # ---- sequential core: one state-machine access per head ----------
        access = self.state.access
        st_l = g.st_l
        t0b_l = h_t0b.tolist()
        ns_l = g.ns_l
        hpage_l = g.hpage_l
        if hpage_l is None:
            hpage_l = g.hpage_l = g.page[g.h_e].tolist()
        state = self.state
        maybe_l = state.l1_maybe
        pend_l = state.l1_pending
        l1s = state.l1
        neg_inf = -INF

        if g.no_bp and not pf_cols and H and H <= 160 and not collect_trace:
            # ---- fused scalar slow path (no-backpressure, small H) -------
            # Same access sequence and the same per-head tail-expansion
            # expressions as the vectorized block below, evaluated one
            # float at a time in head order — interchangeable bit-for-bit.
            # skew/consumed bookkeeping is dropped (see _Geom.no_bp).
            sc = g.sc_lists
            if sc is None:
                sc = g.sc_lists = (g.h_ns_m1.tolist(),
                                   g.h_stride.tolist(), g.h_ret.tolist())
            m1_l, stride_l, ret_l = sc
            ceil = math.ceil
            hbm = fab.hbm_ns
            run = ctr.rat_ns_sum
            rmax = neg_inf
            hmax = neg_inf
            comp = neg_inf
            s_hum = 0
            s_hit = 0
            k5 = [0, 0, 0, 0, 0]
            # Same repeat memo as the large no-backpressure loop below —
            # see the comment there for the safe-window argument.
            od = OrderedDict
            safe_l: List[Optional[float]] = [None] * ns
            memo_l: List[Optional[dict]] = [None] * ns
            for s, pg, t0b, m1, stride, ret in zip(
                    st_l, hpage_l, t0b_l, m1_l, stride_l, ret_l):
                t1 = t0b + l1_lat
                su = safe_l[s]
                if su is None:
                    hp = l1s[s]._heap
                    su = safe_l[s] = hp[0][0] if hp else INF
                    memo = memo_l[s] = {}
                else:
                    memo = memo_l[s]
                kls = -1
                if t1 < su:
                    v = memo.get(pg)
                    if v is not None:
                        if v.__class__ is od:
                            v.move_to_end(pg)
                            resolve = t1
                            kls = 0
                            fill = neg_inf
                        elif t1 < v:
                            resolve = v
                            fill = v
                            kls = 1
                if kls < 0:
                    cl = l1s[s]
                    if pg in maybe_l[s]:
                        hp = cl._heap
                        if hp and hp[0][0] <= t1:
                            cl._commit(t1)
                        sd = cl._sets[hash(pg) % cl.n_sets]
                        if pg in sd:
                            sd.move_to_end(pg)
                            resolve = t1
                            kls = 0
                            fill = neg_inf
                    if kls < 0:
                        pending = pend_l[s]
                        pend = pending.get(pg)
                        if pend is not None:
                            kls = 1
                            fill = pend
                            if pend <= t1:
                                del pending[pg]
                                resolve = t1
                            else:
                                resolve = pend
                        else:
                            resolve, kls, fill = access(s, pg, t0b)
                    hp = cl._heap
                    safe_l[s] = hp[0][0] if hp else INF
                    if t1 >= su:
                        memo.clear()
                    if kls == 0:
                        memo[pg] = sd
                    else:
                        memo[pg] = (resolve
                                    if kls == 1 and resolve != t1 else 0.0)
                k5[kls] += 1
                rat0 = resolve - t0b
                run = run + rat0
                if rat0 > rmax:
                    rmax = rat0
                last = resolve
                if m1 > 0:
                    k = 0
                    if fill > neg_inf:
                        kf = ceil(((fill - l1_lat) - t0b) / stride) - 1.0
                        m1f = float(m1)
                        if kf > m1f:
                            kf = m1f
                        if kf < 0.0:
                            kf = 0.0
                        k = int(kf)
                        if k > 0:
                            run = run + (k * (fill - t0b)
                                         - stride * k * (k + 1) / 2)
                            hc = fill - (t0b + stride)
                            if hc > hmax:
                                hmax = hc
                            if fill > last:
                                last = fill
                            s_hum += k
                    nh = m1 - k
                    if nh > 0:
                        run = run + nh * l1_lat
                        cand = (t0b + m1 * stride) + l1_lat
                        if cand > last:
                            last = cand
                        s_hit += nh
                c2 = (last + hbm) + ret
                if c2 > comp:
                    comp = c2
            ctr.requests += H + s_hum + s_hit
            by = ctr.by_class
            for idx, name in enumerate(CLASSES):
                if k5[idx]:
                    by[name] += k5[idx]
            by[L1_HUM] += s_hum
            by[L1_HIT] += s_hit
            ctr.rat_ns_sum = run
            m = ctr.rat_ns_max
            if rmax > m:
                m = rmax
            if hmax > m:
                m = hmax
            ctr.rat_ns_max = m
            if comp < 0.0:
                comp = 0.0
            return comp

        # ---- quiet-window grouped path (DESIGN.md §15.3) -----------------
        # Large no-backpressure calls where every *station's* lookup
        # window is narrower than the L2 hit latency: any fill staged on a
        # station *during* the call lands at least one L2 latency past the
        # staging access's lookup, i.e. strictly after every lookup at
        # that station — so at stations whose heaps are also quiet past
        # their window, no commit can fire for the whole call and
        # residency/MSHR state are frozen.  Each (station, page) group's
        # outcome then follows from its start-of-call state: resident
        # groups are all-hit, pending-past-the-window groups all
        # hit-under-miss, and a cold group resolves to whatever fill its
        # first head stages (always past the window, hence still pending
        # when read back).  Only those first heads — plus every head at a
        # non-quiet station or of a stale-pending group — run the
        # sequential machinery, in head order, preserving the exact
        # L2/PTW/commit interleaving the event engine sees.
        if g.no_bp and not pf_cols and H and not collect_trace:
            l2_lat = state._l2_lat
            # Per-station lookup windows.  The quiet argument is local to
            # a station: L1 residency/MSHR state is per station, and a
            # fill staged during the call lands at least one L2 hit
            # latency past the *staging* access's lookup — which is at or
            # after that station's first lookup.  So it suffices that each
            # station's own window is narrower than the L2 latency (the
            # old whole-call check is the degenerate one-window case);
            # large calls whose heads interleave many stations pass even
            # when the call-wide span is far wider.
            q2 = g.qg2
            if q2 is None:
                st_arr = np.asarray(st_l, dtype=np.int64)
                so = np.argsort(st_arr, kind="stable")
                sst = st_arr[so]
                starts = np.flatnonzero(np.diff(sst, prepend=-1) != 0)
                q2 = g.qg2 = (so, starts, sst[starts].tolist())
            so, starts, sts_l = q2
            hb = h_t0b[so]
            # min/max commute with the monotone ``+ l1_lat``, so these are
            # exactly the per-station min/max over the per-head t1 values.
            t1f = np.minimum.reduceat(hb, starts) + l1_lat
            t1l = np.maximum.reduceat(hb, starts) + l1_lat
            if bool((t1f + l2_lat > t1l).all()):
                win = dict(zip(sts_l, zip(t1f.tolist(), t1l.tolist())))
                qg = g.qg
                if qg is None:
                    qg = g.qg = _qg_structs(st_l, hpage_l, H)
                h2g, gfirst, order_last, gst, qsts = qg
                gp = g.qg_pages
                if gp is None:
                    gp = g.qg_pages = g.page[g.h_e[gfirst]].tolist()
                quiet = {}
                for s in qsts:
                    tf_s, tl_s = win[s]
                    c = l1s[s]
                    hp = c._heap
                    q = True
                    if hp and hp[0][0] <= tl_s:
                        # Same unobservable pre-commit drain as the warm
                        # fast path: the first access at this station
                        # commits at least this much, in heap order,
                        # before anything can observe the station.
                        if hp[0][0] <= tf_s:
                            c._commit(tf_s)
                        q = not (hp and hp[0][0] <= tl_s)
                    quiet[s] = q
                U = len(gst)
                gcls_l = [0] * U
                gF = [0.0] * U
                for gi in range(U):
                    s = gst[gi]
                    if not quiet[s]:
                        gcls_l[gi] = 3
                        continue
                    pg = gp[gi]
                    if pg in l1s[s].resident:
                        # Resident implies maybe-listed on every fill
                        # path; the guard keeps the corner exact anyway.
                        if pg not in maybe_l[s]:
                            gcls_l[gi] = 3
                        continue
                    pend = pend_l[s].get(pg)
                    if pend is None:
                        gcls_l[gi] = 2
                    elif pend > win[s][1]:
                        gcls_l[gi] = 1
                        gF[gi] = pend
                    else:
                        gcls_l[gi] = 3
                gcls = np.asarray(gcls_l, dtype=np.int64)
                hc = gcls[h2g]
                # All-hit default columns: only class-0 heads keep them —
                # classes 1/2 are overwritten batched below, class 3 and
                # cold leaders by the sequential loop.  (h_t0b + l1_lat)
                # masked afterwards equals the old masked elementwise add.
                res_a = h_t0b + l1_lat
                fill_a = np.full(H, neg_inf)
                cls_a = np.where(hc == 2, 1, hc)
                p1 = hc == 3
                lead = gfirst[gcls == 2]
                if len(lead):
                    p1[lead] = True
                for i in np.flatnonzero(p1).tolist():
                    s = st_l[i]
                    pg = hpage_l[i]
                    t0b = t0b_l[i]
                    t1 = t0b + l1_lat
                    kls = -1
                    if pg in maybe_l[s]:
                        c = l1s[s]
                        hp = c._heap
                        if hp and hp[0][0] <= t1:
                            c._commit(t1)
                        sd = c._sets[hash(pg) % c.n_sets]
                        if pg in sd:
                            sd.move_to_end(pg)
                            resolve = t1
                            kls = 0
                            fill = neg_inf
                    if kls < 0:
                        pending = pend_l[s]
                        pend = pending.get(pg)
                        if pend is not None:
                            kls = 1
                            fill = pend
                            if pend <= t1:
                                del pending[pg]
                                resolve = t1
                            else:
                                resolve = pend
                        else:
                            resolve, kls, fill = access(s, pg, t0b)
                    res_a[i] = resolve
                    fill_a[i] = fill
                    cls_a[i] = kls
                need = (hc == 1) | ((hc == 2) & ~p1)
                if need.any():
                    # A cold group's leader staged its fill past the
                    # window, so it is still pending here; every remaining
                    # head is a hit-under-miss on it.
                    for gi in np.flatnonzero(gcls == 2).tolist():
                        gF[gi] = pend_l[gst[gi]][gp[gi]]
                    hF = np.asarray(gF)[h2g]
                    res_a[need] = hF[need]
                    fill_a[need] = hF[need]
                # Batched recency: one move per resident group in
                # last-touch order reproduces the loop's net effect — at
                # quiet stations only resident groups' heads touch
                # recency, and no commit interleaves with them.
                for gi in order_last.tolist():
                    if gcls_l[gi] == 0:
                        c = l1s[gst[gi]]
                        pg = gp[gi]
                        c._sets[hash(pg) % c.n_sets].move_to_end(pg)
                kcnt = np.bincount(cls_a, minlength=5)
                return self._finish(g, ctr, res_a, fill_a, h_t0b, kcnt,
                                    l1_lat, fab, False, fi_base, ns)

        skew = [0.0] * ns
        release = [-INF] * ns
        consumed = [0] * ns
        totals_l = g.totals_l
        ingress = fab.ingress_entries
        cover = self.buffer_cover
        stall_sum = self.stall_sum
        stall_n = self.stall_n
        # Heads run strictly in flat order (epoch-sorted, station sub-order
        # inside each epoch), so per-head outputs are append-only.
        res_l: List[float] = []
        fill_l: List[float] = []
        t0_l: List[float] = []
        kc = [0, 0, 0, 0, 0]          # per-class head counts, CLASSES order
        res_app, fill_app = res_l.append, fill_l.append
        t0_app = t0_l.append
        t0_arr = None
        probes = 0
        if pf_cols:
            # Epoch-structured walk: each epoch's prefetch probes fire at
            # its first arrival, before its heads.
            h0_l = g.h0_l
            h1_l = g.h1_l
            tf_l = t_first.tolist()
            for e in range(g.E):
                tf = tf_l[e]
                for (valid, stj, pj) in pf_cols:
                    if valid[e]:
                        access(stj[e], pj[e], tf)
                        probes += 1
                for h in range(h0_l[e], h1_l[e]):
                    s = st_l[h]
                    t0 = t0b_l[h] + skew[s]
                    resolve, kls, fill = access(s, hpage_l[h], t0)
                    res_app(resolve)
                    fill_app(fill)
                    t0_app(t0)
                    kc[kls] += 1
                    # Ingress-buffer backpressure (same predicate
                    # expressions as the event engine, term for term).
                    if (resolve - (t0 + l1_lat) > 0
                            and totals_l[s] - consumed[s] >= ingress):
                        block_from = t0 + cover
                        r = release[s]
                        if r > block_from:
                            block_from = r
                        if resolve > block_from:
                            bubble = resolve - block_from
                            skew[s] += bubble
                            release[s] = resolve
                            stall_sum += bubble
                            stall_n += 1
                    consumed[s] += ns_l[h]
        elif g.no_bp:
            # No-backpressure loop: skew provably stays 0.0, so t0 is the
            # precomputed t0b array and the predicate/consumed bookkeeping
            # drops out.  Access branches inlined as in the general loop.
            #
            # Repeat memo: while a head's lookup time stays below the
            # station's next staged-commit time (``safe`` tracks the heap
            # top as of the station's last slow head), no commit can have
            # changed residency in between, so a repeat of an earlier
            # head's (station, page) resolves identically:
            #  * an L1 hit repeats as an L1 hit at its own t1 — the only
            #    state change is the recency move, replayed through the
            #    memoized set dict (identical to the full branch's
            #    move_to_end, minus the probes);
            #  * a still-pending MSHR fill repeats as the same
            #    hit-under-miss (its own fill time is the memoized value,
            #    past the lookup, so the entry wasn't deleted).
            # Any head that may have committed (t1 >= safe) kills the
            # station's memo.  ~90% of churn-call heads repeat one of a
            # handful of pairs, so this replaces the branch chain with one
            # dict probe for most of the call.  Both replay kinds share the
            # dict, tagged by value type: an L1 set dict replays a hit, a
            # float replays a still-pending fill (a pair's kind is stable
            # within a safe window — changing it requires a commit, which
            # ends the window).
            t0_arr = h_t0b
            od = OrderedDict
            safe_l: List[Optional[float]] = [None] * ns
            memo_l: List[Optional[dict]] = [None] * ns
            for s, pg, t0b in zip(st_l, hpage_l, t0b_l):
                t1 = t0b + l1_lat
                su = safe_l[s]
                if su is None:
                    hp = l1s[s]._heap
                    su = safe_l[s] = hp[0][0] if hp else INF
                    memo = memo_l[s] = {}
                else:
                    memo = memo_l[s]
                if t1 < su:
                    v = memo.get(pg)
                    if v is not None:
                        if v.__class__ is od:
                            v.move_to_end(pg)
                            res_app(t1)
                            fill_app(neg_inf)
                            kc[0] += 1
                            continue
                        if t1 < v:
                            res_app(v)
                            fill_app(v)
                            kc[1] += 1
                            continue
                cl = l1s[s]
                kls = -1
                if pg in maybe_l[s]:
                    hp = cl._heap
                    if hp and hp[0][0] <= t1:
                        cl._commit(t1)
                    sd = cl._sets[hash(pg) % cl.n_sets]
                    if pg in sd:
                        sd.move_to_end(pg)
                        resolve = t1
                        kls = 0
                        fill = neg_inf
                if kls < 0:
                    pending = pend_l[s]
                    pend = pending.get(pg)
                    if pend is not None:
                        kls = 1
                        fill = pend
                        if pend <= t1:
                            del pending[pg]
                            resolve = t1
                        else:
                            resolve = pend
                    else:
                        resolve, kls, fill = access(s, pg, t0b)
                hp = cl._heap
                safe_l[s] = hp[0][0] if hp else INF
                if t1 >= su:
                    memo.clear()
                if kls == 0:
                    memo[pg] = sd
                else:
                    # A delete or a staged fill forces the next same-page
                    # head back through the chain (0.0 never replays).
                    memo[pg] = resolve if kls == 1 and resolve != t1 else 0.0
                res_app(resolve)
                fill_app(fill)
                kc[kls] += 1
        else:
            # The first two branches of VecTranslationState.access (L1 hit
            # and MSHR hit-under-miss — ~all steady-state traffic) are
            # inlined; the method handles L2 and walks.  Falling through
            # to access() after an inlined miss is stateless: re-checking
            # the committed-to time commits nothing more, and a missed set
            # probe touches no recency.
            for s, pg, t0b, nsh in zip(st_l, hpage_l, t0b_l, ns_l):
                t0 = t0b + skew[s]
                t1 = t0 + l1_lat
                kls = -1
                if pg in maybe_l[s]:
                    c = l1s[s]
                    hp = c._heap
                    if hp and hp[0][0] <= t1:
                        c._commit(t1)
                    sd = c._sets[hash(pg) % c.n_sets]
                    if pg in sd:
                        sd.move_to_end(pg)
                        resolve = t1
                        kls = 0
                        fill = neg_inf
                if kls < 0:
                    pending = pend_l[s]
                    pend = pending.get(pg)
                    if pend is not None:
                        kls = 1
                        fill = pend
                        if pend <= t1:
                            del pending[pg]
                            resolve = t1
                        else:
                            resolve = pend
                    else:
                        resolve, kls, fill = access(s, pg, t0)
                res_app(resolve)
                fill_app(fill)
                t0_app(t0)
                kc[kls] += 1
                if (resolve - t1 > 0
                        and totals_l[s] - consumed[s] >= ingress):
                    block_from = t0 + cover
                    r = release[s]
                    if r > block_from:
                        block_from = r
                    if resolve > block_from:
                        bubble = resolve - block_from
                        skew[s] += bubble
                        release[s] = resolve
                        stall_sum += bubble
                        stall_n += 1
                consumed[s] += nsh
        self.stall_sum = stall_sum
        self.stall_n = stall_n
        if probes:
            ctr.probes += probes
        res = np.asarray(res_l)
        fill = np.asarray(fill_l)
        t0 = t0_arr if t0_arr is not None else np.asarray(t0_l)
        return self._finish(g, ctr, res, fill, t0, kc, l1_lat, fab,
                            collect_trace, fi_base, ns)

    def _finish(self, g, ctr, res, fill, t0, kcnt, l1_lat, fab,
                collect_trace, fi_base, ns) -> float:
        """Deferred vectorized tail expansion over per-head outputs.

        Shared by the sequential core and the quiet-window grouped path:
        everything past the access loop depends only on the per-head
        (resolve, fill, class) columns, not on how they were produced.
        """
        H = g.H
        rat0 = res - t0
        tail = g.tail
        h_stride = g.h_stride
        if kcnt[0]:
            finite = fill > -INF
            fill_safe = np.where(finite, fill, 0.0)
            tf = finite if g.tail_all else tail & finite
        else:
            # Only L1 hits record a -INF fill, so with none of them every
            # fill is finite: the finite mask and the zero substitution
            # are elementwise identities and can be skipped.
            fill_safe = fill
            tf = None if g.tail_all else tail
        # k_hum = max(0, min(n_s - 1, ceil((fill - l1_lat - t0)/stride) - 1))
        # computed in float (exact: the clamp bounds are far below 2^53).
        kf = np.ceil((fill_safe - l1_lat - t0) / h_stride) - 1.0
        kf = np.maximum(np.minimum(kf, g.h_ns_m1f), 0.0)
        k_hum = (kf if tf is None else np.where(tf, kf, 0.0)).astype(np.int64)
        hum = k_hum * (fill_safe - t0) - h_stride * k_hum * (k_hum + 1) / 2
        hum = np.where(k_hum > 0, hum, 0.0)
        # h_ns_m1 is zero exactly where tail is False and k_hum is masked
        # to zero there, so the plain difference equals the old
        # tail-masked form element for element.
        n_hit = g.h_ns_m1 - k_hum
        hits = n_hit * l1_lat

        s_hum = int(k_hum.sum())
        s_hit = int(n_hit.sum())
        ctr.requests += H + s_hum + s_hit
        by = ctr.by_class
        for idx, name in enumerate(CLASSES):
            if kcnt[idx]:
                by[name] += int(kcnt[idx])
        by[L1_HUM] += s_hum
        by[L1_HIT] += s_hit

        # rat_ns_sum: strict left fold over [rat0, hum, hits] per head, in
        # head order, seeded with the running value — cumsum is sequential,
        # and the zero terms the event engine skips are exact no-ops.
        contrib = np.empty(3 * H + 1)
        contrib[0] = ctr.rat_ns_sum
        contrib[1::3] = rat0
        contrib[2::3] = hum
        contrib[3::3] = hits
        ctr.rat_ns_sum = float(np.cumsum(contrib)[-1])

        if H:
            m = max(ctr.rat_ns_max, float(rat0.max()))
            hmax = float(np.where(k_hum > 0,
                                  fill_safe - (t0 + h_stride), -INF).max())
            if hmax > m:
                m = hmax
            ctr.rat_ns_max = m

        last = res.copy()
        khm = k_hum > 0
        last[khm] = np.maximum(last[khm], fill[khm])
        nhm = n_hit > 0
        last[nhm] = np.maximum(
            last[nhm],
            t0[nhm] + (g.h_ns[nhm] - 1) * h_stride[nhm] + l1_lat)
        completion = float((last + fab.hbm_ns + g.h_ret).max()) if H else 0.0
        if completion < 0.0:
            completion = 0.0

        if collect_trace:
            self._write_trace(fi_base, g.e_fi, g.i0, g.i1, g.hcum, g.h_is0,
                              g.h_ns, t0, rat0, fill, h_stride, ns, l1_lat,
                              res=None)
        return completion

    # -- tracing -------------------------------------------------------------
    def _write_trace(self, fi_base, e_fi, i0, i1, hcum, h_is0, h_ns, t0,
                     rat0, fill, h_stride, ns, l1_lat, res=None) -> None:
        """Per-epoch trace rows, same expressions as the event engine."""
        for e in range(len(e_fi)):
            tr = np.empty(int(i1[e] - i0[e]))
            for h in range(int(hcum[e]), int(hcum[e + 1])):
                pos = int(h_is0[h] - i0[e])
                tr[pos] = rat0[h]
                nsh = int(h_ns[h])
                if nsh > 1:
                    ks = np.arange(1, nsh)
                    arr = t0[h] + ks * h_stride[h]
                    f = fill[h]
                    lat = np.maximum(arr + l1_lat,
                                     f if f > -INF else 0.0) - arr
                    tr[pos + ks * ns] = np.maximum(lat, l1_lat)
            self.trace_chunks.append((fi_base + int(e_fi[e]), int(i0[e]), tr))
