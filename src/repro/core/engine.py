"""Page-epoch simulation engine for collectives over a UALink pod.

Collective schedules (the all-pairs AllToAll of the paper, plus the ring /
recursive-doubling / tree patterns of :mod:`repro.core.patterns`) are
deterministic streaming traffic: in each dependency step every source GPU
concurrently streams chunks to its step peers, requests stripe round-robin
across the 16 UALink stations, and each (flow, page) forms an *epoch* whose
internal request timing is closed-form.  The engine therefore schedules only
epoch-level events — O(flows x pages) of them — and expands per-request
statistics analytically, which is exact for these workloads (see DESIGN.md
§3) and scales to the paper's 4 GB x 64 GPU sweeps in pure Python.

Backpressure model: each target station has a finite ingress buffer
(``FabricConfig.ingress_entries``).  Requests occupy a slot from arrival until
their translation resolves; a page walk that outlasts the buffer stalls the
whole port via credit backpressure, which is what couples Reverse Address
Translation latency into end-to-end collective time (paper Fig. 4).  Stall
windows of concurrent walks on one station are shared, not summed.

A request-level reference DES (:mod:`repro.core.ref_des`) implements the same
physics request-by-request and is used by the test suite to validate this
engine at small collective sizes.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from .config import SimConfig
from .patterns import FlowSpec
from .tlb import TranslationState, Counters, L1_HIT, L1_HUM, INF
from .topology import get_topology


@dataclass
class Flow:
    """One (source -> target) stream of the all-pairs schedule."""

    src: int
    dst: int
    base_addr: int      # NPA of the region this flow writes at the target
    nbytes: int
    t_start: float      # issue time of request 0 at the source CU
    delta_ns: float     # request inter-issue spacing (per-flow BW share)
    stripe: int         # station offset for round-robin striping
    oneway_ns: float = 0.0  # source CU -> target station (topology path)
    return_ns: float = 0.0  # target -> source ack (topology path)


@dataclass
class IterationResult:
    completion_ns: float
    ideal_completion_ns: Optional[float] = None
    counters: Optional[Counters] = None

    @property
    def degradation(self) -> float:
        return (self.completion_ns / self.ideal_completion_ns
                if self.ideal_completion_ns else float("nan"))


@dataclass
class RunResult:
    """Output of one simulation run (possibly several iterations)."""

    iterations: List[IterationResult]
    counters: Counters
    config: SimConfig
    collective_bytes: int
    # Per-request RAT latency trace (ns), ordered by (flow, request index),
    # only populated when cfg.collect_trace.
    trace: Optional[np.ndarray] = None
    trace_flow_bounds: Optional[List[int]] = None
    mean_stall_ns: float = 0.0
    # run_iteration calls fully served by the vectorized warm fast path
    # (DESIGN.md §15.2); always 0 on the event engine.
    fastpath_calls: int = 0

    @property
    def completion_ns(self) -> float:
        return self.iterations[0].completion_ns

    @property
    def total_ns(self) -> float:
        return sum(it.completion_ns for it in self.iterations)

    @property
    def mean_rat_ns(self) -> float:
        return self.counters.mean_rat_ns

    def breakdown(self) -> Dict[str, float]:
        """Mean round-trip latency components per request (paper Fig. 6).

        Fabric components are the tier-0 (intra-tier) path latencies; on
        hierarchical topologies flows crossing upper tiers pay more (see
        ``Flow.oneway_ns``/``return_ns``), which shows up in completion
        time rather than in this per-request decomposition.
        """
        fab = self.config.fabric
        return {
            "oneway_ns": fab.oneway_ns,
            "rat_ns": self.counters.mean_rat_ns,
            "stall_ns": self.mean_stall_ns,
            "hbm_ns": fab.hbm_ns,
            "return_ns": fab.return_ns,
        }


def flows_for_dst(specs: List[FlowSpec], cfg: SimConfig, dst: int,
                  t_start: float) -> List[Flow]:
    """Materialize one step's :class:`FlowSpec` set as flows at ``dst``.

    Per-flow bandwidth share: a source's concurrent outgoing flows of the
    step split its station pool evenly, so the inter-request spacing is
    ``request_bytes * out_degree / gpu_bw`` (the all-pairs ``n - 1`` case of
    the seed engine generalized to arbitrary step out-degrees).  On
    hierarchical topologies a flow crossing a capacity-limited tier is
    additionally paced by its share of *that tier's* per-source capacity —
    a source's flows crossing an oversubscribed uplink split the uplink,
    not the flat station pool — and each flow carries the topology's
    per-path request/ack latencies (DESIGN.md §10).
    """
    fab = cfg.fabric
    topo = get_topology(fab)
    flat = topo.flat
    out_deg: Dict[int, int] = {}
    tier_deg: Dict[Tuple[int, int], int] = {}
    for s in specs:
        out_deg[s.src] = out_deg.get(s.src, 0) + 1
        if not flat:
            k = (s.src, topo.tier(s.src, s.dst))
            tier_deg[k] = tier_deg.get(k, 0) + 1
    dst_base = (dst + 1) << 42  # distinct 4 TB NPA region per target GPU
    oneway = fab.oneway_ns
    ret = fab.return_ns
    flows = []
    for s in specs:
        if s.dst != dst or s.nbytes <= 0:
            continue
        delta = fab.request_bytes * out_deg[s.src] / fab.gpu_bw
        if not flat:
            tier = topo.tier(s.src, dst)
            cap = topo.tier_capacity(tier)
            if cap is not None:
                shaped = fab.request_bytes * tier_deg[(s.src, tier)] / cap
                if shaped > delta:
                    delta = shaped
            oneway = topo.path_latency_ns(s.src, dst)
            ret = topo.return_latency_ns(dst, s.src)
        flows.append(Flow(
            src=s.src, dst=dst,
            base_addr=dst_base + s.offset,
            nbytes=s.nbytes,
            t_start=t_start,
            delta_ns=delta,
            stripe=s.src % fab.stations_per_gpu,
            oneway_ns=oneway,
            return_ns=ret,
        ))
    return flows


def epoch_spans(flows: List[Flow], rb: int, page_bytes: int):
    """(first_arrival, flow_idx, page, i0, i1) spans, sorted by arrival.

    One span per (flow, page): requests ``i0..i1-1`` of flow ``flow_idx``
    touch ``page``.  Arrivals use each flow's own topology path latency
    (``Flow.oneway_ns``).  Shared by the epoch engine and the reference
    DES's probe-schedule construction so both issue identical prefetch
    probes.
    """
    eps = []
    for fi, f in enumerate(flows):
        n_req = max(1, math.ceil(f.nbytes / rb))
        a0 = f.t_start + f.oneway_ns
        first_page = f.base_addr // page_bytes
        last_page = (f.base_addr + f.nbytes - 1) // page_bytes
        for page in range(first_page, last_page + 1):
            lo = max(f.base_addr, page * page_bytes)
            hi = min(f.base_addr + f.nbytes, (page + 1) * page_bytes)
            i0 = (lo - f.base_addr) // rb
            i1 = min(n_req, math.ceil((hi - f.base_addr) / rb))
            if i1 <= i0:
                continue
            eps.append((a0 + i0 * f.delta_ns, fi, page, i0, i1))
    eps.sort()
    return eps


def probe_station(f: Flow, page: int, page_bytes: int, rb: int,
                  ns: int) -> int:
    """Station where ``page``'s first real request of flow ``f`` lands.

    Request ``i`` of a flow stripes to station ``(i + f.stripe) % ns``; the
    first request touching ``page`` has index ``i0 = (lo - base) // rb``
    (``lo`` = first byte of the page inside the flow's range).  Translation
    probes must target exactly this station so they warm the L1 that the
    page's first data request will actually query.
    """
    lo = max(f.base_addr, page * page_bytes)
    i0 = (lo - f.base_addr) // rb
    return (i0 + f.stripe) % ns


def pretranslate_probes(flows: List[Flow], cfg: SimConfig):
    """Yield (t, station, page) pre-translation probes for one collective.

    Paper §6.1: probes issue during the preceding compute window, starting
    ``lead_time_ns`` before the collective, paced every
    ``probe_issue_interval_ns``, warming the first ``pages_per_flow`` pages
    of every flow (0 => all).  Single source of truth for the engine and
    the reference DES, so oracle-equivalence holds by construction.
    """
    pre = cfg.pretranslation
    fab = cfg.fabric
    ns = fab.stations_per_gpu
    rb = fab.request_bytes
    page_bytes = cfg.translation.page_bytes
    if not flows:
        return
    t = flows[0].t_start - pre.lead_time_ns
    k = 0
    for f in flows:
        first_page = f.base_addr // page_bytes
        last_page = (f.base_addr + f.nbytes - 1) // page_bytes
        n_pages = last_page - first_page + 1
        limit = n_pages if pre.pages_per_flow <= 0 else min(
            n_pages, pre.pages_per_flow)
        for j in range(limit):
            page = first_page + j
            yield (t + k * pre.probe_issue_interval_ns,
                   probe_station(f, page, page_bytes, rb, ns), page)
            k += 1


@dataclass
class _Station:
    """Per-station ingress bookkeeping for the backpressure model."""

    skew: float = 0.0          # accumulated ingress stall (sigma)
    release: float = -INF      # end of the currently-covered stall window
    consumed: int = 0          # requests processed so far (for buffer gating)
    total: int = 0             # total requests this iteration


class EpochEngine:
    """Simulates one target GPU of the pod (exact under all-pairs symmetry)."""

    def __init__(self, cfg: SimConfig, dst: int = 0):
        self.cfg = cfg
        self.dst = dst
        fab = cfg.fabric
        self.state = TranslationState(cfg.translation, fab.stations_per_gpu)
        self.stations = [_Station() for _ in range(fab.stations_per_gpu)]
        self.page_bytes = cfg.translation.page_bytes
        self.svc = fab.request_bytes / fab.station_bw  # station service time
        self.buffer_cover = fab.ingress_entries * self.svc
        self.trace_chunks: List[Tuple[int, int, np.ndarray]] = []
        self.stall_sum = 0.0
        self.stall_n = 0

    # -- epoch construction --------------------------------------------------
    def _epochs(self, flows: List[Flow]):
        """Yield (first_arrival, flow_idx, page, i0, i1) sorted by time."""
        fab = self.cfg.fabric
        return epoch_spans(flows, fab.request_bytes, self.page_bytes)

    # -- core ----------------------------------------------------------------
    def run_iteration(self, flows: List[Flow], collect_trace: bool,
                      fi_base: int = 0, first_step: bool = True) -> float:
        """Simulate one step's flow set; returns absolute completion time.

        Called once per collective step (and per iteration); translation
        state persists across calls (TLBs stay warm), station ingress
        bookkeeping resets — each step's stream starts from an empty port,
        matching the reference DES (DESIGN.md §5.2).  ``fi_base`` offsets
        trace flow indices when a run spans several steps; ``first_step``
        marks the first step of an iteration — pre-translation probes fire
        only there, since mid-collective steps are back-to-back barriers
        with no compute window to hide probes in.
        """
        cfg = self.cfg
        fab = cfg.fabric
        rb = fab.request_bytes
        ns = fab.stations_per_gpu
        l1_lat = cfg.translation.l1.hit_latency_ns if cfg.translation.enabled else 0.0
        ctr = self.state.counters
        completion = 0.0

        pre = cfg.pretranslation
        if pre.enabled and cfg.translation.enabled and first_step:
            self._pretranslate(flows)

        epochs = self._epochs(flows)
        # Per-station request totals (for ingress-buffer occupancy gating).
        for st in self.stations:
            st.skew = 0.0
            st.release = -INF
            st.consumed = 0
            st.total = 0
        for f in flows:
            n_req = max(1, math.ceil(f.nbytes / rb))
            base, extra = divmod(n_req, ns)
            for s_off in range(ns):
                station = (s_off + f.stripe) % ns
                self.stations[station].total += base + (1 if s_off < extra else 0)

        for (t_first, fi, page, i0, i1) in epochs:
            f = flows[fi]
            d = f.delta_ns
            a0 = f.t_start + f.oneway_ns

            # Software prefetch (paper §6.2): as this page's stream begins,
            # request translation of the next page(s) of this flow's region.
            if cfg.prefetch.enabled and cfg.translation.enabled:
                self._prefetch(f, page, t_first)

            trace = (np.empty(i1 - i0) if collect_trace else None)

            # Per-station sub-series of this epoch's requests.
            for s_off in range(min(ns, i1 - i0)):
                i_s0 = i0 + s_off
                station = (i_s0 + f.stripe) % ns
                n_s = (i1 - i_s0 + ns - 1) // ns  # requests on this station
                st = self.stations[station]
                t0 = a0 + i_s0 * d + st.skew     # effective head arrival
                res = self.state.access(station, page, t0)
                rat0 = res.resolve - t0
                ctr.add_request(res.klass, rat0)
                ctr.note_max(rat0)
                last_resolve = res.resolve

                # Ingress-buffer backpressure: a translation wait longer than
                # the buffer cover stalls the port (UALink credit flow
                # control).  Only applies when enough requests remain to fill
                # the buffer; overlapping walks share the stall window via
                # `release`, and the stall persists (ingress runs at exactly
                # link rate in all-pairs steady state, so there is no slack
                # to re-absorb the bubble).
                wait = res.resolve - (t0 + l1_lat)
                if (wait > 0 and cfg.translation.enabled
                        and st.total - st.consumed >= fab.ingress_entries):
                    block_from = max(t0 + self.buffer_cover, st.release)
                    if res.resolve > block_from:
                        bubble = res.resolve - block_from
                        st.skew += bubble
                        st.release = res.resolve
                        self.stall_sum += bubble
                        self.stall_n += 1
                st.consumed += n_s

                if collect_trace:
                    trace[i_s0 - i0] = rat0

                if n_s > 1:
                    # Tail: arrivals a_k = t0 + k*stride (k=1..n_s-1), with
                    # the skew accrued so far (constant within an epoch).
                    stride = ns * d
                    fill = res.l1_fill
                    # Requests with a_k + l1_lat < fill stall until the fill
                    # (MSHR hit-under-miss); the rest are plain L1 hits.
                    # #{k >= 1 : k < (fill - l1_lat - t0)/stride}
                    if fill > -INF:
                        x = (fill - l1_lat - t0) / stride
                        k_hum = max(0, min(n_s - 1, math.ceil(x) - 1))
                    else:
                        k_hum = 0
                    if k_hum > 0:
                        # sum over k=1..k_hum of (fill - a_k)
                        hum_sum = (k_hum * (fill - t0)
                                   - stride * k_hum * (k_hum + 1) / 2)
                        ctr.add_request(L1_HUM, hum_sum, n=k_hum)
                        ctr.note_max(fill - (t0 + stride))
                        last_resolve = max(last_resolve, fill)
                    n_hit = n_s - 1 - k_hum
                    if n_hit > 0:
                        ctr.add_request(L1_HIT, n_hit * l1_lat, n=n_hit)
                        last_resolve = max(
                            last_resolve,
                            t0 + (n_s - 1) * stride + l1_lat)
                    if collect_trace:
                        ks = np.arange(1, n_s)
                        arr = t0 + ks * stride
                        lat = np.maximum(arr + l1_lat,
                                         fill if fill > -INF else 0.0) - arr
                        trace[i_s0 - i0 + ks * ns] = np.maximum(lat, l1_lat)

                done = last_resolve + fab.hbm_ns + f.return_ns
                if done > completion:
                    completion = done

            if collect_trace:
                self.trace_chunks.append((fi_base + fi, i0, trace))

        return completion

    # -- optimizations ---------------------------------------------------------
    def _pretranslate(self, flows: List[Flow]) -> None:
        """Paper §6.1: fused pre-translation during the preceding compute.

        Probes target the station where each page's *first data request*
        will land (:func:`probe_station`), so the probe warms exactly the L1
        that request queries.
        """
        for (t, st, page) in pretranslate_probes(flows, self.cfg):
            self.state.access(st, page, t, is_probe=True)
            self.state.counters.probes += 1

    def _prefetch(self, f: Flow, page: int, t: float) -> None:
        """Paper §6.2: software-guided next-page TLB prefetch."""
        fab = self.cfg.fabric
        ns = fab.stations_per_gpu
        last_page = (f.base_addr + f.nbytes - 1) // self.page_bytes
        for j in range(1, self.cfg.prefetch.depth + 1):
            p = page + j
            if p > last_page:
                break
            st = probe_station(f, p, self.page_bytes, fab.request_bytes, ns)
            self.state.access(st, p, t, is_probe=True)
            self.state.counters.probes += 1


def simulate(nbytes: int, cfg: SimConfig) -> RunResult:
    """Simulate ``cfg.collective`` of ``nbytes`` per GPU under ``cfg``.

    Thin wrapper over :class:`repro.core.session.SimSession`: one session is
    created, ``cfg.iterations`` back-to-back invocations of the collective
    are replayed through it (translation state stays warm across
    iterations, exactly as the pre-session engine behaved), and the
    aggregate is returned.  The pattern layer supplies per-step flow sets;
    steps are dependency barriers (step k+1's flows start at step k's
    completion).  Symmetric patterns simulate one representative target
    (exact — every GPU is loaded identically); asymmetric ones (broadcast)
    simulate every receiving target regardless of ``cfg.symmetric``.
    """
    from .session import SimSession  # local import: session builds on engine

    sess = SimSession(cfg)
    for _ in range(cfg.iterations):
        sess.run(nbytes)
    return sess.result(collective_bytes=nbytes)
