"""Persistent-TLB simulation sessions (workload replay, DESIGN.md §8).

The paper's headline result — cold Link-TLB misses cost up to 1.4x on small
collectives while warmed caches erase the overhead — is a statement about
*sequences* of collectives: an inference decode loop fires one small MoE
all-to-all per layer per token, and only the very first invocations pay the
cold-walk tax.  :class:`SimSession` holds one :class:`~repro.core.engine.
EpochEngine` per simulated target GPU (and hence one
:class:`~repro.core.tlb.TranslationState`) across successive collective
invocations, so TLB/PWC warmth carries from call to call exactly as it would
on hardware.  :func:`repro.core.engine.simulate` is a thin wrapper: one
session, ``cfg.iterations`` back-to-back runs.

Sessions support:

* heterogeneous call sequences — each :meth:`run` may override the
  collective pattern, the participating GPU count (a TP subgroup inside the
  pod) and the buffer region (``base_offset``), so model-derived workloads
  (:mod:`repro.workloads`) replay straight through;
* inter-collective idle gaps (:meth:`idle`) that advance the clock; when
  ``SimConfig.tlb_retention_ns`` is set, a gap at least that long flushes
  all cached translations, modelling eviction by competing traffic while
  the pod is quiet;
* per-collective statistics — every :meth:`run` returns a
  :class:`CollectiveResult` carrying its own completion time and counter
  deltas, which is what per-token degradation trajectories are made of.

The request-level oracle mirror is :class:`repro.core.ref_des.RefSession`.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Dict, List, Optional

import numpy as np

from .config import SimConfig
from .engine import (EpochEngine, IterationResult, RunResult,
                     flows_for_dst)
from .engine_vec import (VecEngine, flows_from_specs_multi,
                         rebase_flow_arrays, request_counts,
                         run_step_group)
from .patterns import (get_pattern, simulated_dsts, simulated_dsts_arrays)
from .select import get_policy, session_collective
from .tlb import Counters
from .topology import get_topology

ENGINES = ("event", "vectorized")


def _group_fabric(cfg: SimConfig, collective: Optional[str],
                  n_gpus: Optional[int], rank_stride: int):
    """(name, fab_n, pattern) after the per-call group validation."""
    fab = cfg.fabric
    name = collective if collective is not None else cfg.collective
    fab_n = (fab if n_gpus is None or n_gpus == fab.n_gpus
             else dataclasses.replace(fab, n_gpus=n_gpus))
    if fab_n.n_gpus > fab.n_gpus:
        raise ValueError(
            f"collective group of {fab_n.n_gpus} exceeds pod size "
            f"{fab.n_gpus}")
    if rank_stride < 1:
        raise ValueError(f"rank_stride must be >= 1, got {rank_stride}")
    if (fab_n.n_gpus - 1) * rank_stride + 1 > fab.n_gpus:
        raise ValueError(
            f"strided group ({fab_n.n_gpus} ranks x stride {rank_stride}) "
            f"exceeds pod size {fab.n_gpus}")
    return name, fab_n, get_pattern(name)


def _effective_symmetric(cfg: SimConfig, fab_n, rank_stride: int) -> bool:
    """Whether the single-target shortcut is exact for this placement."""
    symmetric = cfg.symmetric
    topo = get_topology(cfg.fabric)
    if symmetric and not topo.flat:
        # On a tiered fabric the single-target shortcut is only exact when
        # every rank of the group sees the same intra/inter tier mix:
        # the whole group inside one tier-0 block, a stride that makes
        # every pair inter-block, or a contiguous group covering whole
        # blocks.  Anything else (a group straddling a partial block, a
        # misaligned stride) mixes tiers per target — simulate every one.
        block = topo.tier0_group()
        g, s = fab_n.n_gpus, rank_stride
        all_intra = (g - 1) * s + 1 <= block
        uniform = s % block == 0 or (s == 1 and g % block == 0)
        if not (all_intra or uniform):
            symmetric = False
    return symmetric


def resolve_collective(cfg: SimConfig, nbytes: int,
                       collective: Optional[str], n_gpus: Optional[int],
                       rank_stride: int = 1):
    """(name, fab_n, step_specs, dsts) for one session run.

    Single source of truth for per-call pattern/group resolution and
    validation, shared by :class:`SimSession` and
    :class:`~repro.core.ref_des.RefSession` so the two sides of the
    oracle-equivalence contract cannot drift.

    ``rank_stride`` places the group's logical ranks onto pod GPUs
    ``0, stride, 2*stride, ...`` instead of ``0..g-1`` — a data-parallel
    replica group whose members sit one per TP island (rank stride = tp).
    On the flat topology placement is immaterial (any rank labeling is
    isomorphic); on hierarchical topologies it decides which flows cross
    tiers, e.g. a strided gradient ring pays the spine on every hop.
    """
    name, fab_n, pattern = _group_fabric(cfg, collective, n_gpus,
                                         rank_stride)
    step_specs = pattern.steps(nbytes, fab_n)
    if rank_stride > 1:
        step_specs = [
            [dataclasses.replace(s, src=s.src * rank_stride,
                                 dst=s.dst * rank_stride) for s in step]
            for step in step_specs]
    symmetric = _effective_symmetric(cfg, fab_n, rank_stride)
    dsts = simulated_dsts(pattern, step_specs, symmetric, fab_n)
    return name, fab_n, step_specs, dsts


def resolve_collective_arrays(cfg: SimConfig, nbytes: int,
                              collective: Optional[str],
                              n_gpus: Optional[int], rank_stride: int = 1):
    """:func:`resolve_collective` in the columnar :class:`~repro.core.
    patterns.StepArrays` form consumed by the vectorized engine.

    Same validation, same stride placement, same symmetric demotion and the
    same target set — only the schedule representation differs.
    """
    name, fab_n, pattern = _group_fabric(cfg, collective, n_gpus,
                                         rank_stride)
    steps = pattern.steps_arrays(nbytes, fab_n)
    if rank_stride > 1:
        steps = [st.with_stride(rank_stride) for st in steps]
    symmetric = _effective_symmetric(cfg, fab_n, rank_stride)
    dsts = simulated_dsts_arrays(pattern, steps, symmetric, fab_n)
    return name, fab_n, steps, dsts


@dataclass
class CollectiveResult:
    """One collective invocation inside a session."""

    label: str
    collective: str
    nbytes: int
    n_gpus: int
    t_start: float        # absolute session time the collective was issued
    t_end: float          # absolute completion time
    counters: Counters    # counter deltas attributable to this invocation
    # run_iteration calls fully served by the vectorized warm fast path
    # (DESIGN.md §15.2); always 0 on the event engine.
    fastpath_calls: int = 0

    @property
    def completion_ns(self) -> float:
        return self.t_end - self.t_start


@dataclass
class _Plan:
    """Cached per-call geometry of one (collective, size, group, offset).

    ``steps[si]`` holds ``(dst, FlowArrays)`` for every target with flows in
    step ``si``; the ``FlowArrays`` (and the ``_Geom`` they accumulate) are
    reused across calls — only ``t_start`` is reassigned per run.  Cache
    keys and invalidation rules: DESIGN.md §15.1.
    """

    name: str
    fab_n: object
    steps: List[List[tuple]]
    trace_dst: Optional[int]
    base_offset: int
    # Target construction order (the event path's per-call order); sessions
    # adopting a process-cached plan instantiate engines from this.
    dsts: tuple = ()


# Process-wide plan cache (DESIGN.md §15.1).  A plan is a pure function of
# (cfg, call signature) — SimConfig is frozen — so fresh sessions (bench
# reps, fleet replicas, sweep points) reuse one derivation instead of
# re-running resolve/steps_arrays/flow materialization each.  Sharing the
# mutable FlowArrays is safe in-process: the only per-call field, t_start,
# is assigned immediately before the engine consumes it, and sessions run
# sequentially.  Unhashable configs simply skip this layer.
_PLAN_CACHE: Dict[tuple, _Plan] = {}
_PLAN_CACHE_MAX = 8192


class SimSession:
    """Warm-state replay of a sequence of collectives on one pod.

    ``compute_profile`` (a :class:`repro.workloads.calibrate.ComputeProfile`
    or anything with a ``window_ns(phase) -> float | None`` method) makes
    the session resolve phase-tagged inter-collective gaps from measured
    kernel timings instead of the caller-supplied roofline value; ``None``
    (the default) leaves every ``gap_ns`` untouched — bit-for-bit the
    pre-calibration behavior.

    ``policy`` (an :class:`~repro.core.select.AlgorithmPolicy` or a spec
    string — see :func:`~repro.core.select.get_policy`) resolves *logical*
    collective names per call, keyed on whether the call's ``base_offset``
    region has been touched since the last retention flush (cold vs warm
    Link-TLB state); ``None`` keeps the pre-policy behavior: only concrete
    registry names are accepted.
    """

    def __init__(self, cfg: SimConfig, *, compute_profile=None, policy=None):
        if cfg.engine not in ENGINES:
            raise ValueError(
                f"unknown engine {cfg.engine!r}; known: {ENGINES}")
        self.cfg = cfg
        self.compute_profile = compute_profile
        self.policy = get_policy(policy)
        self._warm_regions: set = set()   # base_offsets touched since flush
        self._vec = cfg.engine == "vectorized"
        self.t = 0.0
        self.records: List[CollectiveResult] = []
        self._engines: Dict[int, EpochEngine] = {}
        # Geometry plan cache (vectorized engine only, DESIGN.md §15.1):
        # _plans is keyed on the full call signature; _canonical holds one
        # representative per offset-free signature that other offsets are
        # derived from by (exact) integer address translation.  Entries are
        # pure functions of the config — TLB flushes do NOT invalidate them.
        self._plans: Dict[tuple, _Plan] = {}
        self._canonical: Dict[tuple, _Plan] = {}
        try:
            hash(cfg)
            self._cfg_hashable = True
        except TypeError:
            self._cfg_hashable = False
        # Tracing state (first run() only, mirroring simulate's iteration 0).
        self._trace_dst: Optional[int] = None
        self._flow_sizes: List[int] = []
        # Merged-counters total as of the last run() (see run()).
        self._ctr_cache: Optional[Counters] = None

    # -- clock ---------------------------------------------------------------
    def resolve_gap(self, gap_ns: float, phase: str = "",
                    window_parts=()) -> float:
        """The gap actually applied before a call.

        With a compute profile attached, ``window_parts`` — the
        ``(phase, ns)`` decomposition of the gap (see
        ``CollectiveCall.window_parts``) — is re-resolved part by part, so
        carried multi-sublayer windows calibrate exactly as they would have
        at derive time; a bare ``phase`` resolves a single-window gap; a
        part (or phase) the profile does not know keeps its given ns.
        Without a profile the caller's ``gap_ns`` is returned untouched.
        """
        prof = self.compute_profile
        if prof is None:
            return gap_ns
        if window_parts:
            total = 0.0
            for ph, ns in window_parts:
                w = prof.window_ns(ph) if ph else None
                total += w if w is not None else ns
            return total
        if phase:
            w = prof.window_ns(phase)
            if w is not None:
                return w
        return gap_ns

    def idle(self, gap_ns: float) -> None:
        """Advance the session clock by an inter-collective compute/idle gap.

        With ``cfg.tlb_retention_ns`` set, gaps of at least that length
        flush all cached translations (competing traffic evicts the Link-TLB
        working set); shorter gaps leave warmth intact — the hierarchy has
        no self-decay.
        """
        if gap_ns <= 0:
            return
        self.t += gap_ns
        retention = self.cfg.tlb_retention_ns
        if retention is not None and gap_ns >= retention:
            for eng in self._engines.values():
                eng.state.flush()
            self._warm_regions.clear()

    # -- engines -------------------------------------------------------------
    def _engine(self, dst: int) -> EpochEngine:
        eng = self._engines.get(dst)
        if eng is None:
            cls = VecEngine if self._vec else EpochEngine
            eng = self._engines[dst] = cls(self.cfg, dst=dst)
        return eng

    def _counters_total(self) -> Counters:
        total = Counters()
        for eng in self._engines.values():
            total.merge(eng.state.counters)
        return total

    def _fastpath_total(self) -> int:
        return sum(getattr(eng, "fastpath_calls", 0)
                   for eng in self._engines.values())

    # -- geometry plans (vectorized engine, DESIGN.md §15.1) -----------------
    def _plan_for(self, collective: Optional[str], nbytes: int,
                  n_gpus: Optional[int], rank_stride: int,
                  base_offset: int) -> _Plan:
        """The cached per-step flow geometry for one call signature.

        First resolution of an offset-free signature builds the canonical
        plan (one batched :func:`flows_from_specs_multi` pass per step);
        other ``base_offset`` values clone it by shifting ``base_addr`` —
        an exact integer translation, page-aligned shifts carrying the
        epoch/head geometry cache over (:func:`rebase_flow_arrays`).
        """
        key = (collective, nbytes, n_gpus, rank_stride, base_offset)
        plan = self._plans.get(key)
        if plan is not None:
            return plan
        cfg = self.cfg
        gkey = (cfg,) + key if self._cfg_hashable else None
        if gkey is not None:
            plan = _PLAN_CACHE.get(gkey)
            if plan is not None:
                # Engine (and TLB state) per simulated target exists up
                # front, matching the event path's per-call construction
                # order.
                for d in plan.dsts:
                    self._engine(d)
                self._plans[key] = plan
                return plan
        canon = self._canonical.get(key[:4])
        if canon is None and gkey is not None:
            canon = _PLAN_CACHE.get(gkey[:5])
            if canon is not None:
                self._canonical[key[:4]] = canon
        if canon is None:
            name, fab_n, steps, dsts = resolve_collective_arrays(
                cfg, nbytes, collective, n_gpus, rank_stride)
            groups: List[List[tuple]] = []
            for st in steps:
                fad = flows_from_specs_multi(st, cfg, dsts)
                groups.append([(d, fad[d]) for d in dsts
                               if fad[d] is not None])
            present = {d for grp in groups for d, _ in grp}
            trace_dst = next((d for d in dsts if d in present), None)
            if base_offset:
                for grp in groups:
                    for _, fa in grp:
                        fa.base_addr = fa.base_addr + base_offset
            plan = _Plan(name, fab_n, groups, trace_dst, base_offset,
                         tuple(dsts))
            self._canonical[key[:4]] = plan
            if gkey is not None:
                _PLAN_CACHE[gkey[:5]] = plan
        else:
            delta_addr = base_offset - canon.base_offset
            pb = cfg.translation.page_bytes
            groups = [[(d, rebase_flow_arrays(fa, delta_addr, pb))
                       for d, fa in grp] for grp in canon.steps]
            plan = _Plan(canon.name, canon.fab_n, groups, canon.trace_dst,
                         base_offset, canon.dsts)
        if gkey is not None:
            if len(_PLAN_CACHE) >= _PLAN_CACHE_MAX:
                _PLAN_CACHE.clear()   # wholesale reset; refill is cheap
            _PLAN_CACHE[gkey] = plan
        for d in plan.dsts:
            self._engine(d)
        self._plans[key] = plan
        return plan

    # -- core ----------------------------------------------------------------
    def run(self, nbytes: int, *, collective: Optional[str] = None,
            n_gpus: Optional[int] = None, rank_stride: int = 1,
            gap_ns: float = 0.0,
            base_offset: int = 0, label: str = "",
            phase: str = "", window_parts=()) -> CollectiveResult:
        """Replay one collective starting at the current session time.

        ``collective``/``n_gpus`` override the session defaults per call
        (e.g. a TP all-gather over an 8-GPU subgroup inside a 64-GPU pod);
        ``rank_stride`` places the group on strided pod ranks (a DP replica
        ring spanning TP islands — see :func:`resolve_collective`);
        ``base_offset`` shifts the collective's buffer region inside each
        target's NPA space so distinct logical buffers touch distinct pages;
        ``gap_ns`` is a compute/idle window inserted *before* the collective
        (see :meth:`idle`), re-resolved from the session's compute profile
        when ``phase`` names a calibrated phase (:meth:`resolve_gap`).
        """
        cfg = self.cfg
        fab = cfg.fabric
        gap_ns = self.resolve_gap(gap_ns, phase, window_parts)
        if gap_ns:
            self.idle(gap_ns)
        # Policy resolution after the idle: a gap long enough to flush the
        # TLBs demotes this region to cold before the algorithm is chosen.
        collective = session_collective(
            self.policy, cfg, nbytes, collective, n_gpus,
            warm=base_offset in self._warm_regions)
        self._warm_regions.add(base_offset)

        # Trace only the first collective of the session (simulate's
        # iteration-0 semantics), on the first target that actually
        # produces flows — a symmetric-demoted group's dsts[0] may see
        # only zero-byte specs.
        collect = cfg.collect_trace and not self.records
        # Engine counters mutate only inside run(); the previous call's
        # "after" total is this call's "before" (engines created since hold
        # zeroed counters, and merging zeros is an exact float no-op), so
        # one full merge per call suffices.
        before = self._ctr_cache
        if before is None:
            before = self._counters_total()
        fp_before = self._fastpath_total()
        rb = fab.request_bytes
        t0 = self.t
        t = t0
        if self._vec:
            plan = self._plan_for(collective, nbytes, n_gpus, rank_stride,
                                  base_offset)
            name, fab_n = plan.name, plan.fab_n
            if collect:
                self._trace_dst = plan.trace_dst
            engines = self._engines
            if collect:
                for si, grp in enumerate(plan.steps):
                    comp = t
                    first = si == 0
                    for d, fa in grp:
                        fa.t_start = t
                        trace_this = d == self._trace_dst
                        fi_base = len(self._flow_sizes)
                        if trace_this:
                            self._flow_sizes.extend(request_counts(fa, rb))
                        comp = max(comp, engines[d].run_iteration(
                            fa, trace_this, fi_base=fi_base,
                            first_step=first))
                    t = comp
            else:
                # Hot path: one grouped invocation per step barrier
                # (DESIGN.md §15).
                for si, grp in enumerate(plan.steps):
                    t = run_step_group(engines, grp, t, si == 0)
        else:
            name, fab_n, step_specs, dsts = resolve_collective(
                cfg, nbytes, collective, n_gpus, rank_stride)
            if collect:
                self._trace_dst = next(
                    (d for d in dsts
                     if any(s.dst == d and s.nbytes > 0
                            for step in step_specs for s in step)), None)
            for si, specs in enumerate(step_specs):
                comp = t
                for d in dsts:
                    eng = self._engine(d)
                    flows = flows_for_dst(specs, cfg, d, t_start=t)
                    if base_offset:
                        for f in flows:
                            f.base_addr += base_offset
                    if not flows:
                        continue
                    trace_this = collect and d == self._trace_dst
                    fi_base = len(self._flow_sizes)
                    if trace_this:
                        self._flow_sizes.extend(
                            max(1, math.ceil(f.nbytes / rb)) for f in flows)
                    comp = max(comp, eng.run_iteration(
                        flows, trace_this, fi_base=fi_base,
                        first_step=si == 0))
                t = comp
        self.t = t

        after = self._counters_total()
        self._ctr_cache = after
        rec = CollectiveResult(
            label=label or name, collective=name, nbytes=nbytes,
            n_gpus=fab_n.n_gpus, t_start=t0, t_end=t,
            counters=after.delta(before),
            fastpath_calls=self._fastpath_total() - fp_before)
        self.records.append(rec)
        return rec

    # -- aggregation ---------------------------------------------------------
    def result(self, collective_bytes: Optional[int] = None) -> RunResult:
        """Aggregate the session so far as a :class:`RunResult`.

        Non-destructive: the session can keep running afterwards.  One
        :class:`IterationResult` per collective invocation, counters merged
        over every simulated target, trace (if collected) for the first
        invocation's representative target.
        """
        cfg = self.cfg
        ctr = self._counters_total()

        trace = None
        bounds = None
        if cfg.collect_trace:
            bounds = [0]
            for sz in self._flow_sizes:
                bounds.append(bounds[-1] + sz)
            trace = np.zeros(bounds[-1])
            if self._trace_dst is not None:
                for (fi, i0, arr) in self._engines[self._trace_dst].trace_chunks:
                    trace[bounds[fi] + i0: bounds[fi] + i0 + len(arr)] = arr

        stall_total = sum(e.stall_sum for e in self._engines.values())
        nbytes = (collective_bytes if collective_bytes is not None
                  else (self.records[0].nbytes if self.records else 0))
        return RunResult(
            iterations=[IterationResult(completion_ns=r.completion_ns)
                        for r in self.records],
            counters=ctr, config=cfg, collective_bytes=nbytes,
            trace=trace, trace_flow_bounds=bounds,
            mean_stall_ns=stall_total / (ctr.requests or 1),
            fastpath_calls=self._fastpath_total())
