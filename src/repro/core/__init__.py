# The paper's primary contribution: a Reverse Address Translation simulator
# for UALink-class scale-up pods, the two latency-hiding optimizations the
# paper proposes (fused pre-translation, software TLB prefetch), and the
# translation-aware collective cost model / scheduler the framework uses for
# its own collectives.
from .config import (SimConfig, FabricConfig, TranslationConfig, TLBConfig,
                     PWCConfig, PreTranslationConfig, PrefetchConfig,
                     paper_config, KB, MB, GB)
from .engine import simulate, RunResult
from .patterns import (CollectivePattern, FlowSpec, PATTERNS, LOGICAL,
                       register_pattern, candidates_for, logical_of,
                       get_pattern, analytic_volume)
from .ratsim import run, compare, session, sweep, Comparison
from .ref_des import RefSession, simulate_ref
from .select import (AlgorithmPolicy, AutoPolicy, FixedPolicy, PolicyTable,
                     Resolution, build_policy_table, get_policy, size_bucket)
from .session import CollectiveResult, SimSession
from .topology import Topology, TOPOLOGIES, get_topology

__all__ = [
    "SimConfig", "FabricConfig", "TranslationConfig", "TLBConfig",
    "PWCConfig", "PreTranslationConfig", "PrefetchConfig", "paper_config",
    "KB", "MB", "GB", "simulate", "RunResult", "run", "compare", "session",
    "sweep", "Comparison", "simulate_ref", "RefSession", "SimSession",
    "CollectiveResult", "CollectivePattern", "FlowSpec",
    "PATTERNS", "LOGICAL", "register_pattern", "candidates_for",
    "logical_of", "get_pattern", "analytic_volume",
    "AlgorithmPolicy", "AutoPolicy", "FixedPolicy", "PolicyTable",
    "Resolution", "build_policy_table", "get_policy", "size_bucket",
    "Topology", "TOPOLOGIES", "get_topology",
]
