"""Analytic cost model for collectives over a scale-up pod, with RAT terms.

Closed-form distillation of the simulator, used by the framework's
translation-aware scheduler (:mod:`repro.core.scheduler`) to price collective
schedules without running the DES in the training loop.  The model is the
classic alpha-beta form plus two destination-side translation terms derived
from the paper's analysis:

  T(S, n) = alpha + S_eff / B_gpu + T_cold(S, n) + T_warm(S, n)

  * ``alpha``     — fixed fabric latency (one-way + return).
  * ``S_eff/B``   — bandwidth term (all-pairs moves (n-1)/n of S per GPU over
                    the aggregate station bandwidth).
  * ``T_cold``    — the cold-start stall: the first page walk of each flow
                    outlasts the MSHR/ingress cover and stalls the port
                    (dominates small collectives — the paper's 1.4x).
  * ``T_warm``    — per-page-transition residue for walks that outlast the
                    ingress cover (zero with paper-default buffering).

``fit()`` calibrates the two free parameters (cold-walk latency and effective
cover) against the simulator; ``validate()`` reports model-vs-sim error.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Tuple

from .config import SimConfig, paper_config
from .engine import simulate


@dataclass
class CostModel:
    cfg: SimConfig
    cold_walk_ns: float = None   # filled by __post_init__ / fit()
    warm_walk_ns: float = None

    def __post_init__(self):
        tr = self.cfg.translation
        n_pwc = len(tr.pwc.entries)
        if self.cold_walk_ns is None:
            # L1 miss + L2 miss + all-PWC-miss walk + leaf PTE read.
            self.cold_walk_ns = (tr.l1.hit_latency_ns + tr.l2.hit_latency_ns
                                 + n_pwc * (tr.pwc.lookup_latency_ns
                                            + tr.mem_access_ns)
                                 + tr.mem_access_ns)
        if self.warm_walk_ns is None:
            # L1 miss + L2 miss + all-PWC-hit walk + leaf PTE read.
            self.warm_walk_ns = (tr.l1.hit_latency_ns + tr.l2.hit_latency_ns
                                 + n_pwc * tr.pwc.lookup_latency_ns
                                 + tr.mem_access_ns)

    # ------------------------------------------------------------------
    def _terms(self, nbytes: int, with_rat: bool) -> Dict[str, float]:
        fab = self.cfg.fabric
        tr = self.cfg.translation
        n = fab.n_gpus
        chunk = nbytes // n
        svc = fab.request_bytes / fab.station_bw
        cover = fab.ingress_entries * svc
        alpha = fab.oneway_ns + fab.hbm_ns + fab.return_ns
        bw = (max(0, math.ceil(chunk / fab.request_bytes)) - 1) \
            * fab.request_bytes * (n - 1) / fab.gpu_bw
        terms = {"alpha": alpha, "bandwidth": bw, "cold": 0.0, "warm": 0.0}
        if not with_rat or not tr.enabled:
            return terms

        # Cold stall: the startup walk(s) outlast the ingress cover once the
        # buffer actually fills (enough requests must remain).
        reqs_per_station = (chunk * (n - 1) / fab.request_bytes
                            / fab.stations_per_gpu)
        l1 = tr.l1.hit_latency_ns
        if reqs_per_station >= fab.ingress_entries:
            terms["cold"] = max(0.0, self.cold_walk_ns - l1 - cover)
        else:
            # Buffer absorbs the whole stream; the walk still gates the last
            # request's completion if it outlasts the stream.
            stream = bw
            terms["cold"] = max(0.0, self.cold_walk_ns - stream)

        # Warm page-transition residue (per flow, pages after the first; the
        # stall — if any — hits every station and persists).
        pages_per_flow = max(1, math.ceil(chunk / tr.page_bytes))
        residue = max(0.0, self.warm_walk_ns - l1 - cover)
        if reqs_per_station >= fab.ingress_entries:
            terms["warm"] = residue * (pages_per_flow - 1) * (n - 1)
        return terms

    def collective_time_ns(self, nbytes: int, with_rat: bool = True) -> float:
        return sum(self._terms(nbytes, with_rat).values())

    def degradation(self, nbytes: int) -> float:
        return (self.collective_time_ns(nbytes, True)
                / self.collective_time_ns(nbytes, False))

    # ------------------------------------------------------------------
    def validate(self, sizes) -> Dict[int, Tuple[float, float, float]]:
        """(model, sim, rel-err) of baseline completion per size."""
        out = {}
        for s in sizes:
            sim = simulate(s, self.cfg).completion_ns
            mod = self.collective_time_ns(s)
            out[s] = (mod, sim, abs(mod - sim) / sim)
        return out


def for_pod(n_gpus: int, **kw) -> CostModel:
    return CostModel(cfg=paper_config(n_gpus, **kw))
