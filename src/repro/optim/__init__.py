from .optimizers import (adamw, adafactor, with_master, Optimizer,
                         global_norm, clip_by_global_norm)
from .schedules import cosine_with_warmup

__all__ = ["adamw", "adafactor", "with_master", "Optimizer", "global_norm",
           "clip_by_global_norm", "cosine_with_warmup"]
