"""Sharded optimizers in pure JAX: AdamW and Adafactor.

Optimizer state mirrors parameter sharding (`state_specs` derives the
logical-axis pytree for the state from the parameter specs), giving
ZeRO-style fully-sharded optimizer state for free under pjit.

Adafactor (factored second moment) is the default for the >100 B-parameter
architectures: state is O(rows + cols) instead of O(rows x cols), which is
what lets mistral-123B / qwen3-moe-235B / jamba-398B fit a 256-chip v5e pod
(see DESIGN.md §5 and the dry-run memory analysis).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp


def with_master(inner: "Optimizer", master_dtype=jnp.float32) -> "Optimizer":
    """Mixed precision: bf16 working params, f32 master copy in the state.

    The model/collectives see bf16 weights (halving FSDP all-gather volume);
    the update applies to the f32 master and re-casts.  Standard MaxText /
    Megatron mixed-precision layout."""

    def init(params):
        master = jax.tree.map(
            lambda p: p.astype(master_dtype)
            if jnp.issubdtype(p.dtype, jnp.floating) else p, params)
        return {"master": master, "inner": inner.init(master)}

    def update(grads, state, params, _step=None):
        grads32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        new_master, new_inner = inner.update(grads32, state["inner"],
                                             state["master"])
        new_params = jax.tree.map(
            lambda m, p: m.astype(p.dtype), new_master, params)
        return new_params, {"master": new_master, "inner": new_inner}

    def state_specs(param_specs, param_shapes):
        return {"master": param_specs,
                "inner": inner.state_specs(param_specs, param_shapes)}

    return Optimizer(init=init, update=update, state_specs=state_specs)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), tree), norm


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jnp.ndarray], Tuple[Any, Any]]
    # (param logical specs, param shape pytree) -> state logical specs
    state_specs: Callable[[Any, Any], Any]


# --------------------------------------------------------------------- AdamW
def adamw(schedule, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1, state_dtype=jnp.float32) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, state_dtype)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, _step=None):
        count = state["count"] + 1
        lr = schedule(count)
        c1 = 1 - b1 ** count.astype(jnp.float32)
        c2 = 1 - b2 ** count.astype(jnp.float32)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g
            v_new = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
            step = (m_new / c1) / (jnp.sqrt(v_new / c2) + eps)
            step = step + weight_decay * p.astype(jnp.float32)
            new_p = p.astype(jnp.float32) - lr * step
            return new_p.astype(p.dtype), m_new.astype(state_dtype), \
                v_new.astype(state_dtype)

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        is_t = lambda x: isinstance(x, tuple)
        new_p = jax.tree.map(lambda t: t[0], out, is_leaf=is_t)
        new_m = jax.tree.map(lambda t: t[1], out, is_leaf=is_t)
        new_v = jax.tree.map(lambda t: t[2], out, is_leaf=is_t)
        return new_p, {"m": new_m, "v": new_v, "count": count}

    def state_specs(param_specs, param_shapes=None):
        return {"m": param_specs, "v": param_specs, "count": ()}

    return Optimizer(init=init, update=update, state_specs=state_specs)


# ----------------------------------------------------------------- Adafactor
def adafactor(schedule, decay: float = 0.8, eps: float = 1e-30,
              clip_threshold: float = 1.0,
              weight_decay: float = 0.0,
              min_dim_size_to_factor: int = 128) -> Optimizer:
    """Adafactor (Shazeer & Stern) with factored 2nd moment for big matrices."""

    def _factored(p) -> bool:
        return (p.ndim >= 2
                and p.shape[-1] >= min_dim_size_to_factor
                and p.shape[-2] >= min_dim_size_to_factor)

    def init(params):
        def one(p):
            if _factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"v": jax.tree.map(one, params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, _step=None):
        count = state["count"] + 1
        lr = schedule(count)
        beta = 1.0 - count.astype(jnp.float32) ** (-decay)

        def upd(g, v, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if _factored(p):
                vr = beta * v["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * v["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                denom = (vr[..., None] / jnp.mean(vr, axis=-1, keepdims=True)[..., None]
                         ) * vc[..., None, :]
                u = g * jax.lax.rsqrt(denom + eps)
                nv = {"vr": vr, "vc": vc}
            else:
                nv = {"v": beta * v["v"] + (1 - beta) * g2}
                u = g * jax.lax.rsqrt(nv["v"] + eps)
            # update clipping (RMS <= clip_threshold)
            rms = jnp.sqrt(jnp.mean(u * u) + 1e-30)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            new_p = (p.astype(jnp.float32) - lr * u
                     - lr * weight_decay * p.astype(jnp.float32))
            return new_p.astype(p.dtype), nv

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_v = tdef.flatten_up_to(state["v"])
        outs = [upd(g, v, p) for g, v, p in zip(flat_g, flat_v, flat_p)]
        new_p = tdef.unflatten([o[0] for o in outs])
        new_v = tdef.unflatten([o[1] for o in outs])
        return new_p, {"v": new_v, "count": count}

    def state_specs(param_specs, param_shapes):
        def one(spec, p):
            spec = tuple(spec)
            if _factored(p):
                return {"vr": spec[:-1], "vc": spec[:-2] + spec[-1:]}
            return {"v": spec}
        return {"v": jax.tree.map(one, param_specs, param_shapes,
                                  is_leaf=lambda x: isinstance(x, tuple)),
                "count": ()}

    return Optimizer(init=init, update=update, state_specs=state_specs)
