"""Replay a derived workload through persistent-TLB sessions (DESIGN.md §8).

Two sessions run the same :class:`~repro.workloads.derive.WorkloadTrace`
call-for-call: a baseline (full Reverse Address Translation) and an ideal
(translation disabled).  Compute windows advance both clocks identically, so
per-step degradation is purely the communication-time ratio — token 0 pays
the cold Link-TLB walks, steady-state tokens reuse the warmed entries, and
the trajectory between the two is the paper's inference-serving answer.

Each logical buffer of the trace is laid out in its own page-aligned region
of the target NPA space, so distinct buffers (dispatch vs combine vs
activations vs per-layer gradients) touch distinct Link-TLB entries while
repeated calls on the same buffer hit warm ones.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

from ..core.config import SimConfig
from ..core.session import CollectiveResult, SimSession
from .derive import WorkloadTrace, pod_fabric


@dataclass
class StepStats:
    """Communication statistics of one model step (decode: one token)."""

    step: int
    comm_ns: float = 0.0        # sum of collective completion times
    ideal_comm_ns: float = 0.0
    compute_ns: float = 0.0     # roofline compute windows (both sessions)
    walks: int = 0
    requests: int = 0

    @property
    def degradation(self) -> float:
        return (self.comm_ns / self.ideal_comm_ns
                if self.ideal_comm_ns else float("nan"))


@dataclass
class ReplayResult:
    trace: WorkloadTrace
    cfg: SimConfig
    steps: List[StepStats]
    calls: List[CollectiveResult] = field(default_factory=list)
    ideal_calls: List[CollectiveResult] = field(default_factory=list)

    @property
    def cold_degradation(self) -> float:
        """Step-0 (cold-TLB) communication degradation."""
        return self.steps[0].degradation

    @property
    def steady_degradation(self) -> float:
        """Steady-state degradation: mean over the second half of the steps
        (always excluding step 0 when more than one step was replayed)."""
        if len(self.steps) == 1:
            return self.steps[0].degradation
        tail = self.steps[max(1, len(self.steps) // 2):]
        return sum(s.degradation for s in tail) / len(tail)

    @property
    def total_comm_ns(self) -> float:
        return sum(s.comm_ns for s in self.steps)


def buffer_layout(trace: WorkloadTrace, page_bytes: int) -> Dict[str, int]:
    """Page-aligned base offset per logical buffer of the trace.

    A buffer's region spans twice its largest collective (hierarchical
    patterns stage above the final buffer), rounded up to whole pages.
    """
    sizes: Dict[str, int] = {}
    for c in trace.calls:
        sizes[c.buffer] = max(sizes.get(c.buffer, 0), 2 * c.nbytes)
    layout: Dict[str, int] = {}
    off = 0
    for name in sizes:                       # insertion = first-use order
        layout[name] = off
        pages = -(-sizes[name] // page_bytes)
        off += (pages + 1) * page_bytes
    return layout


def replay(trace: WorkloadTrace, *, cfg: Optional[SimConfig] = None,
           include_ideal: bool = True,
           compute_profile=None) -> ReplayResult:
    """Replay ``trace`` through a warm session (and its ideal twin).

    ``compute_profile`` re-resolves every phase-tagged compute gap from the
    profile's measured windows at replay time (both sessions age
    identically, so degradation stays a pure communication ratio); ``None``
    keeps the trace's derived gaps bit-for-bit.  A trace already derived
    *with* the profile replays identically either way — re-application is
    idempotent.

    The default config simulates the pod the trace was derived for,
    including its topology and tier parameters (:func:`~repro.workloads.
    derive.pod_fabric`); pass ``cfg`` to override fabric or translation
    knobs.
    """
    cfg = cfg or SimConfig(fabric=pod_fabric(trace.pod))
    if cfg.fabric.n_gpus != trace.pod.n_gpus:
        raise ValueError(
            f"cfg pod size {cfg.fabric.n_gpus} != trace pod size "
            f"{trace.pod.n_gpus}")
    layout = buffer_layout(trace, cfg.translation.page_bytes)
    sess = SimSession(cfg, compute_profile=compute_profile)
    ideal = (SimSession(cfg.ideal(), compute_profile=compute_profile)
             if include_ideal else None)

    steps: Dict[int, StepStats] = {}
    calls: List[CollectiveResult] = []
    ideal_calls: List[CollectiveResult] = []
    # With translation disabled a collective's duration depends only on its
    # signature, not on session time or warmth — price each signature once.
    ideal_ns: Dict[tuple, float] = {}
    for c in trace.calls:
        kw = dict(collective=c.collective, n_gpus=c.group,
                  rank_stride=c.stride,
                  gap_ns=c.compute_ns, base_offset=layout[c.buffer],
                  label=c.label, phase=c.phase,
                  window_parts=c.window_parts)
        rec = sess.run(c.nbytes, **kw)
        calls.append(rec)
        st = steps.setdefault(c.step, StepStats(step=c.step))
        st.comm_ns += rec.completion_ns
        st.compute_ns += sess.resolve_gap(c.compute_ns, c.phase,
                                          c.window_parts)
        st.walks += rec.counters.walks
        st.requests += rec.counters.requests
        if ideal is not None:
            sig = (c.collective, c.nbytes, c.group, c.stride)
            if sig not in ideal_ns:
                irec = ideal.run(c.nbytes, **kw)
                ideal_calls.append(irec)
                ideal_ns[sig] = irec.completion_ns
            st.ideal_comm_ns += ideal_ns[sig]

    return ReplayResult(trace=trace, cfg=cfg,
                        steps=[steps[k] for k in sorted(steps)],
                        calls=calls, ideal_calls=ideal_calls)
