"""Derive per-step collective sequences from real model configs (DESIGN.md §8).

The repo carries 12 real architecture configs (:mod:`repro.configs`) and a
RAT simulator (:mod:`repro.core`) that, until now, only priced free-standing
collectives.  This module connects them: given a model config, an input
shape (``decode_32k`` / ``prefill_32k`` / ``train_4k``) and a pod
description, it emits the ordered sequence of collectives one model step
actually fires — sized from the model's own dimensions — ready to replay
through a persistent-TLB session (:mod:`repro.workloads.replay`).

Derivation formulas (first-order, documented in DESIGN.md §8):

* **MoE expert-parallel dispatch/combine** (the paper's collective): the
  ``lax.all_to_all`` of :func:`repro.models.moe.moe_block_ep` exchanges a
  ``[ep, C, d_model]`` buffer where ``C = max(8, T_loc*top_k*cf/E) * E_loc``
  — so ``bytes = ep * C * d_model * dtype_bytes``, twice per MoE layer.
* **Tensor-parallel activation collectives**: sequence-parallel Megatron
  form — one all-gather + one reduce-scatter of the full activation
  (``T_step * d_model * dtype_bytes``) around each sharded sublayer.
* **Data-parallel gradient sync** (train only): one ring all-reduce per
  layer of that layer's TP-sharded parameter bytes, each layer a distinct
  buffer (cold pages every step — unlike the reused activation buffers).
* **Compute windows**: roofline gaps between collectives,
  ``flops / (peak_tflops * mfu)``, with fwd ``2·P_active·T`` (×3 for train).

Pure-Python sizing only — importing this module does not import jax, and
neither does the registry lookup (``arch`` by name): :mod:`repro.configs`
resolves architectures through the jax-free :mod:`repro.models.spec`.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, List, Optional

from ..core.config import FabricConfig
from ..core.select import get_policy
from ..core.topology import get_topology

if TYPE_CHECKING:  # pragma: no cover - typing only
    from ..models.base import ModelConfig


@dataclass(frozen=True)
class PodSpec:
    """The scale-up pod a workload is mapped onto.

    ``ep``/``tp``/``dp`` default per shape kind (see :func:`resolve_pod`):
    inference uses the largest tier-0 group for TP (the whole pod on the
    flat default, one leaf/pod on hierarchical topologies) and the largest
    compatible EP group — which may span tiers, so MoE dispatch/combine
    crosses the oversubscribed uplink while TP activation collectives stay
    on cheap intra-tier paths; train splits the pod into TP x DP replicas
    the same way.
    """

    n_gpus: int = 16
    ep: Optional[int] = None       # expert-parallel group size
    tp: Optional[int] = None       # tensor-parallel group size
    dp: Optional[int] = None       # data-parallel replicas inside the pod
    dtype_bytes: int = 2           # bf16 activations
    grad_bytes: int = 2            # bf16 gradient all-reduce
    peak_tflops: float = 990.0     # dense bf16 peak per GPU
    mfu: float = 0.4               # achieved fraction of peak in compute
    microbatch_tokens: int = 8192  # prefill/train tokens per microbatch
    # Buffer granularity.  "per_layer": zero-copy semantics — collectives
    # write directly into each layer's persistent tensors, so every layer
    # owns distinct pages (UALink remote stores target the real destination
    # buffer; this is the faithful default).  "pooled": all layers exchange
    # through one reused communication arena per collective kind
    # (NCCL-channel-style staging), collapsing the Link-TLB working set.
    buffer_reuse: str = "per_layer"
    # Pod topology (repro.core.topology) + tier parameters, mirrored into
    # the replay FabricConfig so the derived EP/TP/DP placement and the
    # simulated fabric agree.
    topology: str = "single_clos"
    leaf_size: int = 0             # two_tier leaf (0 => fabric default)
    oversubscription: float = 1.0  # two_tier leaf->spine uplink
    pod_size: int = 0              # multi_pod pod (0 => whole fabric)


def pod_fabric(pod: PodSpec) -> FabricConfig:
    """The :class:`FabricConfig` a pod spec describes (replay + placement)."""
    return FabricConfig(n_gpus=pod.n_gpus, topology=pod.topology,
                        leaf_size=pod.leaf_size,
                        oversubscription=pod.oversubscription,
                        pod_size=pod.pod_size)


@dataclass(frozen=True)
class CollectiveCall:
    """One collective of the derived sequence."""

    label: str          # e.g. "tok0/L3/moe_dispatch"
    collective: str     # concrete pattern registry name (resolved)
    nbytes: int         # per-GPU buffer size (pattern semantics)
    group: int          # participating GPU count
    compute_ns: float   # compute window preceding this collective
    buffer: str         # logical buffer id (distinct ids -> distinct pages)
    step: int           # model step (decode: token index)
    # Pod-rank stride of the group (SimSession.run rank_stride): a DP
    # replica group has one member per TP island, so its ring sits on
    # ranks 0, tp, 2*tp, ... — on hierarchical topologies that is what
    # makes gradient sync cross tiers.  1 = contiguous ranks.
    stride: int = 1
    # Provenance of the window: the calibration phase whose *entire*
    # per-layer window precedes this call ("" when the gap is zero or an
    # accumulation of carried sublayer windows).  Lets a ComputeProfile be
    # re-applied at replay time without re-deriving the trace.
    phase: str = ""
    # Exact decomposition of ``compute_ns`` into (phase, ns) sublayer
    # windows, carried windows included in accumulation order — so
    # replay-time profile application (SimSession.resolve_gap) reproduces
    # derive-time application bit-for-bit even when tp == 1 folds several
    # sublayer windows into one gap.  Empty when the gap is zero.
    window_parts: tuple = ()
    # Resolution provenance (DESIGN.md §14): the *logical* collective the
    # emitter requested ("allreduce", "all_to_all", ...) and which policy
    # decision resolved it to ``collective`` ("fixed", "auto:cold",
    # "table:warm", ...).  Empty strings on hand-built traces.
    logical: str = ""
    resolved_by: str = ""


@dataclass
class WorkloadTrace:
    """A derived sequence of collectives plus its provenance."""

    arch: str
    shape: str
    pod: PodSpec
    calls: List[CollectiveCall] = field(default_factory=list)
    tokens_per_step: int = 0
    n_microbatches: int = 1     # prefill/train: microbatches per full pass

    @property
    def n_steps(self) -> int:
        return (self.calls[-1].step + 1) if self.calls else 0

    def step_calls(self, step: int) -> List[CollectiveCall]:
        return [c for c in self.calls if c.step == step]

    def total_bytes(self) -> int:
        return sum(c.nbytes for c in self.calls)


def _largest_common_group(pod_gpus: int, n_experts: int) -> int:
    """Largest EP group that divides both the pod and the expert count."""
    return math.gcd(pod_gpus, n_experts)


def resolve_pod(pod: PodSpec, cfg: "ModelConfig", kind: str) -> PodSpec:
    """Fill in default ep/tp/dp for a shape kind (see module docstring)."""
    n = pod.n_gpus
    ep = pod.ep
    if ep is None:
        ep = _largest_common_group(n, cfg.n_experts) if cfg.n_experts else 1
    elif ep > 1:
        # A user-supplied EP group must be realizable: moe_block_ep shards
        # experts exactly (E_loc = E // ep) inside the pod.
        if ep > n:
            raise ValueError(f"ep({ep}) exceeds pod n_gpus({n})")
        if cfg.n_experts % ep:
            raise ValueError(
                f"ep({ep}) does not divide n_experts({cfg.n_experts})")
    tp = pod.tp
    dp = pod.dp
    # TP activation collectives are latency-bound and fire twice per
    # sublayer: map them onto the largest all-pairs-tier-0 group (the whole
    # pod on the flat default — unchanged — one leaf / one pod on
    # hierarchical topologies).  EP keeps its expert-divisibility group and
    # may span tiers: the MoE a2a is exactly the cross-tier traffic.
    tier0 = get_topology(pod_fabric(pod)).tier0_group()
    if kind == "train":
        if tp is None:
            cap = min(8, tier0)
            tp = 1
            while tp * 2 <= cap and tp * 2 <= n and n % (tp * 2) == 0:
                tp *= 2
        if dp is None:
            dp = n // tp
    else:
        if tp is None:
            tp = min(n, tier0)
        if dp is None:
            dp = n // tp
    if tp * dp != n:
        raise ValueError(f"tp({tp}) x dp({dp}) != pod n_gpus({n})")
    return dataclasses.replace(pod, ep=ep, tp=tp, dp=dp)


def _layer_is_moe(cfg: "ModelConfig", i: int) -> bool:
    return cfg.n_experts > 0 and i % cfg.moe_every == cfg.moe_every - 1


def _attn_params(cfg: "ModelConfig") -> int:
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    return d * h * dh * 2 + d * kv * dh * 2      # q,o + k,v projections


def _ffn_params(cfg: "ModelConfig", i: int, active: bool) -> int:
    if _layer_is_moe(cfg, i):
        experts = cfg.top_k if active else cfg.n_experts
        return (3 * cfg.d_model * cfg.d_ff_expert * experts
                + cfg.d_model * cfg.n_experts)   # experts + router
    return 3 * cfg.d_model * cfg.d_ff if cfg.d_ff > 0 else 0


def layer_param_bytes(cfg: "ModelConfig", i: int, grad_bytes: int) -> int:
    """Total parameter bytes of layer ``i`` (full experts, for grad sync)."""
    return (_attn_params(cfg) + _ffn_params(cfg, i, active=False)) * grad_bytes


def moe_a2a_bytes(cfg: "ModelConfig", tokens_local: int, ep: int,
                  dtype_bytes: int) -> int:
    """Per-GPU bytes of one EP dispatch/combine all-to-all.

    Mirrors :func:`repro.models.moe.moe_block_ep`: the send buffer is
    ``[ep, C, d_model]`` with ``C = _capacity(T_loc) * E_loc`` and
    ``_capacity`` = ``max(8, T_loc * top_k * capacity_factor / E)``.
    """
    e_loc = cfg.n_experts // ep
    cap = max(8, int(tokens_local * cfg.top_k * cfg.capacity_factor
                     / cfg.n_experts))
    return ep * cap * e_loc * cfg.d_model * dtype_bytes


def kv_transfer_fabric(pod: PodSpec) -> FabricConfig:
    """The prefill→decode pair fabric one KV handoff is priced on.

    Two ``pod.n_gpus``-GPU pods joined over the ``multi_pod`` scale-out hop
    (pod 0 = prefill ranks, pod 1 = decode ranks), so every transfer flow
    crosses the oversubscribed inter-pod tier and pays reverse translation
    at the decode pod's Link-MMU (DESIGN.md §16).  The pods' internal
    topology is irrelevant here — the ``kv_transfer`` patterns emit only
    cross-pod flows — so the pair fabric is always ``multi_pod`` regardless
    of ``pod.topology``.
    """
    return FabricConfig(n_gpus=2 * pod.n_gpus, topology="multi_pod",
                        pod_size=pod.n_gpus)


def kv_shard_bytes(cfg: "ModelConfig", prompt_tokens: int,
                   pod: PodSpec) -> int:
    """Per-GPU KV shard of one request's handoff (pattern ``nbytes``).

    The prompt's full KV cache — ``kv_bytes_per_token * prompt_tokens`` —
    is sharded across the prefill pod's GPUs, so each of the ``pod.n_gpus``
    transfer pairs moves the ceiling share.  This is the per-GPU buffer
    size :class:`~repro.core.patterns.KVTransfer` expects.
    """
    total = cfg.kv_bytes_per_token(pod.dtype_bytes) * prompt_tokens
    return max(1, -(-total // pod.n_gpus))


def derive_kv_transfer(cfg: "ModelConfig", prompt_tokens: int, pod: PodSpec,
                       *, policy=None, state: str = "cold",
                       label: str = "kv_transfer",
                       step: int = 0) -> CollectiveCall:
    """The KV-cache handoff of one prefilled request as a CollectiveCall.

    Requested logically as ``"kv_transfer"`` and resolved by ``policy``
    (DESIGN.md §14) keyed on the decode arena's TLB ``state`` — so a table
    or auto policy can pick the striped re-shard variant where it wins,
    while the fixed default keeps the rail-aligned push.  ``group`` is the
    whole pair fabric (``2 * pod.n_gpus``).
    """
    fab = kv_transfer_fabric(pod)
    nbytes = kv_shard_bytes(cfg, prompt_tokens, pod)
    pol = get_policy(policy) or get_policy("fixed")
    res = pol.resolve("kv_transfer", nbytes, fab, state=state)
    return CollectiveCall(
        label=label, collective=res.collective, nbytes=nbytes,
        group=fab.n_gpus, compute_ns=0.0, buffer="kv_arena", step=step,
        logical=res.logical, resolved_by=res.provenance)


def _compute_ns(flops_per_gpu: float, pod: PodSpec) -> float:
    return flops_per_gpu / (pod.peak_tflops * 1e3 * pod.mfu)


def step_shape(spec, pod: PodSpec):
    """(t_step, n_microbatches, flop_mult) of one model step of ``spec``.

    Single source of truth shared by :func:`derive_workload` and the
    calibration harness (:mod:`repro.workloads.calibrate`), so measured
    windows are anchored to exactly the rooflines derivation emits.
    """
    total_tokens = spec.global_batch * (1 if spec.kind == "decode"
                                        else spec.seq_len)
    if spec.kind == "decode":
        t_step, n_micro = spec.global_batch, 1
    else:
        t_step = min(pod.microbatch_tokens, total_tokens)
        n_micro = -(-total_tokens // t_step)
    return t_step, n_micro, (3.0 if spec.kind == "train" else 1.0)


def layer_roofline_ns(cfg: "ModelConfig", i: int, t_step: int,
                      pod: PodSpec, flop_mult: float):
    """Roofline (mixer_ns, ffn_ns) compute windows of layer ``i``."""
    mixer_ns = _compute_ns(
        flop_mult * 2.0 * _attn_params(cfg) * t_step / pod.tp, pod)
    is_moe = _layer_is_moe(cfg, i)
    ffn_ns = _compute_ns(
        flop_mult * 2.0 * _ffn_params(cfg, i, active=True)
        * t_step / (pod.ep if is_moe and pod.ep > 1 else pod.tp), pod)
    return mixer_ns, ffn_ns


class StepEmitter:
    """Emits the per-layer collective sequence of model steps.

    Single source of truth for the per-layer emission loop, shared by
    :func:`derive_workload` (fixed ``t_step`` per shape spec) and the
    serving layer (:mod:`repro.serving`), where each step's ``t_step`` is
    the *live* batch composition — decode tokens plus admitted prefill
    chunk — so collective sizes track continuous batching step by step.

    Compute windows accumulate between emitted collectives: when a sublayer
    emits no traffic (e.g. ``tp == 1``), its window still ages the session
    and is delivered as the next call's gap.  ``_pending_parts`` records the
    ``(phase, ns)`` decomposition of the carried amount so the gap stays
    re-resolvable against a compute profile at replay time.  The pending
    state persists across :meth:`step` calls, exactly as a session clock
    would.

    Collectives are requested *logically* ("allreduce", "all_to_all", ...)
    and resolved to a concrete algorithm by ``policy`` (an
    :class:`~repro.core.select.AlgorithmPolicy` or spec string; default
    fixed — bit-for-bit the historical hard-coded choices).  Resolution is
    keyed on the logical buffer's TLB state: the first emission on a buffer
    since the last :meth:`mark_cold` resolves as cold, repeats as warm —
    the serving layer calls :meth:`mark_cold` whenever an idle gap crosses
    the retention window, so post-flush steps re-select cold-optimal
    algorithms.
    """

    def __init__(self, cfg: "ModelConfig", pod: PodSpec, window=None,
                 policy=None):
        from .calibrate import ffn_phase, mixer_phase   # pure-python helpers
        self.cfg = cfg
        self.pod = pod
        # window(phase, roofline_ns) -> ns: profile resolution hook.
        self.window = window if window is not None else (lambda ph, ns: ns)
        self.policy = get_policy(policy) or get_policy("fixed")
        self._fab = pod_fabric(pod)
        self._warm_buffers: set = set()
        self.calls: List[CollectiveCall] = []
        self._mixer_phase = mixer_phase
        self._ffn_phase = ffn_phase
        self._pending_ns = 0.0
        self._pending_parts: List[tuple] = []

    def mark_cold(self) -> None:
        """Forget buffer warmth (the emitter-side mirror of a TLB flush)."""
        self._warm_buffers.clear()

    def emit(self, label, collective, nbytes, group, compute_ns, buffer,
             step, phase="", stride=1):
        fab_g = (self._fab if group == self._fab.n_gpus
                 else dataclasses.replace(self._fab, n_gpus=group))
        res = self.policy.resolve(
            collective, nbytes, fab_g,
            state="warm" if buffer in self._warm_buffers else "cold")
        self._warm_buffers.add(buffer)
        parts = list(self._pending_parts)
        if compute_ns or phase:
            parts.append((phase, compute_ns))
        # A carried window mixes sublayer provenances: drop the single-phase
        # tag (window_parts keeps the exact decomposition).
        if self._pending_ns:
            phase = ""
        self.calls.append(CollectiveCall(
            label, res.collective, nbytes, group,
            compute_ns=compute_ns + self._pending_ns, buffer=buffer,
            step=step, phase=phase, window_parts=tuple(parts),
            stride=stride, logical=res.logical,
            resolved_by=res.provenance))
        self._pending_ns = 0.0
        self._pending_parts = []

    def carry(self, phase: str, compute_ns: float) -> None:
        """Accumulate a window that emits no traffic of its own."""
        self._pending_ns += compute_ns
        self._pending_parts.append((phase, compute_ns))

    def step(self, step: int, t_step: int, *, flop_mult: float = 1.0,
             prefix: Optional[str] = None) -> None:
        """Emit one model step (every layer) over ``t_step`` active tokens.

        ``prefix`` overrides the default ``s{step}`` label prefix (serving
        labels steps by request batch instead).
        """
        cfg, pod = self.cfg, self.pod
        ep, tp = pod.ep, pod.tp
        prefix = f"s{step}" if prefix is None else prefix
        per_layer = pod.buffer_reuse == "per_layer"
        actv_bytes = t_step * cfg.d_model * pod.dtype_bytes
        t_loc = max(1, t_step // ep)
        a2a = (moe_a2a_bytes(cfg, t_loc, ep, pod.dtype_bytes)
               if cfg.n_experts and ep > 1 else 0)
        for i in range(cfg.n_layers):
            tag = f"{prefix}/L{i}"
            suffix = f"_l{i}" if per_layer else ""
            mp, fp = self._mixer_phase(cfg, i), self._ffn_phase(cfg, i)
            roof_mixer, roof_ffn = layer_roofline_ns(cfg, i, t_step, pod,
                                                     flop_mult)
            attn_ns = self.window(mp, roof_mixer)
            is_moe = _layer_is_moe(cfg, i)
            ffn_ns = self.window(fp, roof_ffn)
            # Mixer sublayer (attention or SSM): sequence-parallel TP pair,
            # ag -> mixer compute -> rs (the compute window sits between the
            # pair, so it is the rs that finds aged TLBs under retention).
            if tp > 1:
                self.emit(f"{tag}/mixer_ag", "all_gather", actv_bytes, tp,
                          0.0, "actv" + suffix, step)
                self.emit(f"{tag}/mixer_rs", "reduce_scatter", actv_bytes,
                          tp, attn_ns, "actv" + suffix, step, phase=mp)
            else:
                self.carry(mp, attn_ns)
            # FFN sublayer: EP all-to-all pair for MoE layers (dispatch ->
            # expert compute -> combine); MoE without an EP group (ep == 1,
            # all experts local) and dense FFNs shard over TP instead.
            if is_moe and a2a > 0:
                self.emit(f"{tag}/moe_dispatch", "all_to_all", a2a, ep,
                          0.0, "moe_disp" + suffix, step)
                self.emit(f"{tag}/moe_combine", "all_to_all", a2a, ep,
                          ffn_ns, "moe_comb" + suffix, step, phase=fp)
            elif tp > 1 and (cfg.d_ff > 0 or is_moe):
                self.emit(f"{tag}/ffn_ag", "all_gather", actv_bytes, tp,
                          0.0, "actv" + suffix, step)
                self.emit(f"{tag}/ffn_rs", "reduce_scatter", actv_bytes, tp,
                          ffn_ns, "actv" + suffix, step, phase=fp)
            else:
                self.carry(fp, ffn_ns)


def derive_workload(arch, shape: str, *, pod: Optional[PodSpec] = None,
                    n_gpus: Optional[int] = None,
                    n_steps: int = 1,
                    compute_profile=None,
                    policy=None) -> WorkloadTrace:
    """Derive the collective sequence of ``n_steps`` model steps.

    ``arch`` is a registry name (``"qwen3-moe-235b-a22b"``) or a
    ``ModelConfig``; ``shape`` names a :data:`repro.configs.shapes.SHAPES`
    entry.  One *step* is one decoded token position (``decode``) or one
    microbatch forward/train pass (``prefill``/``train``); successive steps
    repeat the same per-layer sequence on the same buffers, which is what a
    persistent-TLB replay turns into a warm-vs-cold trajectory.

    ``compute_profile`` (a :class:`repro.workloads.calibrate.ComputeProfile`
    for this exact ``(arch, shape, pod)``) replaces the roofline compute
    windows with the profile's measured-and-calibrated per-phase windows;
    ``None`` (the default) keeps the roofline bit-for-bit.

    ``policy`` selects the concrete algorithm per logically-requested
    collective (:mod:`repro.core.select`); ``None``/``"fixed"`` reproduces
    the historical hard-coded choices bit-for-bit, and each emitted
    :class:`CollectiveCall` records the logical name plus the resolving
    decision (``logical``/``resolved_by``).
    """
    if isinstance(arch, str):
        from ..configs import get_config            # jax-free registry
        cfg = get_config(arch)
    else:
        cfg = arch
    from ..configs.shapes import SHAPES             # pure-python
    spec = SHAPES[shape]

    pod = pod or PodSpec()
    if n_gpus is not None:
        pod = dataclasses.replace(pod, n_gpus=n_gpus)
    pod = resolve_pod(pod, cfg, spec.kind)
    ep, tp, dp = pod.ep, pod.tp, pod.dp

    if compute_profile is not None and not compute_profile.matches(
            cfg.name, shape, pod.n_gpus, ep, tp, dp):
        raise ValueError(
            f"compute profile ({compute_profile.arch}/{compute_profile.shape}"
            f"/g{compute_profile.n_gpus} ep={compute_profile.ep} "
            f"tp={compute_profile.tp} dp={compute_profile.dp}) does not "
            f"match workload ({cfg.name}/{shape}/g{pod.n_gpus} ep={ep} "
            f"tp={tp} dp={dp})")

    def window(phase: str, roofline_ns: float) -> float:
        if compute_profile is not None:
            w = compute_profile.window_ns(phase)
            if w is not None:
                return w
        return roofline_ns

    t_step, n_micro, flop_mult = step_shape(spec, pod)

    trace = WorkloadTrace(arch=cfg.name, shape=shape, pod=pod,
                          tokens_per_step=t_step, n_microbatches=n_micro)
    em = StepEmitter(cfg, pod, window=window, policy=policy)
    trace.calls = em.calls
    for step in range(n_steps):
        em.step(step, t_step, flop_mult=flop_mult)
        # Train: bucketed gradient sync, one ring all-reduce per layer over
        # the DP group.  Distinct buffer per layer: gradient regions are as
        # large as the weights and never share pages with activations.
        # DP replicas sit one per TP island (ranks p, p+tp, p+2*tp, ...),
        # so on hierarchical topologies the ring is strided across tiers —
        # gradient sync is cross-tier traffic.  On the flat default the
        # stride is immaterial (any rank labeling is isomorphic) and is
        # kept at 1, bit-for-bit the pre-topology trace.
        if spec.kind == "train" and dp > 1:
            grad_stride = tp if pod.topology != "single_clos" else 1
            for i in range(cfg.n_layers):
                nb = max(1, layer_param_bytes(cfg, i, pod.grad_bytes) // tp)
                em.emit(f"s{step}/L{i}/grad_ar", "allreduce", nb, dp,
                        0.0, f"grad_l{i}", step, stride=grad_stride)
    return trace
