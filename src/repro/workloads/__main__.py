"""CLI: derive a model workload and replay it through a warm-TLB session.

    PYTHONPATH=src python -m repro.workloads \
        --arch qwen3-moe-235b-a22b --shape decode_32k --gpus 16 --steps 4

Prints the derived collective mix, then the per-step (per-token for decode)
communication-degradation trajectory: step 0 pays the cold Link-TLB walks,
later steps reuse the warmed entries.
"""
from __future__ import annotations

import argparse
from collections import Counter

from ..core.config import paper_config
from .derive import PodSpec, derive_workload
from .replay import replay


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.workloads",
        description="Replay a model-derived collective sequence through the "
                    "RAT simulator with persistent (warm) Link TLBs.")
    p.add_argument("--arch", required=True,
                   help="architecture registry name, e.g. qwen3-moe-235b-a22b")
    p.add_argument("--shape", default="decode_32k",
                   help="input shape: decode_32k | prefill_32k | train_4k")
    p.add_argument("--gpus", type=int, default=16, help="pod size")
    p.add_argument("--steps", type=int, default=4,
                   help="model steps to replay (decode: tokens)")
    p.add_argument("--retention-ns", type=float, default=None,
                   help="flush TLBs when an idle gap exceeds this (default: "
                        "entries survive gaps)")
    args = p.parse_args(argv)

    trace = derive_workload(args.arch, args.shape, pod=PodSpec(),
                            n_gpus=args.gpus, n_steps=args.steps)
    cfg = paper_config(args.gpus)
    if args.retention_ns is not None:
        cfg = cfg.replace(tlb_retention_ns=args.retention_ns)

    pod = trace.pod
    print(f"# {trace.arch} / {trace.shape} on {pod.n_gpus} GPUs "
          f"(ep={pod.ep} tp={pod.tp} dp={pod.dp}), "
          f"{trace.tokens_per_step} tokens/step"
          + (f", {trace.n_microbatches} microbatches/pass"
             if trace.n_microbatches > 1 else ""))
    mix = Counter()
    for c in trace.step_calls(0):
        mix[(c.collective, c.group, c.nbytes)] += 1
    print("# per-step collective mix:")
    for (coll, group, nbytes), k in sorted(mix.items()):
        print(f"#   {k:4d} x {coll:<14s} {nbytes/2**20:9.2f} MB "
              f"over {group} GPUs")

    rep = replay(trace, cfg=cfg)
    print("step,comm_us,ideal_us,degradation,walks,requests")
    for s in rep.steps:
        print(f"{s.step},{s.comm_ns/1e3:.2f},{s.ideal_comm_ns/1e3:.2f},"
              f"{s.degradation:.4f},{s.walks},{s.requests}")
    print(f"# cold (step 0) degradation:   {rep.cold_degradation:.4f}")
    print(f"# steady-state degradation:    {rep.steady_degradation:.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
