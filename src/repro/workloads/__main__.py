"""CLI: derive a model workload and replay it through a warm-TLB session.

    PYTHONPATH=src python -m repro.workloads \
        --arch qwen3-moe-235b-a22b --shape decode_32k --gpus 16 --steps 4

Prints the derived collective mix, then the per-step (per-token for decode)
communication-degradation trajectory: step 0 pays the cold Link-TLB walks,
later steps reuse the warmed entries.

``--calibrate`` measures the Pallas kernel tier (interpret mode off-TPU)
and replays with the resulting per-phase compute windows instead of the
roofline, caching the profile JSON under ``calibration/``; ``--profile``
loads a previously cached JSON instead of measuring.  Everything except
``--calibrate`` itself is jax-free (the registry resolves through
``repro.models.spec``).
"""
from __future__ import annotations

import argparse
from collections import Counter

from ..core.config import SimConfig
from ..core.topology import TOPOLOGIES
from .calibrate import ComputeProfile, calibrate, default_cache_path
from .derive import PodSpec, derive_workload, pod_fabric
from .replay import replay


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.workloads",
        description="Replay a model-derived collective sequence through the "
                    "RAT simulator with persistent (warm) Link TLBs.")
    p.add_argument("--arch", required=True,
                   help="architecture registry name, e.g. qwen3-moe-235b-a22b")
    p.add_argument("--shape", default="decode_32k",
                   help="input shape: decode_32k | prefill_32k | train_4k")
    p.add_argument("--gpus", type=int, default=16, help="pod size")
    p.add_argument("--steps", type=int, default=4,
                   help="model steps to replay (decode: tokens)")
    p.add_argument("--topology", default="single_clos",
                   choices=sorted(TOPOLOGIES),
                   help="pod topology (repro.core.topology); hierarchical "
                        "topologies map TP intra-tier and let the EP "
                        "all-to-all cross the oversubscribed uplink")
    p.add_argument("--leaf", type=int, default=0,
                   help="two_tier: GPUs per leaf switch (0: fabric default)")
    p.add_argument("--oversub", type=float, default=1.0,
                   help="two_tier: leaf->spine oversubscription factor")
    p.add_argument("--pod-size", type=int, default=0,
                   help="multi_pod: GPUs per pod (0: whole fabric)")
    p.add_argument("--retention-ns", type=float, default=None,
                   help="flush TLBs when an idle gap exceeds this (default: "
                        "entries survive gaps)")
    p.add_argument("--engine", default="event",
                   choices=("event", "vectorized"),
                   help="simulation engine (identical results; vectorized "
                        "is ~10x faster at pod scale)")
    p.add_argument("--calibrate", action="store_true",
                   help="measure the kernel tier and replay with calibrated "
                        "compute windows (cached under calibration/)")
    p.add_argument("--profile", default=None, metavar="JSON",
                   help="replay with a previously cached compute profile "
                        "(loads JSON, measures nothing)")
    p.add_argument("--force-calibrate", action="store_true",
                   help="re-measure even when a cached profile exists")
    p.add_argument("--policy", default="fixed", metavar="SPEC",
                   help="collective algorithm selection: fixed | auto | "
                        "table:<path> (repro.core.select; fixed keeps the "
                        "historical choices bit-for-bit)")
    p.add_argument("--disagg", type=int, default=0, metavar="PROMPT_TOKENS",
                   help="also derive the disaggregation KV-cache handoff "
                        "for a prompt of this many tokens: per-GPU shard "
                        "size and the resolved kv_transfer collective "
                        "(DESIGN.md §16)")
    args = p.parse_args(argv)

    profile = None
    if args.calibrate:
        cache = args.profile or default_cache_path(args.arch, args.shape,
                                                   args.gpus)
        profile = calibrate(args.arch, args.shape, n_gpus=args.gpus,
                            cache_path=cache, force=args.force_calibrate)
        print(f"# compute profile ({cache}):")
        for name, w in sorted(profile.phases.items()):
            print(f"#   {name:<11s} roofline {w.roofline_ns/1e3:8.2f} us -> "
                  f"calibrated {w.calibrated_ns/1e3:8.2f} us "
                  f"({'+'.join(w.kernels)})")
    elif args.profile is not None:
        profile = ComputeProfile.load(args.profile)

    trace = derive_workload(
        args.arch, args.shape,
        pod=PodSpec(topology=args.topology, leaf_size=args.leaf,
                    oversubscription=args.oversub, pod_size=args.pod_size),
        n_gpus=args.gpus, n_steps=args.steps, compute_profile=profile,
        policy=args.policy)
    cfg = SimConfig(fabric=pod_fabric(trace.pod), engine=args.engine)
    if args.retention_ns is not None:
        cfg = cfg.replace(tlb_retention_ns=args.retention_ns)

    pod = trace.pod
    print(f"# {trace.arch} / {trace.shape} on {pod.n_gpus} GPUs "
          f"(topology={pod.topology}, ep={pod.ep} tp={pod.tp} dp={pod.dp}), "
          f"{trace.tokens_per_step} tokens/step"
          + (f", {trace.n_microbatches} microbatches/pass"
             if trace.n_microbatches > 1 else ""))
    mix = Counter()
    for c in trace.step_calls(0):
        mix[(c.collective, c.group, c.nbytes)] += 1
    print(f"# per-step collective mix (policy={args.policy}):")
    for (coll, group, nbytes), k in sorted(mix.items()):
        print(f"#   {k:4d} x {coll:<14s} {nbytes/2**20:9.2f} MB "
              f"over {group} GPUs")
    if args.policy != "fixed":
        prov = Counter((c.logical, c.collective, c.resolved_by)
                       for c in trace.calls)
        print("# policy resolutions (logical -> concrete, provenance):")
        for (logical, coll, by), k in sorted(prov.items()):
            print(f"#   {k:4d} x {logical:<14s} -> {coll:<18s} [{by}]")
    if args.disagg > 0:
        from ..configs import get_config
        from .derive import derive_kv_transfer, kv_transfer_fabric
        mcfg = get_config(args.arch) if isinstance(args.arch, str) else args.arch
        call = derive_kv_transfer(mcfg, args.disagg, pod, policy=args.policy)
        kv_fab = kv_transfer_fabric(pod)
        print(f"# disaggregation KV handoff ({args.disagg}-token prompt, "
              f"DESIGN.md §16):")
        print(f"#   {mcfg.kv_bytes_per_token(pod.dtype_bytes)} B/token x "
              f"{args.disagg} tokens / {pod.n_gpus} GPUs = "
              f"{call.nbytes/2**20:.2f} MB per-GPU shard")
        print(f"#   {call.logical} -> {call.collective} [{call.resolved_by}] "
              f"over {kv_fab.n_gpus} GPUs ({kv_fab.topology}, "
              f"pod_size={kv_fab.pod_size})")

    rep = replay(trace, cfg=cfg)
    print("step,comm_us,ideal_us,degradation,walks,requests")
    for s in rep.steps:
        print(f"{s.step},{s.comm_ns/1e3:.2f},{s.ideal_comm_ns/1e3:.2f},"
              f"{s.degradation:.4f},{s.walks},{s.requests}")
    print(f"# cold (step 0) degradation:   {rep.cold_degradation:.4f}")
    print(f"# steady-state degradation:    {rep.steady_degradation:.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
