"""Measured compute windows for workload replay (DESIGN.md §9).

:mod:`repro.workloads.derive` prices the compute gap between collectives
with a pure roofline guess (``flops / (peak * mfu)``).  This module replaces
the guess's *shape* with measurement: it runs the repaired Pallas kernel
tier (``rmsnorm`` + ``flash_attention`` for attention mixers, ``ssd_scan``
for SSM mixers, ``grouped_matmul`` for MoE and dense FFNs) over
representative slices of the exact shapes ``derive_workload`` emits, and
produces a :class:`ComputeProfile` — one calibrated window per
``(arch, shape, phase)`` — cached to JSON and loadable offline (no jax).

Calibration model (roofline-anchored relative timing)
-----------------------------------------------------
Off-TPU the kernels execute in Pallas interpret mode, so absolute wall
times are Python-speed, not hardware-speed.  What interpret mode *does*
measure faithfully is the relative cost structure across kernels — which
phase spends more time per useful FLOP (softmax/normalization overhead,
ragged-group masking, scan recurrences).  The profile therefore keeps the
roofline as the absolute anchor and redistributes it by measured
per-phase inefficiency:

    inv_eff(p)       = wall_ns(p) / flops_measured(p)
    wbar             = sum_p n_p * roofline_ns(p) * inv_eff(p)
                       / sum_p n_p * roofline_ns(p)
    calibrated_ns(p) = roofline_ns(p) * inv_eff(p) / wbar

where ``n_p`` is the phase's layer multiplicity (a 7-mamba:1-attn hybrid
weighs the ssm window seven times).  The normalization preserves the total
step compute (``sum_p n_p * calibrated == sum_p n_p * roofline``) while
phases whose kernels do more non-matmul work per FLOP get proportionally
wider windows — exactly the
quantity replay overlap conclusions are sensitive to (NeuMMU's point about
modeled vs. executed compute).  On a real TPU the same harness runs with
``interpret=False`` and the measured times *are* hardware times; the anchor
then simply corrects residual MFU error.

Module import is jax-free (profiles must load in the pure-simulator
environment); only :func:`calibrate` imports the kernel tier lazily.
"""
from __future__ import annotations

import dataclasses
import json
import math
import time
from dataclasses import asdict, dataclass, field
from pathlib import Path
from typing import Dict, Optional

from .derive import (PodSpec, _layer_is_moe, layer_roofline_ns, resolve_pod,
                     step_shape)

# v2: per-phase `layers` multiplicity entered the anchor normalization —
# v1 caches carry unweighted calibrated windows and must be re-measured.
PROFILE_VERSION = 2

# Caps keeping interpret-mode measurement tractable on CPU while staying on
# the kernels' real tiling grid (the measured slice uses the model's true
# head/state dims; only the token/sequence extents shrink).
_CAP_TOKENS = 128
_CAP_SEQ = 128
_CAP_HEADS = 4
_CAP_EXPERTS = 4
_CAP_FF = 128


@dataclass
class PhaseWindow:
    """One phase's measured + calibrated compute window (per layer)."""

    phase: str                 # attn_mixer | ssm_mixer | moe_ffn | dense_ffn
    kernels: tuple             # kernel names measured for this phase
    roofline_ns: float         # derive.py's per-layer roofline window
    measured_wall_ns: float    # interpret-mode wall time of the capped slice
    measured_flops: float      # analytic flops of the measured slice
    calibrated_ns: float = 0.0
    layers: int = 1            # layer multiplicity (anchor weight)

    @property
    def inv_eff(self) -> float:
        return self.measured_wall_ns / max(self.measured_flops, 1.0)


@dataclass
class ComputeProfile:
    """Per-(arch, shape) calibrated compute windows, keyed by phase."""

    arch: str
    shape: str
    n_gpus: int
    ep: int
    tp: int
    dp: int
    interpret: bool = True     # False when measured on real hardware
    version: int = PROFILE_VERSION
    phases: Dict[str, PhaseWindow] = field(default_factory=dict)

    def window_ns(self, phase: str) -> Optional[float]:
        w = self.phases.get(phase)
        return w.calibrated_ns if w is not None else None

    def matches(self, arch: str, shape: str, n_gpus: int,
                ep: Optional[int] = None, tp: Optional[int] = None,
                dp: Optional[int] = None) -> bool:
        """Is this profile valid for the given workload?  The parallelism
        split matters: rooflines (and hence calibrated windows) scale with
        ep/tp/dp, so a profile for one split must not be applied to
        another.  ``None`` skips a component (unresolved pods)."""
        return (self.arch == arch and self.shape == shape
                and self.n_gpus == n_gpus
                and (ep is None or self.ep == ep)
                and (tp is None or self.tp == tp)
                and (dp is None or self.dp == dp))

    # ------------------------------------------------------------- JSON I/O
    def to_json(self) -> str:
        d = asdict(self)
        for p in d["phases"].values():
            p["kernels"] = list(p["kernels"])
        return json.dumps(d, indent=2, sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "ComputeProfile":
        d = json.loads(text)
        if d.get("version") != PROFILE_VERSION:
            raise ValueError(
                f"compute profile version {d.get('version')!r} != "
                f"{PROFILE_VERSION}; re-run calibration")
        phases = {k: PhaseWindow(**{**v, "kernels": tuple(v["kernels"])})
                  for k, v in d.pop("phases").items()}
        return cls(phases=phases, **d)

    def save(self, path) -> Path:
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(self.to_json())
        return path

    @classmethod
    def load(cls, path) -> "ComputeProfile":
        return cls.from_json(Path(path).read_text())


def default_cache_path(arch: str, shape: str, n_gpus: int,
                       root="calibration") -> Path:
    return Path(root) / f"{arch}_{shape}_g{n_gpus}.json"


# --------------------------------------------------------------------------
# Phase naming shared with derive.py (duck-typed configs default to attn).
# --------------------------------------------------------------------------
def layer_kind(cfg, i: int) -> str:
    pattern = getattr(cfg, "layer_pattern", ()) or ("attn",)
    return pattern[i % len(pattern)]


def mixer_phase(cfg, i: int) -> str:
    return "attn_mixer" if layer_kind(cfg, i) == "attn" else "ssm_mixer"


def ffn_phase(cfg, i: int) -> str:
    return "moe_ffn" if _layer_is_moe(cfg, i) else "dense_ffn"


# --------------------------------------------------------------------------
# Measurement harness
# --------------------------------------------------------------------------
def _time_call(fn, reps: int) -> float:
    """Best-of-``reps`` wall time (ns) of ``fn()``, after one warmup."""
    import jax

    jax.block_until_ready(fn())                    # compile + warm caches
    best = math.inf
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best * 1e9


def _measure_attn_mixer(cfg, reps: int):
    import jax
    import jax.numpy as jnp

    from ..kernels import ops

    D = cfg.d_model
    H = min(cfg.n_heads, _CAP_HEADS)
    KV = max(1, min(cfg.n_kv_heads, H))
    Dh = cfg.d_head
    S = _CAP_SEQ
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    q = jax.random.normal(ks[0], (1, S, H, Dh), jnp.float32)
    k = jax.random.normal(ks[1], (1, S, KV, Dh), jnp.float32)
    v = jax.random.normal(ks[2], (1, S, KV, Dh), jnp.float32)
    x = jax.random.normal(ks[3], (_CAP_TOKENS, D), jnp.float32)
    w = jax.random.normal(ks[4], (D,), jnp.float32)

    wall = (_time_call(lambda: ops.rmsnorm(x, w), reps)
            + _time_call(lambda: ops.flash_attention(
                q, k, v, causal=True, block_q=min(128, S),
                block_k=min(128, S)), reps))
    flops = 4.0 * _CAP_TOKENS * D + 4.0 * H * S * S * Dh
    return wall, flops, ("rmsnorm", "flash_attention")


def _measure_ssm_mixer(cfg, reps: int):
    import jax
    import jax.numpy as jnp

    from ..kernels import ops

    H = min(max(1, cfg.d_model * cfg.ssm_expand // max(cfg.ssm_head_dim, 1)),
            2)
    P = max(cfg.ssm_head_dim, 8)
    N = min(max(cfg.ssm_state, 16), 64)
    S, chunk = _CAP_SEQ, 64
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    x = jax.random.normal(ks[0], (1, S, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (1, S, H), jnp.float32))
    A_log = jax.random.normal(ks[2], (H,), jnp.float32) * 0.5
    B = jax.random.normal(ks[3], (1, S, N), jnp.float32) / math.sqrt(N)
    C = jax.random.normal(ks[4], (1, S, N), jnp.float32) / math.sqrt(N)

    wall = _time_call(lambda: ops.ssd_scan(x, dt, A_log, B, C, chunk=chunk),
                      reps)
    nc = S // chunk
    flops = nc * H * (2.0 * chunk * chunk * (N + P) + 2.0 * chunk * P * N)
    return wall, flops, ("ssd_scan",)


def _measure_ffn(cfg, moe: bool, reps: int):
    import jax
    import jax.numpy as jnp
    import numpy as np

    from ..kernels import ops

    D = cfg.d_model
    F = _CAP_FF
    E = min(cfg.n_experts, _CAP_EXPERTS) if moe else 1
    T = _CAP_TOKENS
    ks = jax.random.split(jax.random.PRNGKey(2), 2)
    lhs = jax.random.normal(ks[0], (T, D), jnp.float32)
    rhs = jax.random.normal(ks[1], (E, D, F), jnp.float32) / math.sqrt(D)
    # equal ragged groups covering every row (the hot MoE case)
    offs = jnp.asarray(np.linspace(0, T, E + 1, dtype=np.int32))

    wall = _time_call(lambda: ops.grouped_matmul(lhs, rhs, offs), reps)
    flops = 2.0 * T * D * F
    return wall, flops, ("grouped_matmul",)


# --------------------------------------------------------------------------
# Roofline windows per phase — shared with derive_workload (derive.py's
# step_shape / layer_roofline_ns are the single source of the formulas, so
# the anchor can never drift from the windows derivation emits).
# --------------------------------------------------------------------------
def _phase_rooflines(cfg, spec, pod: PodSpec):
    """(phase -> per-layer roofline ns, phase -> layer multiplicity)."""
    t_step, _, flop_mult = step_shape(spec, pod)
    roof: Dict[str, float] = {}
    count: Dict[str, int] = {}
    for i in range(cfg.n_layers):
        roof_mixer, roof_ffn = layer_roofline_ns(cfg, i, t_step, pod,
                                                 flop_mult)
        for phase, ns in ((mixer_phase(cfg, i), roof_mixer),
                          (ffn_phase(cfg, i), roof_ffn)):
            roof.setdefault(phase, ns)
            count[phase] = count.get(phase, 0) + 1
    return roof, count


_MEASURERS = {
    "attn_mixer": lambda cfg, reps: _measure_attn_mixer(cfg, reps),
    "ssm_mixer": lambda cfg, reps: _measure_ssm_mixer(cfg, reps),
    "moe_ffn": lambda cfg, reps: _measure_ffn(cfg, True, reps),
    "dense_ffn": lambda cfg, reps: _measure_ffn(cfg, False, reps),
}


def calibrate(arch, shape: str, *, pod: Optional[PodSpec] = None,
              n_gpus: Optional[int] = None, reps: int = 3,
              cache_path=None, force: bool = False) -> ComputeProfile:
    """Measure (or load) the :class:`ComputeProfile` of ``(arch, shape)``.

    ``cache_path`` (or :func:`default_cache_path`) is read unless ``force``
    and written after measurement, so CI and offline replays share one JSON
    artifact.  Measurement imports jax; loading does not.
    """
    if isinstance(arch, str):
        from ..configs import get_config            # lazy: imports jax
        cfg = get_config(arch)
    else:
        cfg = arch
    from ..configs.shapes import SHAPES             # pure-python
    spec = SHAPES[shape]

    pod = pod or PodSpec()
    if n_gpus is not None:
        pod = dataclasses.replace(pod, n_gpus=n_gpus)
    pod = resolve_pod(pod, cfg, spec.kind)

    if cache_path is not None and not force:
        p = Path(cache_path)
        if p.exists():
            try:
                prof = ComputeProfile.load(p)
            except (ValueError, KeyError, TypeError,
                    json.JSONDecodeError):
                prof = None      # stale version / corrupt cache: re-measure
            if prof is not None and prof.matches(cfg.name, shape,
                                                 pod.n_gpus, pod.ep,
                                                 pod.tp, pod.dp):
                return prof

    rooflines, counts = _phase_rooflines(cfg, spec, pod)
    phases: Dict[str, PhaseWindow] = {}
    for phase, roof in rooflines.items():
        wall, flops, kernels = _MEASURERS[phase](cfg, reps)
        phases[phase] = PhaseWindow(
            phase=phase, kernels=kernels, roofline_ns=roof,
            measured_wall_ns=wall, measured_flops=flops,
            layers=counts[phase])

    # Roofline-anchored redistribution (module docstring): preserve the
    # layer-weighted step total while phases inherit their measured
    # relative inefficiency.
    total_roof = sum(w.layers * w.roofline_ns for w in phases.values())
    wbar = (sum(w.layers * w.roofline_ns * w.inv_eff
                for w in phases.values())
            / total_roof) if total_roof > 0 else 1.0
    for w in phases.values():
        w.calibrated_ns = (w.roofline_ns * w.inv_eff / wbar
                           if wbar > 0 else w.roofline_ns)

    prof = ComputeProfile(arch=cfg.name, shape=shape, n_gpus=pod.n_gpus,
                          ep=pod.ep, tp=pod.tp, dp=pod.dp,
                          interpret=True, phases=phases)
    if cache_path is not None:
        prof.save(cache_path)
    return prof
