# Workload replay: model-derived collective sequences (decode/prefill/train
# steps of the real architecture configs) replayed through persistent-TLB
# simulation sessions.  `python -m repro.workloads --arch ... --shape ...`
# prints the per-step warm-vs-cold degradation trajectory; `--calibrate`
# swaps the roofline compute windows for windows measured on the Pallas
# kernel tier (repro.workloads.calibrate).
from .calibrate import (ComputeProfile, PhaseWindow, calibrate,
                        default_cache_path)
from .derive import (CollectiveCall, PodSpec, StepEmitter, WorkloadTrace,
                     derive_workload, layer_param_bytes, moe_a2a_bytes,
                     pod_fabric, resolve_pod)
from .replay import ReplayResult, StepStats, buffer_layout, replay

__all__ = [
    "CollectiveCall", "PodSpec", "StepEmitter", "WorkloadTrace",
    "derive_workload",
    "layer_param_bytes", "moe_a2a_bytes", "pod_fabric", "resolve_pod",
    "ReplayResult", "StepStats", "buffer_layout", "replay",
    "ComputeProfile", "PhaseWindow", "calibrate", "default_cache_path",
]
