from .compression import compress_gradients, CompressionState, make_compressor
from .elastic import ElasticController, HostState
from .trainer import Trainer, TrainerConfig

__all__ = ["compress_gradients", "CompressionState", "make_compressor",
           "ElasticController", "HostState", "Trainer", "TrainerConfig"]
