"""End-to-end training driver: loop + checkpointing + restart + compression.

Runs on whatever devices exist (1 CPU offline, a pod in production): builds
the mesh, jits the train step with the same sharding machinery as the
dry-run, and wires the fault-tolerance substrate — async checkpoints,
auto-resume (bitwise-identical continuation is tested in
tests/test_runtime.py by killing at step k), deterministic data sharding,
optional gradient compression for the cross-pod reduction.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import CheckpointManager
from ..data import make_batch_iterator
from ..models import api
from ..models.base import ModelConfig
from ..optim import Optimizer, adamw, with_master, cosine_with_warmup
from .compression import make_compressor


@dataclass
class TrainerConfig:
    steps: int = 100
    batch_size: int = 8
    seq_len: int = 128
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 25
    async_checkpoint: bool = True
    grad_compression: str = "none"     # none | bf16 | int8
    peak_lr: float = 1e-3
    warmup: int = 10
    seed: int = 0
    log_every: int = 10


class Trainer:
    def __init__(self, cfg: ModelConfig, tcfg: TrainerConfig,
                 optimizer: Optional[Optimizer] = None):
        self.cfg = cfg
        self.tcfg = tcfg
        sched = cosine_with_warmup(tcfg.peak_lr, tcfg.warmup, tcfg.steps)
        self.optimizer = optimizer or with_master(adamw(sched))
        self.ckpt = (CheckpointManager(tcfg.checkpoint_dir)
                     if tcfg.checkpoint_dir else None)
        self.comp_init, self.comp_apply = make_compressor(
            tcfg.grad_compression)
        self._build()

    def _build(self):
        cfg, tcfg = self.cfg, self.tcfg
        train_cfg = cfg.replace(param_dtype=cfg.dtype)
        self.train_cfg = train_cfg

        def loss(p, b):
            return api.loss_fn(train_cfg, p, b)

        def step_fn(params, opt_state, comp_state, batch):
            (lval, metrics), grads = jax.value_and_grad(
                loss, has_aux=True)(params, batch)
            grads, comp_state = self.comp_apply(grads, comp_state)
            from ..optim import clip_by_global_norm
            grads, gnorm = clip_by_global_norm(grads, 1.0)
            new_params, new_opt = self.optimizer.update(
                grads, opt_state, params)
            out_metrics = {"loss": lval, "grad_norm": gnorm,
                           "nll": metrics["nll"]}
            return new_params, new_opt, comp_state, out_metrics

        self._step = jax.jit(step_fn, donate_argnums=(0, 1, 2))

    # ------------------------------------------------------------------
    def init_state(self):
        params, _ = api.init(self.train_cfg, jax.random.PRNGKey(self.tcfg.seed))
        opt_state = self.optimizer.init(params)
        comp_state = self.comp_init(params)
        return {"params": params, "opt": opt_state, "comp": comp_state}

    def run(self, *, resume: bool = True,
            fail_at_step: Optional[int] = None,
            num_shards: int = 1, shard: int = 0) -> Dict:
        """Train; returns history.  ``fail_at_step`` raises mid-run (for the
        failure-injection tests) AFTER the last checkpoint of that step."""
        tcfg = self.tcfg
        state = self.init_state()
        it = make_batch_iterator(self.cfg, tcfg.batch_size, tcfg.seq_len,
                                 seed=tcfg.seed, shard=shard,
                                 num_shards=num_shards)
        start = 0
        if resume and self.ckpt is not None:
            restored_step, restored = self.ckpt.restore_latest(
                {"params": state["params"], "opt": state["opt"],
                 "data": it.state_dict()})
            if restored_step is not None:
                state["params"] = restored["params"]
                state["opt"] = restored["opt"]
                it.load_state_dict(jax.tree.map(np.asarray, restored["data"]))
                start = restored_step
        history: List[Dict] = []
        for step in range(start, tcfg.steps):
            t0 = time.time()
            batch = {k: jnp.asarray(v) for k, v in next(it).items()}
            (state["params"], state["opt"], state["comp"],
             metrics) = self._step(state["params"], state["opt"],
                                   state["comp"], batch)
            if step % tcfg.log_every == 0 or step == tcfg.steps - 1:
                history.append({"step": step,
                                "loss": float(metrics["loss"]),
                                "sec": time.time() - t0})
            if self.ckpt is not None and (step + 1) % tcfg.checkpoint_every == 0:
                tree = {"params": state["params"], "opt": state["opt"],
                        "data": it.state_dict()}
                if tcfg.async_checkpoint:
                    self.ckpt.async_save(step + 1, tree)
                else:
                    self.ckpt.save(step + 1, tree)
            if fail_at_step is not None and step + 1 == fail_at_step:
                if self.ckpt is not None:
                    self.ckpt.wait()
                raise RuntimeError(f"injected failure at step {step + 1}")
        if self.ckpt is not None:
            self.ckpt.wait()
        return {"history": history,
                "final_loss": history[-1]["loss"] if history else None,
                "state": state, "data_step": it.step}
