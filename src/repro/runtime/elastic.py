"""Elastic scaling + straggler mitigation control plane (host-side logic).

On a real cluster this wraps the coordination service; offline, the same
state machine is driven by simulated heartbeats so the policy logic —
detection thresholds, re-mesh decisions, shard reassignment — is tested for
real.  The data plane it drives is:

  * re-mesh: rebuild the device mesh with fewer/more data-parallel replicas;
  * re-shard: checkpoints store logical arrays, so any topology restores
    (repro.checkpoint); the data iterator reshards deterministically
    (repro.data.DataIterator.reshard);
  * stragglers: deterministic per-step data assignment means a replacement
    host recomputes exactly the lost shard — no reshuffle of the stream.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple


@dataclass
class HostState:
    host_id: int
    last_heartbeat: float
    step_times: List[float] = field(default_factory=list)
    alive: bool = True

    def note_step(self, seconds: float) -> None:
        self.step_times.append(seconds)
        if len(self.step_times) > 32:
            self.step_times.pop(0)

    @property
    def mean_step(self) -> float:
        return (sum(self.step_times) / len(self.step_times)
                if self.step_times else 0.0)


@dataclass
class ElasticDecision:
    kind: str            # "ok" | "remesh" | "replace_straggler"
    dead_hosts: Tuple[int, ...] = ()
    stragglers: Tuple[int, ...] = ()
    new_num_shards: Optional[int] = None


class ElasticController:
    """Failure detection + re-mesh policy over host heartbeats."""

    def __init__(self, n_hosts: int, *, heartbeat_timeout_s: float = 60.0,
                 straggler_factor: float = 2.0,
                 min_hosts: int = 1, clock=time.monotonic):
        self.clock = clock
        self.timeout = heartbeat_timeout_s
        self.straggler_factor = straggler_factor
        self.min_hosts = min_hosts
        now = self.clock()
        self.hosts: Dict[int, HostState] = {
            i: HostState(i, now) for i in range(n_hosts)}

    def heartbeat(self, host_id: int, step_seconds: Optional[float] = None):
        h = self.hosts[host_id]
        h.last_heartbeat = self.clock()
        h.alive = True
        if step_seconds is not None:
            h.note_step(step_seconds)

    def poll(self) -> ElasticDecision:
        now = self.clock()
        dead = tuple(h.host_id for h in self.hosts.values()
                     if h.alive and now - h.last_heartbeat > self.timeout)
        for hid in dead:
            self.hosts[hid].alive = False
        alive = [h for h in self.hosts.values() if h.alive]
        if dead:
            n = len(alive)
            # largest power-of-two data-parallel degree that still works
            shards = 1
            while shards * 2 <= n:
                shards *= 2
            if n < self.min_hosts:
                raise RuntimeError("below minimum healthy host count")
            return ElasticDecision(kind="remesh", dead_hosts=dead,
                                   new_num_shards=shards)
        # Straggler: sustained mean step time >> fleet median.
        times = sorted(h.mean_step for h in alive if h.step_times)
        if len(times) >= 4:
            median = times[len(times) // 2]
            strag = tuple(h.host_id for h in alive
                          if h.step_times
                          and h.mean_step > self.straggler_factor * median)
            if strag:
                return ElasticDecision(kind="replace_straggler",
                                       stragglers=strag)
        return ElasticDecision(kind="ok")
