"""Gradient compression for cross-pod reduction, with error feedback.

At 512+ chips the inter-pod gradient all-reduce crosses the slow (DCN/
inter-pod) boundary; compressing it is the classic distributed-optimization
trick.  Two codecs:

  * ``bf16``: round grads to bf16 before the reduction (2x);
  * ``int8``: per-tensor absmax int8 quantization (4x) with **error
    feedback** — the quantization residual is carried to the next step so
    the bias does not accumulate (Seide et al.; convergence-parity tested in
    tests/test_runtime.py).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class CompressionState(NamedTuple):
    error: Any   # pytree of residuals (None when codec has no feedback)


def _quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_gradients(grads, codec: str = "none",
                       state: Optional[CompressionState] = None):
    """Returns (decompressed-after-transport grads, new state).

    The compress->transport->decompress round trip is materialized locally
    (the actual collective rides XLA's all-reduce on the compressed dtype);
    the numerics here are exactly what the wire would carry.
    """
    if codec == "none":
        return grads, state

    if codec == "bf16":
        out = jax.tree.map(
            lambda g: g.astype(jnp.bfloat16).astype(jnp.float32), grads)
        return out, state

    if codec == "int8":
        err = (state.error if state is not None and state.error is not None
               else jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32),
                                 grads))

        def one(g, e):
            g32 = g.astype(jnp.float32) + e
            q, scale = _quantize_int8(g32)
            deq = q.astype(jnp.float32) * scale
            return deq.astype(g.dtype), (g32 - deq)

        flat_g, tdef = jax.tree.flatten(grads)
        flat_e = tdef.flatten_up_to(err)
        outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
        new_g = tdef.unflatten([o[0] for o in outs])
        new_e = tdef.unflatten([o[1] for o in outs])
        return new_g, CompressionState(error=new_e)

    raise ValueError(f"unknown codec {codec!r}")


def make_compressor(codec: str):
    def init(grads):
        if codec == "int8":
            return CompressionState(error=jax.tree.map(
                lambda g: jnp.zeros_like(g, jnp.float32), grads))
        return CompressionState(error=None)

    def apply(grads, state):
        return compress_gradients(grads, codec, state)

    return init, apply
