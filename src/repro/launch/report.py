"""Aggregate results/dryrun/*.json into the EXPERIMENTS.md roofline tables."""
from __future__ import annotations

import json
from typing import Dict, List, Optional

from .dryrun import RESULTS
from .. import configs
from ..configs.shapes import SHAPES

GIB = 2**30


def load_cells() -> List[Dict]:
    cells = []
    for f in sorted(RESULTS.glob("*.json")):
        cells.append(json.loads(f.read_text()))
    return cells


def fmt_s(x: Optional[float]) -> str:
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def roofline_table(cells: List[Dict], mesh: str = "pod_16x16") -> str:
    rows = ["| arch | shape | t_compute | t_memory | t_collective | "
            "bottleneck | peak GiB/dev | fits 16G | useful FLOPs |",
            "|---|---|---|---|---|---|---|---|---|"]
    for arch in configs.list_archs():
        for shape in SHAPES:
            c = next((c for c in cells if c["arch"] == arch
                      and c["shape"] == shape and c["mesh"] == mesh), None)
            if c is None:
                continue
            if c["status"] == "skipped":
                rows.append(f"| {arch} | {shape} | - | - | - | skipped "
                            f"(full attention @500k) | - | - | - |")
                continue
            if c["status"] != "ok":
                rows.append(f"| {arch} | {shape} | ERROR | | | | | | |")
                continue
            r = c["roofline"]
            uf = c.get("useful_flops_ratio")
            rows.append(
                f"| {arch} | {shape} | {fmt_s(r['t_compute_s'])} | "
                f"{fmt_s(r['t_memory_s'])} | {fmt_s(r['t_collective_s'])} | "
                f"{r['bottleneck']} | "
                f"{c['memory']['peak_bytes']/GIB:.2f} | "
                f"{'yes' if c['fits_hbm'] else 'NO*'} | "
                f"{uf:.3f} |" if uf else
                f"| {arch} | {shape} | - | - | - | - | - | - | - |")
    return "\n".join(rows)


def dryrun_table(cells: List[Dict]) -> str:
    rows = ["| arch | shape | mesh | status | compile | peak GiB/dev | "
            "coll GB/dev (ag/ar/rs/a2a) |",
            "|---|---|---|---|---|---|---|"]
    for arch in configs.list_archs():
        for shape in SHAPES:
            for mesh in ("pod_16x16", "multipod_2x16x16"):
                c = next((c for c in cells if c["arch"] == arch
                          and c["shape"] == shape and c["mesh"] == mesh), None)
                if c is None:
                    continue
                if c["status"] != "ok":
                    rows.append(f"| {arch} | {shape} | {mesh} | "
                                f"{c['status']} | - | - | - |")
                    continue
                k = c["roofline"]["coll_by_kind"]
                coll = (f"{k.get('all-gather',0)/1e9:.1f}/"
                        f"{k.get('all-reduce',0)/1e9:.1f}/"
                        f"{k.get('reduce-scatter',0)/1e9:.1f}/"
                        f"{k.get('all-to-all',0)/1e9:.2f}")
                rows.append(
                    f"| {arch} | {shape} | {mesh} | ok | "
                    f"{c['compile_s']}s | "
                    f"{c['memory']['peak_bytes']/GIB:.2f} | {coll} |")
    return "\n".join(rows)


def summary(cells: List[Dict]) -> Dict:
    ok = [c for c in cells if c["status"] == "ok"]
    skipped = [c for c in cells if c["status"] == "skipped"]
    err = [c for c in cells if c["status"] == "error"]
    fits = [c for c in ok if c.get("fits_hbm")]
    return {"ok": len(ok), "skipped": len(skipped), "error": len(err),
            "fits": len(fits), "total": len(cells)}


if __name__ == "__main__":
    cells = load_cells()
    print(json.dumps(summary(cells), indent=1))
    print()
    print(roofline_table(cells))
