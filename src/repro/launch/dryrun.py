import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

# NOTE: the two lines above MUST run before any other import (jax locks the
# device count on first init), hence no module docstring above them.
# ---------------------------------------------------------------------------
# Multi-pod dry-run: lower + compile every (architecture x shape x mesh).
#
# Proves the distribution config is coherent without hardware: 512
# placeholder CPU devices build the production meshes; every cell must
# jit(step).lower(...).compile() and fit v5e HBM per memory_analysis().
# Results (memory, cost analysis, collective bytes, roofline terms) are
# cached to results/dryrun/*.json for EXPERIMENTS.md.
#
# Usage:
#   PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-1.5b \
#       --shape train_4k --mesh single          # one cell
#   PYTHONPATH=src python -m repro.launch.dryrun --all                # all

import argparse
import glob
import json
import pathlib
import shutil
import sys
import tempfile
import time
import traceback


from .. import configs
from ..configs.shapes import SHAPES, shape_applicable
from ..optim import adamw, adafactor, with_master, cosine_with_warmup
from . import roofline as rl
from . import specs as sp
from .mesh import make_production_mesh
from .steps import make_train_step, make_prefill_step, make_serve_step

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"

# Microbatching (grad accumulation) and optimizer choice per size class:
# >=100B-param models train with Adafactor + deeper accumulation.
BIG_ARCHS = {"mistral-large-123b", "qwen3-moe-235b-a22b",
             "jamba-1.5-large-398b"}
HBM_PER_CHIP = 16 * 1024**3   # v5e


def pick_optimizer(arch: str):
    sched = cosine_with_warmup(3e-4, 100, 10_000)
    inner = adafactor(sched) if arch in BIG_ARCHS else adamw(sched)
    return with_master(inner)   # bf16 params + f32 master (mixed precision)


MICROBATCHES = {  # per-arch grad-accumulation depth (train_4k)
    "mistral-large-123b": 16,
    "jamba-1.5-large-398b": 16,
    "qwen3-moe-235b-a22b": 16,
}


def microbatches_for(arch: str, shape_name: str) -> int:
    if shape_name != "train_4k":
        return 1
    return MICROBATCHES.get(arch, 4)


SEQ_SHARD_OFF = set()  # archs where SP reshards cost more than they save


def seq_shard_for(cfg, shape) -> bool:
    # Sequence parallelism whenever seq divides the model axis; essential
    # when attention heads don't shard it (e.g. 12 or 40 heads on model=16).
    if cfg.name in SEQ_SHARD_OFF:
        return False
    return shape.kind in ("train", "prefill") and shape.seq_len % 16 == 0


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             verbose: bool = True) -> dict:
    cfg = configs.get_config(arch)
    shape = SHAPES[shape_name]
    mesh_name = "multipod_2x16x16" if multi_pod else "pod_16x16"
    cell = {"arch": arch, "shape": shape_name, "mesh": mesh_name}
    if not shape_applicable(cfg, shape):
        cell["status"] = "skipped"
        cell["reason"] = ("long_500k requires sub-quadratic attention; "
                          f"{arch} is pure full-attention (DESIGN.md)")
        return cell
    if shape_name == "long_500k" and cfg.sliding_window == 0 and \
            any(k == "attn" for k in cfg.pattern):
        cfg = cfg.replace(sliding_window=4096)   # jamba long-context variant

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    try:
        with mesh:
            if shape.kind == "train":
                opt = pick_optimizer(arch)
                mb = microbatches_for(arch, shape_name)
                step, in_sh, _, (params_s, opt_s) = make_train_step(
                    cfg, opt, mesh, multi_pod=multi_pod, microbatches=mb,
                    seq_shard=seq_shard_for(cfg, shape))
                batch = sp.batch_specs(cfg, shape)
                lowered = step.lower(params_s, opt_s, batch)
            elif shape.kind == "prefill":
                step, in_sh, _, params_s = make_prefill_step(
                    cfg, mesh, shape, multi_pod=multi_pod,
                    seq_shard=seq_shard_for(cfg, shape))
                batch = sp.batch_specs(cfg, shape)
                batch.pop("targets", None)
                lowered = step.lower(params_s, batch)
            else:  # decode
                step, in_sh, _, (params_s, cache_s) = make_serve_step(
                    cfg, mesh, shape, multi_pod=multi_pod)
                lowered = step.lower(params_s, sp.token_specs(shape), cache_s)
            t_lower = time.time() - t0
            # Dump the post-SPMD pre-float-normalization HLO: the CPU
            # backend upcasts bf16 to f32 in the final module, which would
            # double-count collective bytes vs the TPU target.
            dump = tempfile.mkdtemp(prefix="hlodump_")
            compiled = lowered.compile(compiler_options={
                "xla_dump_to": dump,
                "xla_dump_hlo_pass_re": "spmd-partitioning"})
            t_compile = time.time() - t0 - t_lower

        spmd_text = None
        cands = glob.glob(dump + "/*after_spmd-partitioning*.txt")
        if cands:
            main = max(cands, key=lambda f: pathlib.Path(f).stat().st_size)
            spmd_text = pathlib.Path(main).read_text()
        shutil.rmtree(dump, ignore_errors=True)

        mem = compiled.memory_analysis()
        terms = rl.analyze(compiled, spmd_text)
        n_devices = mesh.size
        tokens = (shape.global_batch * shape.seq_len
                  if shape.kind != "decode" else shape.global_batch)
        n_active = configs.active_param_count(cfg)
        if shape.kind == "train":
            mf = rl.model_flops_train(n_active, tokens)
        elif shape.kind == "prefill":
            mf = rl.model_flops_train(n_active, tokens) / 3.0  # fwd only
        else:
            mf = rl.model_flops_decode(n_active, tokens)
        mf_per_dev = mf / n_devices

        cell.update({
            "status": "ok",
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "n_devices": n_devices,
            "memory": {
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
                "peak_bytes": (mem.argument_size_in_bytes
                               + mem.output_size_in_bytes
                               + mem.temp_size_in_bytes
                               - mem.alias_size_in_bytes),
            },
            "fits_hbm": (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                         + mem.output_size_in_bytes
                         - mem.alias_size_in_bytes) < HBM_PER_CHIP,
            "roofline": terms.as_dict(),
            "model_flops_per_device": mf_per_dev,
            "useful_flops_ratio": (mf_per_dev / terms.flops
                                   if terms.flops else None),
        })
        if verbose:
            r = cell["roofline"]
            ufr = cell["useful_flops_ratio"]
            print(f"[ok] {arch} x {shape_name} x {mesh_name}: "
                  f"compile {cell['compile_s']}s, "
                  f"peak {cell['memory']['peak_bytes']/2**30:.2f} GiB/dev "
                  f"(fits={cell['fits_hbm']}), "
                  f"t_comp={r['t_compute_s']:.4f}s t_mem={r['t_memory_s']:.4f}s "
                  f"t_coll={r['t_collective_s']:.4f}s -> {r['bottleneck']}; "
                  f"useful={ufr and round(ufr, 3)}")
    except Exception as e:  # noqa: BLE001 — report, continue the sweep
        cell["status"] = "error"
        cell["error"] = f"{type(e).__name__}: {e}"
        cell["traceback"] = traceback.format_exc()[-2000:]
        if verbose:
            print(f"[ERR] {arch} x {shape_name} x {mesh_name}: {cell['error']}")
    return cell


def cell_path(arch: str, shape: str, multi_pod: bool) -> pathlib.Path:
    mesh = "multi" if multi_pod else "single"
    return RESULTS / f"{arch}__{shape}__{mesh}.json"


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true", help="ignore cache")
    args = ap.parse_args(argv)

    RESULTS.mkdir(parents=True, exist_ok=True)
    archs = configs.list_archs() if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = ([False, True] if args.mesh == "both"
              else [args.mesh == "multi"])

    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                path = cell_path(arch, shape, mp)
                if path.exists() and not args.force:
                    cell = json.loads(path.read_text())
                    print(f"[cached] {arch} x {shape} x "
                          f"{'multi' if mp else 'single'}: {cell['status']}")
                else:
                    cell = run_cell(arch, shape, mp)
                    path.write_text(json.dumps(cell, indent=1))
                if cell["status"] == "error":
                    failures += 1
    print(f"\ndry-run complete; {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
