"""Production mesh construction (lazy: importing this never touches jax
device state — required so smoke tests see 1 device while the dry-run sees
512 placeholder devices via XLA_FLAGS)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model_axis: int = 1):
    """Whatever devices exist locally, as (data, model) for examples/tests."""
    n = len(jax.devices())
    assert n % model_axis == 0
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))
