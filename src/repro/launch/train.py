"""Training launcher: runs any assigned architecture on the local devices.

Full-size configs are for the production meshes (use dryrun.py to validate
those); local runs default to the reduced smoke config unless --full.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b \
        --steps 100 --batch 8 --seq 256 --ckpt /tmp/ckpt [--resume]
"""
from __future__ import annotations

import argparse

from .. import configs
from ..runtime import Trainer, TrainerConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b",
                    choices=configs.list_archs())
    ap.add_argument("--full", action="store_true",
                    help="use the full-size config (pod-scale!)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compress", default="none",
                    choices=["none", "bf16", "int8"])
    args = ap.parse_args(argv)

    cfg = (configs.get_config(args.arch) if args.full
           else configs.get_smoke_config(args.arch))
    tcfg = TrainerConfig(steps=args.steps, batch_size=args.batch,
                         seq_len=args.seq, checkpoint_dir=args.ckpt,
                         grad_compression=args.compress, peak_lr=args.lr,
                         log_every=max(1, args.steps // 20))
    out = Trainer(cfg, tcfg).run(resume=args.resume)
    for h in out["history"]:
        print(f"step {h['step']:>5}  loss {h['loss']:.4f}  {h['sec']:.2f}s")
    print(f"final loss: {out['final_loss']}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
