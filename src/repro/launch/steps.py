"""Train / prefill / serve step builders with full sharding annotations.

``make_train_step``: grad(+microbatch accumulation scan) -> clip -> optimizer
update.  ``make_serve_step``: one decode token against the cache pytree.
Each builder returns (jitted_fn, in_shardings, out_shardings, arg_shapes) so
the dry-run can ``.lower().compile()`` from ShapeDtypeStructs alone.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models import api
from ..models.base import ModelConfig, set_logical_rules, logical_to_pspec
from ..optim import Optimizer, clip_by_global_norm
from ..parallel.sharding import (WorkloadKind, rules_for, param_pspecs,
                                 batch_pspec, cache_pspecs, fit_tree)
from ..configs.shapes import ShapeSpec
from . import specs as sp


def _logits_pspec(cfg: ModelConfig, rules, mesh: Mesh) -> P:
    vshard = "model" if cfg.vocab_size % mesh.shape["model"] == 0 else None
    return P(rules.get("batch"), vshard)


def _serving_dtype(params_s, cfg: ModelConfig):
    """Serving holds bf16 weights (checkpoints are cast on load)."""
    import jax.numpy as jnp
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, cfg.dtype)
        if jnp.issubdtype(x.dtype, jnp.floating) else x, params_s)


def _batch_pspecs(cfg: ModelConfig, rules) -> Dict[str, P]:
    out = {"inputs": batch_pspec(rules, 2), "targets": batch_pspec(rules, 2)}
    if cfg.n_img_tokens > 0:
        out["img_embeds"] = batch_pspec(rules, 3)
    if cfg.is_encoder_decoder:
        out["enc_embeds"] = batch_pspec(rules, 3)
    return out


def make_train_step(cfg: ModelConfig, optimizer: Optimizer, mesh: Mesh, *,
                    multi_pod: bool = False, microbatches: int = 1,
                    clip_norm: float = 1.0, seq_shard: bool = False):
    """Returns (train_step, in_shardings, out_shardings, example_args)."""
    rules = rules_for(WorkloadKind.TRAIN, multi_pod, seq_shard=seq_shard)
    set_logical_rules(rules, dict(mesh.shape))
    # Mixed precision: the model trains on bf16 working params; the f32
    # master lives in the optimizer state (with_master).  FSDP all-gathers
    # therefore move bf16.
    train_cfg = cfg.replace(param_dtype=cfg.dtype)

    def loss(p, b):
        return api.loss_fn(train_cfg, p, b)

    pspec_holder = {}

    def _constrain_grads(g):
        # Pin gradients to the parameter sharding so XLA emits
        # reduce-scatters instead of full-weight f32 all-reduces.
        pp = pspec_holder.get("p")
        if pp is None:
            return g
        return jax.tree.map(
            lambda x, s: jax.lax.with_sharding_constraint(x, s), g, pp)

    def train_step(params, opt_state, batch):
        if microbatches > 1:
            def split(x):
                return x.reshape((microbatches, x.shape[0] // microbatches)
                                 + x.shape[1:])
            mb = jax.tree.map(split, batch)

            def acc(carry, b):
                gsum, lsum = carry
                (l, _), g = jax.value_and_grad(loss, has_aux=True)(params, b)
                g = _constrain_grads(g)
                gsum = jax.tree.map(
                    lambda a, x: a + x.astype(jnp.float32), gsum, g)
                # Pin the accumulator carry too: an unconstrained scan carry
                # settles replicated and turns per-layer grad reductions into
                # full-weight f32 all-reduces (14 TB/step on mistral-123B).
                gsum = _constrain_grads(gsum)
                return (gsum, lsum + l), None

            zeros = _constrain_grads(jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params))
            (gsum, lsum), _ = jax.lax.scan(acc, (zeros, 0.0), mb)
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            lval = lsum / microbatches
        else:
            (lval, _), grads = jax.value_and_grad(loss, has_aux=True)(
                params, batch)
            grads = _constrain_grads(grads)
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        new_params, new_opt = optimizer.update(grads, opt_state, params)
        metrics = {"loss": lval, "grad_norm": gnorm}
        return new_params, new_opt, metrics

    params_s, specs, opt_s = sp.state_shapes(train_cfg, optimizer)
    p_pspecs = param_pspecs(specs, rules)
    # Optimizer state sharding: derive logical axes from the *logical* param
    # specs (factored Adafactor states drop an axis), then map to the mesh.
    o_logical = optimizer.state_specs(specs, params_s)
    o_pspecs = jax.tree.map(
        lambda ax: logical_to_pspec(tuple(ax), rules), o_logical,
        is_leaf=lambda x: isinstance(x, tuple))
    p_pspecs = fit_tree(p_pspecs, params_s, mesh)
    o_pspecs = fit_tree(o_pspecs, opt_s, mesh)
    pspec_holder["p"] = jax.tree.map(
        lambda s: NamedSharding(mesh, s), p_pspecs,
        is_leaf=lambda x: isinstance(x, P))
    b_pspecs = _batch_pspecs(cfg, rules)

    ns = lambda tree: jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                                   is_leaf=lambda x: isinstance(x, P))
    in_sh = (ns(p_pspecs), ns(o_pspecs), ns(b_pspecs))
    out_sh = (ns(p_pspecs), ns(o_pspecs),
              {"loss": NamedSharding(mesh, P()),
               "grad_norm": NamedSharding(mesh, P())})
    jitted = jax.jit(train_step, in_shardings=in_sh, out_shardings=out_sh,
                     donate_argnums=(0, 1))
    return jitted, in_sh, out_sh, (params_s, opt_s)


def make_prefill_step(cfg: ModelConfig, mesh: Mesh, shape: ShapeSpec, *,
                      multi_pod: bool = False, seq_shard: bool = False):
    kind = WorkloadKind.PREFILL
    rules = rules_for(kind, multi_pod, seq_shard=seq_shard)
    if cfg.n_kv_heads % mesh.shape["model"] != 0:
        # GQA kv-heads don't divide the TP axis: shard the cache on head_dim
        # instead (otherwise a 32k cache replicates across the model axis).
        rules["kv_heads"] = None
        rules["head_dim"] = "model"
    set_logical_rules(rules, dict(mesh.shape))
    s_max = shape.seq_len + sp.DECODE_MARGIN

    def prefill_step(params, batch):
        return api.prefill(cfg, params, batch, s_max)

    params_s, specs, _ = sp.state_shapes(cfg)
    params_s = _serving_dtype(params_s, cfg)       # serve from bf16 weights
    p_pspecs = fit_tree(param_pspecs(specs, rules), params_s, mesh)
    b_pspecs = _batch_pspecs(cfg, rules)
    b_pspecs.pop("targets", None)
    cache_shapes = sp.cache_specs_shapes(cfg, shape)
    cache_sh = fit_tree(cache_pspecs(cfg, cache_shapes, rules), cache_shapes,
                        mesh)
    ns = lambda tree: jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                                   is_leaf=lambda x: isinstance(x, P))
    in_sh = (ns(p_pspecs), ns(b_pspecs))
    out_sh = (NamedSharding(mesh, _logits_pspec(cfg, rules, mesh)),
              ns(cache_sh))
    jitted = jax.jit(prefill_step, in_shardings=in_sh, out_shardings=out_sh)
    return jitted, in_sh, out_sh, params_s


def make_serve_step(cfg: ModelConfig, mesh: Mesh, shape: ShapeSpec, *,
                    multi_pod: bool = False):
    """Single-token decode step against a seq_len-deep cache."""
    kind = (WorkloadKind.LONG_DECODE if shape.global_batch == 1
            else WorkloadKind.DECODE)
    rules = rules_for(kind, multi_pod)

    params_s, specs, _ = sp.state_shapes(cfg)
    params_s = _serving_dtype(params_s, cfg)       # serve from bf16 weights
    # FSDP decode of wide-FFN models: chunk the FFN so gathered weights stay
    # bounded (all-gathers cannot be hoisted out of the chunk loop).
    if cfg.d_ff >= 16384 and cfg.ffn_chunks == 1:
        cfg = cfg.replace(ffn_chunks=4)
    set_logical_rules(rules, dict(mesh.shape))

    def serve_step(params, token, caches):
        return api.decode_step(cfg, params, token, caches)
    p_pspecs = fit_tree(param_pspecs(specs, rules), params_s, mesh)
    cache_shapes = sp.cache_specs_shapes(cfg, shape)
    cache_sh = fit_tree(cache_pspecs(cfg, cache_shapes, rules), cache_shapes,
                        mesh)
    ns = lambda tree: jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                                   is_leaf=lambda x: isinstance(x, P))
    in_sh = (ns(p_pspecs),
             NamedSharding(mesh, P(rules.get("batch"))),
             ns(cache_sh))
    out_sh = (NamedSharding(mesh, _logits_pspec(cfg, rules, mesh)),
              ns(cache_sh))
    jitted = jax.jit(serve_step, in_shardings=in_sh, out_shardings=out_sh,
                     donate_argnums=(2,))
    return jitted, in_sh, out_sh, (params_s, cache_shapes)
