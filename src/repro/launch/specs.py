"""ShapeDtypeStruct stand-ins for every model input (no device allocation).

``input_specs(cfg, shape)`` returns the data arguments of the lowered step
for one (architecture x input-shape) cell; ``empty_caches`` builds the
decode-cache pytree (shapes only under ``jax.eval_shape``).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..configs.shapes import ShapeSpec
from ..models.base import ModelConfig
from ..models import transformer, encdec
from ..models.layers import KVCache

S = jax.ShapeDtypeStruct

DECODE_MARGIN = 128  # cache headroom beyond the prefilled seq_len


def batch_specs(cfg: ModelConfig, shape: ShapeSpec,
                microbatches: int = 1) -> Dict[str, Any]:
    """Training / prefill batch: token ids (+ stub modality embeddings)."""
    B = shape.global_batch
    seq = shape.seq_len
    text = seq - (cfg.n_img_tokens if cfg.n_img_tokens else 0)
    out = {"inputs": S((B, text), jnp.int32),
           "targets": S((B, text), jnp.int32)}
    if cfg.n_img_tokens > 0:
        out["img_embeds"] = S((B, cfg.n_img_tokens, cfg.d_model), jnp.float32)
    if cfg.is_encoder_decoder:
        out["enc_embeds"] = S((B, cfg.enc_frames, cfg.d_model), jnp.float32)
    return out


def token_specs(shape: ShapeSpec) -> Any:
    return S((shape.global_batch,), jnp.int32)


def empty_caches(cfg: ModelConfig, batch: int, s_max: int):
    """Decode-cache pytree with concrete zeros (use under eval_shape for
    the dry-run; materialized only by real serving)."""
    if cfg.is_encoder_decoder:
        L = cfg.n_layers
        kv = KVCache(
            k=jnp.zeros((L, batch, s_max, cfg.n_kv_heads, cfg.d_head), cfg.dtype),
            v=jnp.zeros((L, batch, s_max, cfg.n_kv_heads, cfg.d_head), cfg.dtype),
            length=jnp.full((L,), 0, jnp.int32))
        cross = jnp.zeros((L, batch, cfg.enc_frames, cfg.n_kv_heads,
                           cfg.d_head), cfg.dtype)
        return encdec.EncDecCaches(self_kv=kv, cross_k=cross, cross_v=cross)
    one = transformer._empty_caches(cfg, batch, s_max)
    nb = cfg.n_blocks

    def stack(x):
        return jnp.zeros((nb,) + x.shape, x.dtype)

    return jax.tree.map(stack, one)


def cache_specs_shapes(cfg: ModelConfig, shape: ShapeSpec):
    """ShapeDtypeStruct pytree of the decode caches for a shape cell."""
    s_max = shape.seq_len + DECODE_MARGIN
    return jax.eval_shape(
        lambda: empty_caches(cfg, shape.global_batch, s_max))


def state_shapes(cfg: ModelConfig, optimizer=None):
    """(params, specs, opt_state) shapes via eval_shape — no allocation.

    The logical-axes specs are static metadata built during tracing; we
    capture them through a closure (they are not jax types)."""
    from ..models import api
    holder = {}

    def build(k):
        p, s = api.init(cfg, k)
        holder["specs"] = s
        return p

    params = jax.eval_shape(build, S((2,), jnp.uint32))
    opt = None
    if optimizer is not None:
        opt = jax.eval_shape(optimizer.init, params)
    return params, holder["specs"], opt
