"""Scan-aware cost analysis of post-optimization (per-device SPMD) HLO text.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE, so every
``lax.scan`` (layer stacks, grad-accumulation microbatches) under-reports
FLOPs/bytes/collectives by its trip count.  This module re-derives the three
roofline inputs by walking the HLO call graph and multiplying loop bodies by
their ``known_trip_count`` backend config:

  * flops           — 2*M*N*K per dot (incl. dots inside fusions), plus
                      1/elem for arithmetic elementwise ops;
  * hbm bytes       — per top-level op: operand + output bytes (fusion
                      internals stay on-chip — the classic traffic model);
  * collective bytes— output shard bytes per collective op (all-reduce
                      counted 2x: reduce-scatter + all-gather phases).

Validated against ``cost_analysis()`` on unrolled-vs-scanned pairs in
tests/test_hlo_analysis.py.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
    "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1, "f8e3m4": 1, "f8e4m3b11fnuz": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_ASSIGN_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*")
_OPCODE_RE = re.compile(r"\s*([\w\-]+)\(")


def _parse_instr_line(line: str):
    """Parse '%name = SHAPE opcode(...)' robustly (tuple shapes may contain
    parens and '=' inside /*index=N*/ comments)."""
    m = _ASSIGN_RE.match(line)
    if not m:
        return None
    name = m.group(1)
    rest = line[m.end():]
    if rest.startswith("("):          # tuple shape: find matching paren
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        shape, tail = rest[:i + 1], rest[i + 1:]
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        shape, tail = rest[:sp], rest[sp:]
    mo = _OPCODE_RE.match(tail)
    if not mo:
        return None
    return name, shape, mo.group(1)
_COMP_RE = re.compile(
    r"^(?:ENTRY\s+)?%([\w.\-]+)\s*\(.*\)\s*->\s*\S.*\{\s*$")
_CALLS_RE = re.compile(r"(?:calls|to_apply|condition|body)=%([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_LHS_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")
_SKIP_MEM = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
             "iota", "after-all", "partition-id", "replica-id", "domain",
             "opt-barrier"}
_EW_FLOP = {"add", "subtract", "multiply", "divide", "tanh", "exponential",
            "log", "rsqrt", "sqrt", "power", "maximum", "minimum", "negate",
            "floor", "ceil", "cosine", "sine", "logistic", "expm1", "log1p",
            "erf", "atan2", "cbrt", "remainder", "round-nearest-afz",
            "round-nearest-even"}


def _shape_elems_bytes(shape_str: str) -> Tuple[int, int]:
    """Total (elements, bytes) over possibly-tuple shape strings."""
    elems = 0
    nbytes = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        nbytes += n * _DTYPE_BYTES[dtype]
    return elems, nbytes


@dataclass
class Cost:
    flops: float = 0.0
    mem_bytes: float = 0.0
    coll: Dict[str, float] = field(default_factory=dict)

    def add(self, other: "Cost", times: float = 1.0):
        self.flops += other.flops * times
        self.mem_bytes += other.mem_bytes * times
        for k, v in other.coll.items():
            self.coll[k] = self.coll.get(k, 0.0) + v * times

    @property
    def coll_bytes(self) -> float:
        return sum(self.coll.values())


@dataclass
class Instr:
    name: str
    shape: str
    opcode: str
    line: str


class HloModule:
    def __init__(self, text: str):
        self.computations: Dict[str, List[Instr]] = {}
        self._parse(text)
        self._memo: Dict[Tuple[str, bool], Cost] = {}
        self.entry: Optional[str] = None
        m = re.search(r"^ENTRY\s+%([\w.\-]+)", text, re.M)
        if m:
            self.entry = m.group(1)

    def _parse(self, text: str):
        cur: Optional[str] = None
        for line in text.splitlines():
            m = _COMP_RE.match(line)
            if m:
                cur = m.group(1)
                self.computations[cur] = []
                continue
            if cur is None:
                continue
            if line.strip() == "}":
                cur = None
                continue
            parsed = _parse_instr_line(line)
            if parsed:
                name, shape, opcode = parsed
                self.computations[cur].append(
                    Instr(name=name, shape=shape, opcode=opcode, line=line))

    # ------------------------------------------------------------------
    def _sym(self, comp: str) -> Dict[str, str]:
        return {i.name: i.shape for i in self.computations[comp]}

    def _operands(self, instr: Instr) -> List[str]:
        # operand list = %names inside the first (...) after the opcode
        idx = instr.line.find(instr.opcode + "(")
        if idx < 0:
            return []
        start = idx + len(instr.opcode)
        depth = 0
        end = start
        for i, ch in enumerate(instr.line[start:], start):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        return _OPERAND_RE.findall(instr.line[start:end + 1])

    def cost_of(self, comp: str, inside_fusion: bool = False) -> Cost:
        key = (comp, inside_fusion)
        if key in self._memo:
            return self._memo[key]
        total = Cost()
        sym = self._sym(comp)
        for instr in self.computations[comp]:
            op = instr.opcode
            base = op[:-6] if op.endswith("-start") else op
            if op.endswith("-done"):
                continue
            out_elems, out_bytes = _shape_elems_bytes(instr.shape)

            # ---- flops -------------------------------------------------
            if base == "dot":
                k = 1
                ops = self._operands(instr)
                cd = _LHS_CDIMS_RE.search(instr.line)
                if ops and cd:
                    lhs_shape = sym.get(ops[0], "")
                    mm = _SHAPE_RE.search(lhs_shape)
                    if mm:
                        dims = [int(d) for d in mm.group(2).split(",") if d]
                        for ci in cd.group(1).split(","):
                            if ci:
                                k *= dims[int(ci)]
                total.flops += 2.0 * out_elems * k
            elif base in _EW_FLOP:
                total.flops += out_elems
            elif base == "convolution":
                # rare here; treat as dot over window (approximate)
                total.flops += 2.0 * out_elems

            # ---- collectives --------------------------------------------
            if base in _COLLECTIVES:
                mult = 2.0 if base == "all-reduce" else 1.0
                kind = "all-to-all" if base == "ragged-all-to-all" else base
                total.coll[kind] = total.coll.get(kind, 0.0) \
                    + out_bytes * mult

            # ---- memory traffic (top level only) ------------------------
            if not inside_fusion and base not in _SKIP_MEM \
                    and base != "while":
                b = out_bytes
                for o in self._operands(instr):
                    _, ob = _shape_elems_bytes(sym.get(o, ""))
                    b += ob
                total.mem_bytes += b

            # ---- nested computations -------------------------------------
            if base == "while":
                m = _TRIP_RE.search(instr.line)
                if m:
                    trip = int(m.group(1))
                else:
                    trip = self._trip_from_condition(instr.line)
                refs = _CALLS_RE.findall(instr.line)
                for r in refs:
                    if r in self.computations:
                        total.add(self.cost_of(r, inside_fusion), times=trip)
            elif base in ("fusion", "call", "map"):
                for r in _CALLS_RE.findall(instr.line):
                    if r in self.computations:
                        sub = self.cost_of(r, inside_fusion=True)
                        # fusion internals contribute flops only
                        total.flops += sub.flops
                        for k2, v in sub.coll.items():
                            total.coll[k2] = total.coll.get(k2, 0.0) + v
            elif base == "conditional":
                branches = re.search(r"branch_computations=\{([^}]*)\}",
                                     instr.line)
                if branches:
                    names = _OPERAND_RE.findall(branches.group(1))
                    subs = [self.cost_of(n, inside_fusion) for n in names
                            if n in self.computations]
                    if subs:
                        worst = max(subs, key=lambda c: c.flops)
                        total.add(worst)

        self._memo[key] = total
        return total

    def _trip_from_condition(self, while_line: str) -> int:
        """Pre-backend HLO lacks known_trip_count; jax scans compare the
        induction var (starting at 0, step 1) LT a constant in the
        condition computation — recover the bound from that constant."""
        m = re.search(r"condition=%([\w.\-]+)", while_line)
        if not m or m.group(1) not in self.computations:
            return 1
        consts = []
        for i in self.computations[m.group(1)]:
            if i.opcode == "constant":
                mc = re.search(r"constant\((\d+)\)", i.line)
                if mc:
                    consts.append(int(mc.group(1)))
        return max(consts) if consts else 1

    def entry_cost(self) -> Cost:
        assert self.entry is not None, "no ENTRY computation found"
        return self.cost_of(self.entry)


def analyze_hlo_text(text: str) -> Cost:
    return HloModule(text).entry_cost()
