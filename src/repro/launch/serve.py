"""Serving launcher: prefill + batched greedy decode on local devices.

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-780m --tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from .. import configs
from ..models import api


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b",
                    choices=configs.list_archs())
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = configs.get_smoke_config(args.arch)
    params, _ = api.init(cfg, jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    batch = {"inputs": jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size)}
    if cfg.n_img_tokens > 0:
        batch["img_embeds"] = jax.random.normal(
            key, (args.batch, cfg.n_img_tokens, cfg.d_model))
    if cfg.is_encoder_decoder:
        batch["enc_embeds"] = jax.random.normal(
            key, (args.batch, cfg.enc_frames, cfg.d_model))
    s_max = args.prompt_len + args.tokens + 8
    logits, caches = jax.jit(
        lambda p, b: api.prefill(cfg, p, b, s_max))(params, batch)
    step = jax.jit(lambda p, t, c: api.decode_step(cfg, p, t, c))
    tok = jnp.argmax(logits, axis=-1)
    t0 = time.time()
    toks = [np.asarray(tok)]
    for _ in range(args.tokens - 1):
        logits, caches = step(params, tok, caches)
        tok = jnp.argmax(logits, axis=-1)
        toks.append(np.asarray(tok))
    dt = time.time() - t0
    print(f"{args.arch}: decoded {args.tokens} tok x{args.batch} "
          f"({args.batch * args.tokens / max(dt, 1e-9):.1f} tok/s)")
    print("sequence 0:", np.stack(toks, 1)[0].tolist())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
