"""Roofline-term extraction from a compiled (dry-run) step.

Three terms per (arch x shape x mesh), seconds per step on TPU v5e:

    compute    = HLO_FLOPs_per_device / 197e12         (bf16 MXU peak)
    memory     = HLO_bytes_per_device / 819e9           (HBM bandwidth)
    collective = collective_bytes_per_device / 50e9     (per-link ICI)

HLO_FLOPs / bytes come from ``compiled.cost_analysis()``; collective bytes
are NOT in cost_analysis, so we parse the post-SPMD per-device HLO text and
sum the output shard sizes of every all-gather / all-reduce / reduce-scatter
/ all-to-all / collective-permute op (fusion-wrapped or not).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Optional

# TPU v5e hardware constants (targets; this container is CPU-only).
PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?\S+\s*=\s*(\([^)]*\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind output bytes (per device) from post-SPMD HLO."""
    out = {k: 0 for k in _COLLECTIVES}
    seen_done = set()
    for m in _OP_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        # async pairs appear as -start/-done; count the op once (-start)
        line = m.group(0)
        if "-done(" in line:
            continue
        out[kind] += _shape_bytes(shape_str)
    return out


@dataclass
class RooflineTerms:
    flops: float                 # per device
    hbm_bytes: float             # per device
    coll_bytes: int              # per device (sum over kinds)
    coll_by_kind: Dict[str, int] = field(default_factory=dict)
    peak_memory_bytes: Optional[float] = None

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    def as_dict(self) -> Dict:
        return {
            "flops_per_device": self.flops,
            "hbm_bytes_per_device": self.hbm_bytes,
            "coll_bytes_per_device": self.coll_bytes,
            "coll_by_kind": self.coll_by_kind,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "peak_memory_bytes": self.peak_memory_bytes,
        }


def analyze(compiled, spmd_text: Optional[str] = None) -> RooflineTerms:
    """Scan-aware roofline terms from a compiled step.

    ``cost_analysis()`` counts while bodies once, so flops / bytes /
    collectives come from the trip-count-aware HLO walker
    (:mod:`repro.launch.hlo_analysis`), validated against unrolled modules
    in tests/test_hlo_analysis.py.

    ``spmd_text``: post-SPMD, pre-float-normalization HLO dump.  The CPU
    backend upcasts bf16 math to f32 in the *final* module, which would
    double-count collective bytes vs the real TPU lowering — when the dump
    is available, flops + collective bytes come from it (TPU-faithful
    dtypes) while HBM traffic and peak memory come from the final fused
    module."""
    from .hlo_analysis import analyze_hlo_text
    text = compiled.as_text()
    cost = analyze_hlo_text(text)
    if spmd_text is not None:
        pre = analyze_hlo_text(spmd_text)
        cost.flops = pre.flops
        cost.coll = pre.coll
    mem = compiled.memory_analysis()
    peak = None
    if mem is not None:
        try:
            peak = (mem.temp_size_in_bytes + mem.argument_size_in_bytes
                    + mem.output_size_in_bytes - mem.alias_size_in_bytes)
        except AttributeError:
            peak = None
    return RooflineTerms(flops=cost.flops, hbm_bytes=cost.mem_bytes,
                         coll_bytes=int(cost.coll_bytes),
                         coll_by_kind={k: int(v) for k, v in cost.coll.items()},
                         peak_memory_bytes=peak)


def model_flops_train(n_active_params: int, tokens: int) -> float:
    """6*N*D forward+backward useful FLOPs."""
    return 6.0 * n_active_params * tokens


def model_flops_decode(n_active_params: int, tokens: int) -> float:
    """2*N per generated token (forward only)."""
    return 2.0 * n_active_params * tokens
