"""Fault-tolerant checkpointing: sharded, async, integrity-checked, elastic.

Layout per step::

    <dir>/step_000123/
        manifest.json        # step, tree structure, per-leaf shape/dtype/crc
        leaf_00000.npy ...   # one file per pytree leaf (logical full array)
        _COMMITTED           # written last: crash-safe commit marker

Leaves are stored as *logical* (unsharded) arrays keyed by tree path, so a
restart may use ANY device topology — elastic scaling re-shards on load via
the step's in_shardings.  Writes can run on a background thread
(``async_save``) so training continues while the previous step persists;
``wait()`` joins before the next save (single outstanding snapshot).

On real multi-host TPU this pairs with per-host shard files; here we write
host-local logical arrays (process count = 1 offline), which keeps the
commit/restore/GC logic identical.
"""
from __future__ import annotations

import json
import pathlib
import re
import shutil
import threading
import zlib
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(p).strip("[]'.") for p in path)
        flat[key] = leaf
    return flat


def save_checkpoint(directory, step: int, tree, *, keep: int = 3) -> pathlib.Path:
    directory = pathlib.Path(directory)
    tmp = directory / f"step_{step:09d}.tmp"
    final = directory / f"step_{step:09d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    flat = _flatten_with_paths(tree)
    manifest = {"step": step, "leaves": {}}
    for i, (key, leaf) in enumerate(sorted(flat.items())):
        arr = np.asarray(leaf)
        dtype_name = str(arr.dtype)
        if arr.dtype.kind not in "biufc":   # ml_dtypes (bfloat16, fp8, ...)
            arr = arr.view(np.uint16 if arr.dtype.itemsize == 2 else np.uint8)
        fname = f"leaf_{i:05d}.npy"
        np.save(tmp / fname, arr)
        manifest["leaves"][key] = {
            "file": fname,
            "shape": list(arr.shape),
            "dtype": dtype_name,
            "crc32": zlib.crc32(arr.tobytes()) & 0xFFFFFFFF,
        }
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    (tmp / "_COMMITTED").write_text("ok")
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    _gc(directory, keep)
    return final


def _gc(directory: pathlib.Path, keep: int) -> None:
    steps = sorted(int(p.name.split("_")[1]) for p in directory.glob("step_*")
                   if p.is_dir() and not p.name.endswith(".tmp")
                   and (p / "_COMMITTED").exists())
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(directory / f"step_{s:09d}", ignore_errors=True)


def latest_step(directory) -> Optional[int]:
    directory = pathlib.Path(directory)
    if not directory.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in directory.glob("step_*")
             if p.is_dir() and (p / "_COMMITTED").exists()]
    return max(steps) if steps else None


def load_checkpoint(directory, step: int, tree_like, *,
                    shardings=None, verify: bool = True):
    """Restore into the structure of ``tree_like`` (shapes/dtypes enforced).

    ``shardings``: optional pytree of NamedSharding — arrays are placed
    (re-sharded for the *current* topology) with jax.device_put.
    """
    directory = pathlib.Path(directory) / f"step_{step:09d}"
    if not (directory / "_COMMITTED").exists():
        raise FileNotFoundError(f"no committed checkpoint at {directory}")
    manifest = json.loads((directory / "manifest.json").read_text())

    flat_like = _flatten_with_paths(tree_like)
    flat_sh = (_flatten_with_paths(shardings)
               if shardings is not None else {})
    out = {}
    for key, like in flat_like.items():
        meta = manifest["leaves"].get(key)
        if meta is None:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = np.load(directory / meta["file"])
        if verify:
            crc = zlib.crc32(arr.tobytes()) & 0xFFFFFFFF
            if crc != meta["crc32"]:
                raise IOError(f"crc mismatch for {key} "
                              f"(corrupt checkpoint {directory})")
        if str(arr.dtype) != meta["dtype"]:
            import ml_dtypes  # stored as a uint view of an ml_dtypes type
            arr = arr.view(np.dtype(getattr(ml_dtypes, meta["dtype"])))
        want_shape = tuple(np.shape(like))   # () for python scalars
        if tuple(arr.shape) != want_shape:
            raise ValueError(f"{key}: checkpoint shape {arr.shape} != "
                             f"expected {want_shape}")
        if key in flat_sh and flat_sh[key] is not None:
            out[key] = jax.device_put(arr, flat_sh[key])
        elif hasattr(like, "dtype"):
            out[key] = jax.numpy.asarray(arr, dtype=like.dtype)
        else:
            out[key] = type(like)(arr) if want_shape == () else arr
    # unflatten back into tree_like's structure
    treedef = jax.tree_util.tree_structure(tree_like)
    keys = sorted(_flatten_with_paths(tree_like).keys())
    ordered = [out[k] for k in _flatten_with_paths(tree_like).keys()]
    return jax.tree_util.tree_unflatten(treedef, ordered)


class CheckpointManager:
    """Async checkpointing with a single outstanding snapshot."""

    def __init__(self, directory, keep: int = 3):
        self.directory = pathlib.Path(directory)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def async_save(self, step: int, tree) -> None:
        self.wait()
        # Snapshot to host memory synchronously (cheap), write async.
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)

        def work():
            try:
                save_checkpoint(self.directory, step, host_tree,
                                keep=self.keep)
            except BaseException as e:  # noqa: BLE001
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def save(self, step: int, tree) -> None:
        self.wait()
        save_checkpoint(self.directory, step, tree, keep=self.keep)

    def restore_latest(self, tree_like, shardings=None) -> Tuple[Optional[int], Any]:
        step = latest_step(self.directory)
        if step is None:
            return None, None
        return step, load_checkpoint(self.directory, step, tree_like,
                                     shardings=shardings)
