"""Deterministic, checkpointable data pipeline.

``SyntheticLMDataset`` generates a reproducible token stream purely from
(seed, global example index), so:

  * any host can materialize exactly its shard (no data files offline);
  * the iterator state is a single integer (``step``) — checkpoint/restore
    and elastic re-sharding are trivial and bitwise exact;
  * straggler mitigation: deterministic per-step assignment means a
    re-scheduled host recomputes exactly the shard of the host it replaced.

The token function is a splitmix-style integer hash producing a Zipf-ish
marginal over the vocab (so losses have realistic structure), plus a copy
motif that gives the model something learnable within a few hundred steps.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np


def _splitmix64(x: np.ndarray) -> np.ndarray:
    x = (x + np.uint64(0x9E3779B97F4A7C15)).astype(np.uint64)
    z = x
    z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return z ^ (z >> np.uint64(31))


@dataclass(frozen=True)
class SyntheticLMDataset:
    vocab_size: int
    seq_len: int
    seed: int = 0
    copy_period: int = 8   # learnable motif: token repeats every k positions

    def example(self, index: int) -> np.ndarray:
        """Token sequence (seq_len + 1,) for a global example index."""
        base = np.uint64(self.seed) * np.uint64(0x100000001B3) + np.uint64(index)
        pos = np.arange(self.seq_len + 1, dtype=np.uint64)
        h = _splitmix64(base + pos // np.uint64(self.copy_period))
        # Zipf-ish marginal: square the uniform to bias small ids.
        u = (h >> np.uint64(11)).astype(np.float64) / float(1 << 53)
        toks = (u * u * (self.vocab_size - 1)).astype(np.int64)
        return toks

    def batch(self, step: int, batch_size: int,
              shard: int = 0, num_shards: int = 1) -> Dict[str, np.ndarray]:
        """Global batch for ``step``, restricted to ``shard`` of the hosts."""
        per_shard = batch_size // num_shards
        start = step * batch_size + shard * per_shard
        toks = np.stack([self.example(start + i) for i in range(per_shard)])
        return {"inputs": toks[:, :-1].astype(np.int32),
                "targets": toks[:, 1:].astype(np.int32)}


@dataclass
class DataIterator:
    """Stateful, checkpointable iterator over a SyntheticLMDataset."""

    dataset: SyntheticLMDataset
    batch_size: int
    shard: int = 0
    num_shards: int = 1
    step: int = 0
    transform: Optional[object] = None   # callable(batch, step) -> batch

    def __next__(self) -> Dict[str, np.ndarray]:
        b = self.dataset.batch(self.step, self.batch_size, self.shard,
                               self.num_shards)
        if self.transform is not None:
            b = self.transform(b, self.step)
        self.step += 1
        return b

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    # -- checkpointing ------------------------------------------------------
    def state_dict(self) -> Dict:
        return {"step": self.step, "seed": self.dataset.seed,
                "batch_size": self.batch_size}

    def load_state_dict(self, state: Dict) -> None:
        assert state["seed"] == self.dataset.seed, "dataset seed mismatch"
        self.step = int(state["step"])

    def reshard(self, shard: int, num_shards: int) -> "DataIterator":
        """Elastic re-sharding: same stream, new topology, same step."""
        assert self.batch_size % num_shards == 0
        return dataclasses.replace(self, shard=shard, num_shards=num_shards)


def make_batch_iterator(cfg, batch_size: int, seq_len: int, seed: int = 0,
                        shard: int = 0, num_shards: int = 1,
                        extra_fields: Optional[Dict] = None) -> DataIterator:
    """Iterator producing model-ready batches (adds stub modality inputs)."""
    ds = SyntheticLMDataset(vocab_size=cfg.vocab_size, seq_len=seq_len,
                            seed=seed)
    transform = None
    if cfg.n_img_tokens > 0 or cfg.is_encoder_decoder:
        def transform(b, step):
            n = b["inputs"].shape[0]
            rng = np.random.default_rng(step)
            if cfg.n_img_tokens > 0:
                b["img_embeds"] = rng.standard_normal(
                    (n, cfg.n_img_tokens, cfg.d_model)).astype(np.float32)
            if cfg.is_encoder_decoder:
                b["enc_embeds"] = rng.standard_normal(
                    (n, cfg.enc_frames, cfg.d_model)).astype(np.float32)
            return b

    return DataIterator(ds, batch_size, shard, num_shards,
                        transform=transform)
