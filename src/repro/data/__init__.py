from .pipeline import SyntheticLMDataset, DataIterator, make_batch_iterator

__all__ = ["SyntheticLMDataset", "DataIterator", "make_batch_iterator"]
