"""Family-dispatching model API: init / loss / prefill / decode_step."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from .base import ModelConfig
from . import transformer, encdec


def init(cfg: ModelConfig, key: jax.Array):
    if cfg.is_encoder_decoder:
        return encdec.init_encdec(cfg, key)
    return transformer.init_lm(cfg, key)


def loss_fn(cfg: ModelConfig, params, batch: Dict[str, jnp.ndarray]):
    if cfg.is_encoder_decoder:
        return encdec.loss_fn(cfg, params, batch)
    return transformer.loss_fn(cfg, params, batch)


def forward(cfg: ModelConfig, params, batch: Dict[str, jnp.ndarray]):
    if cfg.is_encoder_decoder:
        return encdec.forward(cfg, params, batch["inputs"],
                              batch["enc_embeds"])
    return transformer.forward(cfg, params, batch["inputs"],
                               img_embeds=batch.get("img_embeds"))


def prefill(cfg: ModelConfig, params, batch: Dict[str, jnp.ndarray],
            s_max: int):
    if cfg.is_encoder_decoder:
        return encdec.prefill(cfg, params, batch["inputs"],
                              batch["enc_embeds"], s_max)
    return transformer.prefill(cfg, params, batch["inputs"], s_max,
                               img_embeds=batch.get("img_embeds"))


def decode_step(cfg: ModelConfig, params, token: jnp.ndarray, caches):
    if cfg.is_encoder_decoder:
        return encdec.decode_step(cfg, params, token, caches)
    return transformer.decode_step(cfg, params, token, caches)
