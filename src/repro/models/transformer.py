"""Decoder-only LM assembly: dense / MoE / SSM / hybrid / VLM families.

Layers are grouped into repeating *blocks* (the config's ``layer_pattern``
period — 1 for homogeneous stacks, 8 for Jamba's 7-Mamba+1-attention
interleave) and the block stack runs under ``lax.scan`` over stacked
parameters so HLO size is O(1) in depth (MaxText-style), with optional
``jax.checkpoint`` remat per block.

Three entry points per model: ``forward`` (training), ``prefill`` (build
decode caches), ``decode_step`` (single token with caches).
"""
from __future__ import annotations

import functools
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .base import (ModelConfig, ParamBuilder, stack_layer_params,
                   stacked_specs, with_logical)
from . import layers as L
from .layers import KVCache
from .moe import init_moe, moe_gather
from .ssd import SSMCache, init_ssm, ssm_layer, ssm_prefill, ssm_decode, ssm_dims


def _layer_is_moe(cfg: ModelConfig, global_idx: int) -> bool:
    if cfg.n_experts <= 0:
        return False
    return global_idx % cfg.moe_every == (cfg.moe_every - 1)


def _has_ffn(cfg: ModelConfig) -> bool:
    return cfg.d_ff > 0 or cfg.n_experts > 0


# --------------------------------------------------------------------- init
def init_block(b: ParamBuilder, cfg: ModelConfig, block_idx: int):
    for pos, kind in enumerate(cfg.pattern):
        gi = block_idx * cfg.block_size + pos
        lb = b.child(f"l{pos}")
        lb.ones("ln1", (cfg.d_model,), (None,))
        if kind == "attn":
            L.init_attn(lb, cfg)
        else:
            init_ssm(lb, cfg)
        if _has_ffn(cfg):
            lb.ones("ln2", (cfg.d_model,), (None,))
            if _layer_is_moe(cfg, gi):
                init_moe(lb, cfg)
            else:
                L.init_mlp(lb, cfg)


def init_lm(cfg: ModelConfig, key: jax.Array):
    """Returns (params, logical-axis specs)."""
    b = ParamBuilder(key, cfg.param_dtype)
    init_embed(b, cfg)
    if cfg.n_img_tokens > 0:
        b.normal("mm_proj", (cfg.d_model, cfg.d_model), ("embed", None),
                 fan_in=cfg.d_model)
    blocks, bspecs = [], None
    for i in range(cfg.n_blocks):
        bb = ParamBuilder(jax.random.fold_in(key, i + 1), cfg.param_dtype)
        init_block(bb, cfg, i)
        blocks.append(bb.params)
        bspecs = bb.specs
    params, specs = b.done()
    params["blocks"] = stack_layer_params(blocks)
    specs["blocks"] = stacked_specs(bspecs)
    return params, specs


def init_embed(b: ParamBuilder, cfg: ModelConfig):
    L.init_embed(b, cfg)


# ------------------------------------------------------------------ forward
def _layer_forward(cfg: ModelConfig, kind: str, pos: int, p, x):
    """One layer (mixer + FFN).  Returns (x, aux_loss).

    Remat is applied at THIS granularity: block-level remat would keep
    every layer's gathered weights of a heterogeneous block (Jamba: 8
    layers, 4 of them MoE) alive simultaneously during the recompute."""
    aux = jnp.zeros((), jnp.float32)
    h = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
    if kind == "attn":
        h = L.attention(p["attn"], cfg, h, causal=True,
                        window=cfg.sliding_window)
    else:
        h = ssm_layer(p["ssm"], cfg, h)
    x = x + h
    if _has_ffn(cfg):
        h = L.rmsnorm(x, p["ln2"], cfg.norm_eps)
        if _layer_is_moe(cfg, pos):
            h, a = moe_gather(p["moe"], cfg, h)
            aux = aux + a
        else:
            h = L.mlp(p["mlp"], h, n_chunks=cfg.ffn_chunks)
        x = x + h
    x = with_logical(x, ("batch", "seq", "embed"))
    return x, aux


def _block_forward(cfg: ModelConfig, bp,
                   x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One block (cfg.pattern), full sequence.  Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    for pos, kind in enumerate(cfg.pattern):
        p = bp[f"l{pos}"]
        f = functools.partial(_layer_forward, cfg, kind, pos)
        if cfg.remat:
            f = jax.checkpoint(
                f, policy=jax.checkpoint_policies.nothing_saveable)
        x, a = f(p, x)
        aux = aux + a
    return x, aux


def run_blocks(cfg: ModelConfig, params, x: jnp.ndarray):
    """Scan the block stack.  Returns (x, total_aux_loss)."""
    block_fn = functools.partial(_block_forward, cfg)
    if cfg.scan_layers and cfg.n_blocks > 1:
        def step(carry, bp):
            x, aux = carry
            x, a = block_fn(bp, x)
            return (x, aux + a), None
        (x, aux), _ = lax.scan(step, (x, jnp.zeros((), jnp.float32)),
                               params["blocks"])
    else:
        aux = jnp.zeros((), jnp.float32)
        for i in range(cfg.n_blocks):
            bp = jax.tree.map(lambda v: v[i], params["blocks"])
            x, a = block_fn(bp, x)
            aux = aux + a
    return x, aux


def forward(cfg: ModelConfig, params, tokens: jnp.ndarray,
            img_embeds: Optional[jnp.ndarray] = None,
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """tokens: [B, S_text] -> logits [B, S_total, V].  VLM prepends image."""
    x = L.embed(params, cfg, tokens)
    if cfg.n_img_tokens > 0:
        assert img_embeds is not None
        img = jnp.einsum("bnd,de->bne", img_embeds.astype(cfg.dtype),
                         params["mm_proj"].astype(cfg.dtype))
        x = jnp.concatenate([img, x], axis=1)
    x, aux = run_blocks(cfg, params, x)
    return L.unembed(params, cfg, x), aux


def loss_fn(cfg: ModelConfig, params, batch: Dict[str, jnp.ndarray]):
    """Next-token cross-entropy.  batch: inputs [B,S], targets [B,S]."""
    logits, aux = forward(cfg, params, batch["inputs"],
                          img_embeds=batch.get("img_embeds"))
    if cfg.n_img_tokens > 0:
        logits = logits[:, cfg.n_img_tokens:]
    logits = logits.astype(jnp.float32)
    targets = batch["targets"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    mask = batch.get("mask", jnp.ones_like(targets, jnp.float32))
    nll = jnp.sum((logz - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    loss = nll + 0.01 * aux
    return loss, {"nll": nll, "aux": aux,
                  "tokens": jnp.sum(mask)}


# ------------------------------------------------------------------- decode
class LayerCache(NamedTuple):
    """Union cache for one layer position of a block (attn or ssm slots)."""
    kv: Optional[KVCache]
    ssm: Optional[SSMCache]


def _empty_caches(cfg: ModelConfig, batch: int, s_max: int):
    """Per-block cache pytree (stacked over blocks by the caller)."""
    caches = {}
    d_inner, H, P, N = (ssm_dims(cfg) if any(k != "attn" for k in cfg.pattern)
                        else (0, 0, 0, 0))
    for pos, kind in enumerate(cfg.pattern):
        if kind == "attn":
            kv = KVCache(
                k=jnp.zeros((batch, s_max, cfg.n_kv_heads, cfg.d_head), cfg.dtype),
                v=jnp.zeros((batch, s_max, cfg.n_kv_heads, cfg.d_head), cfg.dtype),
                length=jnp.zeros((), jnp.int32))
            caches[f"l{pos}"] = kv
        else:
            conv_ch = d_inner + 2 * N
            caches[f"l{pos}"] = SSMCache(
                conv=jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch), cfg.dtype),
                state=jnp.zeros((batch, H, P, N), jnp.float32))
    return caches


def _block_prefill(cfg: ModelConfig, bp, x, s_max: int):
    caches = {}
    for pos, kind in enumerate(cfg.pattern):
        p = bp[f"l{pos}"]
        h = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
        if kind == "attn":
            h, c = L.attention_prefill(p["attn"], cfg, h, s_max,
                                       window=cfg.sliding_window)
        else:
            h, c = ssm_prefill(p["ssm"], cfg, h)
        caches[f"l{pos}"] = c
        x = x + h
        if _has_ffn(cfg):
            h = L.rmsnorm(x, p["ln2"], cfg.norm_eps)
            if _layer_is_moe(cfg, pos):
                h, _ = moe_gather(p["moe"], cfg, h)
            else:
                h = L.mlp(p["mlp"], h, n_chunks=cfg.ffn_chunks)
            x = x + h
    return x, caches


def _block_decode(cfg: ModelConfig, bp, x, caches):
    new = {}
    for pos, kind in enumerate(cfg.pattern):
        p = bp[f"l{pos}"]
        h = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
        if kind == "attn":
            h, c = L.attention_decode(p["attn"], cfg, h, caches[f"l{pos}"],
                                      window=cfg.sliding_window)
        else:
            h, c = ssm_decode(p["ssm"], cfg, h, caches[f"l{pos}"])
        new[f"l{pos}"] = c
        x = x + h
        if _has_ffn(cfg):
            h = L.rmsnorm(x, p["ln2"], cfg.norm_eps)
            if _layer_is_moe(cfg, pos):
                h, _ = moe_gather(p["moe"], cfg, h)
            else:
                h = L.mlp(p["mlp"], h, n_chunks=cfg.ffn_chunks)
            x = x + h
    return x, new


def prefill(cfg: ModelConfig, params, tokens: jnp.ndarray, s_max: int,
            img_embeds: Optional[jnp.ndarray] = None):
    """Returns (last-token logits [B,V], stacked caches)."""
    x = L.embed(params, cfg, tokens)
    if cfg.n_img_tokens > 0:
        img = jnp.einsum("bnd,de->bne", img_embeds.astype(cfg.dtype),
                         params["mm_proj"].astype(cfg.dtype))
        x = jnp.concatenate([img, x], axis=1)

    def step(x, bp):
        x, caches = _block_prefill(cfg, bp, x, s_max)
        return x, caches

    if cfg.scan_layers and cfg.n_blocks > 1:
        x, caches = lax.scan(step, x, params["blocks"])
    else:
        cl = []
        for i in range(cfg.n_blocks):
            bp = jax.tree.map(lambda v: v[i], params["blocks"])
            x, c = step(x, bp)
            cl.append(c)
        caches = jax.tree.map(lambda *xs: jnp.stack(xs), *cl)
    logits = L.unembed(params, cfg, x[:, -1:])
    return logits[:, 0], caches


def decode_step(cfg: ModelConfig, params, token: jnp.ndarray, caches):
    """token: [B] -> (logits [B,V], new caches).  Caches stacked over blocks."""
    x = L.embed(params, cfg, token[:, None])

    def step(x, bc):
        bp, cache = bc
        x, new = _block_decode(cfg, bp, x, cache)
        return x, new

    if cfg.scan_layers and cfg.n_blocks > 1:
        x, new_caches = lax.scan(step, x, (params["blocks"], caches))
    else:
        nl = []
        for i in range(cfg.n_blocks):
            bp = jax.tree.map(lambda v: v[i], params["blocks"])
            cache = jax.tree.map(lambda v: v[i], caches)
            x, c = step(x, (bp, cache))
            nl.append(c)
        new_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *nl)
    logits = L.unembed(params, cfg, x)
    return logits[:, 0], new_caches
