"""Mamba2 SSD (state-space duality) layer: chunked train scan + decode step.

Follows the SSD reference recurrence (Dao & Gu, 2024): per head h with scalar
decay a_t = -exp(A_log)*dt_t,

    S_t = exp(a_t) * S_{t-1} + dt_t * x_t B_t^T          (state [P, N])
    y_t = C_t S_t + D * x_t

Training uses the chunked algorithm: quadratic attention-like form within
chunks of length Q, associative recurrence across chunk states.  The chunk
inner loop is the compute hot-spot that :mod:`repro.kernels.ssd_scan`
implements as a Pallas TPU kernel; this module is the pure-jnp path (and the
kernel's oracle lives in ``kernels/ref.py`` mirroring this math).

Sharding note: the projections for z / x / B / C / dt are SEPARATE weight
matrices (not one fused in_proj).  A fused projection would be split at
boundaries that are not multiples of the model-axis shard size, which forces
GSPMD to all-gather the full [d_model, 2*d_inner+2N+H] weight every layer
(observed: +30 GiB/device on jamba-398B).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .base import ModelConfig, ParamBuilder, with_logical
from .layers import rmsnorm


class SSMCache(NamedTuple):
    conv: jnp.ndarray   # [B, K-1, d_inner + 2N] raw conv inputs (x|B|C)
    state: jnp.ndarray  # [B, H, P, N] SSM state


def ssm_dims(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    d_inner = cfg.ssm_expand * cfg.d_model
    P = cfg.ssm_head_dim
    H = d_inner // P
    N = cfg.ssm_state
    return d_inner, H, P, N


def init_ssm(b: ParamBuilder, cfg: ModelConfig, name: str = "ssm"):
    s = b.child(name)
    D = cfg.d_model
    d_inner, H, P, N = ssm_dims(cfg)
    s.normal("z_proj", (D, d_inner), ("embed", "ssm_inner"), fan_in=D)
    s.normal("x_proj", (D, d_inner), ("embed", "ssm_inner"), fan_in=D)
    s.normal("b_proj", (D, N), ("embed", None), fan_in=D)
    s.normal("c_proj", (D, N), ("embed", None), fan_in=D)
    s.normal("dt_proj", (D, H), ("embed", None), fan_in=D)
    s.normal("conv_x", (cfg.ssm_conv, d_inner), (None, "ssm_inner"),
             stddev=0.5)
    s.zeros("conv_x_b", (d_inner,), ("ssm_inner",))
    s.normal("conv_b", (cfg.ssm_conv, N), (None, None), stddev=0.5)
    s.zeros("conv_b_b", (N,), (None,))
    s.normal("conv_c", (cfg.ssm_conv, N), (None, None), stddev=0.5)
    s.zeros("conv_c_b", (N,), (None,))
    s.normal("A_log", (H,), (None,), stddev=0.1)
    s.zeros("D", (H,), (None,))
    s.zeros("dt_bias", (H,), (None,))
    s.ones("norm", (d_inner,), ("ssm_inner",))
    s.normal("out_proj", (d_inner, D), ("ssm_inner", "embed"), fan_in=d_inner)


def _proj_streams(p, cfg: ModelConfig, x: jnp.ndarray):
    """x: [B,S,D] -> (z, xs_raw, B_raw, C_raw, dt_raw) pre-conv streams."""
    dt = x.dtype
    z = jnp.einsum("bsd,de->bse", x, p["z_proj"].astype(dt))
    xs = jnp.einsum("bsd,de->bse", x, p["x_proj"].astype(dt))
    Br = jnp.einsum("bsd,dn->bsn", x, p["b_proj"].astype(dt))
    Cr = jnp.einsum("bsd,dn->bsn", x, p["c_proj"].astype(dt))
    dtr = jnp.einsum("bsd,dh->bsh", x, p["dt_proj"].astype(dt))
    return z, xs, Br, Cr, dtr


def _conv1d(seq: jnp.ndarray, w: jnp.ndarray, bias: jnp.ndarray,
            prev: jnp.ndarray = None) -> jnp.ndarray:
    """Causal depthwise conv + SiLU.  seq: [B,S,C]; w: [K,C]; prev [B,K-1,C]."""
    K = w.shape[0]
    if prev is None:
        pad = jnp.zeros((seq.shape[0], K - 1, seq.shape[2]), seq.dtype)
    else:
        pad = prev.astype(seq.dtype)
    xp = jnp.concatenate([pad, seq], axis=1)
    wc = w.astype(seq.dtype)
    out = sum(xp[:, i:i + seq.shape[1]] * wc[i] for i in range(K))
    return jax.nn.silu(out + bias.astype(seq.dtype))


def _segsum(a: jnp.ndarray) -> jnp.ndarray:
    """a: [..., Q] -> lower-triangular pairwise sums L[i,j] = sum_{j<k<=i} a_k."""
    Q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]        # [..., Q, Q]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, chunk: int):
    """SSD scan.  x:[b,S,H,P] dt:[b,S,H] A:[H] B,C:[b,S,N] (single group).

    Returns y [b,S,H,P] and final state [b,H,P,N].  fp32 internals.
    """
    b, S, H, P = x.shape
    N = B.shape[-1]
    Q = min(chunk, S)
    while S % Q:
        Q -= 1
    nc = S // Q
    f32 = jnp.float32
    xc = x.reshape(b, nc, Q, H, P).astype(f32)
    dtc = dt.reshape(b, nc, Q, H).astype(f32)
    Bc = B.reshape(b, nc, Q, N).astype(f32)
    Cc = C.reshape(b, nc, Q, N).astype(f32)
    a = dtc * (-jnp.exp(A.astype(f32)))               # [b,nc,Q,H] (negative)

    # Intra-chunk (quadratic) term.
    L = jnp.exp(_segsum(a.transpose(0, 1, 3, 2)))     # [b,nc,H,Q,Q]
    CB = jnp.einsum("bcqn,bckn->bcqk", Cc, Bc)        # [b,nc,Q,Q]
    M = CB[:, :, None] * L                            # [b,nc,H,Q,Q]
    y_diag = jnp.einsum("bchqk,bckh,bckhp->bcqhp", M, dtc, xc)

    # Chunk states: S_c = sum_k exp(A_end - A_k) dt_k x_k B_k^T.
    a_cum = jnp.cumsum(a, axis=2)                     # [b,nc,Q,H]
    a_end = a_cum[:, :, -1:]                          # [b,nc,1,H]
    decay = jnp.exp(a_end - a_cum)                    # [b,nc,Q,H]
    states = jnp.einsum("bcqh,bcqh,bcqhp,bcqn->bchpn",
                        decay, dtc, xc, Bc)           # [b,nc,H,P,N]

    # Inter-chunk recurrence over chunk states (associative scan).
    g = jnp.exp(a_end[:, :, 0])                       # [b,nc,H] chunk decay

    def combine(c1, c2):
        g1, s1 = c1
        g2, s2 = c2
        return g1 * g2, s2 + g2[..., None, None] * s1

    gs, ss = lax.associative_scan(combine, (g, states), axis=1)
    # state entering chunk c = ss[c-1]; entering chunk 0 = 0.
    prev = jnp.concatenate([jnp.zeros_like(ss[:, :1]), ss[:, :-1]], axis=1)

    # Off-diagonal contribution: y += C_t exp(a_cum_t) S_prev.
    y_off = jnp.einsum("bcqn,bcqh,bchpn->bcqhp",
                       Cc, jnp.exp(a_cum), prev)
    y = (y_diag + y_off).reshape(b, S, H, P)
    return y, ss[:, -1]                               # final state [b,H,P,N]


def _core(p, cfg: ModelConfig, x, want_cache: bool):
    d_inner, H, P, N = ssm_dims(cfg)
    b, S, _ = x.shape
    G = cfg.ssm_scan_groups if (cfg.ssm_scan_groups > 1
                                and H % cfg.ssm_scan_groups == 0) else 1
    dt_x = x.dtype
    # Shared (small) streams.
    Br = jnp.einsum("bsd,dn->bsn", x, p["b_proj"].astype(dt_x))
    Cr = jnp.einsum("bsd,dn->bsn", x, p["c_proj"].astype(dt_x))
    dtr = jnp.einsum("bsd,dh->bsh", x, p["dt_proj"].astype(dt_x))
    B = _conv1d(Br, p["conv_b"], p["conv_b_b"])
    C = _conv1d(Cr, p["conv_c"], p["conv_c_b"])
    dt = jax.nn.softplus(dtr.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    D_ = x.shape[-1]

    if G == 1:
        z = jnp.einsum("bsd,de->bse", x, p["z_proj"].astype(dt_x))
        xs_raw = jnp.einsum("bsd,de->bse", x, p["x_proj"].astype(dt_x))
        xs = _conv1d(xs_raw, p["conv_x"], p["conv_x_b"])
        xs = with_logical(xs, ("batch", "seq", "ssm_inner"))
        y, state = ssd_chunked(xs.reshape(b, S, H, P), dt, p["A_log"], B, C,
                               cfg.ssm_chunk)
        y = y + (p["D"].astype(jnp.float32))[None, None, :, None] \
            * xs.reshape(b, S, H, P).astype(jnp.float32)
        y = y.reshape(b, S, d_inner).astype(dt_x)
        y = rmsnorm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
        out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(dt_x))
    else:
        # Head-group-chunked SSM: one group's z/x/out weights gathered at a
        # time (lax.scan bodies cannot have their all-gathers hoisted).
        dg, Hg = d_inner // G, H // G
        wz = p["z_proj"].reshape(D_, G, dg).swapaxes(0, 1)    # [G, D, dg]
        wx = p["x_proj"].reshape(D_, G, dg).swapaxes(0, 1)
        cx = p["conv_x"].reshape(cfg.ssm_conv, G, dg).swapaxes(0, 1)
        cxb = p["conv_x_b"].reshape(G, dg)
        A_g = p["A_log"].reshape(G, Hg)
        Dg_ = p["D"].reshape(G, Hg)
        dt_g = dt.reshape(b, S, G, Hg)

        def grp(carry, ws):
            wz_, wx_, cx_, cxb_, A_, D__, dtg_ = ws
            z_ = jnp.einsum("bsd,de->bse", x, wz_.astype(dt_x))
            xr_ = jnp.einsum("bsd,de->bse", x, wx_.astype(dt_x))
            xs_ = _conv1d(xr_, cx_, cxb_)
            yg, st = ssd_chunked(xs_.reshape(b, S, Hg, P), dtg_, A_, B, C,
                                 cfg.ssm_chunk)
            yg = yg + D__.astype(jnp.float32)[None, None, :, None] \
                * xs_.reshape(b, S, Hg, P).astype(jnp.float32)
            return carry, (yg.reshape(b, S, dg).astype(dt_x),
                           z_, st, xr_)

        _, (ys, zs, sts, xrs) = lax.scan(
            grp, 0, (wz, wx, cx, cxb, A_g, Dg_,
                     dt_g.transpose(2, 0, 1, 3)))
        y = jnp.concatenate(list(ys), axis=-1)                # [b,S,d_inner]
        z = jnp.concatenate(list(zs), axis=-1)
        state = jnp.concatenate(list(sts), axis=1)            # [b,H,P,N]
        xs_raw = jnp.concatenate(list(xrs), axis=-1)
        y = rmsnorm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
        wo = p["out_proj"].reshape(G, dg, D_)
        yg = y.reshape(b, S, G, dg).transpose(2, 0, 1, 3)

        def oproj(acc, ws):
            yg_, wo_ = ws
            return acc + jnp.einsum("bse,ed->bsd", yg_, wo_.astype(dt_x)), None

        out, _ = lax.scan(oproj, jnp.zeros_like(x), (yg, wo))

    out = with_logical(out, ("batch", "seq", "embed"))
    if not want_cache:
        return out, None
    if G > 1:
        pass  # xs_raw already assembled above
    else:
        pass
    K = cfg.ssm_conv
    raw = jnp.concatenate([xs_raw, Br, Cr], axis=-1)
    tail = raw[:, -(K - 1):, :]
    if S < K - 1:
        tail = jnp.pad(raw, ((0, 0), (K - 1 - S, 0), (0, 0)))
    cache = SSMCache(conv=tail.astype(cfg.dtype),
                     state=state.astype(jnp.float32))
    return out, cache


def ssm_layer(p, cfg: ModelConfig, x: jnp.ndarray):
    """Full-sequence Mamba2 layer.  x: [B,S,D] -> [B,S,D]."""
    out, _ = _core(p, cfg, x, want_cache=False)
    return out


def ssm_prefill(p, cfg: ModelConfig, x: jnp.ndarray):
    """Like ssm_layer but also returns the decode cache."""
    return _core(p, cfg, x, want_cache=True)


def ssm_decode(p, cfg: ModelConfig, x: jnp.ndarray, cache: SSMCache):
    """Single-token decode.  x: [B,1,D]."""
    d_inner, H, P, N = ssm_dims(cfg)
    b = x.shape[0]
    K = cfg.ssm_conv
    z, xs_raw, B_raw, C_raw, dt_raw = _proj_streams(p, cfg, x)
    raw = jnp.concatenate([xs_raw, B_raw, C_raw], axis=-1)    # [B,1,di+2N]
    conv_in = jnp.concatenate([cache.conv.astype(x.dtype), raw], axis=1)

    def one(stream, w, bias):
        wc = w.astype(x.dtype)
        o = sum(stream[:, i:i + 1] * wc[i] for i in range(K))
        return jax.nn.silu(o + bias.astype(x.dtype))

    xs = one(conv_in[..., :d_inner], p["conv_x"], p["conv_x_b"])
    Bs = one(conv_in[..., d_inner:d_inner + N], p["conv_b"], p["conv_b_b"])
    Cs = one(conv_in[..., d_inner + N:], p["conv_c"], p["conv_c_b"])
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))[:, 0]  # [B,H]
    a = dt * (-jnp.exp(p["A_log"].astype(jnp.float32)))             # [B,H]
    xh = xs.reshape(b, H, P).astype(jnp.float32)
    Bf = Bs[:, 0].astype(jnp.float32)                               # [B,N]
    Cf = Cs[:, 0].astype(jnp.float32)
    new_state = (jnp.exp(a)[..., None, None] * cache.state
                 + jnp.einsum("bh,bhp,bn->bhpn", dt, xh, Bf))
    y = jnp.einsum("bn,bhpn->bhp", Cf, new_state) \
        + p["D"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(b, 1, d_inner).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(x.dtype))
    return out, SSMCache(conv=conv_in[:, 1:].astype(cfg.dtype),
                         state=new_state)
