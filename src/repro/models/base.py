"""Parameter & logical-sharding plumbing for the pure-JAX model zoo.

No flax/haiku: parameters are nested dicts of arrays.  Every leaf carries a
tuple of *logical axis names* (in a parallel "specs" pytree) that
:mod:`repro.parallel.sharding` maps onto physical mesh axes per workload
(train vs prefill vs decode).  This is the MaxText-style logical-axis-rules
pattern, implemented minimally.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

# Model configuration shared by every architecture family.  The dataclass
# itself is jax-free (repro.models.spec) so the config registry and the
# workload/serving layers can resolve architectures without importing jax;
# re-exported here for the JAX tier and backward compatibility.
from .spec import ModelConfig

__all__ = ["ModelConfig", "ParamBuilder", "stack_layer_params",
           "stacked_specs", "set_logical_rules", "mesh_axis_size",
           "logical_to_pspec", "with_logical"]


# ---------------------------------------------------------------------------
# Parameter construction: values + logical-axis specs built together.
# ---------------------------------------------------------------------------

class ParamBuilder:
    """Builds a params pytree and the parallel logical-axes pytree."""

    def __init__(self, key: jax.Array, param_dtype=jnp.float32):
        self._key = key
        self.dtype = param_dtype
        self.params: Dict[str, Any] = {}
        self.specs: Dict[str, Any] = {}

    def _next_key(self):
        self._key, k = jax.random.split(self._key)
        return k

    def normal(self, name: str, shape, axes: Tuple[Optional[str], ...],
               stddev: Optional[float] = None, fan_in: Optional[int] = None):
        assert len(shape) == len(axes), (name, shape, axes)
        if stddev is None:
            fi = (fan_in if fan_in is not None
                  else shape[-2] if len(shape) > 1 else shape[-1])
            stddev = 1.0 / math.sqrt(max(1, fi))
        v = (jax.random.normal(self._next_key(), shape, self.dtype) * stddev)
        self.params[name] = v
        self.specs[name] = axes
        return v

    def zeros(self, name: str, shape, axes: Tuple[Optional[str], ...]):
        self.params[name] = jnp.zeros(shape, self.dtype)
        self.specs[name] = axes
        return self.params[name]

    def ones(self, name: str, shape, axes: Tuple[Optional[str], ...]):
        self.params[name] = jnp.ones(shape, self.dtype)
        self.specs[name] = axes
        return self.params[name]

    def child(self, name: str) -> "ParamBuilder":
        sub = ParamBuilder(self._next_key(), self.dtype)
        self.params[name] = sub.params
        self.specs[name] = sub.specs
        return sub

    def done(self):
        return self.params, self.specs


def stack_layer_params(per_layer: list):
    """Stack a list of per-layer param trees into leading-[L] arrays."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *per_layer)


def stacked_specs(specs, prefix: str = "layers"):
    """Prepend the 'layers' logical axis to every spec leaf (never sharded)."""
    return jax.tree.map(
        lambda ax: ("layers",) + tuple(ax),
        specs, is_leaf=lambda x: isinstance(x, tuple))


# ---------------------------------------------------------------------------
# Logical sharding constraint helper (no-op outside a mesh context).
# ---------------------------------------------------------------------------

_LOGICAL_RULES: Optional[Dict[str, Any]] = None
_AXIS_SIZES: Optional[Dict[str, int]] = None


def set_logical_rules(rules: Optional[Dict[str, Any]],
                      axis_sizes: Optional[Dict[str, int]] = None):
    """Install logical->mesh axis rules for with_logical_constraint.

    ``axis_sizes`` (mesh axis -> size) lets with_logical drop constraints on
    dimensions the axis does not divide instead of failing wholesale."""
    global _LOGICAL_RULES, _AXIS_SIZES
    _LOGICAL_RULES = rules
    _AXIS_SIZES = axis_sizes


def mesh_axis_size(name: str) -> int:
    """Size of a physical mesh axis under the installed rules (1 if unknown)."""
    if _AXIS_SIZES is None:
        return 1
    return _AXIS_SIZES.get(name, 1)


def logical_to_pspec(axes, rules=None) -> jax.sharding.PartitionSpec:
    rules = rules if rules is not None else (_LOGICAL_RULES or {})
    parts = []
    used = set()
    for a in axes:
        m = rules.get(a) if a is not None else None
        # A physical mesh axis may appear at most once in a PartitionSpec.
        if m is not None:
            key = tuple(m) if isinstance(m, (tuple, list)) else (m,)
            if any(k in used for k in key):
                m = None
            else:
                used.update(key)
        parts.append(m)
    return jax.sharding.PartitionSpec(*parts)


def with_logical(x: jnp.ndarray, axes: Tuple[Optional[str], ...],
                 partial: bool = False):
    """Sharding constraint by logical axes; identity if no rules installed.

    When a mapped mesh axis does not divide its dimension, the default is to
    skip the whole constraint (forcing a *weaker* sharding than propagation
    would find is usually a pessimization — e.g. a 49155-vocab logits tensor
    pinned vocab-replicated).  ``partial=True`` instead drops only the
    offending dims and applies the rest (used where a partial pin is the
    point, e.g. the grouped-attention reshape)."""
    if _LOGICAL_RULES is None:
        return x
    spec = logical_to_pspec(axes)
    if _AXIS_SIZES is not None:
        parts = []
        dropped = False
        for dim, part in zip(x.shape, tuple(spec) + (None,) * x.ndim):
            if part is not None:
                names = part if isinstance(part, (tuple, list)) else (part,)
                n = 1
                for a in names:
                    n *= _AXIS_SIZES.get(a, 1)
                if n == 0 or dim % n != 0:
                    part = None
                    dropped = True
            parts.append(part)
        if dropped and not partial:
            return x
        spec = jax.sharding.PartitionSpec(*parts)
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x  # outside mesh context (e.g. plain CPU smoke tests)
