"""Whisper-style encoder-decoder backbone (audio frontend is a stub).

Per the assignment, the conv/mel frontend is stubbed: ``enc_embeds``
([B, frames, d_model], precomputed frame embeddings) enter the encoder
directly.  The decoder is a causal transformer with cross-attention to the
encoder output.  We use RoPE + RMSNorm + SwiGLU uniformly across the zoo
(adaptation from Whisper's learned-pos/LayerNorm/GELU; noted in DESIGN.md).
"""
from __future__ import annotations

from typing import Dict, NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from .base import (ModelConfig, ParamBuilder, stack_layer_params,
                   stacked_specs)
from . import layers as L
from .layers import KVCache


class EncDecCaches(NamedTuple):
    self_kv: KVCache          # stacked over decoder blocks
    cross_k: jnp.ndarray      # [Ld, B, F, KV, Dh]
    cross_v: jnp.ndarray


def init_encdec(cfg: ModelConfig, key: jax.Array):
    b = ParamBuilder(key, cfg.param_dtype)
    L.init_embed(b, cfg)

    enc_blocks, enc_specs = [], None
    for i in range(cfg.n_enc_layers):
        eb = ParamBuilder(jax.random.fold_in(key, 1000 + i), cfg.param_dtype)
        eb.ones("ln1", (cfg.d_model,), (None,))
        L.init_attn(eb, cfg, "attn")
        eb.ones("ln2", (cfg.d_model,), (None,))
        L.init_mlp(eb, cfg)
        enc_blocks.append(eb.params)
        enc_specs = eb.specs
    dec_blocks, dec_specs = [], None
    for i in range(cfg.n_layers):
        db = ParamBuilder(jax.random.fold_in(key, 2000 + i), cfg.param_dtype)
        db.ones("ln1", (cfg.d_model,), (None,))
        L.init_attn(db, cfg, "attn")
        db.ones("ln_x", (cfg.d_model,), (None,))
        L.init_attn(db, cfg, "xattn")
        db.ones("ln2", (cfg.d_model,), (None,))
        L.init_mlp(db, cfg)
        dec_blocks.append(db.params)
        dec_specs = db.specs
    params, specs = b.done()
    params["enc_norm"] = jnp.ones((cfg.d_model,), cfg.param_dtype)
    specs["enc_norm"] = (None,)
    params["enc"] = stack_layer_params(enc_blocks)
    specs["enc"] = stacked_specs(enc_specs)
    params["dec"] = stack_layer_params(dec_blocks)
    specs["dec"] = stacked_specs(dec_specs)
    return params, specs


def encode(cfg: ModelConfig, params, enc_embeds: jnp.ndarray) -> jnp.ndarray:
    """enc_embeds: [B, F, D] (stub frontend output) -> encoder states."""
    x = enc_embeds.astype(cfg.dtype)

    def block(x, bp):
        h = L.rmsnorm(x, bp["ln1"], cfg.norm_eps)
        x = x + L.attention(bp["attn"], cfg, h, causal=False)
        h = L.rmsnorm(x, bp["ln2"], cfg.norm_eps)
        x = x + L.mlp(bp["mlp"], h)
        return x, None

    if cfg.remat:
        block = jax.checkpoint(block)
    x, _ = lax.scan(block, x, params["enc"])
    return L.rmsnorm(x, params["enc_norm"], cfg.norm_eps)


def _dec_block(cfg, bp, x, enc_kv, mode, cache=None, s_max=None):
    h = L.rmsnorm(x, bp["ln1"], cfg.norm_eps)
    new_cache = None
    if mode == "train":
        x = x + L.attention(bp["attn"], cfg, h, causal=True)
    elif mode == "prefill":
        h2, new_cache = L.attention_prefill(bp["attn"], cfg, h, s_max)
        x = x + h2
    else:
        h2, new_cache = L.attention_decode(bp["attn"], cfg, h, cache)
        x = x + h2
    h = L.rmsnorm(x, bp["ln_x"], cfg.norm_eps)
    x = x + L.cross_attention(bp["xattn"], cfg, h, enc_kv[0], enc_kv[1])
    h = L.rmsnorm(x, bp["ln2"], cfg.norm_eps)
    x = x + L.mlp(bp["mlp"], h)
    return x, new_cache


def forward(cfg: ModelConfig, params, tokens: jnp.ndarray,
            enc_embeds: jnp.ndarray):
    """Training forward.  Returns (logits [B,S,V], aux=0)."""
    enc = encode(cfg, params, enc_embeds)
    x = L.embed(params, cfg, tokens)

    def block(x, bp):
        ek, ev = L.encode_kv(bp["xattn"], cfg, enc)
        x, _ = _dec_block(cfg, bp, x, (ek, ev), "train")
        return x, None

    if cfg.remat:
        block = jax.checkpoint(block)
    x, _ = lax.scan(block, x, params["dec"])
    return L.unembed(params, cfg, x), jnp.zeros((), jnp.float32)


def loss_fn(cfg: ModelConfig, params, batch: Dict[str, jnp.ndarray]):
    logits, aux = forward(cfg, params, batch["inputs"], batch["enc_embeds"])
    logits = logits.astype(jnp.float32)
    targets = batch["targets"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    mask = batch.get("mask", jnp.ones_like(targets, jnp.float32))
    nll = jnp.sum((logz - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return nll, {"nll": nll, "aux": aux, "tokens": jnp.sum(mask)}


def prefill(cfg: ModelConfig, params, tokens: jnp.ndarray,
            enc_embeds: jnp.ndarray, s_max: int):
    enc = encode(cfg, params, enc_embeds)
    x = L.embed(params, cfg, tokens)

    def block(x, bp):
        ek, ev = L.encode_kv(bp["xattn"], cfg, enc)
        x, kv = _dec_block(cfg, bp, x, (ek, ev), "prefill", s_max=s_max)
        return x, (kv, ek, ev)

    x, (kvs, eks, evs) = lax.scan(block, x, params["dec"])
    logits = L.unembed(params, cfg, x[:, -1:])
    return logits[:, 0], EncDecCaches(self_kv=kvs, cross_k=eks, cross_v=evs)


def decode_step(cfg: ModelConfig, params, token: jnp.ndarray,
                caches: EncDecCaches):
    x = L.embed(params, cfg, token[:, None])

    def block(x, bc):
        bp, kv, ek, ev = bc
        x, nkv = _dec_block(cfg, bp, x, (ek, ev), "decode", cache=kv)
        return x, nkv

    x, nkvs = lax.scan(block, x, (params["dec"], caches.self_kv,
                                  caches.cross_k, caches.cross_v))
    logits = L.unembed(params, cfg, x)
    return logits[:, 0], caches._replace(self_kv=nkvs)
