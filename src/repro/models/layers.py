"""Core transformer layers: RMSNorm, RoPE, GQA attention, SwiGLU MLP.

Everything is a pure function of (cfg, params, inputs).  Attention supports
full training (causal / bidirectional), prefill (returns a KV cache) and
single-token decode (updates the cache in place functionally), with GQA,
optional per-head qk-norm (Qwen3), QKV bias (Qwen2) and sliding windows
(Jamba long-context).
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .base import ModelConfig, ParamBuilder, with_logical, mesh_axis_size


# ----------------------------------------------------------------- RMSNorm
def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * scale.astype(jnp.float32)).astype(dt)


# -------------------------------------------------------------------- RoPE
def rope_freqs(d_head: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, jnp.float32) / d_head))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float):
    """x: [..., S, H, Dh]; positions: [..., S] (broadcastable)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # [Dh/2]
    ang = positions[..., :, None, None].astype(jnp.float32) * freqs  # [...,S,1,Dh/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------- Attention
class KVCache(NamedTuple):
    k: jnp.ndarray       # [B, S_max, KV, Dh]
    v: jnp.ndarray       # [B, S_max, KV, Dh]
    length: jnp.ndarray  # [] int32 current fill


def init_attn(b: ParamBuilder, cfg: ModelConfig, name: str = "attn",
              rope: bool = True):
    a = b.child(name)
    D, H, KV, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    a.normal("wq", (D, H, Dh), ("embed", "heads", "head_dim"), fan_in=D)
    a.normal("wk", (D, KV, Dh), ("embed", "kv_heads", "head_dim"), fan_in=D)
    a.normal("wv", (D, KV, Dh), ("embed", "kv_heads", "head_dim"), fan_in=D)
    a.normal("wo", (H, Dh, D), ("heads", "head_dim", "embed"), fan_in=H * Dh)
    if cfg.qkv_bias:
        a.zeros("bq", (H, Dh), ("heads", "head_dim"))
        a.zeros("bk", (KV, Dh), ("kv_heads", "head_dim"))
        a.zeros("bv", (KV, Dh), ("kv_heads", "head_dim"))
    if cfg.qk_norm:
        a.ones("q_norm", (Dh,), (None,))
        a.ones("k_norm", (Dh,), (None,))


def _project_qkv(p, cfg: ModelConfig, x: jnp.ndarray, positions, rope: bool):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _sdpa(cfg: ModelConfig, q, k, v, mask) -> jnp.ndarray:
    """q: [B,Sq,H,Dh]; k,v: [B,Sk,KV,Dh]; mask: [B,1,Sq,Sk] or None."""
    B, Sq, H, Dh = q.shape
    KV = k.shape[2]
    group = H // KV
    qg = q.reshape(B, Sq, KV, group, Dh)
    # Give the grouped-head reshape a coherent layout when KV or group
    # divides the TP axis: without this GSPMD cannot propagate the
    # H-sharding of q through the (KV, group) split and falls back to
    # replicate-reshard of the full [B,KV,G,Sq,Sk] score tensor
    # (5.9 TiB/step of f32 all-gathers on qwen3-moe train).  When neither
    # dim divides (granite kv=8 g=2), constraining would *strip* the
    # existing H-sharding instead - skip.
    ms = mesh_axis_size("model")
    if KV % ms == 0 or group % ms == 0:
        qg = with_logical(qg, ("batch", None, "kv_heads", "heads", None),
                          partial=True)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32)
    logits *= 1.0 / math.sqrt(Dh)
    if mask is not None:
        logits = jnp.where(mask[:, :, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w, v)
    return out.reshape(B, Sq, H, Dh)


def _causal_mask(Sq: int, Sk: int, window: int = 0,
                 q_offset: int = 0) -> jnp.ndarray:
    i = jnp.arange(Sq)[:, None] + (Sk - Sq) + q_offset
    j = jnp.arange(Sk)[None, :]
    m = j <= i
    if window > 0:
        m &= j > i - window
    return m[None, None]  # [1,1,Sq,Sk] -> broadcast over batch/kv


# q-chunked (flash-style) attention: never materializes [Sq, Sk] scores for
# the whole sequence at once.  Default chunk keeps the per-chunk score block
# a few hundred MB at 32k context.
Q_CHUNK = 512


def _blocked_sdpa(cfg: ModelConfig, q, k, v, *, causal: bool, window: int,
                  q_chunk: int = Q_CHUNK) -> jnp.ndarray:
    """q: [B,Sq,H,Dh]; k,v: [B,Sk,KV,Dh].  Scans over q chunks."""
    B, Sq, H, Dh = q.shape
    Sk = k.shape[1]
    qc = min(q_chunk, Sq)
    while Sq % qc:
        qc -= 1
    nq = Sq // qc
    if nq == 1:
        mask = _causal_mask(Sq, Sk, window) if causal else None
        return _sdpa(cfg, q, k, v, mask)

    qs = q.reshape(B, nq, qc, H, Dh).swapaxes(0, 1)   # [nq, B, qc, H, Dh]

    def one(_, inp):
        ci, qb = inp
        if causal:
            i = jnp.arange(qc)[:, None] + (Sk - Sq) + ci * qc
            j = jnp.arange(Sk)[None, :]
            m = j <= i
            if window > 0:
                m &= j > i - window
            mask = m[None, None]
        else:
            mask = None
        return 0, _sdpa(cfg, qb, k, v, mask)

    _, outs = lax.scan(one, 0, (jnp.arange(nq), qs))
    return outs.swapaxes(0, 1).reshape(B, Sq, H, Dh)


def attention(p, cfg: ModelConfig, x: jnp.ndarray, *, causal: bool = True,
              rope: bool = True, window: int = 0) -> jnp.ndarray:
    """Full-sequence attention (training / encoding).  x: [B,S,D]."""
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    q, k, v = _project_qkv(p, cfg, x, positions, rope)
    # Megatron-SP: residuals stay seq-sharded; layer internals shard heads
    # (the "seq" position is None so "heads" wins the model axis).
    q = with_logical(q, ("batch", None, "heads", "head_dim"))
    out = _blocked_sdpa(cfg, q, k, v, causal=causal, window=window)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return with_logical(out, ("batch", "seq", "embed"))


def attention_prefill(p, cfg: ModelConfig, x: jnp.ndarray, s_max: int, *,
                      window: int = 0) -> Tuple[jnp.ndarray, KVCache]:
    """Causal prefill that also returns a KV cache padded to ``s_max``."""
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    q, k, v = _project_qkv(p, cfg, x, positions, rope=True)
    out = _blocked_sdpa(cfg, q, k, v, causal=True, window=window)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    KVh, Dh = cfg.n_kv_heads, cfg.d_head
    pad = s_max - S
    kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    cache = KVCache(k=with_logical(kc, ("batch", "cache_seq", "kv_heads", "head_dim")),
                    v=with_logical(vc, ("batch", "cache_seq", "kv_heads", "head_dim")),
                    length=jnp.array(S, jnp.int32))
    return out, cache


def attention_decode(p, cfg: ModelConfig, x: jnp.ndarray, cache: KVCache, *,
                     window: int = 0) -> Tuple[jnp.ndarray, KVCache]:
    """Single-token decode.  x: [B,1,D]; appends to cache at ``length``."""
    B = x.shape[0]
    pos = jnp.broadcast_to(cache.length, (B, 1))
    q, k, v = _project_qkv(p, cfg, x, pos, rope=True)
    kc = lax.dynamic_update_slice_in_dim(cache.k, k.astype(cache.k.dtype),
                                         cache.length, axis=1)
    vc = lax.dynamic_update_slice_in_dim(cache.v, v.astype(cache.v.dtype),
                                         cache.length, axis=1)
    S_max = kc.shape[1]
    j = jnp.arange(S_max)
    valid = j <= cache.length
    if window > 0:
        valid &= j > cache.length - window
    mask = jnp.broadcast_to(valid[None, None, None, :], (B, 1, 1, S_max))
    out = _sdpa(cfg, q, kc, vc, mask)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return out, KVCache(k=kc, v=vc, length=cache.length + 1)


def cross_attention(p, cfg: ModelConfig, x: jnp.ndarray, enc_k, enc_v):
    """Decoder->encoder cross attention (whisper).  No RoPE, no mask."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
    out = _sdpa(cfg, q, enc_k, enc_v, None)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))


def encode_kv(p, cfg: ModelConfig, enc_out: jnp.ndarray):
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"].astype(enc_out.dtype))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"].astype(enc_out.dtype))
    if cfg.qkv_bias:
        k = k + p["bk"].astype(enc_out.dtype)
        v = v + p["bv"].astype(enc_out.dtype)
    return k, v


# -------------------------------------------------------------- SwiGLU MLP
def init_mlp(b: ParamBuilder, cfg: ModelConfig, name: str = "mlp",
             d_ff: Optional[int] = None):
    m = b.child(name)
    D = cfg.d_model
    F = d_ff if d_ff is not None else cfg.d_ff
    m.normal("wi_gate", (D, F), ("embed", "mlp"), fan_in=D)
    m.normal("wi_up", (D, F), ("embed", "mlp"), fan_in=D)
    m.normal("wo", (F, D), ("mlp", "embed"), fan_in=F)


def mlp(p, x: jnp.ndarray, n_chunks: int = 1) -> jnp.ndarray:
    if n_chunks <= 1:
        g = jnp.einsum("bsd,df->bsf", x, p["wi_gate"].astype(x.dtype))
        u = jnp.einsum("bsd,df->bsf", x, p["wi_up"].astype(x.dtype))
        h = jax.nn.silu(g) * u
        h = with_logical(h, ("batch", None, "mlp"))
        return jnp.einsum("bsf,fd->bsd", h, p["wo"].astype(x.dtype))
    # F-chunked (scan) variant: one weight chunk gathered/live at a time.
    D, F = p["wi_gate"].shape
    fc = F // n_chunks
    wg = p["wi_gate"].reshape(D, n_chunks, fc).swapaxes(0, 1)
    wu = p["wi_up"].reshape(D, n_chunks, fc).swapaxes(0, 1)
    wo = p["wo"].reshape(n_chunks, fc, D)

    def step(acc, ws):
        g_, u_, o_ = ws
        h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, g_.astype(x.dtype))) \
            * jnp.einsum("bsd,df->bsf", x, u_.astype(x.dtype))
        h = with_logical(h, ("batch", None, "mlp"))
        return acc + jnp.einsum("bsf,fd->bsd", h, o_.astype(x.dtype)), None

    out, _ = lax.scan(step, jnp.zeros_like(x), (wg, wu, wo))
    return out


# ------------------------------------------------------------- Embeddings
def init_embed(b: ParamBuilder, cfg: ModelConfig):
    b.normal("tok_embed", (cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
             stddev=1.0)
    b.normal("unembed", (cfg.d_model, cfg.vocab_size), ("embed", "vocab"),
             fan_in=cfg.d_model)
    b.ones("final_norm", (cfg.d_model,), (None,))


def embed(params, cfg: ModelConfig, tokens: jnp.ndarray) -> jnp.ndarray:
    x = params["tok_embed"].astype(cfg.dtype)[tokens]
    return with_logical(x, ("batch", "seq", "embed"))


def unembed(params, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["unembed"].astype(x.dtype))
    return with_logical(logits, ("batch", None, "vocab"))
