from .base import ModelConfig, set_logical_rules, logical_to_pspec, with_logical
from .api import init, loss_fn, forward, prefill, decode_step

__all__ = ["ModelConfig", "set_logical_rules", "logical_to_pspec",
           "with_logical", "init", "loss_fn", "forward", "prefill",
           "decode_step"]
