# Lazy package init (PEP 562): the JAX model zoo (.base/.api) only loads
# when one of its names is touched, so jax-free callers can import
# repro.models.spec (the plain ModelConfig dataclass) without pulling jax —
# the serving CLI and workload derivation run offline through that path.
_BASE = ("ModelConfig", "set_logical_rules", "logical_to_pspec",
         "with_logical")
_API = ("init", "loss_fn", "forward", "prefill", "decode_step")

__all__ = list(_BASE + _API)


def __getattr__(name):
    if name in _BASE:
        from . import base
        return getattr(base, name)
    if name in _API:
        from . import api
        return getattr(api, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(__all__))
