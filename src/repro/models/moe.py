"""Mixture-of-Experts FFN: top-k routing with capacity, two execution paths.

``moe_gather``  — scatter/gather dispatch + batched expert einsum.  Pure data
                  movement for dispatch (no one-hot einsum FLOP inflation),
                  shardable under plain pjit: experts are sharded on the
                  "experts" logical axis and GSPMD inserts the (all-to-all
                  equivalent) collectives.  Used by train/dry-run steps.

``moe_block_ep`` — explicit expert parallelism for ``shard_map`` contexts:
                  tokens are exchanged with ``lax.all_to_all`` over the model
                  axis — the *exact* collective the paper studies — and the
                  dispatch collective can be scheduled with the
                  translation-aware warm-up plan (repro.core.overlap).

Both paths share routing; both drop tokens beyond capacity (GShard-style)
with residual passthrough.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax import lax

from .base import ModelConfig, ParamBuilder, with_logical


def init_moe(b: ParamBuilder, cfg: ModelConfig, name: str = "moe"):
    m = b.child(name)
    D, E, F = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    m.normal("router", (D, E), ("embed", None), fan_in=D)
    m.normal("wi_gate", (E, D, F), ("experts", "expert_embed", "expert_mlp"),
             fan_in=D)
    m.normal("wi_up", (E, D, F), ("experts", "expert_embed", "expert_mlp"),
             fan_in=D)
    m.normal("wo", (E, F, D), ("experts", "expert_mlp", "expert_embed"),
             fan_in=F)


def route(p, cfg: ModelConfig, x_flat: jnp.ndarray):
    """Top-k routing in fp32.  Returns (idx [T,k], weights [T,k], aux_loss)."""
    logits = jnp.einsum("td,de->te", x_flat.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = lax.top_k(probs, cfg.top_k)
    w = w / jnp.sum(w, axis=-1, keepdims=True)         # renormalize over top-k
    # Switch-style load-balance auxiliary loss.
    T, E = logits.shape
    me = jnp.mean(probs, axis=0)                       # mean router prob / expert
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(idx, E, dtype=jnp.float32), axis=1), axis=0)
    aux = E * jnp.sum(me * ce) / cfg.top_k
    return idx, w.astype(x_flat.dtype), aux


def _capacity(cfg: ModelConfig, n_tokens: int) -> int:
    c = int(n_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(8, c)


def _expert_ffn(p, x: jnp.ndarray) -> jnp.ndarray:
    """x: [E, C, D] -> [E, C, D] batched SwiGLU over experts."""
    g = jnp.einsum("ecd,edf->ecf", x, p["wi_gate"].astype(x.dtype))
    u = jnp.einsum("ecd,edf->ecf", x, p["wi_up"].astype(x.dtype))
    h = jax.nn.silu(g) * u
    h = with_logical(h, ("experts", None, "expert_mlp"))
    return jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(x.dtype))


def moe_gather(p, cfg: ModelConfig, x: jnp.ndarray):
    """MoE FFN for [B,S,D] input under pjit auto-sharding.

    Dispatch is **per batch row** (capacity enforced per sequence): the
    scatter/gather never crosses the batch dimension, so every tensor stays
    naturally (batch x expert)-sharded — GSPMD inserts only the expert-axis
    exchange (the all-to-all the paper prices), never a global token
    reshuffle (which it implements as replicate-then-partition and blows
    per-device memory)."""
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    C = _capacity(cfg, S)

    # routing (fp32) on [B,S,E]
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = lax.top_k(probs, k)                       # [B,S,k]
    w = (w / jnp.sum(w, axis=-1, keepdims=True)).astype(x.dtype)
    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(jnp.sum(jax.nn.one_hot(idx, E, dtype=jnp.float32), axis=2),
                  axis=(0, 1))
    aux = E * jnp.sum(me * ce) / k

    a = idx.reshape(B, S * k)                          # [B, S*k] expert ids
    onehot = jax.nn.one_hot(a, E, dtype=jnp.int32)     # [B, S*k, E]
    pos = jnp.cumsum(onehot, axis=1) - onehot
    pos = jnp.take_along_axis(pos, a[..., None], axis=2)[..., 0]
    keep = pos < C
    safe_a = jnp.where(keep, a, 0)
    safe_pos = jnp.where(keep, pos, C - 1)
    xr = jnp.broadcast_to(x[:, :, None, :], (B, S, k, D)).reshape(B, S * k, D)
    xr = jnp.where(keep[..., None], xr, 0).astype(x.dtype)

    def disp(xr_row, a_row, pos_row):
        return jnp.zeros((E, C, D), x.dtype).at[a_row, pos_row].add(xr_row)

    buf = jax.vmap(disp)(xr, safe_a, safe_pos)         # [B, E, C, D]
    buf = with_logical(buf, ("batch", "experts", None, None))

    F = p["wi_gate"].shape[-1]
    nch = cfg.ffn_chunks if (cfg.ffn_chunks > 1 and F % cfg.ffn_chunks == 0) else 1
    if nch == 1:
        g = jnp.einsum("becd,edf->becf", buf, p["wi_gate"].astype(x.dtype))
        u = jnp.einsum("becd,edf->becf", buf, p["wi_up"].astype(x.dtype))
        h = jax.nn.silu(g) * u
        h = with_logical(h, ("batch", "experts", None, "expert_mlp"))
        out_e = jnp.einsum("becf,efd->becd", h, p["wo"].astype(x.dtype))
    else:
        # F-chunked expert FFN (scan): bounds simultaneously-gathered
        # expert-weight shards (all-gathers cannot be hoisted out of loops).
        fc = F // nch
        wg = p["wi_gate"].reshape(E, D, nch, fc).transpose(2, 0, 1, 3)
        wu = p["wi_up"].reshape(E, D, nch, fc).transpose(2, 0, 1, 3)
        wo = p["wo"].reshape(E, nch, fc, D).transpose(1, 0, 2, 3)

        def step(acc, ws):
            g_, u_, o_ = ws
            h = jax.nn.silu(jnp.einsum("becd,edf->becf", buf,
                                       g_.astype(x.dtype))) \
                * jnp.einsum("becd,edf->becf", buf, u_.astype(x.dtype))
            h = with_logical(h, ("batch", "experts", None, "expert_mlp"))
            return acc + jnp.einsum("becf,efd->becd", h,
                                    o_.astype(x.dtype)), None

        out_e, _ = lax.scan(step, jnp.zeros_like(buf), (wg, wu, wo))
    out_e = with_logical(out_e, ("batch", "experts", None, None))

    gathered = jax.vmap(lambda o, a_r, p_r: o[a_r, p_r])(
        out_e, safe_a, safe_pos)                       # [B, S*k, D]
    # Combine lands in the sequence-parallel layout: the cross-expert-shard
    # reduction becomes a reduce-scatter into [B, S*k/TP, D] instead of a
    # full all-reduce of [B, S*k, D] (granite train: -31% collective bytes).
    gathered = with_logical(gathered, ("batch", "seq", None))
    gathered = jnp.where(keep[..., None], gathered, 0)
    y = (gathered.reshape(B, S, k, D) * w[..., None]).sum(axis=2)
    return y, aux


def moe_block_ep(p, cfg: ModelConfig, x: jnp.ndarray, axis_name: str,
                 plan=None, overlap_compute=None):
    """Expert-parallel MoE inside ``shard_map`` over ``axis_name``.

    ``x``: [T_loc, D] local tokens.  Experts are sharded: this shard holds
    ``E / axis_size`` of them (p's leaves are the local slices).  Dispatch
    and combine are explicit ``lax.all_to_all`` — the collective the paper
    analyzes — optionally scheduled with a warm-up chunk plan.
    """
    from ..core.overlap import scheduled_all_to_all

    ep = lax.psum(1, axis_name)
    T, D = x.shape
    idx, w, aux = route(p, cfg, x)                     # router is replicated
    E, k = cfg.n_experts, cfg.top_k
    E_loc = E // ep
    C = _capacity(cfg, T) * E_loc                      # capacity per shard

    a = idx.reshape(-1)                                # [T*k] global expert id
    shard = a // E_loc                                 # destination shard
    # position within destination shard's receive slot for this source
    onehot = jax.nn.one_hot(shard, ep, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - onehot
    pos = jnp.take_along_axis(pos, shard[:, None], axis=1)[:, 0]
    keep = pos < C
    safe_shard = jnp.where(keep, shard, 0)
    safe_pos = jnp.where(keep, pos, C - 1)

    xr = jnp.repeat(x, k, axis=0)
    send = jnp.zeros((ep, C, D), x.dtype)
    send = send.at[safe_shard, safe_pos].add(
        jnp.where(keep[:, None], xr, 0).astype(x.dtype))
    send_meta = jnp.zeros((ep, C), jnp.int32)
    send_meta = send_meta.at[safe_shard, safe_pos].add(
        jnp.where(keep, a % E_loc + 1, 0))             # 0 = empty slot

    # ---- dispatch all-to-all (optionally warm-up-scheduled) -------------
    if plan is not None and overlap_compute is not None:
        recv, _ = scheduled_all_to_all(send, axis_name, plan,
                                       compute_fn=overlap_compute[0],
                                       compute_arg=overlap_compute[1])
    else:
        recv = lax.all_to_all(send, axis_name, split_axis=0, concat_axis=0,
                              tiled=True)
    recv_meta = lax.all_to_all(send_meta, axis_name, split_axis=0,
                               concat_axis=0, tiled=True)

    # ---- local expert compute (masked batched FFN over local experts) ---
    recv_flat = recv.reshape(ep * C, D)
    eid = (recv_meta.reshape(-1) - 1)                  # -1 = empty
    buf = jnp.zeros((E_loc, ep * C, D), x.dtype)
    sel = jax.nn.one_hot(eid, E_loc, dtype=x.dtype)    # [ep*C, E_loc]
    buf = jnp.einsum("te,td->etd", sel, recv_flat)
    g = jnp.einsum("etd,edf->etf", buf, p["wi_gate"].astype(x.dtype))
    u = jnp.einsum("etd,edf->etf", buf, p["wi_up"].astype(x.dtype))
    h = jax.nn.silu(g) * u
    out_local = jnp.einsum("etf,efd->etd", h, p["wo"].astype(x.dtype))
    out_flat = jnp.einsum("etd,te->td", out_local, sel)

    # ---- combine all-to-all back ----------------------------------------
    back = lax.all_to_all(out_flat.reshape(ep, C, D), axis_name,
                          split_axis=0, concat_axis=0, tiled=True)
    gathered = back[safe_shard, safe_pos]
    gathered = jnp.where(keep[:, None], gathered, 0)
    y = (gathered.reshape(T, k, D) * w[..., None].astype(x.dtype)).sum(axis=1)
    return y, aux
