"""Architecture specification shared by every model family — jax-free.

:class:`ModelConfig` is the single config object the whole repo keys on:
the JAX model zoo (:mod:`repro.models`), the launch/runtime layers, and the
pure-Python workload derivation (:mod:`repro.workloads`) and serving layers
(:mod:`repro.serving`).  The latter two must resolve registry architectures
*without* importing jax (the serving CLI runs offline), so the config lives
here as a plain dataclass: ``dtype``/``param_dtype`` default to dtype
*names* ("bfloat16"/"float32"), which every jnp call site (``astype``,
``jnp.zeros``, ``ShapeDtypeStruct``...) accepts interchangeably with the
jnp dtype objects the defaults used to be.

:mod:`repro.models.base` re-exports :class:`ModelConfig` for the JAX tier,
so existing ``from repro.models.base import ModelConfig`` imports keep
working (but pull in jax); jax-free callers import from here or from
:mod:`repro.configs`.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab_size: int
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 1e6
    norm_eps: float = 1e-6
    # MoE
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    moe_every: int = 1               # MoE FFN on layers where idx % every == r
    capacity_factor: float = 1.25
    moe_impl: str = "gather"         # "gather" (pjit auto) | "ep" (shard_map)
    # SSM / hybrid
    layer_pattern: Tuple[str, ...] = ()   # repeating pattern, e.g. 7x mamba + attn
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 256
    # encoder-decoder (whisper-style)
    is_encoder_decoder: bool = False
    n_enc_layers: int = 0
    enc_frames: int = 1500
    # VLM (stub frontend provides patch embeddings)
    n_img_tokens: int = 0
    # attention extras
    sliding_window: int = 0          # 0 = full causal
    # execution — dtype *names*, accepted verbatim by every jnp call site;
    # kept as strings so this module (and hence repro.configs) never needs
    # jax.
    dtype: Any = "bfloat16"
    param_dtype: Any = "float32"
    remat: bool = True
    scan_layers: bool = True
    # Chunk FFN weights over the hidden dim inside a lax.scan: bounds the
    # number of simultaneously-gathered FSDP weight shards (XLA cannot hoist
    # an all-gather out of a loop).  1 = unchunked.
    ffn_chunks: int = 1
    # Same idea for SSM layers: scan over head groups so z/x/out projection
    # weights are gathered one group at a time.  1 = unchunked.
    ssm_scan_groups: int = 1

    def kv_bytes_per_token(self, dtype_bytes: int = 2) -> int:
        """KV-cache footprint of one token across all layers, in bytes.

        ``n_kv_heads * d_head`` per K and per V (the factor 2) per layer
        that runs attention — SSM/hybrid patterns only cache KV on their
        ``attn`` layers (Mamba state is step-local, not a growing cache).
        This is the quantity the disaggregated serving handoff transfers
        per prompt token (DESIGN.md §16).
        """
        if self.layer_pattern:
            attn_per_block = sum(1 for kind in self.layer_pattern
                                 if kind == "attn")
            attn_layers = self.n_blocks * attn_per_block
        else:
            attn_layers = self.n_layers
        return self.n_kv_heads * self.d_head * 2 * dtype_bytes * attn_layers

    @property
    def pattern(self) -> Tuple[str, ...]:
        if self.layer_pattern:
            return self.layer_pattern
        return ("attn",)

    @property
    def block_size(self) -> int:
        return len(self.pattern)

    @property
    def n_blocks(self) -> int:
        assert self.n_layers % self.block_size == 0, (
            f"{self.name}: n_layers {self.n_layers} not divisible by "
            f"pattern period {self.block_size}")
        return self.n_layers // self.block_size

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)
