"""Prefill/decode disaggregated serving with KV-cache transfer (DESIGN.md §16).

Colocated serving (:mod:`repro.serving.simulate`) interleaves prefill
chunks with decode tokens on one pod.  Disaggregated serving splits the
roles: dedicated *prefill pods* run prompts, dedicated *decode pods*
generate tokens, and every request's KV cache crosses the ``multi_pod``
scale-out hop in between — an explicit ``kv_transfer`` collective
(:mod:`repro.core.patterns`) sized from the model's KV bytes per token
(:func:`repro.workloads.derive.kv_shard_bytes`) and priced on its own
:class:`~repro.core.session.SimSession` per decode pod, so the transfer
pays real reverse translation at the decode pod's Link-MMU: the first
transfer after a flush walks every page of the KV arena, back-to-back
transfers into the same arena run warm (and engage the PR 9 vectorized
fast path), and an idle gap past ``SimConfig.tlb_retention_ns`` re-pays
the walks.  This is the paper's two-regime scenario on one fabric: bulk
KV transfers next to tiny per-token decode collectives sharing Link-TLB
reach.

Handoff contract (DESIGN.md §16.1): a request occupies a prefill slot
until its prompt completes (the prefill pod serves it as a 1-output-token
request — prefill computes the first token's logits), then its KV
transfer must complete before decode admission — transfer latency lands
directly on TTFT.  The decode pod admits the request as a 1-prompt-token
arrival at the transfer's completion instant; that single-chunk "prefill"
step is the request's first-token step, and the remaining
``output_tokens - 1`` steps are plain decode.  Requests with
``output_tokens <= 1`` finish at prefill and never cross the hop.

Determinism (DESIGN.md §16.4): one global event loop interleaves
arrivals, prefill steps and decode steps in time order (ties: arrival
first, then prefill pods before decode pods, then lowest pod index);
per-decode-pod transfers are serialized on that pod's transfer session,
so decode-side arrival order is nondecreasing by construction and the
serial and pooled sweep executors (:func:`sweep_disagg`) are bit-for-bit
identical on both engines.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from ..core.config import SimConfig
from ..core.select import get_policy
from ..core.session import SimSession
from ..workloads.derive import PodSpec, kv_shard_bytes, kv_transfer_fabric
from .arrivals import Request
from .fleet import ROUTERS, Replica, _route
from .scheduler import RequestStats
from .simulate import (PodStream, ServingAggregates, ServingStep,
                       TrafficPoint, fan_out_points, resolve_traffic_pod)


@dataclass
class KVHandoff:
    """One priced KV-cache transfer: prefill pod -> decode pod."""

    rid: int
    decode_idx: int            # decode pod the request was routed to
    nbytes: int                # per-GPU shard size (pattern semantics)
    offset: int                # KV-arena ring offset the shard landed at
    collective: str            # resolved algorithm (policy decision)
    prefill_finish_ns: float   # when the prefill pod completed the prompt
    start_ns: float            # when the transfer began on the link
    transfer_ns: float         # priced duration
    ideal_ns: float            # zero-translation counterpart
    walks: int                 # page walks the transfer paid
    fastpath_calls: int = 0    # vectorized warm-fast-path engagements

    @property
    def done_ns(self) -> float:
        return self.start_ns + self.transfer_ns

    @property
    def excess_ns(self) -> float:
        """Transfer time beyond its ideal — the cold-RAT tax fig18 plots."""
        return self.transfer_ns - self.ideal_ns


DEFAULT_KV_ARENA_BYTES = 128 * 2**20


class _TransferLink:
    """One decode pod's KV ingress: a serialized transfer session pair.

    The baseline session carries the decode pod's Link-MMU state for its
    **KV arena**: a ``kv_arena_bytes`` ring in which each request's shard
    lands at its own page-aligned offset (a fresh KV region per request,
    as a real paged KV allocator produces), wrapping when full.  The
    steady-state translation working set is therefore the whole arena —
    when it fits the Link-TLB reach, transfers run warm after the first
    lap; when it does not (small L2, or an arena larger than reach),
    transfers keep re-walking — the fig18 two-regime axis.  The ideal
    twin prices the zero-translation counterfactual, memoized per
    (algorithm, size) signature.  ``policy`` resolves the logical
    ``kv_transfer`` class per call, keyed per arena region's cold/warm
    state exactly as serving steps are (DESIGN.md §14).
    """

    def __init__(self, kv_cfg: SimConfig, policy, compute_profile=None,
                 arena_bytes: int = DEFAULT_KV_ARENA_BYTES):
        self.cfg = kv_cfg
        self.sess = SimSession(kv_cfg, compute_profile=compute_profile,
                               policy=policy)
        self.ideal = SimSession(kv_cfg.ideal(),
                                compute_profile=compute_profile)
        self._ideal_ns: Dict[tuple, float] = {}
        self._page = kv_cfg.translation.page_bytes
        self.arena_bytes = arena_bytes
        self._cursor = 0

    def _place(self, nbytes: int) -> int:
        """Ring-allocate a page-aligned arena slot for one KV shard."""
        slot = -(-nbytes // self._page) * self._page
        if self._cursor + slot > self.arena_bytes:
            self._cursor = 0               # wrap: reuse the oldest region
        off = self._cursor
        if slot < self.arena_bytes:
            self._cursor = off + slot
        return off

    def transfer(self, rid: int, decode_idx: int, nbytes: int,
                 finish_ns: float) -> KVHandoff:
        """Price one handoff; starts at ``max(link clock, finish_ns)``.

        The idle-to-start gap ages the link session exactly as serving
        idles do — past ``tlb_retention_ns`` it flushes the KV arena's
        translations, so a quiet decode pod re-pays the walks.
        """
        sess = self.sess
        if finish_ns > sess.t:
            sess.idle(finish_ns - sess.t)
        start = sess.t
        off = self._place(nbytes)
        rec = sess.run(nbytes, collective="kv_transfer", base_offset=off,
                       label=f"kv/r{rid}")
        sig = (rec.collective, nbytes)
        if sig not in self._ideal_ns:
            self._ideal_ns[sig] = self.ideal.run(
                nbytes, collective=rec.collective).completion_ns
        return KVHandoff(
            rid=rid, decode_idx=decode_idx, nbytes=nbytes, offset=off,
            collective=rec.collective, prefill_finish_ns=finish_ns,
            start_ns=start, transfer_ns=rec.completion_ns,
            ideal_ns=self._ideal_ns[sig], walks=rec.counters.walks,
            fastpath_calls=rec.fastpath_calls)


@dataclass
class DisaggResult(ServingAggregates):
    """Per-request / per-step statistics of one disaggregated run.

    ``requests`` holds one merged :class:`RequestStats` per original
    request (rid order): decode-side token timings re-pointed at the
    original arrival, prefill-phase communication accounting folded in,
    and the handoff fields (``prefill_finish_ns`` / ``kv_*``) filled — so
    ``ttft_ns`` measures arrival to first decode token across all three
    stages, and the §16 decomposition properties slice it.
    """

    arch: str
    pod: PodSpec                       # one pod (homogeneous hardware)
    cfg: SimConfig
    prefill: List[Replica]
    decode: List[Replica]
    requests: List[RequestStats]
    handoffs: List[KVHandoff] = field(default_factory=list)
    steps_capped: bool = False

    @property
    def steps(self) -> List[ServingStep]:
        """Every priced serving step, both roles, in global time order."""
        reps = [(0, r) for r in self.prefill] + [(1, r) for r in self.decode]
        return [s for _k, s in sorted(
            ((s.t_start, role, rep.idx, s.step), s)
            for role, rep in reps for s in rep.steps)]

    # -- KV-transfer aggregates ----------------------------------------------
    @property
    def kv_transfer_total_ns(self) -> float:
        return sum(h.transfer_ns for h in self.handoffs)

    @property
    def kv_excess_total_ns(self) -> float:
        return sum(h.excess_ns for h in self.handoffs)

    @property
    def kv_walks(self) -> int:
        return sum(h.walks for h in self.handoffs)

    @property
    def kv_cold_handoffs(self) -> int:
        """Transfers that paid page walks (arena not Link-TLB resident)."""
        return sum(1 for h in self.handoffs if h.walks > 0)

    @property
    def kv_fastpath_calls(self) -> int:
        return sum(h.fastpath_calls for h in self.handoffs)

    def ttft_breakdown(self) -> Dict[str, float]:
        """Mean TTFT decomposition over handed-off, served requests.

        ``prefill_ns`` (arrival -> prompt done, queueing included) +
        ``kv_wait_ns`` (link queueing) + ``kv_transfer_ns`` (of which
        ``kv_excess_ns`` is the cold-RAT tax) + ``decode_wait_ns``
        (transfer done -> first token) = ``ttft_ns``.  Empty dict when no
        request crossed the hop.
        """
        rows = [r for r in self.first_token_served
                if r.kv_start_ns is not None]
        if not rows:
            return {}
        n = len(rows)
        return dict(
            n=n,
            ttft_ns=sum(r.ttft_ns for r in rows) / n,
            prefill_ns=sum(r.prefill_ns for r in rows) / n,
            kv_wait_ns=sum(r.kv_wait_ns for r in rows) / n,
            kv_transfer_ns=sum(r.kv_transfer_ns for r in rows) / n,
            kv_excess_ns=sum(r.kv_transfer_excess_ns for r in rows) / n,
            decode_wait_ns=sum(r.decode_wait_ns for r in rows) / n)

    def replica_rows(self) -> List[dict]:
        """Per-pod summary rows, prefill pods first (cf. fleet rows)."""
        rows = []
        for rep in self.prefill + self.decode:
            steps = rep.steps
            rows.append(dict(
                idx=rep.idx, role=rep.role, routed=rep.routed,
                steps=len(steps), walks=sum(s.walks for s in steps),
                fastpath_calls=sum(s.fastpath_calls for s in steps),
                cold_comm_ns=sum(s.comm_ns for s in steps if s.walks > 0),
                warm_comm_ns=sum(s.comm_ns for s in steps if s.walks == 0)))
        return rows


def simulate_disagg(arch, requests: List[Request], *,
                    pod: Optional[PodSpec] = None,
                    n_gpus: Optional[int] = None,
                    cfg: Optional[SimConfig] = None,
                    prefill_pods: int = 1,
                    decode_pods: int = 1,
                    router: str = "round_robin",
                    max_decode_slots: int = 32,
                    prefill_chunk_tokens: int = 512,
                    steps_cap: Optional[int] = None,
                    kv_arena_bytes: int = DEFAULT_KV_ARENA_BYTES,
                    compute_profile=None,
                    policy=None) -> DisaggResult:
    """Serve ``requests`` on ``prefill_pods`` + ``decode_pods`` pods.

    ``pod``/``n_gpus``/``cfg`` describe **one pod** (exactly the
    :func:`~repro.serving.simulate.simulate_traffic` arguments); the
    deployment is homogeneous hardware with heterogeneous roles.  The
    ``router`` (:data:`~repro.serving.fleet.ROUTERS`) is applied twice:
    arrivals route over prefill pods, completed prefills route their KV
    handoff over decode pods.  ``steps_cap`` bounds the **total** priced
    serving steps across every pod (transfers are not steps).

    The KV hop is priced per decode pod on a dedicated ``multi_pod`` pair
    fabric (:func:`~repro.workloads.derive.kv_transfer_fabric`) sharing
    ``cfg``'s translation/engine/retention knobs — so the L2-reach and
    retention axes a sweep varies apply to the transfer's Link-MMU too;
    each decode pod's shards ring-allocate through a ``kv_arena_bytes``
    arena (:class:`_TransferLink`), whose footprint against the Link-TLB
    reach sets the warm-vs-rewalking transfer regime.
    """
    if prefill_pods < 1 or decode_pods < 1:
        raise ValueError(f"need >= 1 pod per role, got "
                         f"{prefill_pods} prefill / {decode_pods} decode")
    if router not in ROUTERS:
        raise ValueError(f"unknown router {router!r}; known: {ROUTERS}")
    mcfg, pod, cfg = resolve_traffic_pod(arch, pod, n_gpus, cfg)
    policy = get_policy(policy)
    kv_cfg = cfg.replace(fabric=kv_transfer_fabric(pod),
                         collective="kv_transfer")

    def spawn(idx: int, role: str) -> Replica:
        stream = PodStream(mcfg, pod, cfg, [],
                           max_decode_slots=max_decode_slots,
                           prefill_chunk_tokens=prefill_chunk_tokens,
                           compute_profile=compute_profile, policy=policy)
        return Replica(idx=idx, stream=stream, spun_up_ns=0.0, role=role)

    prefill = [spawn(i, "prefill") for i in range(prefill_pods)]
    decode = [spawn(i, "decode") for i in range(decode_pods)]
    links = [_TransferLink(kv_cfg, policy, compute_profile,
                           arena_bytes=kv_arena_bytes)
             for _ in range(decode_pods)]

    arrivals = sorted(requests, key=lambda r: (r.arrival_ns, r.rid))
    origs: Dict[int, Request] = {r.rid: r for r in arrivals}
    if len(origs) != len(arrivals):
        raise ValueError("duplicate request ids in the arrival stream")
    handoffs: List[KVHandoff] = []
    handed: set = set()                # rids already transferred
    ai = 0
    rr_arr = rr_kv = 0
    total_steps = 0
    capped = False

    def handoff_finished(rep: Replica) -> None:
        """Route + price the KV transfer of newly completed prefills."""
        nonlocal rr_kv
        fresh = [r for r in rep.stream.batcher.stats
                 if r.finished and r.rid not in handed]
        for pr in sorted(fresh, key=lambda r: (r.finish_ns, r.rid)):
            handed.add(pr.rid)
            orig = origs[pr.rid]
            if orig.output_tokens <= 1:
                continue               # first token == only token: done
            target, rr_kv = _route(router, decode, orig, rr_kv)
            h = links[target.idx].transfer(
                orig.rid, target.idx,
                kv_shard_bytes(mcfg, orig.prompt_tokens, pod),
                pr.finish_ns)
            handoffs.append(h)
            target.stream.batcher.add(dataclasses.replace(
                orig, arrival_ns=h.done_ns, prompt_tokens=1))
            target.routed += 1

    while True:
        t_arr = arrivals[ai].arrival_ns if ai < len(arrivals) else None
        best: Optional[Tuple[float, int, int]] = None
        best_rep: Optional[Replica] = None
        for role_rank, group in ((0, prefill), (1, decode)):
            for rep in group:
                t_evt = rep.stream.next_event_ns()
                if t_evt is None:
                    continue
                key = (t_evt, role_rank, rep.idx)
                if best is None or key < best:
                    best, best_rep = key, rep

        if t_arr is not None and (best is None or t_arr <= best[0]):
            req = arrivals[ai]
            ai += 1
            target, rr_arr = _route(router, prefill, req, rr_arr)
            # The prefill pod serves the prompt as a 1-output-token
            # request: prefill computes the first token's logits, and the
            # commit that completes it is the handoff trigger.
            target.stream.batcher.add(
                dataclasses.replace(req, output_tokens=1))
            target.routed += 1
            continue

        if best_rep is None:
            break                      # no arrivals left, all pods drained
        step = best_rep.stream.advance()
        if step is not None:
            total_steps += 1
            best_rep.last_busy_ns = step.t_end
        if best_rep.role == "prefill":
            handoff_finished(best_rep)
        if step is not None and steps_cap is not None \
                and total_steps >= steps_cap:
            capped = True
            break

    # -- merge per-request stats onto the original arrival stream ------------
    pre_stats: Dict[int, RequestStats] = {
        r.rid: r for rep in prefill for r in rep.stream.batcher.stats}
    dec_stats: Dict[int, RequestStats] = {
        r.rid: r for rep in decode for r in rep.stream.batcher.stats}
    by_rid: Dict[int, KVHandoff] = {h.rid: h for h in handoffs}
    merged: List[RequestStats] = []
    for rid in sorted(origs):
        orig = origs[rid]
        pr = pre_stats[rid]
        h = by_rid.get(rid)
        if h is None:
            # Finished at prefill (output_tokens <= 1) or prefill still in
            # flight at the step cap: the prefill-side stats are the whole
            # story.  Re-point at the original request (the served clone
            # differs only in output_tokens).
            pr.req = orig
            pr.prefill_finish_ns = pr.finish_ns
            merged.append(pr)
            continue
        dr = dec_stats[rid]
        dr.req = orig                  # TTFT back against the true arrival
        dr.prefill_finish_ns = h.prefill_finish_ns
        dr.kv_start_ns = h.start_ns
        dr.kv_transfer_ns = h.transfer_ns
        dr.kv_transfer_ideal_ns = h.ideal_ns
        dr.kv_transfer_walks = h.walks
        # The request experienced the prefill phase's communication too.
        dr.cold_comm_ns += pr.cold_comm_ns
        dr.warm_comm_ns += pr.warm_comm_ns
        dr.rat_excess_ns += pr.rat_excess_ns
        dr.walks += pr.walks
        merged.append(dr)

    for rep in prefill + decode:
        rep.detach()
    return DisaggResult(arch=mcfg.name, pod=pod, cfg=cfg, prefill=prefill,
                        decode=decode, requests=merged, handoffs=handoffs,
                        steps_capped=capped)


# ------------------------------------------------------------------ sweeps
@dataclass(frozen=True)
class DisaggPoint:
    """One point of a disaggregation sweep: traffic plus the pod split.

    ``traffic`` fully describes one pod, the arrival stream and the
    scheduler knobs (its ``steps_cap`` becomes the deployment's *total*
    step cap); ``prefill_pods``/``decode_pods`` are the ``--disagg P:D``
    split.  Frozen and hashable — the point is the sweep key, so serial
    and pooled executors price it identically.
    """

    traffic: TrafficPoint = TrafficPoint()
    prefill_pods: int = 1
    decode_pods: int = 1
    router: str = "round_robin"
    kv_arena_bytes: int = DEFAULT_KV_ARENA_BYTES


def _disagg_point(task: Tuple[DisaggPoint]) -> DisaggResult:
    (dp,) = task
    t = dp.traffic
    return simulate_disagg(
        t.arch, t.requests(), pod=t.pod_spec(), cfg=t.sim_config(),
        prefill_pods=dp.prefill_pods, decode_pods=dp.decode_pods,
        router=dp.router, max_decode_slots=t.max_decode_slots,
        prefill_chunk_tokens=t.prefill_chunk_tokens,
        steps_cap=t.steps_cap, kv_arena_bytes=dp.kv_arena_bytes,
        compute_profile=t.load_profile(), policy=t.policy)


def sweep_disagg(points: Sequence[DisaggPoint], *,
                 workers: Optional[int] = None
                 ) -> Dict[DisaggPoint, DisaggResult]:
    """Price every :class:`DisaggPoint`, fanned over a process pool.

    Same executor contract as the traffic and fleet sweeps
    (:func:`~repro.serving.simulate.fan_out_points`): serial ≡ pooled
    bit-for-bit, duplicate points priced once.
    """
    return fan_out_points(points, _disagg_point, workers=workers)
