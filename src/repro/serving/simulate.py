"""Request-level serving simulation over persistent-TLB replay (DESIGN.md §11).

:func:`simulate_traffic` drives one :class:`~repro.core.session.SimSession`
with a stream of inference requests instead of a fixed step loop: a
continuous-batching scheduler (:mod:`repro.serving.scheduler`) decides each
step's live batch composition, :class:`repro.workloads.derive.StepEmitter`
sizes that step's collectives from it (EP dispatch bytes scale with active
tokens; prefill chunks interleave with decode tokens), and the session
prices them with whatever Link-TLB warmth the preceding traffic left
behind.  When the pod has no work the session *idles* to the next arrival —
under ``SimConfig.tlb_retention_ns`` a long enough gap flushes the warmed
translations, so the first steps after a quiet period re-pay the cold
walks.  That interaction between arrival burstiness and TLB retention is
the tail-latency mechanism this layer exists to measure.

The zero-translation counterfactual runs the *same* schedule (admission
decisions are driven by the baseline clock) on an ideal fabric: with
translation disabled a collective's duration depends only on its signature,
so each signature is priced once and the ideal timeline is accumulated
analytically.  Per-request degradation is then baseline vs ideal
time-to-first-token on an identical step sequence.

Determinism contract: given the same request list and ``SimConfig``,
:func:`simulate_traffic` is bit-for-bit deterministic across engines
(event ≡ vectorized) and sweep executors (:func:`fan_out_points` serial ≡
process-pooled) — locked by ``tests/test_serving.py``.
"""
from __future__ import annotations

import dataclasses
import multiprocessing
import os
from concurrent.futures import ProcessPoolExecutor
from concurrent.futures.process import BrokenProcessPool
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.config import (PrefetchConfig, PreTranslationConfig, SimConfig)
from ..core.session import SimSession
from ..workloads.derive import (PodSpec, StepEmitter, WorkloadTrace,
                                pod_fabric, resolve_pod)
from ..workloads.replay import buffer_layout
from .arrivals import (Request, bursty_requests, poisson_requests,
                       trace_requests)
from .scheduler import ContinuousBatcher, RequestStats

_PCTS = (50.0, 95.0, 99.0)


@dataclass
class ServingStep:
    """One engine step: live batch composition and priced timing."""

    step: int
    t_start: float
    t_end: float
    decode_tokens: int
    prefill_tokens: int
    comm_ns: float
    ideal_comm_ns: float
    compute_ns: float
    walks: int
    # Vectorized-engine warm-fast-path engagements while pricing this step
    # (0 on the event engine, which has no fast path to engage).
    fastpath_calls: int = 0

    @property
    def degradation(self) -> float:
        return (self.comm_ns / self.ideal_comm_ns
                if self.ideal_comm_ns else float("nan"))


class ServingAggregates:
    """Request/step aggregation shared by single-pod and fleet results.

    Mixin over anything exposing ``requests`` (a list of
    :class:`RequestStats`) and ``steps`` (a list of :class:`ServingStep`);
    :class:`TrafficResult` carries them as fields, the fleet result
    aggregates them over its replicas.
    """

    # -- aggregation ---------------------------------------------------------
    @property
    def finished(self) -> List[RequestStats]:
        return [r for r in self.requests if r.finished]

    @property
    def first_token_served(self) -> List[RequestStats]:
        return [r for r in self.requests if r.first_token_ns is not None]

    def ttft_percentiles(self, pcts: Sequence[float] = _PCTS) -> Dict[float, float]:
        vals = [r.ttft_ns for r in self.first_token_served]
        if not vals:
            return {p: float("nan") for p in pcts}
        return {p: float(np.percentile(vals, p)) for p in pcts}

    def itl_percentiles(self, pcts: Sequence[float] = _PCTS) -> Dict[float, float]:
        vals = [v for r in self.requests for v in r.itl_ns]
        if not vals:
            return {p: float("nan") for p in pcts}
        return {p: float(np.percentile(vals, p)) for p in pcts}

    def ttft_degradations(self) -> List[float]:
        return [r.ttft_degradation for r in self.first_token_served
                if r.ttft_degradation is not None]

    @property
    def mean_ttft_degradation(self) -> float:
        d = self.ttft_degradations()
        return float(np.mean(d)) if d else float("nan")

    @property
    def p99_ttft_degradation(self) -> float:
        d = self.ttft_degradations()
        if not d:
            return float("nan")
        with np.errstate(invalid="ignore"):
            p = float(np.percentile(d, 99.0))
        # Zero-ideal requests carry infinite degradation; when the p99
        # rank lands between two such samples numpy's interpolation is
        # inf - inf = nan, but the order statistic itself is inf.
        if np.isnan(p) and any(np.isinf(x) for x in d):
            return float("inf")
        return p

    # Pod-level comm split, aggregated from steps.  (Per-request
    # ``RequestStats.cold_comm_ns`` is *experienced* latency — every active
    # request counts a shared step in full — so summing it over requests
    # would multiply-count overlapping batches; these are the honest
    # pod-time aggregates.)
    @property
    def cold_comm_ns(self) -> float:
        return sum(s.comm_ns for s in self.steps if s.walks > 0)

    @property
    def warm_comm_ns(self) -> float:
        return sum(s.comm_ns for s in self.steps if s.walks == 0)

    @property
    def cold_steps(self) -> int:
        return sum(1 for s in self.steps if s.walks > 0)

    # Warm-fast-path engagement (vectorized engine only; the event engine
    # reports 0 everywhere and the fraction is 0.0).
    @property
    def fastpath_calls(self) -> int:
        return sum(s.fastpath_calls for s in self.steps)

    @property
    def fastpath_step_fraction(self) -> float:
        """Fraction of priced steps where the warm fast path engaged.

        Steady-state decode traffic on the vectorized engine should sit
        near 1.0; prefill chunks and post-flush steps are the misses.
        """
        if not self.steps:
            return float("nan")
        return (sum(1 for s in self.steps if s.fastpath_calls > 0)
                / len(self.steps))


@dataclass
class TrafficResult(ServingAggregates):
    """Per-request and per-step statistics of one serving simulation."""

    arch: str
    pod: PodSpec
    cfg: SimConfig
    requests: List[RequestStats]
    steps: List[ServingStep]
    steps_capped: bool = False


def _resolve_arch(arch):
    if isinstance(arch, str):
        from ..configs import get_config         # jax-free (models.spec)
        return get_config(arch)
    return arch


def serving_layout(mcfg, pod: PodSpec, max_step_tokens: int,
                   page_bytes: int) -> Dict[str, int]:
    """Page-aligned buffer offsets covering the *largest possible* step.

    Collective sizes vary with live batch composition, but a logical
    buffer's pages must stay put across steps (that is what makes repeated
    steps warm); regions are therefore sized for the worst-case step —
    every decode slot occupied plus a full prefill chunk.
    """
    em = StepEmitter(mcfg, pod)
    em.step(0, max_step_tokens)
    probe = WorkloadTrace(arch=mcfg.name, shape="serving", pod=pod,
                          calls=em.calls)
    return buffer_layout(probe, page_bytes)


def resolve_traffic_pod(arch, pod: Optional[PodSpec],
                        n_gpus: Optional[int],
                        cfg: Optional[SimConfig]):
    """``(mcfg, pod, cfg)`` after the shared serving-entry validation."""
    mcfg = _resolve_arch(arch)
    pod = pod or PodSpec()
    if n_gpus is not None:
        pod = dataclasses.replace(pod, n_gpus=n_gpus)
    pod = resolve_pod(pod, mcfg, "decode")
    cfg = cfg or SimConfig(fabric=pod_fabric(pod))
    if cfg.fabric.n_gpus != pod.n_gpus:
        raise ValueError(f"cfg pod size {cfg.fabric.n_gpus} != "
                         f"pod size {pod.n_gpus}")
    return mcfg, pod, cfg


class PodStream:
    """One pod's serving stream: session, batcher, ideal counterfactual.

    The single-pod engine behind :func:`simulate_traffic`, factored out so
    the fleet layer (:mod:`repro.serving.fleet`) can run N of them — one
    per replica, each with its own :class:`SimSession` (and hence its own
    Link-TLB warmth) — under an external event loop.  ``start_ns`` places
    the stream's clock at the replica's spin-up time: a freshly spun
    replica is a *cold* session whose first steps re-pay the full TLB
    warmup, which is exactly the fleet-scale RAT event.

    :meth:`advance` performs one scheduler decision — price one step, or
    idle to the stream's next arrival — and :meth:`next_event_ns` exposes
    when that decision would happen, so an external loop can interleave
    several streams in global time order without ever letting one stream's
    clock run ahead of an arrival that still has to be routed to it.
    """

    def __init__(self, mcfg, pod: PodSpec, cfg: SimConfig,
                 requests: List[Request], *,
                 max_decode_slots: int = 32,
                 prefill_chunk_tokens: int = 512,
                 compute_profile=None, start_ns: float = 0.0,
                 policy=None):
        self.mcfg, self.pod, self.cfg = mcfg, pod, cfg
        self.layout = serving_layout(
            mcfg, pod, max_decode_slots + prefill_chunk_tokens,
            cfg.translation.page_bytes)
        self.sess = SimSession(cfg, compute_profile=compute_profile)
        self.ideal = SimSession(cfg.ideal(), compute_profile=compute_profile)
        self.sess.t = start_ns
        self.ideal_clock = start_ns
        self._ideal_ns: Dict[tuple, float] = {}  # signature -> ideal ns
        self.batcher = ContinuousBatcher(
            requests, max_decode_slots=max_decode_slots,
            prefill_chunk_tokens=prefill_chunk_tokens)
        # The emitter resolves logical collectives per step; the trace it
        # emits carries concrete names, so the session replays exactly the
        # chosen algorithms (policy=None on the session side).
        self.em = StepEmitter(mcfg, pod, policy=policy)
        self.steps: List[ServingStep] = []

    @property
    def t(self) -> float:
        return self.sess.t

    @property
    def drained(self) -> bool:
        return self.batcher.drained

    def next_event_ns(self) -> Optional[float]:
        """When the next :meth:`advance` call would act; ``None`` = drained.

        ``sess.t`` when a step can be planned now (work admitted or in
        flight), else the stream's next arrival (never before ``sess.t`` —
        a stream cannot plan in its own past).
        """
        b = self.batcher
        if b.decoding or b.prefilling or b.waiting:
            return self.sess.t
        nxt = b.next_arrival_ns()
        if nxt is None:
            return None
        return max(nxt, self.sess.t)

    def advance(self) -> Optional[ServingStep]:
        """One scheduler decision: price one step, or idle to next arrival.

        Returns the priced :class:`ServingStep`, or ``None`` when the
        stream idled (or is drained — check :attr:`drained`).
        """
        plan = self.batcher.plan(self.sess.t)
        if plan is None:
            nxt = self.batcher.next_arrival_ns()
            if nxt is None:          # nothing in flight, nothing to come
                return None
            # Idle to the next arrival: ages (and beyond the retention
            # window, flushes) the warmed TLBs.  The ideal timeline waits
            # for the same arrival.  A flushing gap also resets the
            # emitter's buffer-warmth view, so the first post-flush steps
            # re-select cold-optimal algorithms.
            gap = nxt - self.sess.t
            self.sess.idle(gap)
            retention = self.cfg.tlb_retention_ns
            if retention is not None and gap >= retention:
                self.em.mark_cold()
            self.ideal_clock = max(self.ideal_clock, nxt)
            return None

        # Causality floor for the ideal timeline: the counterfactual run
        # executes the same step sequence, but a step serving a request's
        # *first* prefill chunk cannot start before that request arrived —
        # without this, a faster-than-baseline ideal clock could emit
        # first tokens before their requests exist, inflating degradation
        # with an unphysical queueing term.
        new_arrivals = [r.req.arrival_ns for r, _t in plan.prefill
                        if r.prefill_done == 0]
        if new_arrivals:
            self.ideal_clock = max(self.ideal_clock, max(new_arrivals))

        sess, em, layout = self.sess, self.em, self.layout
        t0 = sess.t
        base = len(em.calls)
        em.step(len(self.steps), plan.total_tokens,
                prefix=f"t{len(self.steps)}")
        comm = ideal_comm = compute = 0.0
        walks = fastpath = 0
        for c in em.calls[base:]:
            kw = dict(collective=c.collective, n_gpus=c.group,
                      rank_stride=c.stride, gap_ns=c.compute_ns,
                      base_offset=layout[c.buffer], label=c.label,
                      phase=c.phase, window_parts=c.window_parts)
            rec = sess.run(c.nbytes, **kw)
            comm += rec.completion_ns
            walks += rec.counters.walks
            fastpath += rec.fastpath_calls
            compute += sess.resolve_gap(c.compute_ns, c.phase,
                                        c.window_parts)
            sig = (c.collective, c.nbytes, c.group, c.stride)
            if sig not in self._ideal_ns:
                self._ideal_ns[sig] = self.ideal.run(
                    c.nbytes, **kw).completion_ns
            ideal_comm += self._ideal_ns[sig]
        self.ideal_clock += compute + ideal_comm
        step = ServingStep(
            step=len(self.steps), t_start=t0, t_end=sess.t,
            decode_tokens=plan.decode_tokens,
            prefill_tokens=plan.prefill_tokens,
            comm_ns=comm, ideal_comm_ns=ideal_comm, compute_ns=compute,
            walks=walks, fastpath_calls=fastpath)
        self.steps.append(step)
        self.batcher.commit(plan, sess.t, self.ideal_clock, comm,
                            ideal_comm, walks)
        return step


def simulate_traffic(arch, requests: List[Request], *,
                     pod: Optional[PodSpec] = None,
                     n_gpus: Optional[int] = None,
                     cfg: Optional[SimConfig] = None,
                     max_decode_slots: int = 32,
                     prefill_chunk_tokens: int = 512,
                     steps_cap: Optional[int] = None,
                     compute_profile=None,
                     policy=None) -> TrafficResult:
    """Serve ``requests`` on a simulated pod; returns per-request latencies.

    ``arch`` is a registry name (resolved without importing jax) or any
    ``ModelConfig``-shaped object.  ``cfg`` overrides the simulated fabric
    and translation knobs (``tlb_retention_ns`` is what couples arrival
    gaps to TLB cold misses); the default simulates the pod the workload
    is mapped onto, exactly as workload replay does.  ``steps_cap`` bounds
    the number of engine steps (unfinished requests simply stay
    unfinished); percentiles are computed over served requests.
    ``policy`` selects each step's collective algorithms
    (:mod:`repro.core.select`; default fixed — bit-for-bit).
    """
    mcfg, pod, cfg = resolve_traffic_pod(arch, pod, n_gpus, cfg)
    stream = PodStream(mcfg, pod, cfg, requests,
                       max_decode_slots=max_decode_slots,
                       prefill_chunk_tokens=prefill_chunk_tokens,
                       compute_profile=compute_profile, policy=policy)
    capped = False
    while not stream.drained:
        if steps_cap is not None and len(stream.steps) >= steps_cap:
            capped = True
            break
        stream.advance()

    return TrafficResult(arch=mcfg.name, pod=pod, cfg=cfg,
                         requests=stream.batcher.stats, steps=stream.steps,
                         steps_capped=capped)


# ------------------------------------------------------------------ sweeps
@dataclass(frozen=True)
class TrafficPoint:
    """One point of a serving sweep — fully describes a simulation.

    Frozen and hashable: the point is the sweep key, and (with its seed) it
    *is* the arrival stream, so a point prices identically on the serial
    and the pooled executor.
    """

    arch: str = "granite-moe-1b-a400m"
    rps: float = 8.0
    arrival: str = "poisson"            # poisson | bursty
    n_requests: int = 64
    seed: int = 0
    n_gpus: int = 16
    topology: str = "single_clos"
    leaf_size: int = 0
    oversubscription: float = 1.0
    pod_size: int = 0
    l2_entries: int = 0                 # 0 => translation default
    retention_ns: Optional[float] = None
    steps_cap: Optional[int] = None
    burst_size: int = 8
    burstiness: float = 16.0
    prompt_mean: int = 256
    output_mean: int = 32
    max_decode_slots: int = 32
    prefill_chunk_tokens: int = 512
    pretranslation: bool = False        # paper §6.1 fused probes
    prefetch: bool = False              # paper §6.2 software prefetch
    trace_path: Optional[str] = None    # arrival="trace"
    engine: str = "event"               # SimConfig.engine (bit-for-bit)
    # Path to a saved ComputeProfile JSON (workloads.calibrate): loaded
    # jax-free *inside* whichever process prices the point, so pooled and
    # serial executors resolve identical calibrated windows.  None keeps
    # the roofline windows (bit-for-bit the uncalibrated behavior).
    profile_path: Optional[str] = None
    # Algorithm-selection policy spec ("fixed" | "auto" | "table:<path>",
    # repro.core.select.get_policy) — a string so the point stays hashable;
    # resolved inside whichever process prices the point, like
    # profile_path.  "fixed" is bit-for-bit the pre-policy behavior.
    policy: str = "fixed"

    def requests(self) -> List[Request]:
        kw = dict(prompt_mean=self.prompt_mean, output_mean=self.output_mean,
                  seed=self.seed)
        if self.arrival == "poisson":
            return poisson_requests(self.n_requests, self.rps, **kw)
        if self.arrival == "bursty":
            return bursty_requests(self.n_requests, self.rps,
                                   burst_size=self.burst_size,
                                   burstiness=self.burstiness, **kw)
        if self.arrival == "trace":
            if not self.trace_path:
                raise ValueError("arrival='trace' needs trace_path")
            return trace_requests(self.trace_path, limit=self.n_requests)
        raise ValueError(f"unknown arrival process {self.arrival!r}")

    def sim_config(self) -> SimConfig:
        pod = self.pod_spec()
        cfg = SimConfig(fabric=pod_fabric(pod),
                        tlb_retention_ns=self.retention_ns,
                        engine=self.engine)
        if self.l2_entries:
            tr = cfg.translation
            cfg = cfg.replace(translation=dataclasses.replace(
                tr, l2=dataclasses.replace(tr.l2, entries=self.l2_entries)))
        if self.pretranslation:
            cfg = cfg.replace(pretranslation=PreTranslationConfig(
                enabled=True, lead_time_ns=3000.0, pages_per_flow=0))
        if self.prefetch:
            cfg = cfg.replace(prefetch=PrefetchConfig(enabled=True, depth=2))
        return cfg

    def pod_spec(self) -> PodSpec:
        return PodSpec(n_gpus=self.n_gpus, topology=self.topology,
                       leaf_size=self.leaf_size,
                       oversubscription=self.oversubscription,
                       pod_size=self.pod_size)

    def load_profile(self):
        """The point's :class:`ComputeProfile`, or ``None``.

        Loaded from ``profile_path`` on demand — jax-free (the profile is
        a JSON cache), and called inside the pool worker so the profile
        object itself never crosses the process boundary.
        """
        if not self.profile_path:
            return None
        from ..workloads.calibrate import ComputeProfile
        return ComputeProfile.load(self.profile_path)


def _traffic_point(task: Tuple[TrafficPoint]) -> TrafficResult:
    (pt,) = task
    return simulate_traffic(pt.arch, pt.requests(), pod=pt.pod_spec(),
                            cfg=pt.sim_config(),
                            max_decode_slots=pt.max_decode_slots,
                            prefill_chunk_tokens=pt.prefill_chunk_tokens,
                            steps_cap=pt.steps_cap,
                            compute_profile=pt.load_profile(),
                            policy=pt.policy)


def fan_out_points(points: Sequence, worker, *,
                   workers: Optional[int] = None) -> Dict:
    """Price hashable sweep points through a module-level ``worker``.

    The shared executor behind :func:`sweep_traffic` and the fleet sweep.
    Mirrors :func:`repro.core.ratsim.sweep`: ``workers=None`` sizes the
    pool to the host, ``workers=0`` forces the serial in-process path, and
    both paths return bit-for-bit identical results — each point's arrival
    stream is regenerated from its seed inside whichever process prices it,
    never shipped across the pool boundary.

    Repeated points are priced **once**: the task list is deduplicated up
    front (a point is its own sweep key, so duplicates are necessarily
    identical work), mirroring ``ratsim.sweep``'s in-flight memoization,
    and the returned mapping still covers every input point — equal points
    are equal keys.
    """
    from ..core.ratsim import _spawnable
    unique: List = []
    seen = set()
    for pt in points:
        if pt not in seen:
            seen.add(pt)
            unique.append(pt)
    tasks = [(pt,) for pt in unique]
    results: List = []
    n_workers = (min(len(tasks), os.cpu_count() or 1)
                 if workers is None else workers)
    if n_workers >= 2 and len(tasks) > 1 and _spawnable():
        try:
            ctx = multiprocessing.get_context("spawn")
            with ProcessPoolExecutor(max_workers=n_workers,
                                     mp_context=ctx) as pool:
                results = list(pool.map(worker, tasks))
        except (OSError, BrokenProcessPool):
            results = []
    if not results and tasks:
        results = [worker(t) for t in tasks]
    return dict(zip(unique, results))


def sweep_traffic(points: Sequence[TrafficPoint], *,
                  workers: Optional[int] = None
                  ) -> Dict[TrafficPoint, TrafficResult]:
    """Price every :class:`TrafficPoint`, fanned over a process pool.

    See :func:`fan_out_points` for the executor contract (serial ≡ pooled
    bit-for-bit; duplicate points priced once).
    """
    return fan_out_points(points, _traffic_point, workers=workers)
