"""Continuous-batching scheduler for the serving simulator (DESIGN.md §11).

vLLM-style continuous batching with chunked prefill: every simulated step
processes one token for each decoding request plus up to
``prefill_chunk_tokens`` prompt tokens from admitted-but-unprefilled
requests, so prefill work interleaves with decode steps instead of stalling
them.  A request occupies one of ``max_decode_slots`` batch slots from the
moment its prefill starts until its last output token, bounding the live
batch the way KV-cache capacity does on real engines.

The scheduler only *plans* token counts; the simulator prices each planned
step's collectives (sized from the live batch composition via
:class:`repro.workloads.derive.StepEmitter`) and reports the step's timing
back through :meth:`ContinuousBatcher.commit`, which advances request state
and records per-request latency samples: time-to-first-token when a prefill
completes (prefill computes the first output token's logits), one
inter-token sample per decode step, and the cold-vs-warm communication
split (a step is *cold* when its collectives performed at least one page
walk — the Link-TLB working set was not resident).

Determinism contract: scheduling decisions are pure functions of the
admitted request sequence and the committed step timings — no RNG, no wall
clock — so a batcher replayed on the same inputs reproduces the same plans
and latency samples bit-for-bit on either simulation engine.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple

from .arrivals import Request


@dataclass
class RequestStats:
    """Per-request latency accounting, threaded from session run deltas."""

    req: Request
    prefill_done: int = 0
    tokens_out: int = 0
    first_token_ns: Optional[float] = None       # absolute baseline time
    ideal_first_token_ns: Optional[float] = None
    finish_ns: Optional[float] = None
    ideal_finish_ns: Optional[float] = None
    itl_ns: List[float] = field(default_factory=list)
    cold_comm_ns: float = 0.0    # comm time of its cold (walking) steps
    warm_comm_ns: float = 0.0    # comm time of its warm steps
    rat_excess_ns: float = 0.0   # sum of (comm - ideal comm) over its steps
    walks: int = 0
    # Disaggregated-serving handoff accounting (DESIGN.md §16); left at the
    # defaults in colocated mode, filled by repro.serving.disagg when the
    # request crosses a prefill pod -> decode pod boundary.  All times are
    # absolute on the one global clock every stream shares.
    prefill_finish_ns: Optional[float] = None  # prefill pod completed prompt
    kv_start_ns: Optional[float] = None        # KV transfer began on the link
    kv_transfer_ns: float = 0.0                # priced transfer duration
    kv_transfer_ideal_ns: float = 0.0          # zero-translation counterpart
    kv_transfer_walks: int = 0                 # page walks the transfer paid
    _last_token_ns: Optional[float] = None

    # -- identity ------------------------------------------------------------
    @property
    def rid(self) -> int:
        return self.req.rid

    @property
    def finished(self) -> bool:
        return self.finish_ns is not None

    # -- latency metrics -----------------------------------------------------
    @property
    def ttft_ns(self) -> Optional[float]:
        if self.first_token_ns is None:
            return None
        return self.first_token_ns - self.req.arrival_ns

    @property
    def ideal_ttft_ns(self) -> Optional[float]:
        if self.ideal_first_token_ns is None:
            return None
        return self.ideal_first_token_ns - self.req.arrival_ns

    @property
    def ttft_degradation(self) -> Optional[float]:
        """Baseline/ideal TTFT ratio; ``None`` only when unserved.

        A *legitimate* zero ideal TTFT (the counterfactual serves the
        first token the instant the request arrives — e.g. an arrival
        coinciding with its first-token step) is infinite degradation,
        not a missing sample: returning ``None`` there silently dropped
        the worst-degraded requests from the percentiles.
        """
        t, i = self.ttft_ns, self.ideal_ttft_ns
        if t is None or i is None:
            return None
        if i <= 0.0:
            return 1.0 if t <= 0.0 else float("inf")
        return t / i

    @property
    def e2e_ns(self) -> Optional[float]:
        if self.finish_ns is None:
            return None
        return self.finish_ns - self.req.arrival_ns

    @property
    def e2e_degradation(self) -> Optional[float]:
        if self.finish_ns is None or self.ideal_finish_ns is None:
            return None
        ideal = self.ideal_finish_ns - self.req.arrival_ns
        actual = self.finish_ns - self.req.arrival_ns
        if ideal <= 0.0:                  # same zero-ideal contract as TTFT
            return 1.0 if actual <= 0.0 else float("inf")
        return actual / ideal

    @property
    def mean_itl_ns(self) -> Optional[float]:
        return (sum(self.itl_ns) / len(self.itl_ns)) if self.itl_ns else None

    # -- disaggregation decomposition (DESIGN.md §16) ------------------------
    # TTFT = prefill phase + transfer queueing + transfer + decode queueing;
    # every term is None until the corresponding handoff stage happened.
    @property
    def kv_done_ns(self) -> Optional[float]:
        if self.kv_start_ns is None:
            return None
        return self.kv_start_ns + self.kv_transfer_ns

    @property
    def kv_transfer_excess_ns(self) -> float:
        """Transfer time beyond its zero-translation ideal (cold-RAT tax)."""
        return self.kv_transfer_ns - self.kv_transfer_ideal_ns

    @property
    def prefill_ns(self) -> Optional[float]:
        """Arrival -> prefill completion (queueing + compute + comm)."""
        if self.prefill_finish_ns is None:
            return None
        return self.prefill_finish_ns - self.req.arrival_ns

    @property
    def kv_wait_ns(self) -> Optional[float]:
        """Prefill completion -> transfer start (serialized-link queueing)."""
        if self.kv_start_ns is None or self.prefill_finish_ns is None:
            return None
        return self.kv_start_ns - self.prefill_finish_ns

    @property
    def decode_wait_ns(self) -> Optional[float]:
        """Transfer completion -> first token (decode admission + step)."""
        if self.first_token_ns is None or self.kv_done_ns is None:
            return None
        return self.first_token_ns - self.kv_done_ns


@dataclass
class StepPlan:
    """One planned engine step: the live batch composition."""

    decode: List[RequestStats]                   # one new token each
    prefill: List[Tuple[RequestStats, int]]      # (request, chunk tokens)

    @property
    def decode_tokens(self) -> int:
        return len(self.decode)

    @property
    def prefill_tokens(self) -> int:
        return sum(t for _r, t in self.prefill)

    @property
    def total_tokens(self) -> int:
        return self.decode_tokens + self.prefill_tokens

    def active(self) -> List[RequestStats]:
        return self.decode + [r for r, _t in self.prefill]


class ContinuousBatcher:
    """Admission + batch-composition state machine.

    ``plan(now_ns)`` admits every request that has arrived by ``now_ns``
    and returns the next step's composition (or ``None`` when the pod has
    no work — the simulator then idles to :meth:`next_arrival_ns`, which is
    where idle-gap TLB aging happens).  After pricing the step, the
    simulator calls ``commit(plan, ...)`` with the step's end times and
    communication statistics.
    """

    def __init__(self, requests: List[Request], *,
                 max_decode_slots: int = 32,
                 prefill_chunk_tokens: int = 512):
        if max_decode_slots < 1:
            raise ValueError(
                f"max_decode_slots must be >= 1, got {max_decode_slots}")
        if prefill_chunk_tokens < 1:
            raise ValueError(
                f"prefill_chunk_tokens must be >= 1, got "
                f"{prefill_chunk_tokens}")
        self.max_decode_slots = max_decode_slots
        self.prefill_chunk_tokens = prefill_chunk_tokens
        order = sorted(requests, key=lambda r: (r.arrival_ns, r.rid))
        self.stats: List[RequestStats] = [RequestStats(req=r) for r in order]
        self._next = 0                           # first not-yet-arrived index
        self.waiting: List[RequestStats] = []    # arrived, prefill not begun
        self.prefilling: List[RequestStats] = []
        self.decoding: List[RequestStats] = []
        self._started = 0                        # prefills ever begun
        self._finished = 0                       # requests ever finished

    # -- arrivals ------------------------------------------------------------
    def _admit(self, now_ns: float) -> None:
        while (self._next < len(self.stats)
               and self.stats[self._next].req.arrival_ns <= now_ns):
            self.waiting.append(self.stats[self._next])
            self._next += 1

    def add(self, req: Request) -> RequestStats:
        """Feed one more request into a live batcher (fleet routing).

        Requests must be added in nondecreasing arrival order — the fleet
        router dispatches at arrival time, so this holds by construction —
        keeping ``stats`` sorted and the ``_next`` admission pointer valid.
        """
        if self.stats and req.arrival_ns < self.stats[-1].req.arrival_ns:
            raise ValueError(
                f"out-of-order add: arrival {req.arrival_ns} precedes "
                f"last routed arrival {self.stats[-1].req.arrival_ns}")
        r = RequestStats(req=req)
        self.stats.append(r)
        return r

    def next_arrival_ns(self) -> Optional[float]:
        if self._next < len(self.stats):
            return self.stats[self._next].req.arrival_ns
        return None

    @property
    def drained(self) -> bool:
        """All requests retired (arrived, served, finished)."""
        return (self._next >= len(self.stats) and not self.waiting
                and not self.prefilling and not self.decoding)

    # -- load accounting (router / autoscaler inputs) -------------------------
    @property
    def queued(self) -> int:
        """Routed requests whose prefill has not begun (admission queue)."""
        return len(self.stats) - self._started

    @property
    def load(self) -> int:
        """Outstanding requests: routed and not yet finished (queue depth)."""
        return len(self.stats) - self._finished

    # -- planning ------------------------------------------------------------
    def plan(self, now_ns: float) -> Optional[StepPlan]:
        self._admit(now_ns)
        budget = self.prefill_chunk_tokens
        prefill: List[Tuple[RequestStats, int]] = []
        # Continue in-flight prefills first (their slots are already held),
        # then start waiting requests while slots and chunk budget remain.
        for r in self.prefilling:
            if budget <= 0:
                break
            take = min(budget, r.req.prompt_tokens - r.prefill_done)
            prefill.append((r, take))
            budget -= take
        while (budget > 0 and self.waiting
               and (len(self.prefilling) + len(self.decoding)
                    < self.max_decode_slots)):
            r = self.waiting.pop(0)
            self._started += 1
            self.prefilling.append(r)
            take = min(budget, r.req.prompt_tokens)
            prefill.append((r, take))
            budget -= take
        if not prefill and not self.decoding:
            return None
        return StepPlan(decode=list(self.decoding), prefill=prefill)

    # -- completion ----------------------------------------------------------
    def commit(self, plan: StepPlan, t_end: float, ideal_t_end: float,
               comm_ns: float, ideal_comm_ns: float, walks: int) -> None:
        """Apply one priced step: token emissions and latency samples.

        Every request active in the step experiences the step's full
        communication latency (latency is shared, not divided), classified
        cold or warm by whether the step's collectives performed page
        walks; the RAT excess is the step's communication time beyond its
        zero-translation ideal.
        """
        cold = walks > 0
        for r in plan.active():
            if cold:
                r.cold_comm_ns += comm_ns
            else:
                r.warm_comm_ns += comm_ns
            r.rat_excess_ns += comm_ns - ideal_comm_ns
            r.walks += walks
        for r, take in plan.prefill:
            r.prefill_done += take
            if r.prefill_done >= r.req.prompt_tokens:
                # Prefill computed the first output token's logits.
                r.tokens_out = 1
                r.first_token_ns = t_end
                r.ideal_first_token_ns = ideal_t_end
                r._last_token_ns = t_end
                self.prefilling.remove(r)
                if r.tokens_out >= r.req.output_tokens:
                    r.finish_ns = t_end
                    r.ideal_finish_ns = ideal_t_end
                    self._finished += 1
                else:
                    self.decoding.append(r)
        for r in plan.decode:
            r.tokens_out += 1
            r.itl_ns.append(t_end - r._last_token_ns)
            r._last_token_ns = t_end
            if r.tokens_out >= r.req.output_tokens:
                r.finish_ns = t_end
                r.ideal_finish_ns = ideal_t_end
                self._finished += 1
                self.decoding.remove(r)
