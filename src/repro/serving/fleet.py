"""Fleet-scale serving: multi-replica routing and autoscaling (DESIGN.md §13).

One :func:`~repro.serving.simulate.simulate_traffic` run drives a single
pod; millions of users mean a *fleet*.  :func:`simulate_fleet` serves one
arrival stream across N pod replicas, each its own
:class:`~repro.serving.simulate.PodStream` (and hence its own
:class:`~repro.core.session.SimSession` with its own Link-TLB warmth),
fronted by

* a **router** dispatching each request at its arrival instant —
  ``round_robin`` (cyclic over live replicas), ``least_loaded`` (fewest
  outstanding requests, ties to the lowest replica index) or ``affinity``
  (a deterministic rid hash, so a request population keeps hitting the
  same replicas and their warmed translations);
* a **bounded admission queue** — when the fleet-wide count of routed-but-
  not-yet-prefilling requests reaches ``max_queue``, new arrivals are
  rejected (recorded, excluded from latency percentiles: an SLO miss of a
  different kind);
* a queue-depth-driven **autoscaler** — when the admission queue exceeds
  ``scale_up_queued``, a new replica is spun up (available after
  ``spinup_latency_ns``); replicas idle longer than ``scale_down_idle_ns``
  are retired.  A newly spun replica starts with **stone-cold TLBs**:
  replica spin-up *is* the cold-RAT event at fleet scale, so every scaling
  decision trades queue wait against the full cold-walk warmup tax that
  the paper prices on a single pod.

Determinism contract: the fleet event loop processes arrivals and replica
step boundaries in global time order (arrival first on ties, lowest
replica index among replicas), every router/autoscaler input is a pure
function of that ordering, and each replica's arrival sub-stream is data —
so the serial and process-pooled sweep executors (:func:`sweep_fleet`)
return bit-for-bit identical results on both simulation engines.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from ..core.config import SimConfig
from ..workloads.derive import PodSpec
from .arrivals import Request
from .scheduler import RequestStats
from .simulate import (PodStream, ServingAggregates, ServingStep,
                       TrafficPoint, fan_out_points, resolve_traffic_pod)

ROUTERS = ("round_robin", "least_loaded", "affinity")

# Knuth multiplicative hash: spreads consecutive rids across replicas
# deterministically (no PYTHONHASHSEED dependence), so session affinity is
# reproducible across processes and sweep executors.
_HASH_MULT = 2654435761


def _rid_hash(rid: int) -> int:
    return (rid * _HASH_MULT) & 0xFFFFFFFF


@dataclass
class Replica:
    """One pod replica: lifecycle bookkeeping plus its served traffic.

    During simulation the replica drives a live :class:`PodStream`; before
    the result is returned the stream is *detached* into the plain
    ``stats``/``steps`` data fields (a live stream holds simulator
    internals that cannot cross the sweep pool boundary — results must
    pickle).
    """

    idx: int
    spun_up_ns: float                  # when it became routable (cold start)
    retired_ns: Optional[float] = None
    last_busy_ns: float = 0.0          # end of its latest priced step
    routed: int = 0                    # requests ever routed to it
    # Replica role in the router: "serve" (colocated fleet — the default,
    # every replica handles prefill and decode) or "prefill"/"decode"
    # (disaggregated mode, repro.serving.disagg — arrivals route only over
    # prefill replicas, KV handoffs only over decode replicas).
    role: str = "serve"
    stats: List[RequestStats] = field(default_factory=list)
    steps: List[ServingStep] = field(default_factory=list)
    stream: Optional[PodStream] = field(default=None, repr=False)

    @property
    def live(self) -> bool:
        return self.retired_ns is None

    def available(self, now_ns: float) -> bool:
        """Routable: spun up by ``now_ns`` and not retired."""
        return self.live and self.spun_up_ns <= now_ns

    def detach(self) -> None:
        """Pull the stream's accounting into data fields and drop it."""
        if self.stream is not None:
            self.stats = self.stream.batcher.stats
            self.steps = self.stream.steps
            self.stream = None


@dataclass
class FleetResult(ServingAggregates):
    """Aggregated per-request / per-step statistics of one fleet run."""

    arch: str
    pod: PodSpec                       # per-replica pod (homogeneous fleet)
    cfg: SimConfig
    replicas: List[Replica]
    rejected: List[Request] = field(default_factory=list)
    steps_capped: bool = False

    # -- aggregation inputs for ServingAggregates ----------------------------
    @property
    def requests(self) -> List[RequestStats]:
        """Every routed request across the fleet, in rid order."""
        out = [r for rep in self.replicas for r in rep.stats]
        out.sort(key=lambda r: r.rid)
        return out

    @property
    def steps(self) -> List[ServingStep]:
        """Every priced step across the fleet, in global time order."""
        return [s for _k, s in sorted(
            ((s.t_start, rep.idx, s.step), s)
            for rep in self.replicas for s in rep.steps)]

    # -- fleet-level accounting ----------------------------------------------
    @property
    def spin_ups(self) -> int:
        """Replicas spun up after t=0 (autoscaler cold starts)."""
        return sum(1 for rep in self.replicas if rep.spun_up_ns > 0.0)

    @property
    def retired(self) -> int:
        return sum(1 for rep in self.replicas if rep.retired_ns is not None)

    @property
    def peak_replicas(self) -> int:
        """Most replicas ever live at once (the capacity actually used).

        Not ``len(self.replicas)`` — with autoscaler churn the same
        capacity slot is filled by several replicas over the run's
        lifetime (spin up, retire, re-spin), and the fleet list keeps
        them all for accounting.
        """
        events = []
        for rep in self.replicas:
            events.append((rep.spun_up_ns, 1))
            if rep.retired_ns is not None:
                events.append((rep.retired_ns, -1))
        live = peak = 0
        for _t, d in sorted(events):
            live += d
            peak = max(peak, live)
        return peak

    @property
    def served(self) -> int:
        return len(self.first_token_served)

    def replica_rows(self) -> List[dict]:
        """Per-replica summary (the cast2md-style scaling-table rows)."""
        rows = []
        for rep in self.replicas:
            steps = rep.steps
            cold = sum(s.comm_ns for s in steps if s.walks > 0)
            warm = sum(s.comm_ns for s in steps if s.walks == 0)
            rows.append(dict(
                idx=rep.idx, role=rep.role, spun_up_ns=rep.spun_up_ns,
                retired_ns=rep.retired_ns, routed=rep.routed,
                steps=len(steps),
                walks=sum(s.walks for s in steps),
                fastpath_calls=sum(s.fastpath_calls for s in steps),
                cold_comm_ns=cold, warm_comm_ns=warm))
        return rows


def _route(router: str, active: List[Replica], req: Request,
           rr_cursor: int) -> Tuple[Replica, int]:
    """Pick the replica for ``req``; returns (replica, next rr cursor).

    ``active`` is the live-and-available list in replica-index order, never
    empty (the fleet keeps at least ``min_replicas`` live replicas, and the
    initial replicas are available from t=0).
    """
    if router == "round_robin":
        return active[rr_cursor % len(active)], rr_cursor + 1
    if router == "least_loaded":
        return min(active, key=lambda r: (r.stream.batcher.load, r.idx)), \
            rr_cursor
    if router == "affinity":
        return active[_rid_hash(req.rid) % len(active)], rr_cursor
    raise ValueError(f"unknown router {router!r}; known: {ROUTERS}")


def simulate_fleet(arch, requests: List[Request], *,
                   pod: Optional[PodSpec] = None,
                   n_gpus: Optional[int] = None,
                   cfg: Optional[SimConfig] = None,
                   replicas: int = 2,
                   router: str = "round_robin",
                   max_queue: Optional[int] = None,
                   autoscale: bool = False,
                   min_replicas: int = 1,
                   max_replicas: int = 0,
                   scale_up_queued: int = 4,
                   scale_down_idle_ns: Optional[float] = None,
                   spinup_latency_ns: float = 0.0,
                   max_decode_slots: int = 32,
                   prefill_chunk_tokens: int = 512,
                   steps_cap: Optional[int] = None,
                   compute_profile=None,
                   policy=None) -> FleetResult:
    """Serve ``requests`` on a fleet of identical pod replicas.

    ``pod``/``n_gpus``/``cfg`` describe **one replica** (exactly the
    :func:`~repro.serving.simulate.simulate_traffic` arguments); the fleet
    is ``replicas`` copies of it.  With ``autoscale=True`` the fleet
    instead starts at ``min_replicas`` and grows on queue pressure up to
    ``max_replicas`` (0 means ``replicas``) — each spin-up appears
    ``spinup_latency_ns`` after the triggering arrival with stone-cold
    TLBs, and replicas idle past ``scale_down_idle_ns`` are retired, so a
    later burst pays the spin-up *and* the cold warmup again.

    ``steps_cap`` bounds the **total** engine steps across the fleet.

    The event loop interleaves arrivals and replica steps in global time
    order (ties: arrival first, then lowest replica index).  Routing,
    admission and scaling all happen at arrival instants; a replica's step
    is atomic, so a step that straddles an arrival exposes its end-of-step
    request state to that arrival's routing decision — the usual
    one-step-granularity approximation of a discrete-step serving sim.
    """
    if replicas < 1:
        raise ValueError(f"replicas must be >= 1, got {replicas}")
    if router not in ROUTERS:
        raise ValueError(f"unknown router {router!r}; known: {ROUTERS}")
    mcfg, pod, cfg = resolve_traffic_pod(arch, pod, n_gpus, cfg)
    # Resolve the policy spec once: every replica shares one policy object
    # (an AutoPolicy's memoized candidate prices are fleet-wide that way).
    from ..core.select import get_policy
    policy = get_policy(policy)
    cap = max_replicas or replicas
    if autoscale:
        if not 1 <= min_replicas <= cap:
            raise ValueError(
                f"need 1 <= min_replicas({min_replicas}) <= "
                f"max_replicas({cap})")
        n_start = min_replicas
    else:
        n_start = replicas

    def spawn(idx: int, now_ns: float) -> Replica:
        # A fresh PodStream == a fresh SimSession == stone-cold TLBs: the
        # replica's first steps re-pay the full cold-walk warmup.
        stream = PodStream(mcfg, pod, cfg, [],
                           max_decode_slots=max_decode_slots,
                           prefill_chunk_tokens=prefill_chunk_tokens,
                           compute_profile=compute_profile,
                           start_ns=now_ns, policy=policy)
        return Replica(idx=idx, stream=stream, spun_up_ns=now_ns,
                       last_busy_ns=now_ns)

    fleet: List[Replica] = [spawn(i, 0.0) for i in range(n_start)]
    rejected: List[Request] = []
    arrivals = sorted(requests, key=lambda r: (r.arrival_ns, r.rid))
    ai = 0
    rr_cursor = 0
    total_steps = 0
    capped = False

    while True:
        t_arr = arrivals[ai].arrival_ns if ai < len(arrivals) else None
        # Earliest replica event (step start or idle-to-arrival target).
        best: Optional[Tuple[float, int]] = None
        for rep in fleet:
            if not rep.live:
                continue                 # retired replicas are drained
            t_evt = rep.stream.next_event_ns()
            if t_evt is None:
                continue
            if best is None or t_evt < best[0]:
                best = (t_evt, rep.idx)

        if t_arr is not None and (best is None or t_arr <= best[0]):
            now = t_arr
            req = arrivals[ai]
            ai += 1
            # Scale-down first: replicas whose streams drained and have
            # been idle past the threshold are retired (highest index
            # first would equal lowest here — each is checked on its own).
            if autoscale and scale_down_idle_ns is not None:
                live = [r for r in fleet if r.live]
                n_live = len(live)
                for rep in reversed(live):       # newest replicas first
                    if n_live <= min_replicas:
                        break
                    if (rep.stream.drained
                            and now - rep.last_busy_ns
                            >= scale_down_idle_ns):
                        rep.retired_ns = now
                        n_live -= 1
            queued = sum(r.stream.batcher.queued for r in fleet if r.live)
            # Bounded admission: reject before routing when the fleet-wide
            # prefill backlog is at capacity.
            if max_queue is not None and queued >= max_queue:
                rejected.append(req)
                continue
            active = [r for r in fleet if r.available(now)]
            if not active:
                # Every live replica still spinning up: the request waits
                # on whichever comes up first (routed there now; its
                # stream clock starts at spin-up anyway).
                target = min((r for r in fleet if r.live),
                             key=lambda r: (r.spun_up_ns, r.idx))
            else:
                target, rr_cursor = _route(router, active, req, rr_cursor)
            target.stream.batcher.add(req)
            target.routed += 1
            # Scale-up after routing: the queue the autoscaler sees
            # includes the arrival that just joined it.  ``cap`` bounds
            # *live* replicas (pending spin-ups included), not the total
            # ever spawned — churn (spin up, retire, re-spin cold) is the
            # whole point.
            if autoscale:
                live_n = sum(1 for r in fleet if r.live)
                if live_n < cap and queued + 1 > scale_up_queued:
                    fleet.append(spawn(len(fleet),
                                       now + spinup_latency_ns))
            continue

        if best is None:
            break                        # no arrivals left, fleet drained
        rep = fleet[best[1]]
        step = rep.stream.advance()
        if step is not None:
            total_steps += 1
            rep.last_busy_ns = step.t_end
            if steps_cap is not None and total_steps >= steps_cap:
                capped = True
                break

    for rep in fleet:
        rep.detach()
    return FleetResult(arch=mcfg.name, pod=pod, cfg=cfg, replicas=fleet,
                       rejected=rejected, steps_capped=capped)


# ------------------------------------------------------------------ sweeps
@dataclass(frozen=True)
class FleetPoint:
    """One point of a fleet sweep: a traffic point plus the fleet policy.

    ``traffic`` fully describes one replica's pod, the arrival stream and
    the per-replica scheduler knobs (its ``steps_cap`` becomes the fleet's
    *total* step cap); the remaining fields are the router/queue/autoscaler
    policy.  Frozen and hashable — the point is the sweep key, and with
    its seed it *is* the workload, so serial and pooled executors price it
    identically.
    """

    traffic: TrafficPoint = TrafficPoint()
    replicas: int = 2
    router: str = "round_robin"
    max_queue: Optional[int] = None
    autoscale: bool = False
    min_replicas: int = 1
    max_replicas: int = 0              # 0 -> replicas
    scale_up_queued: int = 4
    scale_down_idle_ns: Optional[float] = None
    spinup_latency_ns: float = 0.0


def _fleet_point(task: Tuple[FleetPoint]) -> FleetResult:
    (fp,) = task
    t = fp.traffic
    return simulate_fleet(
        t.arch, t.requests(), pod=t.pod_spec(), cfg=t.sim_config(),
        replicas=fp.replicas, router=fp.router, max_queue=fp.max_queue,
        autoscale=fp.autoscale, min_replicas=fp.min_replicas,
        max_replicas=fp.max_replicas,
        scale_up_queued=fp.scale_up_queued,
        scale_down_idle_ns=fp.scale_down_idle_ns,
        spinup_latency_ns=fp.spinup_latency_ns,
        max_decode_slots=t.max_decode_slots,
        prefill_chunk_tokens=t.prefill_chunk_tokens,
        steps_cap=t.steps_cap, compute_profile=t.load_profile(),
        policy=t.policy)


def sweep_fleet(points: Sequence[FleetPoint], *,
                workers: Optional[int] = None
                ) -> Dict[FleetPoint, FleetResult]:
    """Price every :class:`FleetPoint`, fanned over a process pool.

    Same executor contract as :func:`repro.serving.simulate.sweep_traffic`
    (see :func:`~repro.serving.simulate.fan_out_points`): serial
    (``workers=0``) and pooled paths are bit-for-bit identical, duplicate
    points are priced once.
    """
    return fan_out_points(points, _fleet_point, workers=workers)
