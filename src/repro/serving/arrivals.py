"""Seeded request arrival processes for the serving simulator (DESIGN.md §11).

Every generator materializes the *full* request list up front from one
``numpy.random.default_rng(seed)`` stream, so a fixed seed is bit-for-bit
reproducible regardless of how the simulation is later executed (serial,
process-pooled, resumed) — the arrival stream is data, not a side effect of
the run loop.  Times are nanoseconds on the simulated pod clock; rates are
requests per second of simulated time.

Three processes:

* :func:`poisson_requests` — memoryless arrivals at a fixed rate, the
  open-loop baseline of every serving benchmark;
* :func:`bursty_requests` — an on/off modulated Poisson process: bursts of
  ``burst_size`` requests at ``burstiness``-times the nominal rate,
  separated by off periods sized so the long-run rate is still ``rps``.
  The off periods are what make the Link-TLB retention clock
  (``SimConfig.tlb_retention_ns``) bite: a gap longer than the retention
  window flushes the warmed translations and the next burst re-pays the
  cold walks — the tail-latency mechanism fig15 measures;
* :func:`trace_requests` — replay a recorded trace file, one request per
  line: ``arrival_ns,prompt_tokens,output_tokens`` (``#`` comments and
  blank lines ignored).

Determinism contract: the same generator with the same seed and parameters
returns the identical request list byte-for-byte on every platform —
everything downstream (simulate / fleet / disagg) inherits its determinism
from this.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

import numpy as np


@dataclass(frozen=True)
class Request:
    """One inference request of the arrival stream."""

    rid: int
    arrival_ns: float
    prompt_tokens: int
    output_tokens: int


def _lengths(rng: np.random.Generator, n: int, mean: int, cap: int):
    """Sampled token counts: lognormal around ``mean``, clipped to [1, cap].

    Lognormal matches the long right tail of real prompt/output length
    distributions (most requests short, a few very long) without extra
    parameters; sigma 0.8 puts ~p99 at ~6x the median.
    """
    if mean <= 0:
        raise ValueError(f"mean token count must be positive, got {mean}")
    draws = rng.lognormal(mean=np.log(mean), sigma=0.8, size=n)
    return np.clip(draws.astype(np.int64), 1, max(1, cap))


def poisson_requests(n_requests: int, rps: float, *, seed: int = 0,
                     prompt_mean: int = 256, output_mean: int = 32,
                     prompt_cap: int = 4096, output_cap: int = 512,
                     start_ns: float = 0.0) -> List[Request]:
    """``n_requests`` Poisson arrivals at ``rps`` requests/second."""
    if rps <= 0:
        raise ValueError(f"rps must be positive, got {rps}")
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(scale=1e9 / rps, size=n_requests)
    times = start_ns + np.cumsum(gaps)
    prompts = _lengths(rng, n_requests, prompt_mean, prompt_cap)
    outputs = _lengths(rng, n_requests, output_mean, output_cap)
    return [Request(i, float(times[i]), int(prompts[i]), int(outputs[i]))
            for i in range(n_requests)]


def bursty_requests(n_requests: int, rps: float, *, burst_size: int = 8,
                    burstiness: float = 16.0, seed: int = 0,
                    prompt_mean: int = 256, output_mean: int = 32,
                    prompt_cap: int = 4096, output_cap: int = 512,
                    start_ns: float = 0.0) -> List[Request]:
    """On/off bursts: ``burst_size`` requests at ``burstiness * rps``, then
    an off period sized so the long-run average rate is ``rps``.

    ``burstiness`` must exceed 1 (1 degenerates to plain Poisson).  The
    mean off period is ``burst_size/rps * (1 - 1/burstiness)`` seconds —
    at the default parameters and single-digit ``rps`` that is hundreds of
    milliseconds of pod silence between bursts, far beyond any plausible
    ``tlb_retention_ns``.
    """
    if rps <= 0:
        raise ValueError(f"rps must be positive, got {rps}")
    if burstiness <= 1.0:
        raise ValueError(f"burstiness must exceed 1, got {burstiness}")
    if burst_size < 1:
        raise ValueError(f"burst_size must be >= 1, got {burst_size}")
    rng = np.random.default_rng(seed)
    intra_scale = 1e9 / (rps * burstiness)
    off_scale = burst_size * 1e9 / rps * (1.0 - 1.0 / burstiness)
    times = []
    t = start_ns
    while len(times) < n_requests:
        if times:                                   # off period between bursts
            t += rng.exponential(scale=off_scale)
        for _ in range(min(burst_size, n_requests - len(times))):
            t += rng.exponential(scale=intra_scale)
            times.append(t)
    prompts = _lengths(rng, n_requests, prompt_mean, prompt_cap)
    outputs = _lengths(rng, n_requests, output_mean, output_cap)
    return [Request(i, float(times[i]), int(prompts[i]), int(outputs[i]))
            for i in range(n_requests)]


def trace_requests(path: str, *, limit: Optional[int] = None) -> List[Request]:
    """Load ``arrival_ns,prompt_tokens,output_tokens`` lines from a file.

    ``limit`` keeps the first ``limit`` data lines *in file order* (the
    natural truncation of a recorded trace), then the kept entries are
    sorted by arrival time.  Request ids are assigned *after* the sort, so
    rids are always 0..n-1 in arrival order exactly as the generated
    processes produce them — an out-of-order trace file does not leak file
    order into rid-based tie-breaks downstream (scheduler admission and
    router affinity both key on rid).  Equal arrival times keep file order
    (stable sort).
    """
    entries: List[tuple] = []           # (arrival_ns, prompt, output)
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line or line.startswith("#"):
                continue
            arrival, prompt, output = line.split(",")[:3]
            entries.append((float(arrival), int(prompt), int(output)))
            if limit is not None and len(entries) >= limit:
                break
    entries.sort(key=lambda e: e[0])
    return [Request(i, a, p, o) for i, (a, p, o) in enumerate(entries)]
