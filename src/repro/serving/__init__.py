# Request-level serving traffic over workload replay: seeded arrival
# processes (Poisson / bursty / trace file), a continuous-batching scheduler
# whose live batch composition sizes each step's collectives, and
# per-request TTFT / inter-token latency accounting with a cold-vs-warm
# Link-TLB split (DESIGN.md §11).  The fleet layer (DESIGN.md §13) serves
# one stream across N pod replicas behind a router, a bounded admission
# queue and a queue-depth autoscaler whose spin-ups start stone-cold.
# The disaggregation layer (DESIGN.md §16) splits prefill and decode onto
# dedicated pods with an explicitly priced KV-cache transfer in between.
# `python -m repro.serving --arch ... --rps ...` (optionally `--fleet`
# or `--disagg P:D`) runs offline (no jax).
from .arrivals import (Request, bursty_requests, poisson_requests,
                       trace_requests)
from .disagg import (DisaggPoint, DisaggResult, KVHandoff, simulate_disagg,
                     sweep_disagg)
from .fleet import (FleetPoint, FleetResult, Replica, simulate_fleet,
                    sweep_fleet)
from .scheduler import ContinuousBatcher, RequestStats, StepPlan
from .simulate import (PodStream, ServingStep, TrafficPoint, TrafficResult,
                       serving_layout, simulate_traffic, sweep_traffic)

__all__ = [
    "Request", "bursty_requests", "poisson_requests", "trace_requests",
    "ContinuousBatcher", "RequestStats", "StepPlan",
    "PodStream", "ServingStep", "TrafficPoint", "TrafficResult",
    "serving_layout", "simulate_traffic", "sweep_traffic",
    "FleetPoint", "FleetResult", "Replica", "simulate_fleet", "sweep_fleet",
    "DisaggPoint", "DisaggResult", "KVHandoff", "simulate_disagg",
    "sweep_disagg",
]
