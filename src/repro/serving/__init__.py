# Request-level serving traffic over workload replay: seeded arrival
# processes (Poisson / bursty / trace file), a continuous-batching scheduler
# whose live batch composition sizes each step's collectives, and
# per-request TTFT / inter-token latency accounting with a cold-vs-warm
# Link-TLB split.  `python -m repro.serving --arch ... --rps ...` runs
# offline (no jax).  DESIGN.md §11.
from .arrivals import (Request, bursty_requests, poisson_requests,
                       trace_requests)
from .scheduler import ContinuousBatcher, RequestStats, StepPlan
from .simulate import (ServingStep, TrafficPoint, TrafficResult,
                       serving_layout, simulate_traffic, sweep_traffic)

__all__ = [
    "Request", "bursty_requests", "poisson_requests", "trace_requests",
    "ContinuousBatcher", "RequestStats", "StepPlan",
    "ServingStep", "TrafficPoint", "TrafficResult", "serving_layout",
    "simulate_traffic", "sweep_traffic",
]
