"""CLI: simulate request-level serving traffic on a RAT-simulated pod.

    PYTHONPATH=src python -m repro.serving \
        --arch granite-moe-1b-a400m --rps 8 --steps-cap 200

Runs fully offline (no jax): the architecture registry resolves through the
jax-free :mod:`repro.models.spec`, and the simulator is numpy-only.  Prints
the per-step trace (optional), then p50/p95/p99 time-to-first-token and
inter-token latency with the cold-vs-warm Link-TLB communication split.

``--arrival bursty`` generates on/off bursts; together with
``--retention-ns`` the idle gaps between bursts flush the warmed
translations and each burst's leading requests re-pay the cold walks — the
tail-latency regime fig15 sweeps.

``--fleet N`` serves the same stream across N pod replicas behind a router
(``--router``), a bounded admission queue (``--max-queue``) and, with
``--autoscale``, a queue-depth autoscaler whose spin-ups start with
stone-cold TLBs — the fleet-scale regime fig16 sweeps (DESIGN.md §13).

``--disagg P:D`` switches to prefill/decode disaggregation: P prefill pods
and D decode pods, every request's KV cache crossing the pod boundary as an
explicit ``kv_transfer`` collective whose latency lands on TTFT — the
regime fig18 sweeps (DESIGN.md §16).  Mutually exclusive with ``--fleet``.
"""
from __future__ import annotations

import argparse
import sys

from ..core.topology import TOPOLOGIES
from .disagg import DisaggPoint, _disagg_point
from .fleet import ROUTERS, FleetPoint, _fleet_point
from .simulate import TrafficPoint, _traffic_point


def _parse_disagg(spec: str) -> tuple:
    """Parse the ``--disagg P:D`` pod split, e.g. ``1:2``."""
    try:
        p, _, d = spec.partition(":")
        pods = (int(p), int(d))
    except ValueError:
        pods = (0, 0)
    if pods[0] < 1 or pods[1] < 1:
        raise argparse.ArgumentTypeError(
            f"--disagg wants P:D with P,D >= 1 (e.g. 1:2), got {spec!r}")
    return pods


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.serving",
        description="Request-level serving traffic over persistent-TLB "
                    "workload replay (runs offline, no jax).")
    p.add_argument("--arch", required=True,
                   help="architecture registry name, e.g. "
                        "granite-moe-1b-a400m")
    p.add_argument("--rps", type=float, default=8.0,
                   help="mean arrival rate, requests per simulated second")
    p.add_argument("--arrival", default="poisson",
                   choices=("poisson", "bursty", "trace"),
                   help="arrival process (bursty: on/off modulated Poisson)")
    p.add_argument("--trace", default=None, metavar="FILE",
                   help="arrival trace file for --arrival trace "
                        "(arrival_ns,prompt_tokens,output_tokens lines)")
    p.add_argument("--requests", type=int, default=64,
                   help="number of requests to generate")
    p.add_argument("--seed", type=int, default=0,
                   help="arrival-stream seed (bit-for-bit reproducible)")
    p.add_argument("--gpus", type=int, default=16, help="pod size")
    p.add_argument("--topology", default="single_clos",
                   choices=sorted(TOPOLOGIES), help="pod topology")
    p.add_argument("--leaf", type=int, default=0,
                   help="two_tier: GPUs per leaf switch (0: fabric default)")
    p.add_argument("--oversub", type=float, default=1.0,
                   help="two_tier: leaf->spine oversubscription factor")
    p.add_argument("--pod-size", type=int, default=0,
                   help="multi_pod: GPUs per pod (0: whole fabric)")
    p.add_argument("--steps-cap", type=int, default=None,
                   help="stop after this many engine steps")
    p.add_argument("--retention-ns", type=float, default=None,
                   help="flush TLBs when an idle gap exceeds this "
                        "(default: entries survive gaps)")
    p.add_argument("--l2-entries", type=int, default=0,
                   help="override L2 Link-TLB entries (0: Table-1 default)")
    p.add_argument("--burst-size", type=int, default=8,
                   help="bursty: requests per burst")
    p.add_argument("--burstiness", type=float, default=16.0,
                   help="bursty: intra-burst rate multiplier")
    p.add_argument("--prompt-mean", type=int, default=256,
                   help="mean sampled prompt length (tokens)")
    p.add_argument("--output-mean", type=int, default=32,
                   help="mean sampled output length (tokens)")
    p.add_argument("--slots", type=int, default=32,
                   help="continuous-batching decode slots")
    p.add_argument("--prefill-chunk", type=int, default=512,
                   help="max prefill tokens admitted per step")
    p.add_argument("--pretranslate", action="store_true",
                   help="enable paper-§6.1 fused pre-translation probes")
    p.add_argument("--prefetch", action="store_true",
                   help="enable paper-§6.2 software TLB prefetch")
    p.add_argument("--engine", default="event",
                   choices=("event", "vectorized"),
                   help="simulation engine (identical results; vectorized "
                        "is ~10x faster at pod scale)")
    p.add_argument("--profile", default=None, metavar="FILE",
                   help="saved ComputeProfile JSON: calibrated compute "
                        "windows replace the rooflines (loaded jax-free)")
    p.add_argument("--policy", default="fixed", metavar="SPEC",
                   help="collective algorithm selection: fixed | auto | "
                        "table:<path> (repro.core.select; fixed keeps the "
                        "historical choices bit-for-bit)")
    p.add_argument("--per-step", action="store_true",
                   help="print the per-step trace CSV")
    fl = p.add_argument_group(
        "fleet", "serve the stream across N pod replicas (DESIGN.md §13)")
    fl.add_argument("--fleet", type=int, default=0, metavar="N",
                    help="fleet mode: number of pod replicas (with "
                         "--autoscale, the default max)")
    fl.add_argument("--router", default="round_robin",
                    choices=ROUTERS, help="request routing policy")
    fl.add_argument("--max-queue", type=int, default=None,
                    help="bounded admission: reject arrivals beyond this "
                         "fleet-wide prefill backlog")
    fl.add_argument("--autoscale", action="store_true",
                    help="start at --min-replicas and grow on queue "
                         "pressure; spin-ups start with stone-cold TLBs")
    fl.add_argument("--min-replicas", type=int, default=1,
                    help="autoscale floor (never retired below this)")
    fl.add_argument("--max-replicas", type=int, default=0,
                    help="autoscale ceiling on live replicas (0: --fleet)")
    fl.add_argument("--scale-up-queued", type=int, default=4,
                    help="spin up a replica when the admission queue "
                         "exceeds this depth")
    fl.add_argument("--scale-down-idle-ns", type=float, default=None,
                    help="retire a replica idle longer than this "
                         "(default: never retire)")
    fl.add_argument("--spinup-latency-ns", type=float, default=0.0,
                    help="delay between the scaling decision and the "
                         "replica becoming routable")
    dg = p.add_argument_group(
        "disaggregation",
        "dedicated prefill/decode pods with KV-cache transfer "
        "(DESIGN.md §16)")
    dg.add_argument("--disagg", type=_parse_disagg, default=None,
                    metavar="P:D",
                    help="disaggregated mode: P prefill pods and D decode "
                         "pods (routed by --router); incompatible with "
                         "--fleet")
    dg.add_argument("--kv-arena-mb", type=int, default=128,
                    help="decode-pod KV arena ring size (MB): the "
                         "transfer's steady-state Link-TLB working set")
    args = p.parse_args(argv)
    if args.disagg is not None and args.fleet > 0:
        p.error("--disagg and --fleet are mutually exclusive (a "
                "disaggregated deployment is its own replica set)")

    pt = TrafficPoint(
        arch=args.arch, rps=args.rps, arrival=args.arrival,
        n_requests=args.requests, seed=args.seed, n_gpus=args.gpus,
        topology=args.topology, leaf_size=args.leaf,
        oversubscription=args.oversub, pod_size=args.pod_size,
        l2_entries=args.l2_entries, retention_ns=args.retention_ns,
        steps_cap=args.steps_cap, burst_size=args.burst_size,
        burstiness=args.burstiness, prompt_mean=args.prompt_mean,
        output_mean=args.output_mean, max_decode_slots=args.slots,
        prefill_chunk_tokens=args.prefill_chunk,
        pretranslation=args.pretranslate, prefetch=args.prefetch,
        trace_path=args.trace, engine=args.engine,
        profile_path=args.profile, policy=args.policy)
    if args.disagg is not None:
        dp = DisaggPoint(traffic=pt, prefill_pods=args.disagg[0],
                         decode_pods=args.disagg[1], router=args.router,
                         kv_arena_bytes=args.kv_arena_mb * 2**20)
        res = _disagg_point((dp,))
    elif args.fleet > 0:
        fp = FleetPoint(
            traffic=pt, replicas=args.fleet, router=args.router,
            max_queue=args.max_queue, autoscale=args.autoscale,
            min_replicas=args.min_replicas, max_replicas=args.max_replicas,
            scale_up_queued=args.scale_up_queued,
            scale_down_idle_ns=args.scale_down_idle_ns,
            spinup_latency_ns=args.spinup_latency_ns)
        res = _fleet_point((fp,))
    else:
        res = _traffic_point((pt,))

    pod = res.pod
    print(f"# {res.arch} serving on {pod.n_gpus} GPUs "
          f"(topology={pod.topology}, ep={pod.ep} tp={pod.tp} dp={pod.dp}), "
          f"{args.arrival} arrivals at {args.rps} rps, seed {args.seed}")
    if args.fleet > 0:
        mode = (f"autoscale {args.min_replicas}.."
                f"{args.max_replicas or args.fleet}" if args.autoscale
                else f"static {args.fleet}")
        print(f"# fleet: {mode} replicas, router={args.router}, "
              f"{res.spin_ups} spin-ups, {res.retired} retired, "
              f"{len(res.rejected)} rejected")
        print("replica,spun_up_us,retired_us,routed,steps,walks,"
              "cold_comm_us,warm_comm_us")
        for row in res.replica_rows():
            ret = ("" if row["retired_ns"] is None
                   else f"{row['retired_ns']/1e3:.2f}")
            print(f"{row['idx']},{row['spun_up_ns']/1e3:.2f},{ret},"
                  f"{row['routed']},{row['steps']},{row['walks']},"
                  f"{row['cold_comm_ns']/1e3:.2f},"
                  f"{row['warm_comm_ns']/1e3:.2f}")
    if args.disagg is not None:
        pp, dd = args.disagg
        print(f"# disagg: {pp} prefill + {dd} decode pods, "
              f"router={args.router}, {len(res.handoffs)} KV handoffs "
              f"({res.kv_cold_handoffs} cold, {res.kv_walks} walks, "
              f"{res.kv_fastpath_calls} fastpath)")
        print("pod,role,routed,steps,walks,cold_comm_us,warm_comm_us")
        for row in res.replica_rows():
            print(f"{row['idx']},{row['role']},{row['routed']},"
                  f"{row['steps']},{row['walks']},"
                  f"{row['cold_comm_ns']/1e3:.2f},"
                  f"{row['warm_comm_ns']/1e3:.2f}")
        bd = res.ttft_breakdown()
        if bd:
            print(f"# TTFT decomposition (mean over {bd['n']:.0f} "
                  f"handed-off requests, us): "
                  f"prefill {bd['prefill_ns']/1e3:.2f} + "
                  f"kv_wait {bd['kv_wait_ns']/1e3:.2f} + "
                  f"kv_transfer {bd['kv_transfer_ns']/1e3:.2f} "
                  f"(RAT excess {bd['kv_excess_ns']/1e3:.2f}) + "
                  f"decode_wait {bd['decode_wait_ns']/1e3:.2f} = "
                  f"ttft {bd['ttft_ns']/1e3:.2f}")
    served = res.first_token_served
    print(f"# steps: {len(res.steps)}"
          + (" (capped)" if res.steps_capped else "")
          + f", requests: {len(res.requests)} generated, "
          f"{len(served)} served first token, {len(res.finished)} finished")
    if args.per_step:
        print("step,t_start_us,decode_tok,prefill_tok,comm_us,ideal_us,"
              "degradation,walks")
        for s in res.steps:
            print(f"{s.step},{s.t_start/1e3:.2f},{s.decode_tokens},"
                  f"{s.prefill_tokens},{s.comm_ns/1e3:.2f},"
                  f"{s.ideal_comm_ns/1e3:.2f},{s.degradation:.4f},{s.walks}")
    if not served:
        print("# no requests served (raise --steps-cap or --rps)",
              file=sys.stderr)
        return 1
    ttft = res.ttft_percentiles()
    itl = res.itl_percentiles()
    print("metric,p50_us,p95_us,p99_us")
    print(f"ttft,{ttft[50.0]/1e3:.2f},{ttft[95.0]/1e3:.2f},"
          f"{ttft[99.0]/1e3:.2f}")
    print(f"inter_token,{itl[50.0]/1e3:.2f},{itl[95.0]/1e3:.2f},"
          f"{itl[99.0]/1e3:.2f}")
    cold, warm = res.cold_comm_ns, res.warm_comm_ns
    tot = cold + warm
    print(f"# cold-vs-warm comm split: cold {cold/1e3:.2f} us "
          f"({(cold/tot if tot else 0.0)*100:.1f}%) over {res.cold_steps} "
          f"walking steps, warm {warm/1e3:.2f} us")
    print(f"# TTFT degradation vs zero-RAT ideal: "
          f"mean {res.mean_ttft_degradation:.4f}, "
          f"p99 {res.p99_ttft_degradation:.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
