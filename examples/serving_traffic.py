"""Serving traffic: what Link-TLB cold misses do to request tail latency.

Workload replay (examples/workload_replay.py) prices fixed step loops; real
inference serving is a *stream of requests* — bursty arrivals, continuous
batching, and idle gaps between bursts during which competing traffic
evicts the warmed translations.  This example (repro.serving, DESIGN.md
§11, jax-free) runs the same bursty request stream twice:

  1. with TLB retention disabled — every burst after the first rides the
     entries the previous one warmed;
  2. with a 50 us retention window — each inter-burst gap flushes the
     TLBs, every burst's leading steps re-pay the cold walks, and the
     degradation concentrates in the p99 time-to-first-token tail
     (fig15's regime).

    PYTHONPATH=src python examples/serving_traffic.py
"""
import sys

sys.path.insert(0, "src")

from repro.serving import TrafficPoint
from repro.serving.simulate import _traffic_point


def show(tag, res):
    ttft = res.ttft_percentiles()
    itl = res.itl_percentiles()
    cold, warm = res.cold_comm_ns, res.warm_comm_ns
    print(f"  {tag}")
    print(f"    TTFT p50/p95/p99: {ttft[50.0]/1e3:8.2f} /"
          f" {ttft[95.0]/1e3:8.2f} / {ttft[99.0]/1e3:8.2f} us;"
          f"  inter-token p50: {itl[50.0]/1e3:6.2f} us")
    print(f"    TTFT degradation mean {res.mean_ttft_degradation:.4f}, "
          f"p99 {res.p99_ttft_degradation:.4f};  "
          f"{res.cold_steps} cold steps, "
          f"cold comm {cold/1e3:.0f} us vs warm {warm/1e3:.0f} us")


def main():
    pt = TrafficPoint(arch="granite-moe-1b-a400m", rps=16.0,
                      arrival="bursty", n_requests=12, seed=7,
                      burst_size=4, burstiness=24.0,
                      prompt_mean=128, output_mean=8, steps_cap=60)
    print(f"=== {pt.arch}: bursty serving on {pt.n_gpus} GPUs "
          f"(topology={pt.topology}, collective mix from the live batch) ===")
    show("no retention (gaps keep warmth):", _traffic_point((pt,)))
    import dataclasses
    aged = dataclasses.replace(pt, retention_ns=50_000.0)
    show("tlb_retention_ns=50us (gaps flush):", _traffic_point((aged,)))
    print("  -> with retention, the idle gaps between bursts re-pay the "
          "cold walks\n     and degradation concentrates in the TTFT tail "
          "(p99 >> mean).")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
