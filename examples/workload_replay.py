"""Workload replay: warm-vs-cold Link-TLB trajectories of real model steps.

The paper prices free-standing collectives from cold TLBs; real serving
fires *sequences* — one MoE dispatch/combine all-to-all per layer per
decoded token.  This example replays model-derived sequences through
persistent-TLB sessions (repro.core.session + repro.workloads) and prints:

  1. the session API itself: cold vs warm vs idle-aged reruns;
  2. a granite-MoE decode loop (token 0 pays the cold walks, later tokens
     ride warm TLBs);
  3. the TLB-reach contrast: qwen3-moe's per-layer buffers overflow the L2
     Link TLB, so even steady-state tokens keep walking.

    PYTHONPATH=src python examples/workload_replay.py
"""
import sys

sys.path.insert(0, "src")

from repro.core import ratsim, paper_config, MB


def main():
    print("=== SimSession: translation state persists across collectives ===")
    s = ratsim.session(16)
    print(f"    (collective={s.cfg.collective}, "
          f"topology={s.cfg.fabric.topology}, {s.cfg.fabric.n_gpus} GPUs)")
    cold = s.run(1 * MB)
    warm = s.run(1 * MB)
    moved = s.run(1 * MB, base_offset=64 * MB)     # fresh buffer: cold again
    print(f"  cold  run: {cold.completion_ns/1e3:8.2f} us "
          f"({cold.counters.walks} page walks)")
    print(f"  warm  run: {warm.completion_ns/1e3:8.2f} us "
          f"({warm.counters.walks} page walks)")
    print(f"  new buffer: {moved.completion_ns/1e3:7.2f} us "
          f"({moved.counters.walks} page walks — TLB cold, PWC still warm)")

    aged = ratsim.session(16, cfg=paper_config(16).replace(
        tlb_retention_ns=1e6))
    aged.run(1 * MB)
    r = aged.run(1 * MB, gap_ns=5e6)               # long idle: flushed
    print(f"  after 5ms idle (1ms retention): {r.completion_ns/1e3:.2f} us "
          f"({r.counters.walks} page walks — aged out)\n")

    from repro.workloads import derive_workload, replay

    print("=== granite-moe decode: per-token degradation trajectory ===")
    trace = derive_workload("granite-moe-1b-a400m", "decode_32k",
                            n_gpus=16, n_steps=4)
    colls = ", ".join(sorted({c.collective for c in trace.calls}))
    print(f"    (topology={trace.pod.topology}, collectives: {colls})")
    rep = replay(trace)
    for st in rep.steps:
        print(f"  token {st.step}: comm {st.comm_ns/1e3:8.2f} us, "
              f"degradation {st.degradation:.4f}, walks {st.walks}")
    print(f"  cold {rep.cold_degradation:.4f} vs steady "
          f"{rep.steady_degradation:.4f} — warm TLBs erase the cold tax\n")

    print("=== qwen3-moe-235b: working set exceeds L2 Link-TLB reach ===")
    trace = derive_workload("qwen3-moe-235b-a22b", "decode_32k",
                            n_gpus=16, n_steps=2)
    colls = ", ".join(sorted({c.collective for c in trace.calls}))
    print(f"    (topology={trace.pod.topology}, collectives: {colls})")
    rep = replay(trace)
    for st in rep.steps:
        print(f"  token {st.step}: degradation {st.degradation:.4f}, "
              f"walks {st.walks}")
    print("  steady-state walks stay high: capacity misses, not cold misses\n")

    from repro.workloads import PodSpec

    print("=== two-tier pod: TP stays intra-leaf, the EP a2a crosses the "
          "spine ===")
    trace = derive_workload(
        "granite-moe-1b-a400m", "decode_32k",
        pod=PodSpec(topology="two_tier", leaf_size=4, oversubscription=2.0),
        n_gpus=16, n_steps=2)
    pod = trace.pod
    print(f"    (topology={pod.topology}, ep={pod.ep} tp={pod.tp} "
          f"dp={pod.dp})")
    rep = replay(trace)
    for st in rep.steps:
        print(f"  token {st.step}: degradation {st.degradation:.4f}, "
              f"walks {st.walks}")


if __name__ == "__main__":
    main()
