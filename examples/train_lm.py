"""End-to-end training driver: ~100M-parameter LM for a few hundred steps.

Exercises the full substrate on local devices: deterministic data pipeline,
mixed-precision AdamW (bf16 params + f32 master), per-layer remat, async
checkpointing with auto-resume, and optional gradient compression.

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--resume]
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

from repro.models.base import ModelConfig
from repro.runtime import Trainer, TrainerConfig


def model_100m() -> ModelConfig:
    # ~100M params: 12L x d512 x ffn2048, 32k vocab
    return ModelConfig(
        name="lm-100m", family="dense", n_layers=12, d_model=512,
        n_heads=8, n_kv_heads=4, d_head=64, d_ff=2048, vocab_size=32768,
        rope_theta=10_000.0, remat=False, scan_layers=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compress", default="none",
                    choices=["none", "bf16", "int8"])
    args = ap.parse_args()

    cfg = model_100m()
    tcfg = TrainerConfig(steps=args.steps, batch_size=args.batch,
                         seq_len=args.seq, checkpoint_dir=args.ckpt,
                         checkpoint_every=100, grad_compression=args.compress,
                         peak_lr=3e-4, warmup=20, log_every=20)
    t0 = time.time()
    out = Trainer(cfg, tcfg).run(resume=args.resume)
    for h in out["history"]:
        print(f"step {h['step']:>4}  loss {h['loss']:.4f}  {h['sec']:.2f}s/step")
    print(f"\nfinal loss {out['final_loss']:.4f} "
          f"({time.time()-t0:.0f}s total); checkpoints in {args.ckpt}")
    first = out["history"][0]["loss"] if out["history"] else None
    if first and out["final_loss"] < first * 0.7:
        print("loss decreased >30% — learning works")


if __name__ == "__main__":
    main()
