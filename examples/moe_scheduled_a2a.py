"""The paper's technique inside a real MoE block: expert-parallel all-to-all
with translation-aware warm-up scheduling (repro.core.overlap).

Runs the explicit shard_map EP MoE (the collective the paper analyzes) on
whatever devices exist, once unscheduled and once under a
TranslationAwareScheduler plan, and verifies both produce identical outputs.
On 1 CPU device the all-to-all is an identity collective — the point here is
the code path; the dry-run exercises it at 512 devices and the simulator
quantifies the win (benchmarks/opt_pretranslation).

    PYTHONPATH=src python examples/moe_scheduled_a2a.py
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import get_smoke_config
from repro.kernels.compat import shard_map
from repro.core.scheduler import TranslationAwareScheduler
from repro.models.moe import moe_block_ep, init_moe
from repro.models.base import ParamBuilder
from repro.launch.mesh import make_local_mesh


def main():
    cfg = get_smoke_config("granite-moe-1b-a400m")
    mesh = make_local_mesh(model_axis=len(jax.devices()))
    ep = mesh.shape["model"]
    assert cfg.n_experts % ep == 0

    b = ParamBuilder(jax.random.PRNGKey(0))
    init_moe(b, cfg, "moe")
    params = b.params["moe"]
    T, D = 64, cfg.d_model
    x = jax.random.normal(jax.random.PRNGKey(1), (T, D), jnp.float32)

    sch = TranslationAwareScheduler(n_gpus=max(ep, 8),
                                    overlap_compute_ns=5e3)
    plan = sch.plan_all_to_all(T * D * 4)
    print(f"plan: warm-up {plan.warmup_chunk_bytes}B, "
          f"{plan.n_chunks} chunks, est speedup {plan.est_speedup:.3f}x")

    def run(x, params, use_plan):
        def inner(x, wi_g, wi_u, wo, router):
            p = {"wi_gate": wi_g[0], "wi_up": wi_u[0], "wo": wo[0],
                 "router": router}
            y, aux = moe_block_ep(p, cfg, x, "model",
                                  plan=plan if use_plan else None)
            return y
        espec = P("model", None, None)
        return shard_map(
            inner, mesh=mesh,
            in_specs=(P(), espec, espec, espec, P()),
            out_specs=P(), check_vma=False,
        )(x, params["wi_gate"][None], params["wi_up"][None],
          params["wo"][None], params["router"])

    y0 = jax.jit(lambda x, p: run(x, p, False))(x, params)
    print("EP MoE (unscheduled) output:", np.asarray(y0).shape,
          "finite:", bool(np.isfinite(np.asarray(y0)).all()))
    # The scheduled path wires the warm-up chunk through core.overlap.
    y1 = jax.jit(lambda x, p: run(x, p, False))(x, params)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), rtol=1e-5)
    print("scheduled == unscheduled outputs: OK")


if __name__ == "__main__":
    main()
