"""Serving example: prefill + batched greedy decode with KV/SSM caches.

Runs a reduced config of any assigned architecture (--arch) on local
devices, prefilel a prompt batch, then decodes tokens autoregressively.

    PYTHONPATH=src python examples/serve_decode.py --arch qwen2-1.5b --tokens 32
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import api


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b",
                    choices=configs.list_archs())
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = configs.get_smoke_config(args.arch)
    params, _ = api.init(cfg, jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    batch = {"inputs": jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size)}
    if cfg.n_img_tokens > 0:
        batch["img_embeds"] = jax.random.normal(
            key, (args.batch, cfg.n_img_tokens, cfg.d_model))
    if cfg.is_encoder_decoder:
        batch["enc_embeds"] = jax.random.normal(
            key, (args.batch, cfg.enc_frames, cfg.d_model))

    s_max = args.prompt_len + args.tokens + 8
    t0 = time.time()
    logits, caches = jax.jit(
        lambda p, b: api.prefill(cfg, p, b, s_max))(params, batch)
    print(f"prefill[{args.batch}x{args.prompt_len}] in {time.time()-t0:.2f}s")

    step = jax.jit(lambda p, t, c: api.decode_step(cfg, p, t, c))
    tok = jnp.argmax(logits, axis=-1)
    out = [np.asarray(tok)]
    t0 = time.time()
    for _ in range(args.tokens - 1):
        logits, caches = step(params, tok, caches)
        tok = jnp.argmax(logits, axis=-1)
        out.append(np.asarray(tok))
    dt = time.time() - t0
    seqs = np.stack(out, axis=1)
    print(f"decoded {args.tokens} tokens/seq in {dt:.2f}s "
          f"({args.batch*args.tokens/dt:.1f} tok/s on CPU)")
    print("first sequence token ids:", seqs[0][:16], "...")
    assert np.isfinite(seqs).all()


if __name__ == "__main__":
    main()
