"""Quickstart: reproduce the paper's headline result in ~5 seconds.

Simulates Reverse Address Translation overheads for all-pairs AllToAll on a
UALink pod, prints the Fig-4 degradation sweep, and shows the paper's two
proposed optimizations (fused pre-translation, software TLB prefetch)
recovering the loss.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

from repro.core import ratsim, paper_config, simulate, MB, GB
from repro.core.config import PreTranslationConfig, FabricConfig, PrefetchConfig


def main():
    cfg0 = paper_config(16)
    print("=== Reverse Address Translation overhead vs zero-RAT ideal ===")
    print(f"    (collective={cfg0.collective}, "
          f"topology={cfg0.fabric.topology})")
    print(f"{'pod':>6} " + " ".join(f"{s//MB:>7}MB" for s in
                                    (1*MB, 4*MB, 16*MB, 64*MB, 256*MB, 1*GB)))
    for n in (8, 16, 32, 64):
        degs = [ratsim.compare(s, n).degradation
                for s in (1*MB, 4*MB, 16*MB, 64*MB, 256*MB, 1*GB)]
        print(f"{n:>4}gpu " + " ".join(f"{d:8.3f}" for d in degs))
    print("\npaper: up to 1.4x at 1MB, ~1.1x at 16MB, amortized for large\n")

    print("=== beyond the paper: hierarchical pods (fig14) ===")
    for topo in ("single_clos", "two_tier"):
        cfg = paper_config(64).replace(fabric=FabricConfig(
            n_gpus=64, topology=topo, leaf_size=16, oversubscription=2.0))
        c = ratsim.compare(1 * MB, 64, cfg=cfg)
        print(f"  64gpu 1MB on {topo:<12s}: degradation "
              f"{c.degradation:.3f}x "
              f"(completion {c.baseline.completion_ns/1e3:.2f} us)")
    print()

    print("=== paper 6.1: fused pre-translation (warm TLBs during compute) ===")
    for s in (1*MB, 16*MB):
        base = ratsim.compare(s, 16)
        cfg = paper_config(16).replace(pretranslation=PreTranslationConfig(
            enabled=True, lead_time_ns=3000.0, pages_per_flow=0))
        opt = simulate(s, cfg)
        print(f"  {s//MB:>3}MB: baseline {base.degradation:.3f}x -> "
              f"pre-translated {opt.completion_ns/base.ideal.completion_ns:.3f}x")

    print("\n=== paper 6.2: software TLB prefetch (scarce ingress buffering) ===")
    fab = FabricConfig(n_gpus=16, ingress_entries=64)
    cfg = paper_config(16).replace(fabric=fab)
    for s in (16*MB, 64*MB):
        base = simulate(s, cfg)
        opt = simulate(s, cfg.replace(prefetch=PrefetchConfig(enabled=True, depth=2)))
        print(f"  {s//MB:>3}MB: prefetch speedup "
              f"{base.completion_ns/opt.completion_ns:.3f}x")

    print("\n=== translation-aware collective planning (framework integration) ===")
    from repro.core.scheduler import TranslationAwareScheduler
    sch = TranslationAwareScheduler(n_gpus=16, overlap_compute_ns=5e3)
    plan = sch.plan_all_to_all(8 * MB)
    print(f"  8MB MoE all-to-all: warm-up chunk {plan.warmup_chunk_bytes//MB}MB, "
          f"{plan.n_chunks} pipeline chunks, est. speedup {plan.est_speedup:.3f}x,"
          f" per-peer buffer {plan.per_peer_buffer_bytes//MB}MB "
          "(Fig 11: one page/peer)")


if __name__ == "__main__":
    main()
